"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.expressions import generate_chain_algorithms, make_chain_inputs, reference_product
from repro.kernels import chain_matmul, flash_attention, matmul, ssd_mix
from repro.kernels.flash_attention.flash_attention import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.matmul.ref import matmul_ref


# --------------------------------------------------------- flash attention -

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-3), (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize(
    "bh,sq,skv,d,causal,win,cap,bq,bk",
    [
        (2, 256, 256, 64, True, None, None, 128, 128),
        (1, 128, 128, 128, False, None, None, 64, 128),
        (2, 128, 512, 64, True, None, None, 64, 128),    # decode-ish sq<skv
        (1, 256, 256, 64, True, 64, None, 64, 64),       # sliding window
        (1, 256, 256, 64, True, None, 50.0, 128, 64),    # gemma softcap
    ],
)
def test_flash_kernel_sweep(bh, sq, skv, d, causal, win, cap, bq, bk, dtype, tol):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (bh, sq, d), dtype)
    k = jax.random.normal(ks[1], (bh, skv, d), dtype)
    v = jax.random.normal(ks[2], (bh, skv, d), dtype)
    out = flash_attention_kernel(
        q, k, v, causal=causal, window=win, logit_cap=cap,
        block_q=bq, block_k=bk, interpret=True,
    )
    ref = flash_attention_ref(q, k, v, causal=causal, window=win, logit_cap=cap)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_flash_ops_gqa_broadcast():
    """ops wrapper: [b,s,h,d] layout + kv-head broadcast == model reference."""
    from repro.models.attention import attention_reference

    b, s, h, kv, d = 2, 128, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    ref = attention_reference(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)


# ----------------------------------------------------------------- matmul --

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize(
    "m,k,n,bm,bn,bk",
    [
        (256, 256, 256, 128, 128, 128),
        (300, 200, 450, 128, 128, 128),     # non-multiples (padding path)
        (64, 512, 128, 256, 256, 512),      # block > dim (clamping path)
        (128, 128, 1024, 128, 256, 128),
    ],
)
def test_matmul_kernel_sweep(m, k, n, bm, bn, bk, dtype, tol):
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    a = (jax.random.normal(ks[0], (m, k)) / np.sqrt(k)).astype(dtype)
    b = (jax.random.normal(ks[1], (k, n)) / np.sqrt(k)).astype(dtype)
    out = matmul(a, b, block_m=bm, block_n=bn, block_k=bk, interpret=True)
    ref = matmul_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


@given(st.integers(1, 3), st.integers(1, 3), st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_matmul_property_random_shapes(i, j, k_):
    """Property: kernel == oracle for irregular (non-aligned) shapes."""
    m, k, n = 17 * i, 23 * j, 13 * k_
    ks = jax.random.split(jax.random.PRNGKey(i * 100 + j * 10 + k_), 2)
    a = jax.random.normal(ks[0], (m, k), jnp.float32)
    b = jax.random.normal(ks[1], (k, n), jnp.float32)
    out = matmul(a, b, block_m=16, block_n=16, block_k=16, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(matmul_ref(a, b)), rtol=2e-4, atol=2e-4
    )


def test_chain_matmul_all_algorithms():
    """The paper's six algorithms, executed on the Pallas GEMM."""
    dims = (24, 16, 4, 20, 12)
    mats = make_chain_inputs(dims, seed=2)
    ref = np.asarray(reference_product(mats))
    for alg in generate_chain_algorithms(dims):
        out = chain_matmul(alg, mats, interpret=True, block_m=16, block_n=16, block_k=16)
        np.testing.assert_allclose(np.asarray(out), ref, rtol=5e-4, atol=5e-4, err_msg=alg.name)


# -------------------------------------------------------------------- SSD --

@pytest.mark.parametrize("chunk", [32, 64, 128])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-4)])
def test_ssd_kernel_sweep(chunk, dtype, tol):
    b, s, h, p, n = 2, 128, 4, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bm = jax.random.normal(ks[3], (b, s, 1, n))
    cm = jax.random.normal(ks[4], (b, s, 1, n))
    out = ssd_mix(x, dt, a_log, bm, cm, chunk=chunk, use_kernel=True, interpret=True)
    ref = ssd_mix(x, dt, a_log, bm, cm, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=tol, atol=tol)


def test_ssd_kernel_groups():
    """g > 1 (grouped B/C) broadcast path."""
    b, s, h, p, n, g = 1, 64, 4, 16, 8, 2
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bm = jax.random.normal(ks[3], (b, s, g, n))
    cm = jax.random.normal(ks[4], (b, s, g, n))
    out = ssd_mix(x, dt, a_log, bm, cm, chunk=32, use_kernel=True, interpret=True)
    ref = ssd_mix(x, dt, a_log, bm, cm, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-4, atol=3e-4)
