"""AlgorithmFamily registry: the census's one algorithm-source seam.

Covers the registry contract, byte-identity of the ported synthetic
families against a pre-refactor golden store, the kernel_variants
family's FLOP-identical-by-construction invariants, the store-kind
registry behind queue/fsck auto-detection, and the jax-free metadata
guarantee for cost-model census workers."""

import json
import os
import subprocess
import sys

import pytest

from repro.core.family import (
    AlgorithmFamily,
    InstanceSpec,
    KERNEL_SITES,
    family_names,
    get_family,
    register_family,
)
from repro.core.sweep import SweepSpec, instance_entry, run_shard, write_merged

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "census_small.jsonl")


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    for var in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS"):
        env.setdefault(var, "1")
    return env


# -------------------------------------------------------------- registry ---

def test_registry_contents_and_order():
    assert family_names() == (
        "chain", "gram", "distributive", "solve", "bilinear",
        "kernel_variants",
    )
    for name in family_names():
        fam = get_family(name)
        assert fam.name == name
        assert fam.description  # the report footnotes render these


def test_get_family_unknown_raises_listing_known():
    with pytest.raises(KeyError, match="kernel_variants"):
        get_family("strassen")


def test_register_family_requires_name():
    with pytest.raises(ValueError):
        register_family(AlgorithmFamily())


def test_sweep_spec_rejects_unregistered_family():
    with pytest.raises(ValueError, match="unknown families"):
        SweepSpec(families={"strassen": {}})


def test_instance_spec_roundtrip():
    inst = InstanceSpec(index=3, uid="chain-n3-i00003", family="chain",
                        params={"n_matrices": 3, "lo": 24, "hi": 96, "seed": 3})
    assert InstanceSpec.from_dict(inst.to_dict()) == inst
    # core.sweep re-exports the moved class unchanged
    from repro.core import sweep
    assert sweep.InstanceSpec is InstanceSpec


# ----------------------------------- synthetic expansion (byte-identity) ---

def test_expansion_snapshot_uids_and_params():
    """The exact pre-refactor uid/params rows for every synthetic family —
    any drift here silently orphans existing census stores."""
    spec = SweepSpec(families={
        "chain": {"count": 3, "n_matrices": [3, 4], "lo": 24, "hi": 96},
        "gram": {"sizes": [24], "per_size": 2},
        "bilinear": {"sizes": [40], "per_size": 1},
    })
    rows = [(i.index, i.uid, i.family, i.params) for i in spec.expand()]
    assert rows == [
        (0, "bilinear-n40-s000", "bilinear", {"size": 40, "seed": 0}),
        (1, "chain-n3-i00000", "chain",
         {"n_matrices": 3, "lo": 24, "hi": 96, "seed": 0}),
        (2, "chain-n4-i00001", "chain",
         {"n_matrices": 4, "lo": 24, "hi": 96, "seed": 1}),
        (3, "chain-n3-i00002", "chain",
         {"n_matrices": 3, "lo": 24, "hi": 96, "seed": 2}),
        (4, "gram-n24-s000", "gram", {"size": 24, "seed": 0}),
        (5, "gram-n24-s001", "gram", {"size": 24, "seed": 1}),
    ]


def test_golden_census_byte_identical(tmp_path):
    """A small all-families cost-model census, run through the registry,
    must merge byte-identical to the committed pre-refactor golden store
    (captured before the AlgorithmFamily seam existed)."""
    spec = SweepSpec(
        name="census",
        families={
            "chain": {"count": 8, "n_matrices": [3, 4], "lo": 24, "hi": 96},
            "gram": {"sizes": [24, 40], "per_size": 2},
            "distributive": {"sizes": [24, 40], "per_size": 2},
            "solve": {"sizes": [24, 40], "per_size": 2},
            "bilinear": {"sizes": [24, 40], "per_size": 2},
        },
        n_shards=4,
        backend="cost_model",
        max_measurements=12,
    )
    root = str(tmp_path / "census")
    for shard in range(spec.n_shards):
        run_shard(spec, root, shard)
    merged = write_merged(spec, root)
    with open(merged, "rb") as fh:
        got = fh.read()
    with open(GOLDEN, "rb") as fh:
        want = fh.read()
    assert got == want


# ------------------------------------------------------- kernel_variants ---

def _kv_inst(site, size, seed=0, interpret=True):
    return InstanceSpec(
        index=0, uid=f"kernel_variants-{site}-n{size}-s{seed:03d}",
        family="kernel_variants",
        params={"site": site, "size": size, "seed": seed,
                "interpret": interpret},
    )


def test_kernel_variants_expansion():
    fam = get_family("kernel_variants")
    rows = fam.expand_grid({"sites": ["matmul", "ssd"], "sizes": [32, 64],
                            "per_size": 2})
    assert [i.uid for i in rows] == [
        "kernel_variants-matmul-n32-s000", "kernel_variants-matmul-n32-s001",
        "kernel_variants-matmul-n64-s000", "kernel_variants-matmul-n64-s001",
        "kernel_variants-ssd-n32-s000", "kernel_variants-ssd-n32-s001",
        "kernel_variants-ssd-n64-s000", "kernel_variants-ssd-n64-s001",
    ]
    assert all(i.params["interpret"] for i in rows)
    with pytest.raises(ValueError, match="unknown kernel site"):
        fam.expand_grid({"sites": ["conv"], "sizes": [32]})
    with pytest.raises(ValueError, match="chunk lengths"):
        # 24 only divides by chunk 8 -> fewer than 2 ssd variants
        fam.expand_grid({"sites": ["ssd"], "sizes": [24]})


def test_kernel_variants_flop_identical_by_construction():
    """Every variant of an instance carries the same analytic FLOP count
    and the same kernel decomposition (the shared math), so the whole
    instance sits in S_F and can never be RT-filtered apart."""
    for site in KERNEL_SITES:
        for size in (32, 64):
            inst = _kv_inst(site, size)
            flops, meta, _ = instance_entry(inst)
            assert len(flops) >= 2, (site, size)
            assert len(set(flops.values())) == 1, (site, flops)
            kernel_rows = set(map(str, meta["kernels"].values()))
            assert len(kernel_rows) == 1  # one shared decomposition
            decomp = get_family("kernel_variants").decompose(inst.params)
            assert set(decomp) == set(flops)
            for alg, ks in decomp.items():
                assert sum(k.flops for k in ks) == pytest.approx(flops[alg])
                assert all(k.op == "gemm" for k in ks)


def test_kernel_variants_decompose_via_decompose_instance():
    from repro.explain.decompose import decompose_instance

    inst = _kv_inst("attention", 32)
    ks = decompose_instance(inst.family, inst.params)
    assert set(ks) == {"reference_grouped", "reference_broadcast",
                      "chunked_flash"}
    b, h, s, d = 1, 2, 32, 16
    total = sum(k.flops for k in ks["chunked_flash"])
    assert total == pytest.approx(2.0 * b * h * s * s * d * 2)


def test_kernel_variants_metadata_needs_no_jax():
    """A cost-model census worker building kernel_variants sessions (and
    stepping them) must never import jax — the family's FLOP tables and
    kernel decompositions are pure metadata."""
    code = """
import sys
from repro.core.sweep import SweepSpec, build_sweep_session, record_from_session
spec = SweepSpec(
    name="kv", backend="cost_model", n_shards=1, max_measurements=6,
    families={"kernel_variants": {"sites": ["matmul", "attention", "ssd"],
                                  "sizes": [32], "per_size": 1}},
)
for inst in spec.expand():
    session = build_sweep_session(spec, inst)
    while session.step():
        pass
    record = record_from_session(session, spec)
    assert record["family"] == "kernel_variants"
assert "jax" not in sys.modules, "jax imported on the cost_model path"
print("OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", code], env=_env(),
        capture_output=True, text=True, timeout=120,
    )
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout


def test_lazy_package_imports_need_no_jax():
    """Satellite: importing repro.autotune / repro.kernels themselves (the
    kernel family's metadata neighbours) must not pull in jax until an
    attribute is resolved."""
    code = """
import sys
import repro.autotune
import repro.kernels
assert "jax" not in sys.modules, "package import pulled in jax"
assert sorted(repro.kernels.__all__) == [
    "chain_matmul", "flash_attention", "matmul", "ssd_mix"]
assert "VariantSite" in repro.autotune.__all__
print("OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", code], env=_env(),
        capture_output=True, text=True, timeout=120,
    )
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout


def test_lazy_package_attributes_resolve():
    import repro.autotune
    import repro.kernels

    assert callable(repro.autotune.matmul_blocks_site)
    # `chain_matmul`/`ssd_mix` have no like-named subpackage, so the lazy
    # resolution is import-order-immune in-suite; `matmul` and
    # `flash_attention` can be shadowed by their subpackages after a
    # dotted import (pytest collection imports test_kernels.py), so their
    # clean-order behaviour is asserted in a fresh interpreter below
    assert callable(repro.kernels.chain_matmul)
    assert callable(repro.kernels.ssd_mix)


def test_lazy_kernel_callables_resolve_in_clean_order():
    """In a fresh interpreter, every exported kernel name resolves to a
    callable through the lazy ``__getattr__`` — including the two that
    share their name with a subpackage."""
    code = """
import repro.kernels
for name in repro.kernels.__all__:
    assert callable(getattr(repro.kernels, name)), name
print("OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", code], env=_env(),
        capture_output=True, text=True, timeout=240,
    )
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout


# ------------------------------------------------------------ explainer ---

def test_explain_workloads_defaults_to_entry_filter():
    class Toy(AlgorithmFamily):
        name = "toy-test-family"
        description = "toy"

        def entry(self, inst):
            wl = {"a": lambda: 1, "b": lambda: 2, "c": lambda: 3}
            return ({"a": 1.0, "b": 1.0, "c": 1.0},
                    {"size": 1, "dims": None, "kernels": {}},
                    lambda: wl)

    fam = Toy()
    out = fam.explain_workloads(
        InstanceSpec(index=0, uid="t", family="toy-test-family", params={}),
        ["b", "c"],
    )
    assert sorted(out) == ["b", "c"]
    assert out["b"]() == 2


# ------------------------------------------------------------ store kinds ---

def test_store_kind_detection(tmp_path):
    from repro.core.stores import (
        AmbiguousStore,
        detect_store_kind,
        store_kinds,
    )

    assert [k.name for k in store_kinds()] == ["sweep", "explain", "oracle"]
    root = str(tmp_path)
    assert detect_store_kind(root) is None
    with open(os.path.join(root, "spec.json"), "w") as fh:
        json.dump({}, fh)
    assert detect_store_kind(root).name == "sweep"
    os.replace(os.path.join(root, "spec.json"),
               os.path.join(root, "espec.json"))
    assert detect_store_kind(root).name == "explain"
    os.replace(os.path.join(root, "espec.json"),
               os.path.join(root, "ocache.json"))
    assert detect_store_kind(root).name == "oracle"
    os.replace(os.path.join(root, "ocache.json"),
               os.path.join(root, "espec.json"))
    with open(os.path.join(root, "spec.json"), "w") as fh:
        json.dump({}, fh)
    with pytest.raises(AmbiguousStore, match="multiple campaign kinds"):
        detect_store_kind(root)


def test_store_kind_registry_rejects_spec_file_collision():
    from repro.core.stores import StoreKind, register_store_kind

    with pytest.raises(ValueError, match="already claimed"):
        register_store_kind(StoreKind(name="other-sweep",
                                      spec_file="spec.json"))


def test_open_queue_routes_through_registry(tmp_path):
    from repro.launch.queue import open_queue

    with pytest.raises(SystemExit, match="known store kinds"):
        open_queue(str(tmp_path))
    # an ambiguous root refuses instead of silently draining as a sweep
    for name in ("spec.json", "espec.json"):
        with open(os.path.join(str(tmp_path), name), "w") as fh:
            json.dump({}, fh)
    with pytest.raises(SystemExit, match="multiple campaign kinds"):
        open_queue(str(tmp_path))


def test_fsck_store_kind_reports_ambiguous(tmp_path):
    from repro.launch.fsck import _detect_n_shards, _store_kind

    root = str(tmp_path)
    assert _store_kind(root) == "unknown"
    for name in ("spec.json", "espec.json"):
        with open(os.path.join(root, name), "w") as fh:
            json.dump({}, fh)
    assert _store_kind(root) == "ambiguous"
    # n-shard detection falls back to scanning shard files
    open(os.path.join(root, "shard-0002.jsonl"), "w").close()
    assert _detect_n_shards(root) == 3


# ---------------------------------------------------------------- report ---

def test_census_report_carries_family_footnotes():
    from repro.launch.report_md import census_tables

    records = [{
        "uid": "kernel_variants-matmul-n32-s000", "index": 0,
        "family": "kernel_variants", "size": 32, "is_anomaly": True,
        "reason": "min_flops_split", "converged": True,
    }]
    md = census_tables(records, name="kv")
    assert "*kernel_variants*:" in md
    assert "Pallas" in md
