"""Ranking-as-a-service: the dispatch oracle and its two-tier cache.

Contracts under test, from the ISSUE's acceptance bar:

* warm-cache queries on census-measured instances answer ``measured``
  with rankings byte-identical to the census records (100% hit rate);
* an in-bucket but unmeasured instance answers ``bucketed`` from the
  aggregate; a true miss answers ``model_only`` IMMEDIATELY and is
  durably enqueued — the hot path never blocks on a measurement;
* the background queue (the ordinary pull queue: the cache root is a
  registered store kind) drains enqueued misses under the census's own
  spec, after which the same query answers ``measured`` byte-identically
  to what the census itself records for that instance;
* fsck repairs a damaged cache shard like any other shard, and a
  re-warm restores the excised entries.
"""

import json
import os

import pytest

from repro.core.lease import default_owner
from repro.core.stores import detect_store_kind
from repro.core.sweep import (
    ShardStore,
    StoreDamaged,
    SweepSpec,
    merge_shards,
    run_shard,
    write_merged,
)
from repro.launch.fsck import fsck_store
from repro.launch.queue import drain, open_queue
from repro.serve.cache import (
    CONFIDENCE_BUCKETED,
    CONFIDENCE_MEASURED,
    CONFIDENCE_MODEL_ONLY,
    OracleCache,
    OracleCacheSpec,
    aggregate_entry,
    cache_key,
    shard_of_key,
    split_key,
)
from repro.serve.oracle import (
    OracleQueue,
    RankingOracle,
    default_machine_name,
    hit_rate,
)


@pytest.fixture(scope="module")
def census(tmp_path_factory):
    """One small deterministic cost-model census, drained and merged."""
    root = str(tmp_path_factory.mktemp("census"))
    spec = SweepSpec(
        name="oracle-census",
        families={
            "gram": {"sizes": [48, 64], "per_size": 3},
            "solve": {"sizes": [48], "per_size": 2},
        },
        n_shards=2,
        backend="cost_model",
        dispatch_s=1e-6,
        max_measurements=12,
    )
    spec.save(os.path.join(root, "spec.json"))
    for shard in range(spec.n_shards):
        run_shard(spec, root, shard)
    write_merged(spec, root)
    return spec, root, merge_shards(spec, root)


def _warmed(tmp_path, census, **spec_overrides):
    spec, root, records = census
    kwargs = dict(census=root, n_shards=2)
    kwargs.update(spec_overrides)
    cspec = OracleCacheSpec(**kwargs)
    cache = OracleCache.create(str(tmp_path / "cache"), cspec)
    cache.warm(records, (), machine=default_machine_name(cspec, spec))
    return RankingOracle.open(cache.root)


def _empty(tmp_path, census, **spec_overrides):
    _, root, _ = census
    kwargs = dict(census=root, n_shards=2)
    kwargs.update(spec_overrides)
    out = str(tmp_path / "cache")
    OracleCache.create(out, OracleCacheSpec(**kwargs))
    return RankingOracle.open(out)


# ------------------------------------------------------------------ the key ---


def test_cache_key_roundtrip_and_stable_sharding():
    key = cache_key("gram", "[32, 64)", "sweep:census")
    assert split_key(key) == ("gram", "[32, 64)", "sweep:census")
    assert shard_of_key(key, 4) == shard_of_key(key, 4)
    assert 0 <= shard_of_key(key, 4) < 4
    with pytest.raises(ValueError):
        cache_key("gr|am", "[32, 64)", "m")


# ----------------------------------------------------------------- verdicts ---


def test_warm_cache_answers_measured_byte_identical(tmp_path, census):
    _, _, records = census
    oracle = _warmed(tmp_path, census)
    verdicts = oracle.query_batch(
        [{"family": r["family"], "params": r["params"]} for r in records],
        enqueue=False,
    )
    assert hit_rate(verdicts) == 1.0
    for verdict, record in zip(verdicts, records):
        assert verdict["confidence"] == CONFIDENCE_MEASURED
        assert verdict["uid"] == record["uid"]
        # byte-identical to the census report's ranking
        assert (json.dumps(verdict["ranks"], sort_keys=True)
                == json.dumps(record["ranks"], sort_keys=True))
        assert verdict["is_anomaly"] == record["is_anomaly"]
        assert verdict["min_flops_algs"] == record["min_flops_algs"]
        assert all(r["confidence"] == 1.0 for r in verdict["ranking"])


def test_unmeasured_instance_in_warm_bucket_answers_bucketed(tmp_path, census):
    oracle = _warmed(tmp_path, census)
    verdict = oracle.query("gram", {"size": 50, "seed": 777}, enqueue=False)
    assert verdict["confidence"] == CONFIDENCE_BUCKETED
    assert verdict["bucket"] == "[32, 64)"
    assert verdict["n_records"] >= 3
    # aggregate confidences are vote shares
    assert all(0.0 < r["confidence"] <= 1.0 for r in verdict["ranking"])


def test_empty_cache_miss_answers_model_only_and_enqueues(tmp_path, census):
    _, _, records = census
    oracle = _empty(tmp_path, census)
    record = records[0]
    verdict = oracle.query(record["family"], record["params"])
    assert verdict["confidence"] == CONFIDENCE_MODEL_ONLY
    assert verdict["enqueued"] is True
    assert verdict["n_records"] == 0
    # a real ranking is still returned (the analytic fallback)
    assert verdict["ranks"] and verdict["ranking"]
    assert set(verdict["ranks"]) == set(record["ranks"])
    # the miss is durable and the shard re-opened to the queue
    shard = shard_of_key(verdict["key"], oracle.spec.n_shards)
    assert oracle.cache.pending(shard)
    # enqueue=False answers without touching the queue
    before = oracle.cache.miss_totals()[0]
    v2 = oracle.query("gram", {"size": 500, "seed": 0}, enqueue=False)
    assert v2["confidence"] == CONFIDENCE_MODEL_ONLY and not v2["enqueued"]
    assert oracle.cache.miss_totals()[0] == before


def test_miss_enqueue_drain_then_measured_byte_identical(tmp_path, census):
    """The ISSUE's round trip: empty cache -> model_only, queue worker
    drains the miss, the same query answers measured and byte-identical
    to the census report's ranking for that instance."""
    _, _, records = census
    oracle = _empty(tmp_path, census)
    record = records[3]
    first = oracle.query(record["family"], record["params"])
    assert first["confidence"] == CONFIDENCE_MODEL_ONLY
    assert first["uid"] == record["uid"]  # grid instances keep real uids

    # the cache root IS a queue: drain it through the ordinary pull loop
    queue = open_queue(oracle.root)
    assert isinstance(queue, OracleQueue)
    assert drain(queue, default_owner()) is True
    assert queue.progress() == {"completed": 1, "total": 1}

    oracle.reload()
    second = oracle.query(record["family"], record["params"])
    assert second["confidence"] == CONFIDENCE_MEASURED
    assert (json.dumps(second["ranks"], sort_keys=True)
            == json.dumps(record["ranks"], sort_keys=True))
    assert second["is_anomaly"] == record["is_anomaly"]


def test_ad_hoc_params_get_stable_out_of_grid_uids(tmp_path, census):
    oracle = _warmed(tmp_path, census)
    a = oracle.query("gram", {"size": 50, "seed": 123}, enqueue=False)
    b = oracle.query("gram", {"size": 50, "seed": 123}, enqueue=False)
    assert a["uid"] == b["uid"] and a["uid"].startswith("gram-adhoc-")
    assert a["index"] >= (1 << 32)  # never collides with grid indices


def test_machine_override_is_a_distinct_key(tmp_path, census):
    oracle = _warmed(tmp_path, census)
    default = oracle.query("gram", {"size": 48, "seed": 0}, enqueue=False)
    other = oracle.query("gram", {"size": 48, "seed": 0},
                         machine="cpu-1core", enqueue=False)
    assert default["confidence"] == CONFIDENCE_MEASURED
    assert other["confidence"] == CONFIDENCE_MODEL_ONLY  # not warmed
    assert default["key"] != other["key"]


# ---------------------------------------------------------------- the cache ---


def test_warm_is_idempotent_and_lru_serves_without_io(tmp_path, census):
    spec, root, records = census
    cspec = OracleCacheSpec(census=root, n_shards=2)
    cache = OracleCache.create(str(tmp_path / "cache"), cspec)
    machine = default_machine_name(cspec, spec)
    first = cache.warm(records, (), machine=machine)
    assert first == len(cache) > 0
    assert cache.warm(records, (), machine=machine) == 0  # nothing new
    # a repeated get is a pure LRU hit
    key = cache.keys()[0]
    entry = cache.get(key)
    hits_before = cache.hits
    assert cache.get(key) is entry
    assert cache.hits == hits_before + 1


def test_lru_capacity_bounds_memory_but_not_correctness(tmp_path, census):
    spec, root, records = census
    cspec = OracleCacheSpec(census=root, n_shards=2, lru_capacity=1)
    cache = OracleCache.create(str(tmp_path / "cache"), cspec)
    cache.warm(records, (), machine=default_machine_name(cspec, spec))
    keys = cache.keys()
    assert len(keys) > 1
    for key in keys + keys:  # evict and re-fault every entry from disk
        entry = cache.get(key)
        assert entry is not None and entry["key"] == key
        assert len(cache._lru) == 1


def test_aggregate_entry_modal_ranks_and_anomaly_rule():
    sources = {
        "u1": {"index": 0, "size": 48, "ranks": {"a": 1, "b": 2},
               "mean_ranks": {"a": 1.0, "b": 2.0}, "is_anomaly": False,
               "reason": "", "min_flops_algs": ["b"], "cause": None,
               "cause_evidence": None, "offending_kernel": None},
        "u2": {"index": 1, "size": 50, "ranks": {"a": 1, "b": 2},
               "mean_ranks": {"a": 1.1, "b": 1.9}, "is_anomaly": True,
               "reason": "sf_not_best", "min_flops_algs": ["b"],
               "cause": "dispatch_overhead", "cause_evidence": 0.8,
               "offending_kernel": None},
        "u3": {"index": 2, "size": 52, "ranks": {"a": 2, "b": 1},
               "mean_ranks": {"a": 1.8, "b": 1.2}, "is_anomaly": True,
               "reason": "sf_not_best", "min_flops_algs": ["b"],
               "cause": "dispatch_overhead", "cause_evidence": 0.6,
               "offending_kernel": None},
    }
    entry = aggregate_entry("f|[32, 64)|m", sources, seq=0)
    assert entry["ranks"] == {"a": 1, "b": 2}           # modal ranks
    assert entry["n_records"] == 3
    assert entry["anomaly_rate"] == pytest.approx(2 / 3)
    # min-FLOPs alg b sits in modal rank 2 > best rank 1: bucket anomaly
    assert entry["is_anomaly"] is True
    assert entry["cause"] == "dispatch_overhead"
    assert entry["cause_evidence"] == pytest.approx(0.7)
    by_alg = {r["alg"]: r for r in entry["ranking"]}
    assert by_alg["a"]["confidence"] == pytest.approx(2 / 3)
    # deterministic: same sources, same seq -> identical entry
    assert aggregate_entry("f|[32, 64)|m", sources, seq=0) == entry


def test_explain_causes_ride_into_measured_verdicts(tmp_path, census):
    spec, root, records = census
    anomalous = [r for r in records if r["is_anomaly"]] or records[:1]
    target = anomalous[0]
    explained = [{
        "uid": target["uid"], "cause": "dispatch_overhead",
        "evidence": 0.9, "offending_kernel": "gemm::0",
    }]
    cspec = OracleCacheSpec(census=root, n_shards=2)
    cache = OracleCache.create(str(tmp_path / "cache"), cspec)
    cache.warm(records, explained, machine=default_machine_name(cspec, spec))
    oracle = RankingOracle.open(cache.root)
    verdict = oracle.query(target["family"], target["params"], enqueue=False)
    assert verdict["confidence"] == CONFIDENCE_MEASURED
    assert verdict["cause"] == "dispatch_overhead"
    assert verdict["cause_evidence"] == pytest.approx(0.9)


# ------------------------------------------------------- store kind + fsck ---


def test_cache_root_is_a_registered_store_kind(tmp_path, census):
    oracle = _warmed(tmp_path, census)
    kind = detect_store_kind(oracle.root)
    assert kind is not None and kind.name == "oracle"
    assert kind.load_n_shards(oracle.root) == oracle.spec.n_shards
    queue = open_queue(oracle.root)
    assert queue.kind == "oracle" and queue.n_shards == oracle.spec.n_shards


def test_fsck_repairs_damaged_cache_shard_and_rewarm_restores(tmp_path, census):
    """The satellite's damaged-cache-shard case: mid-file bitrot in a
    cache shard is loud (writers refuse), fsck excises + quarantines +
    rebuilds the manifest, and a re-warm restores the lost entries."""
    spec, root, records = census
    oracle = _warmed(tmp_path, census)
    out = oracle.root
    machine = default_machine_name(oracle.spec, spec)

    # find a shard holding >= 2 entries and corrupt a byte of its FIRST line
    shard = next(
        s for s in range(oracle.spec.n_shards)
        if len(ShardStore(out, s).open(readonly=True).records) >= 2
    )
    path = ShardStore(out, shard).records_path
    data = open(path, "rb").read()
    first_nl = data.index(b"\n")
    open(path, "wb").write(b"\x00" + data[1:first_nl + 1] + data[first_nl + 1:])

    # loud: a writer refuses the shard, the scan counts the damage
    with pytest.raises(StoreDamaged):
        ShardStore(out, shard).open()
    damaged_cache = OracleCache.open(out)
    assert any(s == shard for s, _, _ in damaged_cache.damaged)

    report = fsck_store(out)
    assert [f for f in report.findings
            if f.shard == shard and f.kind == "mid_file_corruption"]
    assert report.remaining == 0
    quarantine = os.path.join(out, "quarantine")
    assert any(".line-" in f for f in os.listdir(quarantine))
    assert fsck_store(out).clean  # idempotent

    # the excised entry is a miss now; re-warming restores it
    repaired = OracleCache.open(out)
    lost = set(oracle.cache.keys()) - set(repaired.keys())
    assert lost
    repaired.warm(records, (), machine=machine)
    assert set(repaired.keys()) == set(oracle.cache.keys())
    fresh = RankingOracle.open(out)
    verdicts = fresh.query_batch(
        [{"family": r["family"], "params": r["params"]} for r in records],
        enqueue=False,
    )
    assert hit_rate(verdicts) == 1.0
    assert all(v["confidence"] == CONFIDENCE_MEASURED for v in verdicts)


def test_queue_pause_and_resume_is_lossless(tmp_path, census):
    """max_steps pauses mid-miss without committing; the next pass
    re-measures deterministically and commits the same entry."""
    _, _, records = census
    oracle = _empty(tmp_path, census)
    record = records[1]
    oracle.query(record["family"], record["params"])
    queue = OracleQueue(oracle.root)
    shard = shard_of_key(
        cache_key(record["family"],
                  oracle.query(record["family"], record["params"])["bucket"],
                  oracle.machine_name),
        oracle.spec.n_shards,
    )
    queue.run_shard(shard, max_steps=2)          # pause almost immediately
    assert queue.progress()["completed"] == 0    # nothing half-committed
    queue.run_shard(shard)                       # full pass commits
    assert queue.progress() == {"completed": 1, "total": 1}
    oracle.reload()
    verdict = oracle.query(record["family"], record["params"], enqueue=False)
    assert verdict["confidence"] == CONFIDENCE_MEASURED
    assert (json.dumps(verdict["ranks"], sort_keys=True)
            == json.dumps(record["ranks"], sort_keys=True))
