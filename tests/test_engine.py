"""Tests for the ExperimentEngine subsystem: sessions, scheduling policies,
JSON persistence / resume, and the interleaved rank_sites campaigns."""

import json
import os

import numpy as np
import pytest

from repro.core import (
    ExperimentEngine,
    MeasurementSession,
    MeasurementStore,
    NoiseProfile,
    SimulatedTimer,
    measure_and_rank,
    timer_from_dict,
    timer_to_dict,
)
from repro.autotune import CampaignSite, rank_sites, reports_from_engine


def _profiles(bases, rel_sigma=0.05):
    return {n: NoiseProfile(base=b, rel_sigma=rel_sigma) for n, b in bases.items()}


BASES = {"a": 1.0, "b": 1.05, "c": 1.5, "d": 1.52}


def _timer(seed=5):
    return SimulatedTimer(_profiles(BASES), seed=seed)


# ------------------------------------------------------------- sessions ---

def test_session_steps_match_measure_and_rank_iteration_for_iteration():
    """Stepping a session manually reproduces measure_and_rank exactly:
    same history records, same final ranks, same convergence flag."""
    ref = measure_and_rank(
        sorted(BASES), _timer(), m_per_iteration=3, eps=0.02, max_measurements=36
    )
    session = MeasurementSession(
        "s", sorted(BASES), _timer(), m_per_iteration=3, eps=0.02, max_measurements=36
    )
    steps = 0
    while not session.done:
        rec = session.step()
        assert rec == ref.history[steps]
        steps += 1
    assert steps == len(ref.history)
    assert session.result() == ref


def test_session_json_roundtrip_resumes_bit_identical():
    """Kill a session mid-campaign, serialize through real JSON text, resume
    — the final result equals the uninterrupted run's."""
    ref = measure_and_rank(
        sorted(BASES), _timer(), m_per_iteration=3, eps=0.02, max_measurements=36
    )
    session = MeasurementSession(
        "s", sorted(BASES), _timer(), m_per_iteration=3, eps=0.02, max_measurements=36
    )
    session.step()
    session.step()
    blob = json.dumps(session.to_dict())
    resumed = MeasurementSession.from_dict(json.loads(blob))
    while not resumed.done:
        resumed.step()
    assert resumed.result() == ref


def test_interrupt_mid_step_rolls_back_and_resumes_bit_identical():
    """An interrupt inside step()'s measurement loop must not persist a
    partial iteration or a shifted timer RNG stream: a save taken after the
    exception sits at a whole-iteration boundary, so resume still matches
    the uninterrupted run exactly. With batched draws the interruptible
    points are between per-algorithm sample blocks — the iteration is
    already mid-flight (some algorithms measured) when the interrupt
    lands."""

    class Interrupting(SimulatedTimer):
        def __init__(self, *a, **k):
            super().__init__(*a, **k)
            self.batches = 0
            self.explode_at = None

        def measure_many(self, name, m):
            self.batches += 1
            if self.explode_at is not None and self.batches >= self.explode_at:
                raise KeyboardInterrupt
            return super().measure_many(name, m)

    ref = measure_and_rank(
        sorted(BASES), _timer(), m_per_iteration=3, eps=0.02, max_measurements=36
    )
    timer = Interrupting(_profiles(BASES), seed=5)
    session = MeasurementSession(
        "s", sorted(BASES), timer, m_per_iteration=3, eps=0.02, max_measurements=36
    )
    session.step()
    timer.explode_at = timer.batches + 2  # mid-iteration: 1 of 4 algs drawn
    with pytest.raises(KeyboardInterrupt):
        session.step()
    assert session.measurements_per_alg == 3  # partial iteration rolled back
    timer.explode_at = None

    blob = json.dumps(session.to_dict())
    resumed = MeasurementSession.from_dict(json.loads(blob))
    while not resumed.done:
        resumed.step()
    assert resumed.result() == ref


def test_rank_sites_rejects_sites_combined_with_resume_from(tmp_path):
    state = os.fspath(tmp_path / "campaign.json")
    rank_sites(_campaign_sites(), max_steps=1, save_path=state,
               m_per_iteration=3, eps=0.02, max_measurements=30)
    with pytest.raises(ValueError):
        rank_sites(_campaign_sites(), resume_from=state)


def test_detached_session_ranks_existing_data_but_cannot_measure():
    session = MeasurementSession(
        "s", sorted(BASES), _timer(), eps=-1.0, max_measurements=30
    )
    session.step()
    d = session.to_dict(include_timer=False)
    detached = MeasurementSession.from_dict(d)
    # ranking the persisted data needs no backend ...
    assert detached.result().names_in_order == session.result().names_in_order
    # ... but stepping does
    with pytest.raises(RuntimeError):
        detached.step()


# ---------------------------------------------------------- store / timer ---

def test_batched_draw_campaign_resumes_bit_identical():
    """Satellite regression: with vectorized measure_many (one RNG call per
    distribution component, non-trivial accounting for bimodal + outlier
    profiles), a killed-and-resumed campaign must still be bit-identical to
    an uninterrupted one."""
    profiles = {
        "a": NoiseProfile(base=1.0, rel_sigma=0.03, bimodal_shift=1.0,
                          bimodal_prob=0.5, outlier_prob=0.05),
        "b": NoiseProfile(base=1.1, rel_sigma=0.03, bimodal_shift=0.6,
                          bimodal_prob=0.5),
        "c": NoiseProfile(base=1.6, rel_sigma=0.03),
    }

    def make():
        return MeasurementSession(
            "s", sorted(profiles), SimulatedTimer(profiles, seed=21),
            m_per_iteration=4, eps=0.01, max_measurements=24,
        )

    full = make()
    while not full.done:
        full.step()

    killed = make()
    killed.step()
    resumed = MeasurementSession.from_dict(json.loads(json.dumps(killed.to_dict())))
    while not resumed.done:
        resumed.step()

    assert resumed.result() == full.result()
    assert json.dumps(resumed.to_dict(), sort_keys=True) == \
        json.dumps(full.to_dict(), sort_keys=True)


def test_batched_draws_match_scalar_loop_for_lognormal_profiles():
    """A pure-lognormal profile must consume exactly the RNG stream the
    historical scalar loop did: measure_many(m) == m successive measure()
    calls, and the stream continues identically afterwards."""
    t1 = SimulatedTimer(_profiles(BASES), seed=9)
    t2 = SimulatedTimer(_profiles(BASES), seed=9)
    assert t1.measure_many("a", 10) == [t2.measure("a") for _ in range(10)]
    assert t1.measure("b") == t2.measure("b")


def test_measurement_store_json_roundtrip():
    store = MeasurementStore()
    store.add("x", [1.0, 2.5, 3.25])
    store.add("y", [0.125])
    blob = json.dumps(store.to_dict())
    back = MeasurementStore.from_dict(json.loads(blob))
    assert dict(back.as_mapping()) == dict(store.as_mapping())
    assert back.min_count() == store.min_count()
    assert back.counts() == store.counts()


def test_simulated_timer_roundtrip_preserves_rng_stream():
    t1 = _timer(seed=13)
    [t1.measure("a") for _ in range(5)]
    t2 = timer_from_dict(json.loads(json.dumps(timer_to_dict(t1))))
    assert [t1.measure("a") for _ in range(4)] == [t2.measure("a") for _ in range(4)]


# ------------------------------------------------------------ scheduling ---

def _never_converging_session(name, seed):
    return MeasurementSession(
        name, sorted(BASES), _timer(seed), m_per_iteration=3,
        eps=-1.0, max_measurements=12,
    )


def test_round_robin_covers_all_sessions():
    engine = ExperimentEngine(policy="round_robin")
    for i in range(3):
        engine.add_session(_never_converging_session(f"s{i}", i))
    for _ in range(3):
        engine.step()
    assert [s.iterations for s in engine.sessions] == [1, 1, 1]
    results = engine.run()
    assert engine.done
    assert set(results) == {"s0", "s1", "s2"}
    assert all(s.measurements_per_alg == 12 for s in engine.sessions)


def test_least_converged_first_prioritizes_unstarted_then_largest_norm():
    engine = ExperimentEngine(policy="least_converged_first")
    for i in range(3):
        engine.add_session(_never_converging_session(f"s{i}", i))
    stepped = {engine.step()[0] for _ in range(3)}
    assert stepped == {"s0", "s1", "s2"}  # inf-norm sessions go first
    expected = max(engine.pending(), key=lambda s: s.norm).name
    assert engine.step()[0] == expected


def test_until_deadline_budget_stops_campaign():
    engine = ExperimentEngine(policy="until_deadline")
    engine.add_session(_never_converging_session("s0", 0))
    with pytest.raises(ValueError):
        engine.run()  # no budget given
    engine.run(deadline_s=0.0)
    assert engine.steps_taken == 0 and not engine.done
    # a real budget makes progress and still respects the measurement cap
    engine.run(deadline_s=60.0)
    assert engine.done


def test_engine_rejects_duplicate_names_and_unknown_policy():
    with pytest.raises(ValueError):
        ExperimentEngine(policy="definitely_not_a_policy")
    engine = ExperimentEngine()
    engine.add_session(_never_converging_session("dup", 0))
    with pytest.raises(ValueError):
        engine.add_session(_never_converging_session("dup", 1))


# ------------------------------------------------- campaigns (rank_sites) ---

def _campaign_sites():
    """Three variant sites with distinct noise landscapes + FLOP tables."""
    sites = []
    tables = [
        ({"v0": 1.00, "v1": 1.04, "v2": 1.60}, {"v0": 10.0, "v1": 20.0, "v2": 5.0}),
        ({"v0": 2.00, "v1": 1.10, "v2": 1.12}, {"v0": 10.0, "v1": 10.0, "v2": 30.0}),
        ({"v0": 0.50, "v1": 0.80, "v2": 0.79}, {"v0": 5.0, "v1": 6.0, "v2": 7.0}),
    ]
    for i, (bases, flops) in enumerate(tables):
        sites.append(
            CampaignSite(
                name=f"site{i}",
                timer=SimulatedTimer(_profiles(bases, rel_sigma=0.04), seed=100 + i),
                flops=flops,
                initial_order=sorted(bases),
                backend="simulated",
            )
        )
    return sites


def test_rank_sites_interleaves_kill_and_resume_to_same_ranks(tmp_path):
    """The acceptance path: >= 3 sites as one interleaved campaign, killed
    after N engine iterations, resumed via ExperimentEngine.load — final
    ranks identical to the uninterrupted campaign's."""
    kwargs = dict(m_per_iteration=3, eps=0.02, max_measurements=30,
                  policy="least_converged_first")

    full = rank_sites(_campaign_sites(), **kwargs)
    assert len(full) == 3

    state = os.fspath(tmp_path / "campaign.json")
    partial = rank_sites(_campaign_sites(), max_steps=4, save_path=state, **kwargs)
    assert len(partial) == 3  # best-so-far reports exist mid-campaign

    engine = ExperimentEngine.load(state)
    assert engine.pending(), "campaign should have been killed mid-flight"
    assert engine.steps_taken == 4
    engine.run()
    resumed = reports_from_engine(engine)

    for name, report in full.items():
        assert resumed[name].ranking == report.ranking
        assert resumed[name].selected == report.selected
        assert resumed[name].discriminant.is_anomaly == report.discriminant.is_anomaly


def test_rank_sites_resume_from_path_api(tmp_path):
    """rank_sites(resume_from=...) finishes a killed campaign in one call."""
    kwargs = dict(m_per_iteration=3, eps=0.02, max_measurements=30)
    full = rank_sites(_campaign_sites(), **kwargs)
    state = os.fspath(tmp_path / "campaign.json")
    rank_sites(_campaign_sites(), max_steps=2, save_path=state, **kwargs)
    resumed = rank_sites(resume_from=state, **kwargs)
    for name, report in full.items():
        assert resumed[name].ranking == report.ranking


def test_rank_sites_deadline_budget_omits_unscheduled_sessions(tmp_path):
    """Reading reports must never measure: with a zero budget nothing was
    scheduled, so nothing is reported — and the saved state stays empty so
    a resume re-measures nothing."""
    state = os.fspath(tmp_path / "campaign.json")
    reports = rank_sites(
        _campaign_sites(), policy="until_deadline", deadline_s=0.0,
        m_per_iteration=3, eps=0.02, max_measurements=30, save_path=state,
    )
    assert reports == {}
    engine = ExperimentEngine.load(state)
    assert all(s.measurements_per_alg == 0 for s in engine.sessions)
    # lifting the budget completes the campaign from the persisted state
    engine.run(deadline_s=60.0)
    assert len(reports_from_engine(engine)) == 3
