"""The benchmark regression gate (benchmarks/check_regression.py):
row matching, threshold verdicts, error rows, empty intersections, and the
committed BENCH baselines being valid gate inputs."""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, ROOT)

from benchmarks.check_regression import (  # noqa: E402
    check_pair,
    compare,
    fresh_errors,
    main,
)


def _payload(rows):
    return {"schema": 1, "rows": rows}


def _row(name, us, derived=""):
    return {"name": name, "us_per_call": us, "derived": derived}


def test_compare_matches_by_name_and_flags_regressions():
    base = _payload([_row("a.x", 100.0), _row("a.y", 50.0), _row("gone", 1.0)])
    fresh = _payload([_row("a.x", 120.0), _row("a.y", 80.0), _row("new", 1.0)])
    rows = compare(base, fresh, threshold=0.30)
    assert [r["name"] for r in rows] == ["a.x", "a.y"]
    by = {r["name"]: r for r in rows}
    assert not by["a.x"]["regressed"]          # x1.20 within 30%
    assert by["a.y"]["regressed"]              # x1.60 over 30%
    assert by["a.y"]["ratio"] == pytest.approx(1.6)


def test_compare_skips_error_and_non_numeric_rows():
    base = _payload([_row("a.x", 100.0), _row("b.ERROR", 0)])
    fresh = _payload([_row("a.x", 90.0),
                      {"name": "a.x2", "us_per_call": "nan?", "derived": ""}])
    rows = compare(base, fresh)
    assert [r["name"] for r in rows] == ["a.x"]
    assert fresh_errors(_payload([_row("sweep.ERROR", 0)])) == ["sweep.ERROR"]


def _write(tmp_path, name, payload):
    p = str(tmp_path / name)
    with open(p, "w") as fh:
        json.dump(payload, fh)
    return p


def test_check_pair_verdicts(tmp_path):
    base = _write(tmp_path, "base.json",
                  _payload([_row("a.x", 100.0), _row("a.y", 100.0)]))
    good = _write(tmp_path, "good.json",
                  _payload([_row("a.x", 110.0), _row("a.y", 95.0)]))
    ok, lines = check_pair(base, good, 0.30)
    assert ok and any("ok   a.x" in l for l in lines)
    bad = _write(tmp_path, "bad.json",
                 _payload([_row("a.x", 200.0), _row("a.y", 95.0)]))
    ok, lines = check_pair(base, bad, 0.30)
    assert not ok
    assert any(l.startswith("FAIL a.x") for l in lines)
    # an errored fresh row fails even when every match is fine
    err = _write(tmp_path, "err.json",
                 _payload([_row("a.x", 100.0), _row("sweep.ERROR", 0)]))
    ok, _ = check_pair(base, err, 0.30)
    assert not ok
    # nothing in common: the gate must not silently pass
    other = _write(tmp_path, "other.json", _payload([_row("z.z", 1.0)]))
    ok, lines = check_pair(base, other, 0.30)
    assert not ok and any("compared nothing" in l for l in lines)


def test_best_of_n_fresh_runs(tmp_path):
    from benchmarks.check_regression import merge_best_of

    runs = [
        _payload([_row("a.x", 200.0), _row("a.y", 90.0), _row("b.ERROR", 0)]),
        _payload([_row("a.x", 110.0), _row("a.y", 300.0)]),
    ]
    merged = merge_best_of(runs)
    rows = {r["name"]: r["us_per_call"] for r in merged["rows"]}
    # per-row minimum across runs; an error in ONE run is forgiven when
    # another run succeeded
    assert rows == {"a.x": 110.0, "a.y": 90.0}
    both_err = merge_best_of([_payload([_row("b.ERROR", 0)])] * 2)
    assert [r["name"] for r in both_err["rows"]] == ["b.ERROR"]
    # check_pair accepts a comma list for the fresh side: a load spike in
    # one run does not fail the gate
    base = _write(tmp_path, "base.json", _payload([_row("a.x", 100.0)]))
    spiky = _write(tmp_path, "spiky.json", _payload([_row("a.x", 250.0)]))
    quiet = _write(tmp_path, "quiet.json", _payload([_row("a.x", 105.0)]))
    ok, _ = check_pair(base, spiky, 0.30)
    assert not ok
    ok, lines = check_pair(base, f"{spiky},{quiet}", 0.30)
    assert ok, lines


def test_main_exit_codes_and_multiple_pairs(tmp_path, capsys):
    base = _write(tmp_path, "base.json", _payload([_row("a.x", 100.0)]))
    same = _write(tmp_path, "same.json", _payload([_row("a.x", 100.0)]))
    slow = _write(tmp_path, "slow.json", _payload([_row("a.x", 500.0)]))
    assert main(["--pair", base, same]) == 0
    assert main(["--pair", base, same, "--pair", base, slow]) == 1
    # a generous threshold waves the same pair through
    assert main(["--pair", base, slow, "--threshold", "5.0"]) == 0
    out = capsys.readouterr().out
    assert "PASS" in out and "FAIL" in out


def test_committed_baselines_are_valid_gate_inputs():
    """The repo's BENCH_sweep/BENCH_explain baselines must stay parseable
    and self-comparable (identity = PASS), so the CI gate can always run
    against them — this is what the CI explain-smoke imports too."""
    for name in ("BENCH_sweep.json", "BENCH_explain.json"):
        path = os.path.join(ROOT, name)
        with open(path) as fh:
            payload = json.load(fh)
        ok, lines = check_pair(path, path, 0.30)
        assert ok, lines
        rows = compare(payload, payload)
        assert rows and all(r["ratio"] == 1.0 for r in rows)


def test_cli_module_runs():
    base = os.path.join(ROOT, "BENCH_sweep.json")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.check_regression",
         "--pair", base, base],
        cwd=ROOT, capture_output=True, text=True, timeout=60,
    )
    assert proc.returncode == 0, proc.stderr
    assert "PASS" in proc.stdout
