"""Unit tests for repro.core — the paper's Procedures 1-4.

Property-based variants live in test_core_properties.py (they need the
optional ``hypothesis`` package; this module must collect on a bare env).
"""

import numpy as np
import pytest

from repro.core import (
    DEFAULT_QUANTILE_RANGES,
    CostModelTimer,
    MeasurementStore,
    NoiseProfile,
    Outcome,
    SimulatedTimer,
    Timer,
    compare_measurements,
    convergence_norm,
    filter_candidates,
    first_differences,
    flops_discriminant_test,
    initial_hypothesis_by_time,
    mean_ranks,
    measure_and_rank,
    min_flops_set,
    relative_flops,
    relative_times,
    sort_algorithms,
    sort_by_measurements,
)


# ----------------------------------------------------------- Procedure 1 ---

def test_compare_disjoint_faster():
    t_fast = [1.0, 1.1, 1.2]
    t_slow = [2.0, 2.1, 2.2]
    assert compare_measurements(t_fast, t_slow, 25, 75) is Outcome.BETTER
    assert compare_measurements(t_slow, t_fast, 25, 75) is Outcome.WORSE


def test_compare_overlap_equivalent():
    a = [1.0, 2.0, 3.0]          # q25=1.5, q75=2.5
    b = [1.5, 2.5, 3.5]          # q25=2.0, q75=3.0 — windows overlap
    assert compare_measurements(a, b, 25, 75) is Outcome.EQUIVALENT


def test_compare_invalid_range():
    with pytest.raises(ValueError):
        compare_measurements([1.0], [2.0], 75, 25)
    with pytest.raises(ValueError):
        compare_measurements([1.0], [2.0], 0.0, 75)


def test_wider_range_merges_more():
    """Paper Table III: wide quantile ranges declare equivalence more often."""
    rng = np.random.default_rng(0)
    a = rng.normal(1.0, 0.2, 50)
    b = rng.normal(1.3, 0.2, 50)
    wide = compare_measurements(a, b, 5, 95)
    narrow = compare_measurements(a, b, 45, 55)
    assert wide is Outcome.EQUIVALENT
    assert narrow is Outcome.BETTER


# ----------------------------------------------------------- Procedure 2 ---

def _paper_fig4_comparator():
    rel = {
        ("alg1", "alg2"): Outcome.WORSE,
        ("alg1", "alg3"): Outcome.EQUIVALENT,
        ("alg3", "alg4"): Outcome.WORSE,
        ("alg1", "alg4"): Outcome.WORSE,
        ("alg2", "alg4"): Outcome.EQUIVALENT,
        ("alg2", "alg1"): Outcome.BETTER,
        ("alg2", "alg3"): Outcome.BETTER,
        ("alg4", "alg1"): Outcome.BETTER,
        ("alg4", "alg3"): Outcome.BETTER,
        ("alg3", "alg1"): Outcome.EQUIVALENT,
        ("alg4", "alg2"): Outcome.EQUIVALENT,
    }
    return lambda a, b: rel[(a, b)]


def test_sort_reproduces_paper_fig4():
    """The worked example of Sec. III ends at ranks [1, 1, 2, 2]."""
    names, ranks = sort_algorithms(
        ["alg1", "alg2", "alg3", "alg4"], _paper_fig4_comparator(), tie_break="class"
    )
    assert names == ["alg2", "alg4", "alg1", "alg3"]
    assert ranks == [1, 1, 2, 2]


def test_sort_literal_rule_differs():
    """The paper's literal pseudocode rule gives [1,1,2,3] on Fig. 4 — the
    documented discrepancy (DESIGN.md §7)."""
    _, ranks = sort_algorithms(
        ["alg1", "alg2", "alg3", "alg4"], _paper_fig4_comparator(), tie_break="literal"
    )
    assert ranks == [1, 1, 2, 3]


def test_sort_single_and_empty():
    assert sort_algorithms(["x"], lambda a, b: Outcome.EQUIVALENT) == (["x"], [1])


def test_sort_separated_distributions_fully_ordered():
    meas = {
        "fast": list(np.linspace(1.0, 1.05, 10)),
        "mid": list(np.linspace(2.0, 2.05, 10)),
        "slow": list(np.linspace(3.0, 3.05, 10)),
    }
    names, ranks = sort_by_measurements(["slow", "mid", "fast"], meas, (25, 75))
    assert names == ["fast", "mid", "slow"]
    assert ranks == [1, 2, 3]


# ----------------------------------------------------------- Procedure 3 ---

def test_mean_ranks_three_classes():
    """Fig. 3-style data: two fast, two mid, two slow -> classes 1/2/3 at
    (q25, q75)."""
    rng = np.random.default_rng(7)
    meas = {
        "a0": rng.normal(1.00, 0.05, 40).tolist(),
        "a1": rng.normal(1.02, 0.05, 40).tolist(),
        "a2": rng.normal(1.50, 0.05, 40).tolist(),
        "a3": rng.normal(1.52, 0.05, 40).tolist(),
        "a4": rng.normal(2.00, 0.05, 40).tolist(),
        "a5": rng.normal(2.02, 0.05, 40).tolist(),
    }
    res = mean_ranks(sorted(meas), meas)
    table = dict(zip(res.order, res.ranks))
    assert {table["a0"], table["a1"]} == {1}
    assert {table["a2"], table["a3"]} == {2}
    assert {table["a4"], table["a5"]} == {3}
    # mean ranks respect the class structure
    assert res.mean_ranks["a0"] < res.mean_ranks["a2"] < res.mean_ranks["a4"]


# ----------------------------------------------------------- Procedure 4 ---

def test_convergence_norm_matches_paper_example():
    x = [1, 1, 1.86, 2.0, 2.57, 2.57]
    y = [1, 1, 1.86, 1.86, 2.43, 2.43]
    dx = first_differences(x)
    dy = first_differences(y)
    assert abs(convergence_norm(dy, dx, 5) - 0.028) < 1e-3


def test_measure_and_rank_converges_and_orders():
    profiles = {
        "fast": NoiseProfile(base=1.0, rel_sigma=0.02),
        "fast2": NoiseProfile(base=1.01, rel_sigma=0.02),
        "slow": NoiseProfile(base=2.0, rel_sigma=0.02),
    }
    timer = SimulatedTimer(profiles, seed=3)
    res = measure_and_rank(
        ["slow", "fast", "fast2"], timer, m_per_iteration=3,
        eps=0.03, max_measurements=30,
    )
    assert res.converged
    ranks = res.ranks
    assert ranks["fast"] == ranks["fast2"] == 1
    assert ranks["slow"] > 1
    assert res.measurements_per_alg <= 30
    assert len(res.history) >= 1


def test_measure_and_rank_budget_cap():
    # eps < 0 can never fire (norm >= 0): the loop must stop on the budget
    profiles = {
        "a": NoiseProfile(base=1.0, rel_sigma=0.5),
        "b": NoiseProfile(base=1.02, rel_sigma=0.5),
    }
    res = measure_and_rank(
        ["a", "b"], SimulatedTimer(profiles, seed=0),
        m_per_iteration=2, eps=-1.0, max_measurements=8,
    )
    assert res.measurements_per_alg == 8
    assert not res.converged


def test_cost_model_timer_deterministic():
    timer = CostModelTimer({"x": 1.0, "y": 2.0})
    res = measure_and_rank(["y", "x"], timer, m_per_iteration=2, max_measurements=8)
    assert res.ranks == {"x": 1, "y": 2}


class _ExplodingTimer(Timer):
    """Fails on any measurement — proves warm-start paths never measure."""

    def measure(self, name: str) -> float:
        raise AssertionError(f"unexpected measurement of {name!r}")


def test_warm_start_full_store_ranks_without_measuring():
    """A pre-populated store at (or past) the budget must be ranked as-is,
    not measured again past ``max_measurements`` (the old fallback bug)."""
    store = MeasurementStore()
    store.add("fast", [1.0 + 0.01 * i for i in range(10)])
    store.add("slow", [2.0 + 0.01 * i for i in range(10)])
    res = measure_and_rank(
        ["fast", "slow"], _ExplodingTimer(),
        m_per_iteration=3, max_measurements=10, store=store,
    )
    assert res.ranks == {"fast": 1, "slow": 2}
    assert res.measurements_per_alg == 10
    assert store.counts() == {"fast": 10, "slow": 10}


def test_warm_start_partial_store_measures_only_missing():
    """Algorithms with zero data still get one batch; warm ones do not."""
    store = MeasurementStore()
    store.add("warm", [1.0] * 12)
    timer = CostModelTimer({"warm": 1.0, "cold": 2.0})
    res = measure_and_rank(
        ["warm", "cold"], timer,
        m_per_iteration=3, max_measurements=10, store=store,
    )
    assert len(store.get("warm")) == 12          # untouched
    assert len(store.get("cold")) == 3           # one batch of M
    assert res.ranks == {"warm": 1, "cold": 2}


# ------------------------------------------------------ scores / filters ---

def test_relative_scores():
    rf = relative_flops({"a": 100.0, "b": 150.0})
    assert rf == {"a": 0.0, "b": 0.5}
    rt = relative_times({"a": 2.0, "b": 1.0})
    assert rt == {"a": 1.0, "b": 0.0}
    assert min_flops_set({"a": 1.0, "b": 1.0, "c": 2.0}) == ("a", "b")


def test_filter_candidates_keeps_min_flops_always():
    flops = {"minf": 100.0, "fast": 200.0, "slowhi": 300.0}
    times = {"minf": 5.0, "fast": 1.0, "slowhi": 4.0}  # minf slow single-run
    cand = filter_candidates(flops, times, rt_threshold=1.5)
    assert "minf" in cand.names          # S_F always kept
    assert "fast" in cand.names
    assert "slowhi" in cand.dropped      # RT = 3.0 >= 1.5


# -------------------------------------------------------- discriminant -----

def _ranking_from(meas, order=None):
    store = MeasurementStore()
    for k, v in meas.items():
        store.add(k, v)
    timer = CostModelTimer({k: float(np.median(v)) for k, v in meas.items()})
    return measure_and_rank(
        order or sorted(meas), timer, m_per_iteration=2, max_measurements=6
    )


def test_discriminant_valid():
    res = _ranking_from({"a": [1.0] * 5, "b": [2.0] * 5})
    rep = flops_discriminant_test(res, {"a": 10.0, "b": 20.0})
    assert not rep.is_anomaly


def test_discriminant_anomaly_outside_min_flops():
    """Condition 1: a non-min-FLOPs algorithm strictly beats S_F."""
    res = _ranking_from({"minf": [2.0] * 5, "hiflops": [1.0] * 5})
    rep = flops_discriminant_test(res, {"minf": 10.0, "hiflops": 20.0})
    assert rep.is_anomaly and rep.reason == "faster_outside_min_flops"


def test_discriminant_anomaly_min_flops_split():
    """Condition 2: members of S_F land in different classes."""
    res = _ranking_from({"m1": [1.0] * 5, "m2": [3.0] * 5})
    rep = flops_discriminant_test(res, {"m1": 10.0, "m2": 10.0})
    assert rep.is_anomaly and rep.reason == "min_flops_split"


def test_discriminant_requires_sf_present():
    res = _ranking_from({"a": [1.0] * 5})
    with pytest.raises(ValueError):
        flops_discriminant_test(res, {"a": 10.0, "zzz_min": 1.0})


# --------------------------------------------------------- turbo (bimodal) -

def test_bimodal_fast_mode_quantiles():
    """Paper Sec. IV: with turbo-boost bimodality, (q25,q75) merges the
    algorithms but the left-tail quantile set separates them by fast-mode
    performance."""
    from repro.core import FAST_MODE_QUANTILE_RANGES

    profiles = {
        # alg_a: faster in fast mode, same slow mode
        "a": NoiseProfile(base=1.0, rel_sigma=0.01, bimodal_shift=1.0, bimodal_prob=0.5),
        "b": NoiseProfile(base=1.25, rel_sigma=0.01, bimodal_shift=0.6, bimodal_prob=0.5),
    }
    timer = SimulatedTimer(profiles, seed=11)
    res_default = measure_and_rank(
        ["a", "b"], timer, m_per_iteration=6, max_measurements=60, eps=0.001
    )
    timer2 = SimulatedTimer(profiles, seed=12)
    res_fast = measure_and_rank(
        ["a", "b"], timer2, m_per_iteration=6, max_measurements=60, eps=0.001,
        quantile_ranges=FAST_MODE_QUANTILE_RANGES,
        report_range=(15.0, 45.0),
    )
    # default (IQR-centred) view merges; the left-tail view separates
    assert res_fast.ranks["a"] == 1
    assert res_fast.ranks["b"] == 2


# ------------------------------------------------- wall-clock timer batching -

def test_wall_clock_measure_many_batches():
    """One batch = m samples; the calibration pass (which doubles as the
    blocking-contract check) runs once ever, and a sub-floor workload is
    sampled as r inner calls per sample (per-call mean)."""
    from repro.core import WallClockTimer

    calls = {"n": 0}

    def workload():
        calls["n"] += 1
        return 0.0  # plain value: no block_until_ready, trivially blocking

    timer = WallClockTimer({"w": workload})
    values = timer.measure_many("w", 5)
    assert len(values) == 5 and all(v >= 0.0 for v in values)
    r = timer.inner_repeats["w"]
    assert r >= 1  # trivially fast: the min-measurable guard repeats it
    assert calls["n"] == 1 + 5 * r  # one discarded calibration call + 5 loops
    assert timer.measure_many("w", 0) == []
    # the single-measure path goes through the same batch code (and the
    # calibration result is reused, not recomputed)
    assert isinstance(timer.measure("w"), float)
    assert calls["n"] == 1 + 6 * r


def test_wall_clock_rejects_non_blocking_workload():
    """A workload that dispatches async and returns before the result is
    ready must be refused loudly, not silently timed."""
    import time as _time

    from repro.core import WallClockTimer

    class LazyResult:
        def block_until_ready(self):
            _time.sleep(0.005)  # result only materialises when blocked on

    timer = WallClockTimer({"lazy": LazyResult})
    with pytest.raises(RuntimeError, match="not blocking"):
        timer.measure("lazy")


def test_wall_clock_accepts_blocking_workload_with_ready_result():
    """A workload that blocks internally and returns an already-ready
    result (block_until_ready is then ~instant) passes the check."""
    import time as _time

    from repro.core import WallClockTimer

    class ReadyResult:
        def block_until_ready(self):
            return self

    def workload():
        _time.sleep(0.002)  # the actual compute, inside the call
        return ReadyResult()

    timer = WallClockTimer({"ok": workload})
    values = timer.measure_many("ok", 3)
    assert len(values) == 3
    assert all(v >= 0.002 for v in values)
