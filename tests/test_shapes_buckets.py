"""The repo's ONE shape-bucketing rule (repro.configs.shapes).

Contracts under test: power-of-two parity with the census's historical
``size_bucket`` (bit-for-bit — report tables must not move), boundary
determinism (every size has exactly one bucket; boundaries partition
``[1, inf)`` with no gaps or overlaps at any granularity), and jax-free
importability — both consumers (census report tables, oracle cache keys)
live on jax-free paths.
"""

import subprocess
import sys

import pytest

from repro.configs.shapes import bucket_bounds, shape_bucket
from repro.core.sweep import size_bucket


def test_per_octave_1_matches_census_power_of_two_buckets():
    for size in list(range(1, 2050)) + [10**6, 2**20, 2**20 + 1]:
        lo = 1
        while lo * 2 <= size:
            lo *= 2
        assert shape_bucket(size) == f"[{lo}, {lo * 2})"


def test_census_size_bucket_delegates_to_shared_rule():
    for size in (1, 7, 48, 64, 96, 255, 256, 4097):
        assert size_bucket(size) == shape_bucket(size)


@pytest.mark.parametrize("per_octave", [1, 2, 3, 4, 7])
def test_buckets_partition_every_size(per_octave):
    """Each size lands in exactly one bucket, buckets tile contiguously:
    a bucket's hi is the next bucket's lo, nothing is skipped."""
    prev_hi = 1
    size = 1
    while size < 3000:
        lo, hi = bucket_bounds(size, per_octave)
        assert lo <= size < hi
        assert lo == prev_hi  # contiguous: no gap, no overlap
        # every size inside [lo, hi) maps back to the same bucket
        assert bucket_bounds(lo, per_octave) == (lo, hi)
        assert bucket_bounds(hi - 1, per_octave) == (lo, hi)
        prev_hi = hi
        size = hi


@pytest.mark.parametrize("per_octave", [2, 3, 4])
def test_boundary_values_are_deterministic_and_increasing(per_octave):
    """Boundaries are a pure function of (size, per_octave): recomputing
    yields identical bounds, and within an octave they strictly grow."""
    for size in range(1, 1200):
        first = bucket_bounds(size, per_octave)
        assert first == bucket_bounds(size, per_octave)
        lo, hi = first
        assert lo < hi
        octave = 1
        while octave * 2 <= size:
            octave *= 2
        assert octave <= lo and hi <= 2 * octave


def test_finer_buckets_nest_inside_the_octave():
    # per_octave=4 sub-buckets of [256, 512) never cross the octave edge
    seen = set()
    for size in range(256, 512):
        seen.add(bucket_bounds(size, 4))
    assert len(seen) == 4
    assert min(lo for lo, _ in seen) == 256
    assert max(hi for _, hi in seen) == 512


def test_rejects_bad_inputs():
    with pytest.raises(ValueError):
        shape_bucket(0)
    with pytest.raises(ValueError):
        shape_bucket(64, per_octave=0)


def test_bucketing_and_oracle_paths_import_without_jax():
    """The census planner and the serving oracle must not pay the model
    stack's jax import just to bucket a size or answer a cached query."""
    code = (
        "import sys\n"
        "import repro.configs.shapes\n"
        "import repro.serve.cache\n"
        "import repro.serve.oracle\n"
        "assert 'jax' not in sys.modules, 'jax leaked into the hot path'\n"
    )
    subprocess.run([sys.executable, "-c", code], check=True)
