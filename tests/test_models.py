"""Per-arch smoke tests + model-stack invariants (brief deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import (
    ForwardOptions,
    ModelConfig,
    attention_chunked,
    attention_local_chunked,
    attention_reference,
    audio_frame_embeds,
    encdec_decode_step,
    encdec_forward,
    encdec_prefill,
    init_encdec_params,
    init_encdec_state,
    init_lm_params,
    init_lm_state,
    lm_decode_step,
    lm_forward,
    lm_prefill,
    merge_vision_embeds,
    param_counts,
    ssd_chunked,
    ssd_reference,
    training_flops,
    vision_patch_embeds,
)
from repro.models.layers import embed_tokens


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_step(arch):
    """REDUCED config of each family: one forward step, shapes + no NaNs."""
    cfg = get_config(arch, smoke=True)
    cfg.validate()
    key = jax.random.PRNGKey(0)
    b, s = 2, 32
    if cfg.is_encoder_decoder:
        params, _ = init_encdec_params(cfg, key)
        enc = audio_frame_embeds(cfg, b, cfg.encoder_seq)
        dec = jax.random.randint(jax.random.PRNGKey(1), (b, 16), 0, cfg.vocab_size)
        logits, aux = encdec_forward(cfg, params, enc, dec)
        assert logits.shape == (b, 16, cfg.vocab_size)
    elif cfg.frontend == "vision_stub":
        params, _ = init_lm_params(cfg, key)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (b, 64), 0, cfg.vocab_size)
        te = embed_tokens(cfg, params["embed"], tokens)
        embeds = merge_vision_embeds(cfg, te, vision_patch_embeds(cfg, b, 16))
        logits, aux = lm_forward(cfg, params, embeds=embeds)
        assert logits.shape == (b, 64, cfg.vocab_size)
    else:
        params, _ = init_lm_params(cfg, key)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
        logits, aux = lm_forward(cfg, params, tokens=tokens)
        assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaN logits"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_grad_step(arch):
    """One loss+grad step per reduced config: finite loss, finite grads."""
    from repro.train.trainer import LossConfig, make_loss_fn

    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    b, s = 2, 16
    if cfg.is_encoder_decoder:
        params, _ = init_encdec_params(cfg, key)
        batch = {
            "enc_embeds": audio_frame_embeds(cfg, b, cfg.encoder_seq),
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab_size),
        }
    elif cfg.frontend == "vision_stub":
        params, _ = init_lm_params(cfg, key)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
        te = embed_tokens(cfg, params["embed"], tokens)
        batch = {
            "embeds": merge_vision_embeds(cfg, te, vision_patch_embeds(cfg, b, 8)),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab_size),
        }
    else:
        params, _ = init_lm_params(cfg, key)
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab_size),
        }
    loss_fn = make_loss_fn(cfg, ForwardOptions(attn_impl="reference"), LossConfig())
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0.0


@pytest.mark.parametrize(
    "arch", [a for a in ARCH_NAMES if a != "whisper-tiny"]
)
def test_smoke_decode_consistency(arch):
    """prefill + decode logits == full-forward logits (per family)."""
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    b, s = 2, 24
    params, _ = init_lm_params(cfg, key)
    if cfg.frontend == "vision_stub":
        tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
        embeds = embed_tokens(cfg, params["embed"], tokens)
        logits, _ = lm_forward(cfg, params, embeds=embeds)
        state = init_lm_state(cfg, b, s + 8)
        _, state = lm_prefill(cfg, params, state, embeds=embeds[:, : s - 1])
    else:
        tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
        logits, _ = lm_forward(cfg, params, tokens=tokens)
        state = init_lm_state(cfg, b, s + 8)
        _, state = lm_prefill(cfg, params, state, tokens=tokens[:, : s - 1])
    lg, state = lm_decode_step(cfg, params, state, tokens[:, s - 1 : s], jnp.int32(s - 1))
    ref = logits[:, s - 1, :]
    err = float(jnp.max(jnp.abs(lg - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert err < 5e-2, f"{arch}: decode relerr {err}"


def test_whisper_decode_consistency():
    cfg = get_config("whisper-tiny", smoke=True)
    params, _ = init_encdec_params(cfg, jax.random.PRNGKey(0))
    b = 2
    enc = audio_frame_embeds(cfg, b, cfg.encoder_seq)
    dec = jax.random.randint(jax.random.PRNGKey(2), (b, 8), 0, cfg.vocab_size)
    logits, _ = encdec_forward(cfg, params, enc, dec)
    st = init_encdec_state(cfg, b, 16, cfg.encoder_seq)
    st = encdec_prefill(cfg, params, st, enc)
    for t in range(4):
        lg, st = encdec_decode_step(cfg, params, st, dec[:, t : t + 1], jnp.int32(t))
    ref = logits[:, 3, :]
    err = float(jnp.max(jnp.abs(lg - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert err < 5e-2


# ----------------------------------------------------- attention variants --

def _qkv(b=2, s=128, h=4, kv=2, d=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    return q, k, v


def test_attention_variants_agree():
    """grouped == broadcast == chunked (mathematically equivalent)."""
    q, k, v = _qkv()
    ref_g = attention_reference(q, k, v, gqa="grouped")
    ref_b = attention_reference(q, k, v, gqa="broadcast")
    chk = attention_chunked(q, k, v, q_block=32, kv_block=64)
    np.testing.assert_allclose(np.asarray(ref_b), np.asarray(ref_g), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(chk), np.asarray(ref_g), rtol=2e-4, atol=2e-4)


def test_local_chunked_matches_masked_reference():
    q, k, v = _qkv(s=256)
    window = 48
    ref = attention_reference(q, k, v, window=window)
    loc = attention_local_chunked(q, k, v, window=window, q_block=32)
    np.testing.assert_allclose(np.asarray(loc), np.asarray(ref), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("qb,kb", [(16, 32), (32, 32), (64, 128)])
def test_chunked_blocksizes_equivalent(qb, kb):
    q, k, v = _qkv(s=128)
    ref = attention_reference(q, k, v)
    out = attention_chunked(q, k, v, q_block=qb, kv_block=kb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_sliding_window_decode_ring_buffer():
    """Windowed decode with a ring cache == full-cache windowed decode."""
    cfg = ModelConfig(
        name="ring", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
        vocab_size=128, sliding_window=8, dtype="float32", param_dtype="float32",
    )
    params, _ = init_lm_params(cfg, jax.random.PRNGKey(0))
    b, s = 1, 30
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, 128)
    logits, _ = lm_forward(cfg, params, tokens=tokens, opts=ForwardOptions(attn_impl="reference"))
    # ring cache is rounded up to >= window+1: force tiny max_len anyway
    state = init_lm_state(cfg, b, max_len=s + 2)
    _, state = lm_prefill(cfg, params, state, tokens=tokens[:, : s - 1])
    lg, _ = lm_decode_step(cfg, params, state, tokens[:, s - 1 : s], jnp.int32(s - 1))
    err = float(jnp.max(jnp.abs(lg - logits[:, s - 1]))) / float(jnp.max(jnp.abs(logits[:, s - 1])))
    assert err < 5e-2, err


# --------------------------------------------------------------- SSD -------

@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunked_equals_sequential(chunk):
    b, s, h, p, n = 2, 64, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bm = jax.random.normal(ks[3], (b, s, 1, n))
    cm = jax.random.normal(ks[4], (b, s, 1, n))
    y_ref, st_ref = ssd_reference(x, dt, a_log, bm, cm)
    y, st = ssd_chunked(x, dt, a_log, bm, cm, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref), rtol=3e-4, atol=3e-4)


def test_ssd_state_carry_composes():
    """Running two halves with carried state == one full run."""
    b, s, h, p, n = 1, 32, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bm = jax.random.normal(ks[3], (b, s, 1, n))
    cm = jax.random.normal(ks[4], (b, s, 1, n))
    y_full, st_full = ssd_reference(x, dt, a_log, bm, cm)
    y1, st1 = ssd_reference(x[:, :16], dt[:, :16], a_log, bm[:, :16], cm[:, :16])
    y2, st2 = ssd_reference(
        x[:, 16:], dt[:, 16:], a_log, bm[:, 16:], cm[:, 16:], init_state=st1
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], axis=1)), np.asarray(y_full),
        rtol=2e-4, atol=2e-4,
    )
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full), rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------- flops -------

def test_param_counts_match_actual_tree():
    for arch in ("granite-8b", "qwen2-moe-a2.7b", "mamba2-1.3b"):
        cfg = get_config(arch, smoke=True)
        params, _ = init_lm_params(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        analytic = param_counts(cfg).total
        # analytic skips norm scales — must agree within 1.5%
        assert abs(actual - analytic) / actual < 0.015, (arch, actual, analytic)


def test_training_flops_scale_linearly_in_tokens():
    cfg = get_config("granite-8b", smoke=False)
    f1 = training_flops(cfg, 8, 1024)
    f2 = training_flops(cfg, 16, 1024)
    assert abs(f2 / f1 - 2.0) < 1e-6
