"""Property-based tests for repro.core (require ``hypothesis``).

Kept separate from test_core.py so the example-based tier-1 suite collects
and runs on environments without hypothesis installed.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core import Outcome, compare_measurements, sort_by_measurements


@given(
    st.lists(st.floats(0.1, 10.0), min_size=1, max_size=40),
    st.lists(st.floats(0.1, 10.0), min_size=1, max_size=40),
)
@settings(max_examples=60, deadline=None)
def test_comparison_antisymmetric(a, b):
    """Property: cmp(a, b) is the flip of cmp(b, a)."""
    ab = compare_measurements(a, b, 25, 75)
    ba = compare_measurements(b, a, 25, 75)
    assert ab is ba.flipped()


@given(st.lists(st.floats(0.1, 10.0), min_size=1, max_size=40))
@settings(max_examples=30, deadline=None)
def test_comparison_reflexive_equivalent(a):
    assert compare_measurements(a, a, 25, 75) is Outcome.EQUIVALENT


@given(
    st.lists(st.floats(0.5, 5.0), min_size=2, max_size=8),
    st.floats(0.0, 0.3),
)
@settings(max_examples=40, deadline=None)
def test_sort_rank_invariants(base_times, spread):
    """Property: ranks start at 1, are non-decreasing along the sequence,
    and adjacent ranks differ by at most 1 — for arbitrary measurement
    tables."""
    rng = np.random.default_rng(42)
    meas = {
        f"a{i}": rng.normal(t, max(spread * t, 1e-6), 12).clip(1e-3).tolist()
        for i, t in enumerate(base_times)
    }
    names, ranks = sort_by_measurements(sorted(meas), meas, (25, 75))
    assert ranks[0] == 1
    for r0, r1 in zip(ranks, ranks[1:]):
        assert r0 <= r1 <= r0 + 1
    assert sorted(names) == sorted(meas)
