"""Golden equality: the vectorized analysis core vs the legacy pairwise path.

The tentpole contract — the batched QuantileTable path must be
*bit-identical* to the paper-literal pairwise evaluation: same
RankingResult (order, ranks, mean ranks, history), same serialized session
JSON, kill/resume preserved. Sessions differ ONLY in ``vectorized``; both
see the same timer seed, so any divergence is an analysis-path bug.
"""

import json

import numpy as np
import pytest

from repro.core import (
    FAST_MODE_QUANTILE_RANGES,
    CostModelTimer,
    ExperimentEngine,
    MeasurementSession,
    NoiseProfile,
    SimulatedTimer,
    mean_ranks,
)


def _lognormal_timer(seed=5):
    profiles = {
        f"a{i}": NoiseProfile(base=1.0 + 0.04 * i, rel_sigma=0.05)
        for i in range(6)
    }
    return sorted(profiles), SimulatedTimer(profiles, seed=seed)


def _bimodal_timer(seed=11):
    profiles = {
        "a": NoiseProfile(base=1.0, rel_sigma=0.01, bimodal_shift=1.0,
                          bimodal_prob=0.5),
        "b": NoiseProfile(base=1.25, rel_sigma=0.01, bimodal_shift=0.6,
                          bimodal_prob=0.5),
        "c": NoiseProfile(base=1.05, rel_sigma=0.01, bimodal_shift=0.9,
                          bimodal_prob=0.5, outlier_prob=0.05),
    }
    return sorted(profiles), SimulatedTimer(profiles, seed=seed)


def _costmodel_timer(seed=2):
    costs = {f"v{i}": 1.0 + 0.1 * (i % 5) + 0.01 * i for i in range(24)}
    return sorted(costs), CostModelTimer(costs, rel_sigma=0.08, seed=seed)


INSTANCES = {
    "lognormal_p6": (_lognormal_timer, {}),
    "bimodal_fastmode_p3": (
        _bimodal_timer,
        {"quantile_ranges": FAST_MODE_QUANTILE_RANGES,
         "report_range": (15.0, 45.0)},
    ),
    "costmodel_p24": (_costmodel_timer, {"eps": 0.01}),
}


def _run(make, extra, vectorized, steps=None):
    order, timer = make()
    kwargs = {"m_per_iteration": 3, "eps": 0.02, "max_measurements": 24, **extra}
    session = MeasurementSession(
        "golden", order, timer, vectorized=vectorized, **kwargs,
    )
    if steps is None:
        while not session.done:
            session.step()
    else:
        for _ in range(steps):
            session.step()
    return session


@pytest.mark.parametrize("instance", sorted(INSTANCES))
def test_vectorized_path_bit_identical_to_legacy(instance):
    """Order, ranks, mean ranks, convergence history AND the full serialized
    session JSON agree between the two analysis paths, per instance."""
    make, extra = INSTANCES[instance]
    fast = _run(make, extra, vectorized=True)
    legacy = _run(make, extra, vectorized=False)
    assert fast.history == legacy.history
    assert fast.result() == legacy.result()
    assert json.dumps(fast.to_dict(), sort_keys=True) == \
        json.dumps(legacy.to_dict(), sort_keys=True)


def test_vectorized_kill_resume_campaign_matches_legacy_uninterrupted():
    """A vectorized campaign killed mid-flight, persisted through real JSON
    and resumed must equal the legacy path's uninterrupted run — the
    acceptance path for 'kill/resume preserved'."""
    make, extra = INSTANCES["lognormal_p6"]
    legacy = _run(make, extra, vectorized=False)

    killed = _run(make, extra, vectorized=True, steps=2)
    blob = json.dumps(killed.to_dict())
    resumed = MeasurementSession.from_dict(json.loads(blob), vectorized=True)
    while not resumed.done:
        resumed.step()

    assert resumed.result() == legacy.result()
    assert json.dumps(resumed.to_dict(), sort_keys=True) == \
        json.dumps(legacy.to_dict(), sort_keys=True)


def test_engine_campaign_vectorized_vs_legacy_sessions():
    """Interleaved campaign golden check: the same three sessions stepped by
    the same scheduler produce identical engine state either way (the table
    is cached per session across interleaved steps — store versioning must
    keep it honest)."""

    def build(vectorized):
        engine = ExperimentEngine(policy="least_converged_first")
        for name, (make, extra) in sorted(INSTANCES.items()):
            order, timer = make()
            kwargs = {"m_per_iteration": 3, "eps": 0.02,
                      "max_measurements": 18, **extra}
            engine.add_session(MeasurementSession(
                name, order, timer, vectorized=vectorized, **kwargs,
            ))
        engine.run()
        return engine

    fast, legacy = build(True), build(False)
    assert json.dumps(fast.to_dict(), sort_keys=True) == \
        json.dumps(legacy.to_dict(), sort_keys=True)
    for name, res in fast.results().items():
        assert res == legacy.results()[name]


def test_mean_ranks_table_path_with_offladder_report_range():
    """mean_ranks equality when report_range is NOT in the ladder (the
    re-added per_range entry must exist and agree between paths), plus the
    reuse fix: the report table IS the ladder entry when it is a member."""
    from repro.core import MeasurementStore, QuantileTable

    rng = np.random.default_rng(3)
    meas = {f"m{i}": rng.normal(1.0 + 0.2 * i, 0.1, 15).tolist() for i in range(5)}
    store = MeasurementStore()
    for k, v in meas.items():
        store.add(k, v)

    ladder = ((5.0, 95.0), (25.0, 75.0), (35.0, 65.0))
    for report in ((25.0, 75.0), (10.0, 90.0)):  # in-ladder and off-ladder
        table = QuantileTable.from_ranges(store, (*ladder, report))
        fast = mean_ranks(sorted(meas), None, quantile_ranges=ladder,
                          report_range=report, table=table)
        legacy = mean_ranks(sorted(meas), meas, quantile_ranges=ladder,
                            report_range=report, memoize=False)
        assert fast.order == legacy.order
        assert fast.ranks == legacy.ranks
        assert fast.mean_ranks == legacy.mean_ranks
        assert fast.per_range == legacy.per_range
        assert report in fast.per_range  # the docstring's promise, now kept
        assert dict(zip(fast.order, fast.ranks)) == fast.per_range[report]
        # means average the ladder only, never the off-ladder report range
        assert fast.mean_ranks == {
            n: sum(fast.per_range[q][n] for q in ladder) / len(ladder)
            for n in meas
        }
