"""Int8 KV-cache: round-trip bounds + decode-attention error bounds."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.models.attention import decode_attention, init_kv_cache, update_kv_cache
from repro.serve.quant import (
    dequantize_kv,
    init_quant_kv_cache,
    quant_decode_attention,
    quantize_kv,
    update_quant_kv_cache,
)


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_quantize_roundtrip_bound(seed):
    k = jax.random.normal(jax.random.PRNGKey(seed), (2, 8, 2, 16))
    q8, s = quantize_kv(k)
    rec = dequantize_kv(q8, s, jnp.float32)
    amax = np.abs(np.asarray(k)).max(axis=-1, keepdims=True)
    err = np.abs(np.asarray(rec) - np.asarray(k))
    assert (err <= amax / 127.0 + 1e-6).all()


def test_quant_decode_attention_close_to_fp():
    b, S, K, H, hd = 2, 64, 2, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, 1, H, hd))
    cache_fp = init_kv_cache(b, S, K, hd, jnp.float32)
    cache_q8 = init_quant_kv_cache(b, S, K, hd)
    # fill 40 positions
    k_new = jax.random.normal(ks[1], (b, 40, K, hd))
    v_new = jax.random.normal(ks[2], (b, 40, K, hd))
    cache_fp = update_kv_cache(cache_fp, k_new, v_new, jnp.int32(0))
    cache_q8 = update_quant_kv_cache(cache_q8, k_new, v_new, jnp.int32(0))

    out_fp = decode_attention(q, cache_fp["k"], cache_fp["v"], jnp.int32(40))
    out_q8 = quant_decode_attention(q, cache_q8, jnp.int32(40))
    rel = float(
        jnp.max(jnp.abs(out_q8 - out_fp)) / (jnp.max(jnp.abs(out_fp)) + 1e-9)
    )
    assert rel < 0.05, rel  # int8 cache stays within 5% on attention output


def test_quant_cache_halves_bytes():
    b, S, K, hd = 1, 128, 2, 64
    fp = init_kv_cache(b, S, K, hd, jnp.bfloat16)
    q8 = init_quant_kv_cache(b, S, K, hd)
    fp_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(fp))
    q8_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(q8))
    assert q8_bytes < 0.6 * fp_bytes
