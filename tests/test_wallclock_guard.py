"""WallClockTimer minimum-measurable-time guard: sub-dispatch-cost
workloads get an automatic inner-repeat loop (mean per-call time), slow
workloads stay single-call, and wall-clock census records surface the
chosen counts."""

import time

import pytest

from repro.core.measure import WallClockTimer


def test_fast_workload_gets_inner_repeats():
    timer = WallClockTimer({"fast": lambda: None}, check_blocking=False,
                           min_time_s=1e-3)
    samples = timer.measure_many("fast", 3)
    assert len(samples) == 3
    r = timer.inner_repeats["fast"]
    assert r > 1
    # per-call means: orders of magnitude under the floor even repeated
    assert all(0.0 <= s < 1e-3 for s in samples)


def test_slow_workload_stays_single_call():
    timer = WallClockTimer({"slow": lambda: time.sleep(2e-3)},
                           check_blocking=False, min_time_s=1e-3)
    s = timer.measure("slow")
    assert timer.inner_repeats["slow"] == 1
    assert s >= 2e-3


def test_guard_disabled_with_zero_floor():
    timer = WallClockTimer({"fast": lambda: None}, check_blocking=False,
                           min_time_s=0.0)
    timer.measure("fast")
    assert timer.inner_repeats["fast"] == 1


def test_repeat_count_is_capped():
    timer = WallClockTimer({"fast": lambda: None}, check_blocking=False,
                           min_time_s=10.0)  # absurd floor
    timer.measure("fast")
    assert timer.inner_repeats["fast"] == WallClockTimer.MAX_INNER_REPEATS


def test_calibration_happens_once():
    calls = []
    timer = WallClockTimer({"w": lambda: calls.append(1)},
                           check_blocking=False, min_time_s=0.0)
    timer.measure_many("w", 2)
    n_after_first = len(calls)
    timer.measure_many("w", 2)
    # second batch: exactly 2 calls, no re-calibration
    assert len(calls) == n_after_first + 2


def test_blocking_check_still_enforced():
    class FakeAsync:
        def block_until_ready(self):
            time.sleep(2e-3)

    timer = WallClockTimer({"async": FakeAsync})
    with pytest.raises(RuntimeError, match="not blocking"):
        timer.measure("async")


def test_wall_clock_census_record_surfaces_inner_repeats():
    """End to end through the sweep layer: a wall_clock census record on a
    sub-floor workload family carries the chosen counts (and deterministic
    backends never grow the field)."""
    from repro.core.sweep import SweepSpec, build_sweep_session, record_from_session

    spec = SweepSpec(
        name="wc", backend="wall_clock", n_shards=1, max_measurements=6,
        families={"bilinear": {"sizes": [8], "per_size": 1}},
    )
    inst = spec.expand()[0]
    session = build_sweep_session(spec, inst)
    while session.step():
        pass
    record = record_from_session(session, spec)
    assert "inner_repeats" in record
    assert set(record["inner_repeats"]) == set(record["flops"])
    assert all(r >= 1 for r in record["inner_repeats"].values())
    # the deterministic backends must NOT carry the field (byte-identity)
    det = SweepSpec(
        name="wc", backend="cost_model", n_shards=1, max_measurements=6,
        families={"bilinear": {"sizes": [8], "per_size": 1}},
    )
    session = build_sweep_session(det, det.expand()[0])
    while session.step():
        pass
    assert "inner_repeats" not in record_from_session(session, det)
