"""Learned cost model + active census: feature exactness, serialization
drift, the confidence gate's acceptance numbers, and kill/resume."""

import json
import math
import os

import pytest

from repro.api import predict_ranks, run_census, train_predictor
from repro.core.sweep import (
    SweepSpec,
    census_summary,
    merge_shards,
    run_shard,
    sweep_progress,
    synthetic_instance_model,
)
from repro.explain.decompose import kernels_from_record
from repro.predict.active import (
    PREDICT_REL_TOL,
    ActivePredictor,
    prediction_errors,
)
from repro.predict.features import (
    FEATURE_NAMES,
    census_machine,
    kernel_features,
    training_rows,
)
from repro.predict.model import ModelDrift, RidgeModel, train_model

#: weighted toward families whose algorithms are separated by real FLOP
#: gaps (solve/distributive skip confidently) with a slice of the
#: equal-FLOPs regime (bilinear/chain) that must STAY measured — this is
#: what buys the >=5x acceptance without losing a single anomaly
ACCEPT_FAMILIES = {
    "solve": {"sizes": [16, 32, 64, 128], "per_size": 5},
    "distributive": {"sizes": [16, 32, 64, 128], "per_size": 5},
    "bilinear": {"sizes": [16, 32], "per_size": 1},
    "chain": {"count": 4, "n_matrices": [3], "lo": 24, "hi": 96},
}


def _spec(**overrides):
    kwargs = dict(
        name="acc",
        families=ACCEPT_FAMILIES,
        n_shards=2,
        backend="cost_model",
        max_measurements=9,
        chunk_size=4,
        save_every=8,
    )
    kwargs.update(overrides)
    return SweepSpec(**kwargs)


@pytest.fixture(scope="module")
def census(tmp_path_factory):
    """(spec, root, records, model_path): one full measured census plus
    the model trained from it, shared by the read-only tests."""
    root = str(tmp_path_factory.mktemp("census"))
    spec = _spec()
    run_census(root, spec)
    records = merge_shards(spec, root)
    model_path = train_predictor(root, os.path.join(root, "model.json"))
    return spec, root, records, model_path


# ---------------------------------------------------------------- features ---

def test_kernel_features_match_decompose_and_roofline(census):
    """Every vector slot is EXACTLY the decompose/roofline quantity it is
    named for — no approximation is allowed to creep into the features."""
    spec, _, records, _ = census
    _, machine = census_machine(spec)
    rec = next(r for r in records if r["family"] == "solve")
    for alg, kernels in kernels_from_record(rec).items():
        vec = dict(zip(FEATURE_NAMES, kernel_features(
            kernels, machine, spec.dispatch_s)))
        flops = sum(k.flops for k in kernels)
        nbytes = sum(k.bytes for k in kernels)
        assert vec["log10_flops"] == math.log10(flops)
        assert vec["log10_bytes"] == math.log10(nbytes)
        assert vec["log10_intensity"] == math.log10(flops / nbytes)
        assert vec["kernel_count"] == float(len(kernels))
        assert vec["log10_max_kernel_flops"] == math.log10(
            max(k.flops for k in kernels))
        assert vec["log10_t_compute"] == math.log10(machine.t_compute(flops))
        t_mem = machine.t_memory(nbytes)
        assert vec["log10_t_memory"] == math.log10(max(t_mem, 1e-30))
        dispatch = (machine.dispatch_overhead_s + spec.dispatch_s) * len(kernels)
        assert vec["log10_t_roofline"] == math.log10(
            max(machine.t_compute(flops), t_mem) + dispatch)


def test_training_targets_are_reconstructed_truth(census):
    """Targets come from the census's own deterministic rebuild pointers
    (synthetic_instance_model), bit-exactly, one row per (uid, alg)."""
    spec, _, records, _ = census
    X, y, keys, n_skipped = training_rows(spec, records)
    assert n_skipped == 0
    assert len(X) == len(y) == len(keys)
    truth = {}
    for rec in records:
        model = synthetic_instance_model(
            spec, int(rec["index"]),
            {k: float(v) for k, v in rec["flops"].items()},
            {a: len(ks) for a, ks in rec["kernels"].items()},
            base_seed=rec.get("base_seed"),
        )
        for alg, cost in model.costs.items():
            truth[(rec["uid"], alg)] = math.log10(cost)
    assert set(keys) == set(truth)
    for key, target in zip(keys, y):
        assert target == truth[key]


def test_wall_clock_census_is_not_trainable():
    spec = _spec(backend="wall_clock")
    with pytest.raises(ValueError, match="wall-clock"):
        train_model(spec, [{"uid": "x"}])


# ----------------------------------------------------------- serialization ---

def test_train_serialize_load_round_trip(census, tmp_path):
    spec, _, records, _ = census
    model = train_model(spec, records)
    path = model.save(str(tmp_path / "m.json"))
    loaded = RidgeModel.load(path)
    assert loaded.to_dict() == model.to_dict()
    assert loaded.train_digest == model.train_digest
    vec = [1.0] * len(FEATURE_NAMES)
    assert loaded.predict_one(vec) == model.predict_one(vec)


def test_load_rejects_tampered_payload(census, tmp_path):
    """Any byte-level edit to the saved model fails its own checksum."""
    _, _, _, model_path = census
    d = json.load(open(model_path))
    d["coef"][0] += 0.25
    path = str(tmp_path / "tampered.json")
    json.dump(d, open(path, "w"))
    with pytest.raises(ModelDrift, match="checksum"):
        RidgeModel.load(path)


def test_load_rejects_feature_schema_drift(census, tmp_path):
    """A model serialized under a different feature layout must refuse to
    load even when its payload is internally consistent."""
    import zlib

    _, _, _, model_path = census
    d = json.load(open(model_path))
    d["feature_names"][0] = "log10_flops_v2"
    body = {k: v for k, v in d.items() if k != "_crc"}
    d["_crc"] = format(zlib.crc32(
        json.dumps(body, sort_keys=True, separators=(",", ":"))
        .encode("utf-8")) & 0xFFFFFFFF, "08x")
    path = str(tmp_path / "drifted.json")
    json.dump(d, open(path, "w"))
    with pytest.raises(ModelDrift, match="feature schema"):
        RidgeModel.load(path)


def test_predictor_rejects_machine_mismatch(census):
    """The active gate never applies a model across machines: the census's
    resolved machine label must equal the one the model embeds."""
    spec, _, _, model_path = census
    other = _spec(name="other")  # deterministic machine label sweep:other
    with pytest.raises(ModelDrift, match="machine"):
        ActivePredictor.open(model_path, other)
    ActivePredictor.open(model_path, spec)  # matching label loads fine


# ------------------------------------------------------------ active census ---

def test_active_census_throughput_and_anomaly_recall(census, tmp_path):
    """The ISSUE acceptance: on the deterministic backend the active
    census covers the same grid with >=5x fewer measured instances AND
    finds the exact same anomaly set as the full census."""
    spec, _, full_records, model_path = census
    aspec = _spec(predictor_model=model_path, predict_threshold=0.95)
    root = str(tmp_path / "active")
    run_census(root, aspec)
    records = merge_shards(aspec, root)
    assert [r["uid"] for r in records] == [r["uid"] for r in full_records]

    predicted = [r for r in records if r.get("provenance") == "predicted"]
    measured = [r for r in records if r.get("provenance") != "predicted"]
    assert len(records) / len(measured) >= 5.0

    full_anomalies = sorted(r["uid"] for r in full_records if r["is_anomaly"])
    active_anomalies = sorted(r["uid"] for r in records if r["is_anomaly"])
    assert full_anomalies and active_anomalies == full_anomalies
    # the equal-FLOPs regime the anomalies live in stayed measured
    assert all(r.get("provenance") != "predicted"
               for r in records if r["family"] == "bilinear")

    # predicted records carry the provenance contract, not fake counts
    for rec in predicted:
        assert rec["measurements_per_alg"] == 0 and rec["iterations"] == 0
        assert 0.95 <= rec["predicted"]["confidence"] <= 1.0

    # the skip fraction is surfaced, never silent: progress, summary, report
    prog = sweep_progress(aspec, root)
    assert prog["predicted"] == len(predicted) > 0
    summary = census_summary(records)
    assert summary["total"]["predicted"] == len(predicted)

    from repro.launch.report_md import census_tables

    md = census_tables(records, name="acc")
    assert "predicted without measurement" in md
    assert f"{len(predicted)}/{len(records)}" in md


def test_active_census_resume_is_byte_identical(census, tmp_path):
    """Predicted records are pure functions of (spec, model, instance):
    an interrupted active census resumes to the same bytes."""
    spec, _, _, model_path = census
    aspec = _spec(predictor_model=model_path, predict_threshold=0.95)
    straight, chopped = str(tmp_path / "a"), str(tmp_path / "b")
    run_shard(aspec, straight, 0)
    for _ in range(100):
        run_shard(aspec, chopped, 0, max_steps=3)
        manifest = os.path.join(chopped, "shard-0000.manifest.json")
        if (os.path.exists(manifest)
                and json.load(open(manifest)).get("done")):
            break
    else:
        pytest.fail("shard did not finish in 100 slices")
    assert (open(os.path.join(chopped, "shard-0000.jsonl")).read()
            == open(os.path.join(straight, "shard-0000.jsonl")).read())


# ------------------------------------------------------------- evaluation ---

def test_prediction_errors_score_against_ground_truth(census):
    spec, root, records, model_path = census
    rows = prediction_errors(spec, records, RidgeModel.load(model_path))
    assert len(rows) == len(records)
    for row in rows:
        assert row["abs_dlog10_t"] is not None
        assert 0.0 <= row["flip_prob"] <= 1.0
    # the model must at least agree with the census on most verdicts
    match = sum(1 for r in rows if r["anomaly_match"]) / len(rows)
    assert match >= 0.9

    from repro.launch.report_md import predict_tables

    md = predict_tables(rows, name="acc")
    assert "| family |" in md and "would skip" in md


def test_predict_ranks_facade_subset(census):
    spec, root, _, model_path = census
    uids = [i.uid for i in spec.expand()][:3]
    preds = predict_ranks(model_path, root, uids=uids)
    assert [p.uid for p in preds] == uids
    for p in preds:
        assert p.confidence == 1.0 - p.flip_prob
        assert set(p.ranks) == set(p.times)
        assert min(p.ranks.values()) == 1
