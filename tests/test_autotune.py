"""Autotuner: the paper's pipeline as the framework's variant selector."""

import numpy as np
import pytest

from repro.autotune import (
    TuneReport,
    moe_dispatch_site,
    rank_site,
    rank_site_costmodel,
    ssd_chunk_site,
)
from repro.core import CostModelTimer


def test_moe_dispatch_site_selects_gather():
    rep = rank_site(
        moe_dispatch_site(tokens=512, d=64, e=8, top_k=2, d_ff=32),
        max_measurements=12,
    )
    assert rep.selected == "gather"
    ranks = rep.ranking.ranks
    if "dense" in ranks:  # dense may be dropped by the RT pre-filter
        assert ranks["gather"] <= ranks["dense"]
    else:
        assert "dense" in rep.dropped


def test_variants_compute_identical_outputs():
    """The site's variants must be mathematically equivalent."""
    import jax

    site = moe_dispatch_site(tokens=128, d=32, e=4, top_k=2, d_ff=16)
    arrays = site.make_inputs(0)
    outs = {v.name: np.asarray(v.build(*arrays)()) for v in site.variants}
    # gather drops overflow tokens; with capacity_factor they agree closely
    diff = np.abs(outs["gather"] - outs["dense"])
    agree = (diff < 1e-3).mean()
    assert agree > 0.9, f"only {agree:.2%} of outputs agree"


def test_costmodel_ranking_deterministic_and_selected():
    costs = {"a": 1.0, "b": 1.0, "c": 2.0}
    flops = {"a": 10.0, "b": 20.0, "c": 5.0}
    rep = rank_site_costmodel("site", costs, flops, max_measurements=8)
    # a and b tie on cost -> same class; min-FLOPs member selected
    assert rep.ranking.ranks["a"] == rep.ranking.ranks["b"] == 1
    assert rep.selected == "a"
    # c has min FLOPs but is slower -> anomaly condition 1
    assert rep.discriminant.is_anomaly
    assert rep.discriminant.reason == "faster_outside_min_flops"


def test_summary_renders():
    rep = rank_site_costmodel("s", {"x": 1.0, "y": 2.0}, {"x": 1.0, "y": 2.0})
    text = rep.summary()
    assert "rank 1" in text and "x" in text
