"""Pull-based work queue: filesystem leases, multi-host drains, takeover.

The contract under test is the ISSUE's acceptance scenario: any number of
hosts lease shards of one shared store, a SIGKILLed host's shard is
adopted after its lease TTL expires, and the merged census stays
byte-identical to an uninterrupted 1-host run — because a lease takeover
is literally the kill/resume path.
"""

import json
import os
import signal
import subprocess
import sys
import time

import pytest

from repro.core.lease import (
    Lease,
    LeaseInfo,
    LeaseLost,
    acquire_lease,
    read_lease,
)
from repro.core.sweep import ShardStore, SweepSpec, run_shard, write_merged
from repro.launch.queue import SweepQueue, _shard_done, drain, open_queue

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    for var in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS"):
        env.setdefault(var, "1")
    return env


# ----------------------------------------------------------------- leases ---

def test_acquire_is_exclusive_and_released(tmp_path):
    path = str(tmp_path / "s.lease.json")
    a = acquire_lease(path, "a:1:x")
    assert isinstance(a, Lease)
    assert read_lease(path).owner == "a:1:x"
    # a live lease blocks every other acquirer
    assert acquire_lease(path, "b:2:y") is None
    a.release()
    assert read_lease(path) is None
    b = acquire_lease(path, "b:2:y")
    assert b is not None and read_lease(path).owner == "b:2:y"


def test_heartbeat_is_rate_limited_and_refreshes(tmp_path):
    path = str(tmp_path / "s.lease.json")
    lease = acquire_lease(path, "a:1:x", interval=3600.0)
    first = read_lease(path).heartbeat_at
    lease.heartbeat()            # within interval: no rewrite
    assert read_lease(path).heartbeat_at == first
    time.sleep(0.01)
    lease.heartbeat(force=True)  # forced: rewrites now
    assert read_lease(path).heartbeat_at > first


def test_expired_lease_is_broken_and_adopted(tmp_path):
    path = str(tmp_path / "s.lease.json")
    dead = acquire_lease(path, "dead:1:x", ttl=0.05)
    assert dead is not None
    time.sleep(0.1)
    taker = acquire_lease(path, "taker:2:y", ttl=30.0)
    assert taker is not None
    assert read_lease(path).owner == "taker:2:y"
    # the dead owner finds out at its next heartbeat and must stop
    with pytest.raises(LeaseLost):
        dead.heartbeat(force=True)
    # ... and its release must not clobber the new owner's lease
    dead.release()
    assert read_lease(path).owner == "taker:2:y"


def test_torn_lease_file_reads_as_none(tmp_path):
    path = str(tmp_path / "s.lease.json")
    with open(path, "w") as fh:
        fh.write('{"owner": "half')
    assert read_lease(path) is None


def test_lease_info_expiry_math():
    info = LeaseInfo(owner="o", acquired_at=100.0, heartbeat_at=100.0,
                     ttl=30.0)
    assert not info.expired(now=120.0)
    assert info.expired(now=131.0)
    assert info.age(now=110.0) == 10.0


# ------------------------------------------------------- in-process drains ---

def _plan(root, **overrides):
    kwargs = dict(
        name="t",
        families={
            "chain": {"count": 6, "n_matrices": [3, 4], "lo": 24, "hi": 96},
            "bilinear": {"sizes": [32, 64], "per_size": 2},
        },
        n_shards=3,
        backend="cost_model",
        max_measurements=9,
        chunk_size=2,
        save_every=4,
    )
    kwargs.update(overrides)
    spec = SweepSpec(**kwargs)
    spec.save(os.path.join(root, "spec.json"))
    return spec


def test_single_owner_drain_matches_direct_run(tmp_path):
    straight, queued = str(tmp_path / "a"), str(tmp_path / "b")
    os.makedirs(straight), os.makedirs(queued)
    spec = _plan(straight)
    for s in range(spec.n_shards):
        run_shard(spec, straight, s)
    write_merged(spec, straight)

    _plan(queued)
    queue = open_queue(queued)
    assert isinstance(queue, SweepQueue)
    assert drain(queue, "host:1:a", poll=0.01) is True
    queue.merge()
    assert (open(os.path.join(queued, "merged.jsonl")).read()
            == open(os.path.join(straight, "merged.jsonl")).read())
    # every lease was released on the way out
    assert not [f for f in os.listdir(queued) if "lease" in f]


def test_two_owners_interleaved_passes_drain_byte_identically(tmp_path):
    """Two hosts alternating single-pass drains (max_steps pauses shards
    mid-chunk) must converge on the same bytes as one uninterrupted host —
    every handoff exercises the lease-then-resume path."""
    straight, queued = str(tmp_path / "a"), str(tmp_path / "b")
    os.makedirs(straight), os.makedirs(queued)
    spec = _plan(straight)
    for s in range(spec.n_shards):
        run_shard(spec, straight, s)

    _plan(queued)
    queue = open_queue(queued)
    owners = ["hostA:1:x", "hostB:2:y"]
    for round_ in range(200):
        if all(_shard_done(queued, s) for s in range(spec.n_shards)):
            break
        drain(queue, owners[round_ % 2], interval=0.0, max_steps=3)
    else:
        pytest.fail("queue did not drain in 200 interleaved passes")
    for s in range(spec.n_shards):
        name = f"shard-{s:04d}.jsonl"
        assert (open(os.path.join(queued, name)).read()
                == open(os.path.join(straight, name)).read())


def test_drain_skips_foreign_live_lease(tmp_path):
    out = str(tmp_path)
    spec = _plan(out)
    foreign = acquire_lease(ShardStore(out, 0).lease_path, "other:9:z",
                            ttl=3600.0)
    queue = open_queue(out)
    done = drain(queue, "me:1:a", max_steps=10_000)  # single pass
    assert done is False                      # shard 0 still foreign-held
    assert not os.path.exists(ShardStore(out, 0).records_path)
    for s in range(1, spec.n_shards):         # but everything else drained
        assert _shard_done(out, s)
    foreign.release()
    assert drain(queue, "me:1:a", poll=0.01) is True


def test_explain_store_drains_through_queue(tmp_path):
    """The queue auto-detects an explain store and drains it to the same
    bytes as direct shard runs."""
    from repro.explain.runner import (
        ExplainSpec,
        run_explain_shard,
        write_merged_explained,
    )

    census = str(tmp_path / "census")
    os.makedirs(census)
    spec = _plan(census, eff_sigma=0.25, noise_sigma=0.01)
    for s in range(spec.n_shards):
        run_shard(spec, census, s)

    espec = ExplainSpec(census=census, n_shards=2, chunk_size=4,
                        save_every=5, max_measurements=9)
    straight, queued = str(tmp_path / "a"), str(tmp_path / "b")
    for s in range(espec.n_shards):
        run_explain_shard(espec, straight, s)
    write_merged_explained(espec, straight)

    os.makedirs(queued)
    espec.save(os.path.join(queued, "espec.json"))
    queue = open_queue(queued)
    assert queue.kind == "explain"
    assert drain(queue, "host:1:a", poll=0.01) is True
    queue.merge()
    assert (open(os.path.join(queued, "merged.jsonl")).read()
            == open(os.path.join(straight, "merged.jsonl")).read())


# ------------------------------------------------- CLI + SIGKILL takeover ---

#: Enough instances of tens of ms each that a SIGKILL lands while the
#: victim host is mid-shard (mirrors test_sweep.CLI_GRID).
QUEUE_GRID = [
    "--chains", "32", "--chain-sizes", "4,5", "--lo", "24", "--hi", "160",
    "--families", "bilinear", "--sizes", "32,64", "--per-size", "4",
    "--shards", "4", "--max-measurements", "12",
    "--chunk-size", "2", "--save-every", "4",
]


def _cli(module, args, **kwargs):
    cmd = [sys.executable, "-m", f"repro.launch.{module}"] + args
    return subprocess.run(
        cmd, env=_env(), capture_output=True, text=True, timeout=300, **kwargs
    )


def test_cli_sigkill_leased_host_takeover_byte_identical(tmp_path):
    """The acceptance scenario end to end: a host holding leases is
    SIGKILLed mid-chunk; its leases go stale, a second host adopts them
    after TTL expiry, and the merged census is byte-identical to an
    uninterrupted 1-host run."""
    straight, killed = str(tmp_path / "straight"), str(tmp_path / "killed")
    done = _cli("sweep", ["run", "--out", straight, "--workers", "1"]
                + QUEUE_GRID)
    assert done.returncode == 0, done.stderr

    plan = _cli("sweep", ["plan", "--out", killed] + QUEUE_GRID)
    assert plan.returncode == 0, plan.stderr
    victim = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.queue", "work",
         "--out", killed, "--host", "victim",
         "--ttl", "2", "--heartbeat", "0.1", "--poll", "0.1"],
        env=_env(), start_new_session=True,
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
    )
    try:
        # wait until at least one record batch hit disk, then SIGKILL the
        # whole process group mid-census — the lease file stays behind
        deadline = time.time() + 120
        while time.time() < deadline:
            if victim.poll() is not None:
                break
            jsonls = [f for f in os.listdir(killed)
                      if f.endswith(".jsonl")]
            if any(os.path.getsize(os.path.join(killed, f)) > 0
                   for f in jsonls):
                break
            time.sleep(0.005)
        was_running = victim.poll() is None
        os.killpg(victim.pid, signal.SIGKILL)
    finally:
        victim.wait()
    assert was_running, "victim drained the queue before the kill; " \
                        "enlarge QUEUE_GRID"

    # the adopter must wait out the dead lease's TTL, break it, resume the
    # half-done shard, and drain the rest
    adopt = _cli("queue", ["run", "--out", killed, "--hosts", "1",
                           "--ttl", "2", "--heartbeat", "0.2",
                           "--poll", "0.2"])
    assert adopt.returncode == 0, adopt.stderr
    assert "merged" in adopt.stdout

    merged_straight = open(os.path.join(straight, "merged.jsonl")).read()
    merged_killed = open(os.path.join(killed, "merged.jsonl")).read()
    assert merged_killed == merged_straight
    assert merged_straight.count("\n") == 40  # 32 chains + 8 bilinear


def test_cli_two_hosts_drain_byte_identical(tmp_path):
    """Two simulated hosts pulling from one store produce the same bytes
    as a 1-worker run (the CI smoke's local twin, smaller grid)."""
    grid = ["--chains", "8", "--chain-sizes", "3", "--lo", "16", "--hi", "64",
            "--families", "bilinear", "--sizes", "32", "--per-size", "2",
            "--shards", "4", "--max-measurements", "6",
            "--chunk-size", "2", "--save-every", "4"]
    straight, shared = str(tmp_path / "straight"), str(tmp_path / "shared")
    done = _cli("sweep", ["run", "--out", straight, "--workers", "1"] + grid)
    assert done.returncode == 0, done.stderr
    plan = _cli("sweep", ["plan", "--out", shared] + grid)
    assert plan.returncode == 0, plan.stderr
    run = _cli("queue", ["run", "--out", shared, "--hosts", "2",
                         "--poll", "0.1"])
    assert run.returncode == 0, run.stderr
    assert (open(os.path.join(shared, "merged.jsonl")).read()
            == open(os.path.join(straight, "merged.jsonl")).read())


def test_cli_status_reports_leases_and_counts(tmp_path):
    out = str(tmp_path)
    spec = _plan(out)
    run_shard(spec, out, 0)
    holder = acquire_lease(ShardStore(out, 1).lease_path, "probe:7:q")
    assert holder is not None
    status = _cli("queue", ["status", "--out", out])
    assert status.returncode == 0, status.stderr
    assert "sweep queue" in status.stdout
    assert "[done]" in status.stdout          # shard 0 finished
    assert "leased by probe:7:q" in status.stdout
    holder.release()


def test_queue_rejects_unplanned_directory(tmp_path):
    with pytest.raises(SystemExit, match="plan a campaign"):
        open_queue(str(tmp_path))


# --------------------------------------------- manifest-served shard math ---

def test_shard_counts_tail_scans_only_new_bytes(tmp_path):
    """After a manifest commit, shard_counts must serve from the manifest
    watermark plus a tail scan of freshly appended bytes — including a
    torn tail — without reparsing the whole file."""
    from repro.core.sweep import shard_counts

    store = ShardStore(str(tmp_path), 0).open()
    store.append_records([
        {"uid": "a", "index": 0, "family": "chain", "is_anomaly": True},
        {"uid": "b", "index": 1, "family": "chain", "is_anomaly": False},
    ])
    store.write_manifest()
    # records appended after the manifest (a crash window) still count ...
    with open(store.records_path, "a") as fh:
        fh.write(json.dumps({"uid": "c", "index": 2, "family": "bilinear",
                             "is_anomaly": False}) + "\n")
        fh.write('{"uid": "torn", "ind')  # ... and a torn tail is ignored
    counts = shard_counts(ShardStore(str(tmp_path), 0))
    assert counts["done"] == 3
    assert counts["by_family"]["chain"] == {"done": 2, "anomalies": 1}
    assert counts["by_family"]["bilinear"] == {"done": 1, "anomalies": 0}
    assert counts["done_flag"] is False


def test_shard_counts_falls_back_on_legacy_manifest(tmp_path):
    from repro.core.sweep import shard_counts

    store = ShardStore(str(tmp_path), 0).open()
    store.append_records([{"uid": "a", "index": 0, "family": "chain",
                           "is_anomaly": False}])
    # a pre-queue manifest: no records_bytes watermark, no by_family
    with open(store.manifest_path, "w") as fh:
        json.dump({"shard": 0, "n_completed": 1,
                   "completed_uids": ["a"]}, fh)
    counts = shard_counts(ShardStore(str(tmp_path), 0))
    assert counts["done"] == 1
    assert counts["by_family"]["chain"]["done"] == 1
