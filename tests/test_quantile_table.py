"""QuantileTable: batched quantile windows vs the pairwise Procedure-1 path.

The vectorized analysis core is only admissible because it is *bit-identical*
to the paper-literal implementation; these tests pin that down at the
window/comparison level (the session/campaign level lives in
test_vectorized_golden.py). Property-based variants use hypothesis through
the compat shim, so the example-based edge cases still run on bare envs.
"""

import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import (
    DEFAULT_QUANTILE_RANGES,
    MeasurementStore,
    Outcome,
    QuantileTable,
    compare_measurements,
    quantile_window,
)

LADDER = DEFAULT_QUANTILE_RANGES + ((2.5, 97.5),)  # one off-ladder range too


def _store(table):
    store = MeasurementStore()
    for name, values in table.items():
        store.add(name, values)
    return store


# ------------------------------------------------------------ properties ---

@given(
    st.dictionaries(
        st.sampled_from([f"alg{i}" for i in range(6)]),
        st.lists(st.floats(0.1, 10.0), min_size=1, max_size=40),
        min_size=1,
        max_size=6,
    )
)
@settings(max_examples=60, deadline=None)
def test_table_windows_equal_pairwise_windows(meas):
    """Property: every (algorithm × range) window from the batched table is
    bitwise equal to quantile_window on the raw vector — across ragged row
    lengths (each algorithm's N differs)."""
    store = _store(meas)
    table = QuantileTable.from_ranges(store, LADDER)
    for name, values in meas.items():
        for lo, hi in LADDER:
            assert table.window(name, lo, hi) == quantile_window(values, lo, hi)


@given(
    st.lists(st.floats(0.1, 10.0), min_size=1, max_size=30),
    st.lists(st.floats(0.1, 10.0), min_size=1, max_size=30),
)
@settings(max_examples=60, deadline=None)
def test_table_compare_equals_compare_measurements(a, b):
    """Property: the three-way comparison through the table is the same
    Outcome as the pairwise Procedure 1, for every ladder range."""
    store = _store({"a": a, "b": b})
    table = QuantileTable.from_ranges(store, LADDER)
    for lo, hi in LADDER:
        assert table.compare("a", "b", lo, hi) is compare_measurements(a, b, lo, hi)
        assert table.compare("b", "a", lo, hi) is compare_measurements(b, a, lo, hi)


@given(
    st.integers(1, 5),
    st.floats(0.1, 10.0),
)
@settings(max_examples=30, deadline=None)
def test_table_duplicate_values_collapse_windows(n, value):
    """Property: a constant measurement vector (duplicates) collapses every
    window to (value, value), table and pairwise alike."""
    store = _store({"x": [value] * n})
    table = QuantileTable.from_ranges(store, LADDER)
    for lo, hi in LADDER:
        win = table.window("x", lo, hi)
        assert win == quantile_window([value] * n, lo, hi)
        assert win[0] == win[1] == pytest.approx(value)


# ------------------------------------------------------------ edge cases ---

def test_single_measurement_window_collapses():
    """N == 1: both quantiles collapse to the lone value (well-defined, per
    quantile_window's contract)."""
    store = _store({"x": [3.25]})
    table = QuantileTable.from_ranges(store, [(5.0, 95.0), (25.0, 75.0)])
    assert table.window("x", 25.0, 75.0) == (3.25, 3.25)
    assert table.window("x", 5.0, 95.0) == (3.25, 3.25)


def test_duplicate_heavy_rows_match_pairwise():
    meas = {"a": [1.0, 1.0, 5.0], "b": [1.0, 1.0, 1.0, 1.0]}
    store = _store(meas)
    table = QuantileTable.from_ranges(store, DEFAULT_QUANTILE_RANGES)
    for lo, hi in DEFAULT_QUANTILE_RANGES:
        for name in meas:
            assert table.window(name, lo, hi) == quantile_window(meas[name], lo, hi)
        assert table.compare("a", "b", lo, hi) is compare_measurements(
            meas["a"], meas["b"], lo, hi
        )


def test_zero_measurement_algorithm_raises_like_pairwise():
    store = MeasurementStore()
    store.add("full", [1.0, 2.0])
    store.add("empty", [])
    table = QuantileTable.from_ranges(store, [(25.0, 75.0)])
    with pytest.raises(ValueError, match="zero measurements"):
        table.window("empty", 25.0, 75.0)
    with pytest.raises(ValueError):
        quantile_window([], 25.0, 75.0)


def test_unknown_bound_and_invalid_range_rejected():
    store = _store({"a": [1.0, 2.0], "b": [3.0, 4.0]})
    table = QuantileTable(store, [25.0, 75.0])
    with pytest.raises(KeyError, match="not in table bounds"):
        table.window("a", 10.0, 90.0)
    with pytest.raises(ValueError):  # same contract as compare_measurements
        table.compare("a", "b", 75.0, 25.0)
    with pytest.raises(ValueError):
        QuantileTable(store, [0.0, 75.0])


def test_table_invalidates_on_store_version_bump():
    """The cache keys on the store's version counter: appending measurements
    must refresh the windows; an untouched store must not recompute."""
    store = _store({"x": [1.0, 1.0, 1.0]})
    table = QuantileTable.from_ranges(store, [(25.0, 75.0)])
    assert table.window("x", 25.0, 75.0) == (1.0, 1.0)
    v0 = store.version
    store.add("x", [5.0, 5.0, 5.0])
    assert store.version > v0
    lo, hi = table.window("x", 25.0, 75.0)
    assert (lo, hi) == quantile_window(store.get("x"), 25.0, 75.0)
    assert hi > 1.0


def test_shuffle_preserves_windows_and_bumps_version():
    """Shuffling permutes rows in place (one permutation per row); quantiles
    are order-independent so the windows cannot move, but the version must
    bump so dependent caches re-validate."""
    rng = np.random.default_rng(0)
    store = _store({"x": list(np.linspace(1.0, 2.0, 17)), "y": [4.0, 3.0, 5.0]})
    table = QuantileTable.from_ranges(store, DEFAULT_QUANTILE_RANGES)
    before = {
        (n, r): table.window(n, *r)
        for n in ("x", "y")
        for r in DEFAULT_QUANTILE_RANGES
    }
    sorted_rows = {n: sorted(store.get(n)) for n in ("x", "y")}
    v0 = store.version
    store.shuffle(rng)
    assert store.version > v0
    assert {n: sorted(store.get(n)) for n in ("x", "y")} == sorted_rows
    for (n, r), win in before.items():
        assert table.window(n, *r) == win


def test_columnar_store_amortized_append_and_views():
    """Many small appends must land in one growing buffer; row() is a view
    (no copy) and get()/as_mapping()/to_dict() still speak lists of floats."""
    store = MeasurementStore()
    for i in range(100):
        store.add("x", [float(i)])
    assert store.count("x") == 100
    row = store.row("x")
    assert isinstance(row, np.ndarray) and row.dtype == np.float64
    assert row.base is not None  # a view into the growing buffer
    assert store.get("x") == [float(i) for i in range(100)]
    assert store.to_dict() == {"measurements": {"x": [float(i) for i in range(100)]}}
    assert dict(store.as_mapping()) == {"x": [float(i) for i in range(100)]}
