"""Tests for the Linnea-like expression layer (chains + families)."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import relative_flops
from repro.expressions import (
    ANOMALY_331,
    FIG3_75,
    build_workloads,
    dp_optimal_flops,
    enumerate_trees,
    flops_table,
    generate_chain_algorithms,
    get_instance,
    linear_extensions,
    make_chain_inputs,
    reference_product,
    solve_family,
    tree_flops,
    verify_algorithms,
)


def test_catalan_counts():
    assert [len(enumerate_trees(n)) for n in (1, 2, 3, 4, 5, 6)] == [1, 1, 2, 5, 14, 42]


def test_chain4_has_six_algorithms():
    """Paper Sec. I: 5 parenthesizations -> at least 6 algorithms
    ((AB)(CD) has two instruction orders)."""
    algs = generate_chain_algorithms((8, 9, 10, 11, 12))
    assert len(algs) == 6
    labels = [a.label for a in algs]
    assert sum("(AB)(CD)" in l for l in labels) == 2


def test_paper_table1_rf_reproduced():
    algs = generate_chain_algorithms(ANOMALY_331)
    rf = sorted(round(v, 2) for v in relative_flops(flops_table(algs)).values())
    assert rf == [0.0, 0.0, 0.04, 0.11, 0.27, 0.32]


def test_paper_table2_rf_reproduced():
    algs = generate_chain_algorithms(FIG3_75)
    rf = sorted(round(v, 2) for v in relative_flops(flops_table(algs)).values())
    expect = [0.0, 0.0, 2.78, 2.78, 5.59, 5.59]  # paper rounds differently by 0.01
    assert all(abs(a - b) <= 0.015 for a, b in zip(rf, expect)), rf


@given(st.lists(st.integers(2, 40), min_size=4, max_size=6))
@settings(max_examples=30, deadline=None)
def test_enumerated_min_matches_dp(dims):
    """Property: exhaustive enumeration minimum == DP optimum."""
    algs = generate_chain_algorithms(tuple(dims))
    assert min(a.flops for a in algs) == dp_optimal_flops(dims)


@given(st.lists(st.integers(2, 12), min_size=4, max_size=5), st.integers(0, 99))
@settings(max_examples=15, deadline=None)
def test_all_algorithms_equivalent(dims, seed):
    """Property: every parenthesization/order computes the same product."""
    dims = tuple(dims)
    mats = make_chain_inputs(dims, seed=seed)
    verify_algorithms(generate_chain_algorithms(dims), mats, rtol=5e-3, atol=5e-3)


def test_instruction_orders_are_valid_toposorts():
    for tree in enumerate_trees(5):
        for ext in linear_extensions(tree):
            assert sorted(ext) == list(range(len(ext)))


def test_workloads_block_and_run():
    inst = get_instance("fig3_75", smoke=True)
    algs = inst.algorithms()
    mats = make_chain_inputs(inst.dims, seed=0)
    table = build_workloads(algs, mats, jit=True, warmup=True)
    ref = np.asarray(reference_product(mats))
    for name, fn in table.items():
        np.testing.assert_allclose(np.asarray(fn()), ref, rtol=2e-3, atol=2e-3)


def test_solve_family_flops_ordering():
    fam = solve_family(256)
    f = fam.flops_table()
    assert f["solve_chol"] < f["solve_lu"] < f["solve_inverse"]
    # variants compute the same solution
    import jax.numpy as jnp

    w = fam.workloads(size=64, seed=0)
    outs = {k: np.asarray(v()) for k, v in w.items()}
    for k in ("solve_lu", "solve_chol"):
        np.testing.assert_allclose(outs[k], outs["solve_inverse"], rtol=2e-2, atol=2e-2)
