"""VariantSite invariants for the sites the kernel_variants census family
wraps: analytic FLOP counts cross-checked against the explainer's roofline
kernel table, and variant-output equivalence in Pallas interpret mode on
CPU (the wall-clock CI lane's correctness precondition — ranking variants
that compute different things would be meaningless)."""

import numpy as np
import pytest

from repro.autotune import attention_site, matmul_blocks_site, ssd_chunk_site
from repro.explain.decompose import KernelSpec


def _outputs(site, seed=0):
    arrays = site.make_inputs(seed)
    return {v.name: np.asarray(v.build(*arrays)()) for v in site.variants}


# ----------------------------------------------------------------- matmul ---

def test_matmul_site_flops_match_roofline_gemm():
    m, k, n = 48, 32, 64
    site = matmul_blocks_site(m=m, k=k, n=n, blocks=[(16, 16, 16)],
                              interpret=True)
    want = KernelSpec("gemm", (m, k, n)).flops  # the roofline table's 2mkn
    assert want == 2.0 * m * k * n
    for name, f in site.flops_table().items():
        assert f == pytest.approx(want), name


def test_matmul_variants_equivalent_interpret():
    site = matmul_blocks_site(m=32, k=32, n=32,
                              blocks=[(16, 16, 16), (32, 32, 32)],
                              interpret=True)
    outs = _outputs(site)
    assert set(outs) == {"blocks_16x16x16", "blocks_32x32x32", "xla_dot"}
    ref = outs["xla_dot"]
    for name, out in outs.items():
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4,
                                   err_msg=name)


# -------------------------------------------------------------- attention ---

def test_attention_site_flops_match_roofline_pair():
    b, s, h, kv, d = 1, 32, 2, 1, 16
    site = attention_site(b=b, s=s, h=h, kv=kv, d=d)
    # the shared math is the scores GEMM + the output GEMM with batch*heads
    # folded into rows — the decomposition the census family publishes
    want = (KernelSpec("gemm", (b * h * s, d, s)).flops
            + KernelSpec("gemm", (b * h * s, s, d)).flops)
    assert want == 2.0 * b * h * s * s * d * 2
    for name, f in site.flops_table().items():
        assert f == pytest.approx(want), name


def test_attention_variants_equivalent():
    site = attention_site(b=1, s=32, h=2, kv=1, d=16)
    outs = _outputs(site)
    assert set(outs) == {"reference_grouped", "reference_broadcast",
                         "chunked_flash"}
    ref = outs["reference_grouped"]
    for name, out in outs.items():
        np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-3,
                                   err_msg=name)


# -------------------------------------------------------------------- ssd ---

def test_ssd_site_flops_match_family_decomposition():
    b, s, h, p, n = 1, 32, 2, 8, 8
    site = ssd_chunk_site(b=b, s=s, h=h, p=p, n=n, chunks=[8, 16, 32])
    table = site.flops_table()
    for q in (8, 16, 32):
        # the site's per-chunk analytic count...
        want = b * s * h * (2.0 * q * n + 2.0 * q * p + 4.0 * p * n)
        assert table[f"chunk_{q}"] == pytest.approx(want)
    # ...and the census family's shared-math decomposition reproduces the
    # reference chunk's count exactly, as a sum of roofline gemms
    q0 = 8
    kernels = [
        KernelSpec("gemm", (b * h * s, n, q0)),
        KernelSpec("gemm", (b * h * s, q0, p)),
        KernelSpec("gemm", (b * h * s, n, p)),
        KernelSpec("gemm", (b * h * s, p, n)),
    ]
    assert sum(k.flops for k in kernels) == pytest.approx(table["chunk_8"])


def test_ssd_variants_equivalent():
    site = ssd_chunk_site(b=1, s=32, h=2, p=8, n=8, chunks=[8, 16, 32])
    outs = _outputs(site)
    assert set(outs) == {"chunk_8", "chunk_16", "chunk_32"}
    ref = outs["chunk_32"]
    for name, out in outs.items():
        np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-3,
                                   err_msg=name)


# ------------------------------------------- the family's workload bridge ---

def test_family_workloads_are_site_workloads():
    """The kernel_variants family's build_workloads must produce exactly
    the site's variant names (warmed, blocking thunks the WallClockTimer
    accepts)."""
    from repro.core.family import InstanceSpec
    from repro.core.sweep import instance_entry

    inst = InstanceSpec(
        index=0, uid="kernel_variants-matmul-n32-s000",
        family="kernel_variants",
        params={"site": "matmul", "size": 32, "seed": 0, "interpret": True},
    )
    flops, _, build = instance_entry(inst)
    wl = build()
    assert set(wl) == set(flops)
    for fn in wl.values():
        fn()  # already warmed; must run
