"""Sharding plans, cell lowering, and the roofline HLO analyzer."""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.configs.shapes import ShapeSpec  # noqa: E402
from repro.distributed.sharding import (  # noqa: E402
    attention_strategy,
    batch_spec,
    cache_seq_spec,
    expert_strategy,
    make_plan,
    tree_shardings,
)
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.launch.specs import build_cell, param_shapes  # noqa: E402
from repro.models import ModelConfig  # noqa: E402
from repro.roofline import analyze  # noqa: E402


# ------------------------------------------------------------- strategies --

def test_attention_strategy_selection():
    mk = lambda h, kv: ModelConfig(
        name="t", n_layers=2, d_model=h * 16, n_heads=h, n_kv_heads=kv, d_ff=64,
        vocab_size=64,
    )
    assert attention_strategy(mk(32, 16), 16) == "head"
    assert attention_strategy(mk(32, 8), 16) == "head_q"
    assert attention_strategy(mk(40, 8), 16) == "sequence"   # qwen3
    assert attention_strategy(mk(24, 8), 16) == "sequence"   # granite-moe
    assert attention_strategy(mk(6, 6), 16) == "sequence"    # whisper
    assert attention_strategy(mk(6, 6), 1) == "head"         # no TP


def test_expert_strategy_selection():
    moe = lambda e: ModelConfig(
        name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=64,
        vocab_size=64, n_experts=e, top_k=2,
    )
    assert expert_strategy(moe(16), 16) == "expert"   # jamba
    assert expert_strategy(moe(60), 16) == "tensor"   # qwen2-moe
    assert expert_strategy(moe(40), 16) == "tensor"   # granite-moe


def test_spec_divisibility_fallback():
    mesh = make_mesh(n_pods=1, dp=2, tp=4)
    cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=64, vocab_size=65)   # vocab 65 !% 4
    plan = make_plan(cfg, mesh)
    spec = plan.spec_for(("vocab", "embed"), (65, 64))
    assert spec[0] is None            # vocab rule dropped
    assert plan.fallbacks             # and recorded
    spec2 = plan.spec_for(("vocab", "embed"), (64, 64))
    assert spec2[0] in ("model", ("model",))


def test_batch_and_cache_specs():
    mesh = make_mesh(n_pods=1, dp=2, tp=4)
    assert batch_spec(mesh, 8, 1) == PartitionSpec(("data",), None)
    assert batch_spec(mesh, 3, 1) == PartitionSpec(None, None)  # 3 !% 2
    # batch divides dp: seq over model only
    assert cache_seq_spec(mesh, 8) == PartitionSpec(("data",), ("model",), None, None)
    # batch 1: seq over (data, model)
    assert cache_seq_spec(mesh, 1) == PartitionSpec(None, ("data", "model"), None, None)


def test_tree_shardings_cover_params():
    mesh = make_mesh(n_pods=1, dp=2, tp=4)
    cfg = get_config("granite-8b", smoke=True)
    shapes, axes = param_shapes(cfg)
    plan = make_plan(cfg, mesh)
    sh = tree_shardings(plan, axes, shapes)
    leaves = jax.tree.leaves(sh, is_leaf=lambda x: isinstance(x, NamedSharding))
    assert leaves and all(isinstance(s, NamedSharding) for s in leaves)
    # mlp wi: [layers, d_model(embed->data), d_ff(ffn->model)]
    wi = sh["units"]["sub0"]["mlp"]["wi"]
    assert wi.spec == PartitionSpec(None, ("data",), ("model",))


# ------------------------------------------------------------ cell builds --

@pytest.mark.parametrize("shape", [
    ShapeSpec("t", 128, 8, "train"),
    ShapeSpec("p", 256, 8, "prefill"),
    ShapeSpec("d", 256, 8, "decode"),
])
def test_build_cell_compiles_small_mesh(shape):
    mesh = make_mesh(n_pods=1, dp=2, tp=4)
    cfg = get_config("granite-8b", smoke=True)
    cell = build_cell("granite-8b", cfg, shape, mesh)
    compiled = cell.lower().compile()
    counts = analyze(compiled.as_text())
    assert counts.flops > 0
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes >= 0


def test_build_cell_multipod_smoke():
    mesh = make_mesh(n_pods=2, dp=2, tp=2)
    cfg = get_config("granite-8b", smoke=True)
    shape = ShapeSpec("t", 64, 8, "train")
    cell = build_cell("granite-8b", cfg, shape, mesh)
    compiled = cell.lower().compile()
    counts = analyze(compiled.as_text())
    # gradient sync must span the pod axis: some collective exists
    assert counts.total_collective_bytes > 0


# -------------------------------------------------------------- analyzer ---

def test_analyzer_scan_trip_count():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    n, L = 128, 5
    c = jax.jit(scanned).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((L, n, n), jnp.float32),
    ).compile()
    counts = analyze(c.as_text())
    assert abs(counts.flops / (2 * n**3 * L) - 1) < 0.02


def test_breakdown_by_opcode_on_inline_typed_hlo():
    """jax 0.4.x CPU prints operands WITH inline types
    (``dot(f32[...] %x, ...)``); the per-opcode breakdown must count dot
    FLOPs and carry trip-count weighting on that dialect too (PR 3 only
    regression-tested ``analyze``)."""
    from repro.roofline.hlo import breakdown_by_opcode

    m, k, n = 48, 96, 32
    c = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
    ).compile()
    txt = c.as_text()
    if "dot(f32[" not in txt:
        pytest.skip("this jax prints the bare-operand HLO dialect; the "
                    "inline-typed regression does not apply")
    table = breakdown_by_opcode(txt)
    assert table["dot"]["flops"] == pytest.approx(2.0 * m * k * n)
    assert table["dot"]["count"] == 1.0

    # scanned body: the dot row must be multiplied by the trip count
    L = 7

    def scanned(x, ws):
        return jax.lax.scan(lambda h, w: (h @ w, None), x, ws)[0]

    c2 = jax.jit(scanned).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((L, n, n), jnp.float32),
    ).compile()
    table2 = breakdown_by_opcode(c2.as_text())
    assert table2["dot"]["flops"] == pytest.approx(2.0 * n**3 * L)
    assert table2["dot"]["count"] == pytest.approx(float(L))


def test_attention_score_traffic_on_inline_typed_hlo():
    """Score-shaped [b, h, sq, skv] outputs must be found (and byte-counted)
    on the inline-typed dialect; mismatched seq dims must count nothing."""
    from repro.roofline.hlo import attention_score_traffic

    b, h, s, d = 2, 2, 64, 8

    def scores(q, kk):
        # the softmax consumer keeps the [b, h, sq, skv] score tensor
        # materialised (a bare einsum's size-1 dims get bitcast away)
        return jax.nn.softmax(jnp.einsum("bhqd,bhkd->bhqk", q, kk), axis=-1)

    c = jax.jit(scores).lower(
        jax.ShapeDtypeStruct((b, h, s, d), jnp.float32),
        jax.ShapeDtypeStruct((b, h, s, d), jnp.float32),
    ).compile()
    txt = c.as_text()
    traffic = attention_score_traffic(txt, [s])
    # at least the materialised score tensor itself, written + read once
    assert traffic >= 4 * b * h * s * s
    # a seq set that matches nothing counts nothing
    assert attention_score_traffic(txt, [s + 1]) == 0.0


def test_analyzer_collectives_and_per_device_flops():
    mesh = make_mesh(n_pods=1, dp=2, tp=4)

    def mlp(x, w1, w2):
        return jax.nn.relu(x @ w1) @ w2

    shx = NamedSharding(mesh, PartitionSpec("data", None))
    sh1 = NamedSharding(mesh, PartitionSpec(None, "model"))
    sh2 = NamedSharding(mesh, PartitionSpec("model", None))
    c = jax.jit(mlp, in_shardings=(shx, sh1, sh2), out_shardings=shx).lower(
        jax.ShapeDtypeStruct((64, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 512), jnp.float32),
        jax.ShapeDtypeStruct((512, 256), jnp.float32),
    ).compile()
    counts = analyze(c.as_text())
    total = 2 * 64 * 256 * 512 * 2
    assert abs(counts.flops / (total / 8) - 1) < 0.02
    assert counts.collective_bytes.get("all-reduce", 0) > 0
