"""Checkpoint / data / fault-tolerance / compression / optimizer tests."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.checkpoint import (
    CheckpointManager,
    all_steps,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.data import DataConfig, SyntheticLM
from repro.distributed.compression import (
    ErrorFeedback,
    dequantize_tree,
    quantize_int8,
    quantize_tree,
)
from repro.train.ft import FailureDetector, StragglerMonitor, reassign_shards
from repro.train.optimizer import AdamW, Adafactor, cosine_schedule, global_norm


# ------------------------------------------------------------- checkpoint --

def _state():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((4,))},
        "step": jnp.int32(7),
    }


def test_checkpoint_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        state = _state()
        save_checkpoint(d, 42, state, extra={"next_step": 43})
        restored, step, extra = restore_checkpoint(d, state)
        assert step == 42 and extra["next_step"] == 43
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_and_latest():
    with tempfile.TemporaryDirectory() as d:
        state = _state()
        save_checkpoint(d, 1, state)
        save_checkpoint(d, 2, state)
        assert latest_step(d) == 2
        # simulate a crash leaving a tmp dir: must be ignored
        os.makedirs(os.path.join(d, "step_00000003.tmp0"))
        assert latest_step(d) == 2
        # LATEST pointing at a deleted dir falls back to newest valid
        import shutil

        shutil.rmtree(os.path.join(d, "step_00000002"))
        assert latest_step(d) == 1


def test_checkpoint_keep_k():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in range(5):
            mgr.save(s, _state())
        assert all_steps(d) == [3, 4]


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 0, _state())
        bad = {"params": {"w": jnp.zeros((2, 2)), "b": jnp.ones((4,))}, "step": jnp.int32(0)}
        with pytest.raises(ValueError):
            restore_checkpoint(d, bad)


# ------------------------------------------------------------------- data --

def test_data_deterministic_and_shard_consistent():
    pipe = SyntheticLM(DataConfig(vocab_size=211, seq_len=32, global_batch=8))
    g = pipe.global_batch(5)
    assert g["tokens"].shape == (8, 32)
    # shard slices tile the global batch exactly
    parts = [pipe.batch(5, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), g["tokens"])
    # resume determinism
    np.testing.assert_array_equal(pipe.batch(5, 2, 4)["tokens"], parts[2])
    # labels are next-token shifted
    full = np.concatenate([g["tokens"], g["labels"][:, -1:]], axis=1)
    np.testing.assert_array_equal(full[:, 1:], g["labels"])


def test_data_has_learnable_structure():
    cfg = DataConfig(vocab_size=97, seq_len=128, global_batch=4, structure=0.8)
    pipe = SyntheticLM(cfg)
    b = pipe.global_batch(0)
    toks = np.concatenate([b["tokens"], b["labels"][:, -1:]], axis=1)
    copies = (toks[:, cfg.copy_offset:] == toks[:, : -cfg.copy_offset]).mean()
    assert copies > 0.5  # strong copy structure


@given(st.integers(0, 50), st.integers(1, 4))
@settings(max_examples=15, deadline=None)
def test_data_elastic_invariance(step, log_shards):
    """Property: the global batch is identical for ANY shard count — the
    elastic-resume guarantee."""
    n_shards = 2 ** (log_shards - 1)
    pipe = SyntheticLM(DataConfig(vocab_size=64, seq_len=8, global_batch=8))
    g = pipe.global_batch(step)["tokens"]
    parts = [pipe.batch(step, i, n_shards)["tokens"] for i in range(n_shards)]
    np.testing.assert_array_equal(np.concatenate(parts), g)


# --------------------------------------------------------------------- ft --

def test_failure_detector_and_rejoin():
    t = [0.0]
    fd = FailureDetector([0, 1, 2], timeout_s=10, clock=lambda: t[0])
    t[0] = 8.0
    for h in (0, 1):
        fd.heartbeat(h)
    t[0] = 15.0
    ev = fd.check(step=3)
    assert ev.removed == (2,) and set(ev.healthy) == {0, 1}
    fd.join(2)
    ev = fd.check(step=4)
    assert ev is not None and ev.added == (2,)


def test_straggler_flagging_needs_patience():
    sm = StragglerMonitor([0, 1, 2], threshold=1.5, patience=3)
    for _ in range(4):
        sm.record(0, 1.0)
        sm.record(1, 1.0)
        sm.record(2, 2.5)
    assert sm.check() == []        # strike 1
    assert sm.check() == []        # strike 2
    assert sm.check() == [2]       # strike 3 -> flagged


def test_reassign_shards_total_and_deterministic():
    table = reassign_shards([3, 1, 7], 8)
    all_shards = sorted(s for v in table.values() for s in v)
    assert all_shards == list(range(8))
    assert table == reassign_shards([7, 3, 1], 8)


# ------------------------------------------------------------ compression --

@given(st.lists(st.floats(-100, 100), min_size=1, max_size=64))
@settings(max_examples=30, deadline=None)
def test_quantize_roundtrip_error_bound(values):
    x = jnp.asarray(values, jnp.float32)
    leaf = quantize_int8(x)
    rec = np.asarray(leaf.q, np.float32) * float(leaf.scale)
    amax = float(np.max(np.abs(np.asarray(x)))) or 1.0
    assert np.max(np.abs(rec - np.asarray(x))) <= amax / 127.0 + 1e-6


def test_error_feedback_bounded():
    rng = np.random.default_rng(0)
    res = ErrorFeedback.init({"w": jnp.zeros(128)})
    true_sum = np.zeros(128)
    rec_sum = np.zeros(128)
    for i in range(30):
        g = {"w": jnp.asarray(rng.normal(size=128), jnp.float32)}
        true_sum += np.asarray(g["w"])
        q, res = ErrorFeedback.compress(g, res)
        rec_sum += np.asarray(dequantize_tree(q)["w"])
    # telescoping: cumulative error stays bounded by one quantisation step
    assert np.abs(rec_sum - true_sum).max() < 0.25


# -------------------------------------------------------------- optimizer --

def _quadratic_loss(params):
    return sum(jnp.sum(jnp.square(p)) for p in jax.tree.leaves(params))


@pytest.mark.parametrize("opt_cls", [AdamW, Adafactor])
def test_optimizers_descend(opt_cls):
    opt = opt_cls(schedule=cosine_schedule(0.05, 0, 100))
    params = {"w": jnp.ones((4, 8)), "b": jnp.ones((8,))}
    state = opt.init(params)
    loss0 = float(_quadratic_loss(params))
    for _ in range(20):
        grads = jax.grad(_quadratic_loss)(params)
        params, state, metrics = opt.update(grads, state, jnp.float32)
    assert float(_quadratic_loss(params)) < loss0 * 0.5
    assert np.isfinite(float(metrics["grad_norm"]))


def test_adamw_grad_clipping():
    opt = AdamW(schedule=cosine_schedule(0.1, 0, 10), clip_norm=1.0)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    huge = {"w": jnp.full((4,), 1e6)}
    new_params, state, metrics = opt.update(huge, state, jnp.float32)
    assert float(metrics["grad_norm"]) > 1.0
    # clipped update magnitude stays sane
    assert float(jnp.max(jnp.abs(new_params["w"] - params["w"]))) < 1.0
