"""DiscriminantSweep: grid expansion, shard store, kill/resume, CLI."""

import json
import os
import signal
import subprocess
import sys
import time

import pytest

from repro.core.sweep import (
    ShardStore,
    SweepSpec,
    census_summary,
    merge_shards,
    run_shard,
    size_bucket,
    write_merged,
)

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
EXAMPLES = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "examples"))


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    for var in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS"):
        env.setdefault(var, "1")
    return env


def _small_spec(**overrides):
    kwargs = dict(
        name="t",
        families={
            "chain": {"count": 6, "n_matrices": [3, 4], "lo": 24, "hi": 96},
            "bilinear": {"sizes": [32, 64], "per_size": 2},
        },
        n_shards=3,
        backend="cost_model",
        max_measurements=9,
        chunk_size=2,
        save_every=4,
    )
    kwargs.update(overrides)
    return SweepSpec(**kwargs)


# ------------------------------------------------------------- expansion ---

def test_expand_deterministic_unique_and_sharded():
    spec = _small_spec()
    a, b = spec.expand(), spec.expand()
    assert [i.to_dict() for i in a] == [i.to_dict() for i in b]
    uids = [i.uid for i in a]
    assert len(set(uids)) == len(uids) == 10
    assert [i.index for i in a] == list(range(10))
    # shards partition the grid
    seen = []
    for s in range(spec.n_shards):
        seen += [i.uid for i in spec.shard_instances(s)]
    assert sorted(seen) == sorted(uids)


def test_spec_roundtrips_through_json(tmp_path):
    spec = _small_spec()
    path = spec.save(str(tmp_path / "spec.json"))
    loaded = SweepSpec.load(path)
    assert loaded.to_dict() == spec.to_dict()
    assert [i.uid for i in loaded.expand()] == [i.uid for i in spec.expand()]


def test_spec_rejects_unknown_family_and_backend():
    with pytest.raises(ValueError):
        SweepSpec(families={"nope": {}})
    with pytest.raises(ValueError):
        SweepSpec(backend="telepathy")


def test_expand_rejects_duplicate_uids():
    spec = SweepSpec(families={"bilinear": {"sizes": [64, 64], "per_size": 1}})
    with pytest.raises(ValueError, match="duplicate instance uids"):
        spec.expand()


def test_size_bucket():
    assert size_bucket(32) == "[32, 64)"
    assert size_bucket(63) == "[32, 64)"
    assert size_bucket(64) == "[64, 128)"


# ------------------------------------------------------------ shard store ---

def test_store_recovers_torn_tail(tmp_path):
    store = ShardStore(str(tmp_path), 0).open()
    store.append_records([{"uid": "a", "index": 0}, {"uid": "b", "index": 1}])
    # simulate a SIGKILL mid-append: half a JSON line, no newline
    with open(store.records_path, "a") as fh:
        fh.write('{"uid": "c", "ind')
    reopened = ShardStore(str(tmp_path), 0).open()
    assert reopened.completed_uids() == ["a", "b"]
    # the torn bytes are gone: appending c again works cleanly
    reopened.append_records([{"uid": "c", "index": 2}])
    final = ShardStore(str(tmp_path), 0).open()
    assert final.completed_uids() == ["a", "b", "c"]
    for line in open(store.records_path):
        json.loads(line)


def test_store_append_skips_duplicates(tmp_path):
    store = ShardStore(str(tmp_path), 1).open()
    assert store.append_records([{"uid": "x", "index": 0}]) == 1
    assert store.append_records([{"uid": "x", "index": 0},
                                 {"uid": "y", "index": 1}]) == 1
    assert store.completed_uids() == ["x", "y"]


# ------------------------------------------------------- run_shard/resume ---

def test_run_shard_completes_and_is_idempotent(tmp_path):
    spec = _small_spec()
    root = str(tmp_path)
    for s in range(spec.n_shards):
        run_shard(spec, root, s)
    records = merge_shards(spec, root)
    assert [r["uid"] for r in records] == [i.uid for i in spec.expand()]
    assert all(not ShardStore(root, s).has_engine_state()
               for s in range(spec.n_shards))
    before = open(os.path.join(root, "shard-0000.jsonl")).read()
    run_shard(spec, root, 0)  # no-op: everything already recorded
    assert open(os.path.join(root, "shard-0000.jsonl")).read() == before


def test_interrupted_resume_is_bit_identical(tmp_path):
    spec = _small_spec()
    straight, chopped = str(tmp_path / "a"), str(tmp_path / "b")
    run_shard(spec, straight, 0)
    # drive the same shard in 3-step slices, pausing mid-chunk repeatedly
    for _ in range(100):
        run_shard(spec, chopped, 0, max_steps=3)
        manifest = os.path.join(chopped, "shard-0000.manifest.json")
        if (os.path.exists(manifest)
                and json.load(open(manifest)).get("done")):
            break
    else:
        pytest.fail("shard did not finish in 100 slices")
    assert (open(os.path.join(chopped, "shard-0000.jsonl")).read()
            == open(os.path.join(straight, "shard-0000.jsonl")).read())


def test_records_hold_only_deterministic_fields(tmp_path):
    spec = _small_spec()
    run_shard(spec, str(tmp_path), 0)
    rec = ShardStore(str(tmp_path), 0).open().records[0]
    assert {"uid", "index", "family", "size", "p", "is_anomaly", "reason",
            "ranks", "mean_ranks", "converged"} <= set(rec)
    # nothing time- or host-dependent may leak into the census
    assert not any("time" in k or "host" in k or "wall" in k for k in rec)


def test_wall_clock_backend_resumes_mid_chunk(tmp_path):
    spec = _small_spec(
        backend="wall_clock",
        families={"chain": {"count": 2, "n_matrices": [3], "lo": 8, "hi": 24}},
        n_shards=1,
        chunk_size=2,
        max_measurements=6,
        eps=-1.0,  # never converges: each session needs exactly 2 steps,
                   # so max_steps=3 pauses mid-chunk deterministically
    )
    root = str(tmp_path)
    run_shard(spec, root, 0, max_steps=3)   # pause mid-chunk
    store = ShardStore(root, 0)
    assert store.has_engine_state()
    run_shard(spec, root, 0)                # rebuilds workloads, finishes
    assert not ShardStore(root, 0).has_engine_state()
    assert len(ShardStore(root, 0).open().records) == 2


# ---------------------------------------------------------- merge / report ---

def test_merge_dedupes_across_shards(tmp_path):
    spec = _small_spec(n_shards=2)
    root = str(tmp_path)
    rec = {"uid": "dup", "index": 0, "is_anomaly": False}
    ShardStore(root, 0).open().append_records([rec])
    ShardStore(root, 1).open().append_records(
        [dict(rec, is_anomaly=True), {"uid": "solo", "index": 1}]
    )
    merged = merge_shards(spec, root)
    assert [r["uid"] for r in merged] == ["dup", "solo"]
    assert merged[0]["is_anomaly"] is False  # first occurrence wins


def test_census_summary_and_tables(tmp_path):
    spec = _small_spec()
    root = str(tmp_path)
    for s in range(spec.n_shards):
        run_shard(spec, root, s)
    records = merge_shards(spec, root)
    summary = census_summary(records)
    assert summary["total"]["n"] == len(records)
    assert set(summary["by_family"]) == {"chain", "bilinear"}
    rate = summary["total"]["rate"]
    assert 0.0 <= rate <= 1.0

    from repro.launch.report_md import census_tables

    md = census_tables(records, name="t")
    assert "anomaly rate" in md and "| family |" in md.replace("| family ", "| family ")
    assert "chain" in md and "bilinear" in md

    path = write_merged(spec, root)
    assert sum(1 for _ in open(path)) == len(records)


# -------------------------------------------------------- CLI + kill/resume ---

#: Grid sized so a mid-run SIGKILL lands while shards are in flight: ~40
#: instances of tens of ms each, small chunks, frequent engine saves.
CLI_GRID = [
    "--chains", "32", "--chain-sizes", "4,5", "--lo", "24", "--hi", "160",
    "--families", "bilinear", "--sizes", "32,64", "--per-size", "4",
    "--shards", "4", "--max-measurements", "12",
    "--chunk-size", "2", "--save-every", "4",
]


def _sweep_cli(args, **kwargs):
    cmd = [sys.executable, "-m", "repro.launch.sweep"] + args
    return subprocess.run(
        cmd, env=_env(), capture_output=True, text=True, timeout=300, **kwargs
    )


def test_cli_kill_resume_census_identical(tmp_path):
    """The acceptance scenario: multi-worker sweep, SIGKILL mid-shard,
    resume, merged census identical to an uninterrupted run."""
    straight, killed = str(tmp_path / "straight"), str(tmp_path / "killed")

    done = _sweep_cli(["run", "--out", straight, "--workers", "2"] + CLI_GRID)
    assert done.returncode == 0, done.stderr

    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.sweep", "run",
         "--out", killed, "--workers", "2"] + CLI_GRID,
        env=_env(), start_new_session=True,
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
    )
    try:
        # wait until at least one record batch hit disk, then SIGKILL the
        # whole process group (parent + both workers) mid-census
        deadline = time.time() + 120
        while time.time() < deadline:
            if proc.poll() is not None:
                break
            jsonls = [f for f in os.listdir(killed)
                      if f.endswith(".jsonl")] if os.path.isdir(killed) else []
            if any(os.path.getsize(os.path.join(killed, f)) > 0 for f in jsonls):
                break
            time.sleep(0.005)
        was_running = proc.poll() is None
        os.killpg(proc.pid, signal.SIGKILL)
    finally:
        proc.wait()
    assert was_running, "sweep finished before the kill; enlarge CLI_GRID"

    resumed = _sweep_cli(["run", "--out", killed, "--workers", "2"])
    assert resumed.returncode == 0, resumed.stderr

    merged_straight = open(os.path.join(straight, "merged.jsonl")).read()
    merged_killed = open(os.path.join(killed, "merged.jsonl")).read()
    assert merged_killed == merged_straight
    assert merged_straight.count("\n") == 40  # 32 chains + 8 bilinear

    report = _sweep_cli(["report", "--out", killed])
    assert report.returncode == 0, report.stderr
    assert "anomaly rate" in report.stdout
    assert "| family |" in report.stdout or "By expression family" in report.stdout


def test_cli_status_and_merge(tmp_path):
    out = str(tmp_path / "census")
    run = _sweep_cli([
        "run", "--out", out, "--workers", "2",
        "--chains", "4", "--chain-sizes", "3", "--families", "",
        "--shards", "2", "--max-measurements", "6",
    ])
    assert run.returncode == 0, run.stderr
    status = _sweep_cli(["status", "--out", out])
    assert "4/4 instances complete" in status.stdout
    merge = _sweep_cli(["merge", "--out", out])
    assert merge.returncode == 0 and "merged 4 records" in merge.stdout


def test_plan_force_removes_stale_shard_artifacts(tmp_path):
    """Re-planning must not let records measured under the old grid satisfy
    the new one (uids encode family/n/index, not the grid bounds)."""
    out = str(tmp_path / "census")
    base = ["--chains", "4", "--chain-sizes", "3", "--families", "",
            "--shards", "2", "--max-measurements", "6"]
    first = _sweep_cli(["run", "--out", out, "--workers", "1"] + base)
    assert first.returncode == 0, first.stderr
    old_merged = open(os.path.join(out, "merged.jsonl")).read()

    replan = _sweep_cli(["plan", "--out", out, "--force"] + base[:-2]
                        + ["--lo", "200", "--hi", "400",
                           "--max-measurements", "6"])
    assert replan.returncode == 0, replan.stderr
    assert "stale" in replan.stdout
    assert not [f for f in os.listdir(out) if f.endswith(".jsonl")]

    rerun = _sweep_cli(["run", "--out", out, "--workers", "1"])
    assert rerun.returncode == 0, rerun.stderr
    new_merged = open(os.path.join(out, "merged.jsonl")).read()
    assert new_merged != old_merged
    assert all(d >= 200 for r in new_merged.splitlines()
               for d in json.loads(r)["dims"])


def test_anomaly_hunt_delegates_to_sweep_subsystem(tmp_path):
    """examples/anomaly_hunt.py is a thin wrapper over the census: its
    state directory must be a real one-shard sweep store."""
    out = str(tmp_path / "hunt")
    script = os.path.join(EXAMPLES, "anomaly_hunt.py")
    proc = subprocess.run(
        [sys.executable, script, "--n", "3", "--chain", "3",
         "--lo", "16", "--hi", "48", "--backend", "cost_model", "--out", out],
        env=_env(), capture_output=True, text=True, timeout=300,
    )
    assert proc.returncode == 0, proc.stderr
    assert "anomaly rate:" in proc.stdout
    # the subsystem's shard layout, not an ad-hoc loop
    assert os.path.exists(os.path.join(out, "spec.json"))
    store = ShardStore(out, 0).open()
    assert len(store.records) == 3
    spec = SweepSpec.load(os.path.join(out, "spec.json"))
    assert spec.families["chain"]["count"] == 3
