"""Graceful degradation when ``hypothesis`` is not installed.

Test modules import ``given``/``settings``/``st`` from here instead of from
``hypothesis`` directly. On a bare environment the property-based tests are
skipped (each replaced by a zero-arg skipper), while every example-based
test in the same module still collects and runs — the tier-1 suite must
never fail at collection over an optional dependency.
"""

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only on bare envs
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Accepts any strategy-building syntax and returns itself."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies:
        def __getattr__(self, name):
            return _Strategy()

    st = _Strategies()

    def given(*args, **kwargs):
        def decorate(fn):
            def skipper():
                pytest.skip("hypothesis not installed")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return decorate

    def settings(*args, **kwargs):
        return lambda fn: fn
