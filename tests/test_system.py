"""End-to-end system tests: sharded training, elastic resume, serving,
and the full paper pipeline on real measurements."""

import os
import tempfile

import pytest

# distributed system tests need >1 device; set BEFORE jax import
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402

from repro.checkpoint import CheckpointManager  # noqa: E402
from repro.core import (  # noqa: E402
    WallClockTimer,
    flops_discriminant_test,
    initial_hypothesis_by_time,
    measure_and_rank,
)
from repro.data import DataConfig, SyntheticLM  # noqa: E402
from repro.distributed.sharding import (  # noqa: E402
    batch_spec,
    make_plan,
    state_specs,
    tree_shardings,
)
from repro.expressions import (  # noqa: E402
    build_workloads,
    flops_table,
    get_instance,
    make_chain_inputs,
)
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import (  # noqa: E402
    ForwardOptions,
    ModelConfig,
    init_lm_params,
    init_lm_state,
    lm_forward,
)
from repro.serve.engine import ServingEngine, make_prefill, make_serve_step  # noqa: E402
from repro.train.elastic import ElasticConfig, ElasticTrainer  # noqa: E402
from repro.train.optimizer import AdamW, cosine_schedule  # noqa: E402
from repro.train.trainer import init_train_state, make_train_step  # noqa: E402

CFG = ModelConfig(
    name="sys-test", n_layers=4, d_model=64, n_heads=8, n_kv_heads=4,
    d_ff=128, vocab_size=512, dtype="float32", param_dtype="float32",
)


def _sharded_params(cfg, mesh):
    params, axes = init_lm_params(cfg, jax.random.PRNGKey(0))
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    plan = make_plan(cfg, mesh, mode="train")
    return jax.device_put(params, tree_shardings(plan, axes, shapes)), plan


def test_sharded_training_loss_decreases():
    mesh = make_mesh(n_pods=1, dp=2, tp=4)
    params, _ = _sharded_params(CFG, mesh)
    optimizer = AdamW(schedule=cosine_schedule(1e-3, 5, 100))
    state = init_train_state(CFG, optimizer, params)
    step_fn = make_train_step(CFG, optimizer, ForwardOptions(attn_impl="reference"),
                              num_microbatches=2)
    jstep = jax.jit(step_fn, donate_argnums=(0,))
    data = SyntheticLM(DataConfig(vocab_size=512, seq_len=64, global_batch=8))
    bspec = NamedSharding(mesh, batch_spec(mesh, 8, 1))
    losses = []
    with mesh:
        for step in range(8):
            batch = {k: jax.device_put(v, bspec) for k, v in data.batch(step).items()}
            state, metrics = jstep(state, batch)
            losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses


def test_sharded_decode_matches_dense():
    mesh = make_mesh(n_pods=1, dp=2, tp=4)
    params, plan = _sharded_params(CFG, mesh)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 24), 0, 512)
    state = init_lm_state(CFG, 8, 32)
    st_shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    state = jax.device_put(state, state_specs(CFG, plan, st_shapes, 8))
    pre = jax.jit(make_prefill(CFG))
    stp = jax.jit(make_serve_step(CFG))
    with mesh:
        _, state = pre(params, state, tokens=tokens[:, :23])
        lg, _ = stp(params, state, tokens[:, 23:24], jnp.int32(23))
    dense_logits, _ = lm_forward(CFG, jax.device_get(params), tokens=tokens)
    ref = np.asarray(dense_logits[:, 23])
    err = np.max(np.abs(np.asarray(lg) - ref)) / (np.max(np.abs(ref)) + 1e-9)
    assert err < 5e-2, err


def test_elastic_train_survives_membership_change():
    mesh_fn = lambda n_hosts: make_mesh(n_pods=1, dp=n_hosts, tp=2)
    with tempfile.TemporaryDirectory() as d:
        data = SyntheticLM(DataConfig(vocab_size=512, seq_len=32, global_batch=8))
        optimizer = AdamW(schedule=cosine_schedule(1e-3, 2, 50))
        trainer = ElasticTrainer(
            cfg=CFG, optimizer=optimizer, data=data,
            ckpt=CheckpointManager(d, keep=3),
            make_mesh_fn=mesh_fn,
            opts=ForwardOptions(attn_impl="reference"),
            elastic_cfg=ElasticConfig(checkpoint_every=4),
        )
        trainer.start(
            n_hosts=4,
            init_params_fn=lambda: init_lm_params(CFG, jax.random.PRNGKey(0))[0],
        )
        # lose half the hosts before step 6
        history = trainer.run(12, membership_events={6: 2})
        steps = [h["step"] for h in history]
        assert steps == list(range(12))
        losses = [h["loss"] for h in history]
        assert losses[-1] < losses[0]
        assert np.isfinite(losses).all()
        # after the re-mesh the dp width is 2
        assert trainer.mesh.shape["data"] == 2


def test_generation_deterministic_greedy():
    cfg = CFG.replace(vocab_size=128)
    params, _ = init_lm_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_len=32, temperature=0.0)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128)
    out1 = engine.generate(prompts, n_new=8)
    out2 = engine.generate(prompts, n_new=8)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 16)


def test_full_paper_pipeline_on_chain_instance():
    """Measure -> filter -> rank -> FLOPs test on a real instance: the
    system-level behaviour the paper defines."""
    inst = get_instance("fig3_75", smoke=True)
    algs = inst.algorithms()
    flops = flops_table(algs)
    workloads = build_workloads(algs, make_chain_inputs(inst.dims), warmup=True)
    timer = WallClockTimer(workloads)
    single = {n: timer.measure(n) for n in workloads}
    res = measure_and_rank(
        initial_hypothesis_by_time(single), timer,
        m_per_iteration=3, eps=0.03, max_measurements=24,
    )
    rep = flops_discriminant_test(res, flops)
    assert res.measurements_per_alg <= 24
    assert set(res.ranks) == set(flops)
    assert rep.reason in ("none", "faster_outside_min_flops", "min_flops_split")
