"""GPipe pipeline executor vs sequential reference."""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.distributed.pipeline import bubble_fraction, pipeline_apply  # noqa: E402
from repro.launch.compat import make_mesh  # noqa: E402


def _stage_fn(params, x):
    return jnp.tanh(x @ params["w"] + params["b"])


def test_pipeline_matches_sequential():
    n_stages, m, mb, d = 4, 6, 2, 16
    mesh = make_mesh((n_stages,), ("stage",))
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    params = {
        "w": jax.random.normal(ks[0], (n_stages, d, d)) / np.sqrt(d),
        "b": jax.random.normal(ks[1], (n_stages, d)) * 0.1,
    }
    micro = jax.random.normal(ks[2], (m, mb, d))

    out = pipeline_apply(_stage_fn, params, micro, mesh)

    # sequential reference
    ref = micro
    for s in range(n_stages):
        ref = _stage_fn({"w": params["w"][s], "b": params["b"][s]}, ref)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_bubble_fraction():
    assert bubble_fraction(4, 6) == 3 / 9
    assert bubble_fraction(1, 8) == 0.0
