"""Test-process configuration.

Distributed system tests (test_distributed, test_system) need a small
multi-device host platform; the flag must be set before jax initialises its
backend, which pytest's collection order cannot guarantee module-side. This
is 8 devices for sharding tests — NOT the dry-run's 512, which is set only
inside ``repro.launch.dryrun`` (smoke tests and benches must not see 512).
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
