"""One CLI: umbrella dispatch, legacy aliases, fsck parity, lazy facade."""

import os
import re
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

SURFACES = ("census", "explain", "queue", "fsck", "oracle", "predict")

#: every module-path entrypoint that must keep working as an alias
LEGACY_ALIASES = {
    "repro.launch.sweep": "census",
    "repro.launch.explain": "explain",
    "repro.launch.queue": "queue",
    "repro.launch.fsck": "fsck",
    "repro.launch.oracle": "oracle",
    "repro.launch.predict": "predict",
}

#: the five routes that must expose the SAME fsck flag set
FSCK_ROUTES = (
    ["fsck"],
    ["census", "fsck"],
    ["explain", "fsck"],
    ["queue", "fsck"],
    ["oracle", "fsck"],
)


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    for var in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS"):
        env.setdefault(var, "1")
    return env


def _repro(args, **kwargs):
    return subprocess.run(
        [sys.executable, "-m", "repro"] + args,
        env=_env(), capture_output=True, text=True, timeout=300, **kwargs
    )


# ---------------------------------------------------------------- umbrella ---

def test_umbrella_help_lists_every_surface():
    proc = _repro(["--help"])
    assert proc.returncode == 0, proc.stderr
    for surface in SURFACES:
        assert re.search(rf"^  {surface}\s+\S", proc.stdout, re.M), surface


def test_unknown_surface_fails_with_usage():
    proc = _repro(["telepathy"])
    assert proc.returncode == 2
    assert "unknown surface 'telepathy'" in proc.stderr
    assert "python -m repro <surface>" in proc.stderr


@pytest.mark.parametrize("surface", SURFACES)
def test_every_surface_help_is_rebranded(surface):
    """Each surface answers --help under its umbrella name (prog is passed
    through, not duplicated) without importing heavy deps."""
    proc = _repro([surface, "--help"])
    assert proc.returncode == 0, proc.stderr
    assert proc.stdout.startswith(f"usage: repro {surface}")


def test_umbrella_census_predict_round_trip(tmp_path):
    """Dispatch is real, not help-only: census run -> predict train ->
    active census run -> status reports the skip fraction."""
    grid = ["--chains", "0", "--families", "solve", "--sizes", "16,32",
            "--per-size", "2", "--shards", "2", "--max-measurements", "6"]
    full = str(tmp_path / "full")
    model = str(tmp_path / "model.json")
    active = str(tmp_path / "active")

    run = _repro(["census", "run", "--out", full, "--workers", "1"] + grid)
    assert run.returncode == 0, run.stderr
    assert "4/4 instances complete" in run.stdout

    train = _repro(["predict", "train", "--census", full, "--out", model])
    assert train.returncode == 0, train.stderr
    assert "residual sigma" in train.stdout

    rerun = _repro(["census", "run", "--out", active, "--workers", "1"]
                   + grid + ["--predictor", model])
    assert rerun.returncode == 0, rerun.stderr

    status = _repro(["census", "status", "--out", active])
    assert status.returncode == 0, status.stderr
    assert "predicted without measurement" in status.stdout
    assert "skip fraction" in status.stdout

    ev = _repro(["predict", "eval", "--census", full, "--model", model])
    assert ev.returncode == 0, ev.stderr
    assert "| family |" in ev.stdout and "would skip" in ev.stdout


# ------------------------------------------------------------------ aliases ---

@pytest.mark.parametrize("module,surface", sorted(LEGACY_ALIASES.items()))
def test_legacy_module_paths_still_work_with_pointer(module, surface):
    proc = subprocess.run(
        [sys.executable, "-m", module, "--help"],
        env=_env(), capture_output=True, text=True, timeout=300,
    )
    assert proc.returncode == 0, proc.stderr
    assert "legacy alias" in proc.stderr
    assert f"python -m repro {surface}" in proc.stderr
    # the umbrella route itself must NOT carry the deprecation note
    clean = _repro([surface, "--help"])
    assert "legacy alias" not in clean.stderr


# -------------------------------------------------------------- fsck parity ---

def _usage_options(help_text):
    """The option strings argparse places in the usage block."""
    usage = help_text.split("\n\n")[0]
    return set(re.findall(r"--[\w-]+", usage)) | set(
        re.findall(r"(?<!-)-[a-z]\b", usage))


def test_fsck_option_set_is_identical_on_all_five_routes():
    """The CLI-drift regression: every fsck route is the same parser, so
    the five help texts must advertise the same option set."""
    helps = {}
    for route in FSCK_ROUTES:
        proc = _repro(route + ["--help"])
        assert proc.returncode == 0, (route, proc.stderr)
        helps[" ".join(route)] = _usage_options(proc.stdout)
    reference = helps["fsck"]
    assert reference >= {"--out", "--dry-run"}
    assert all(opts == reference for opts in helps.values()), helps


# ------------------------------------------------------------------- facade ---

def test_import_repro_and_facade_stay_jax_free():
    """`import repro` (and touching the lazy facade) must not drag in jax
    or the launch modules — PEP 562 keeps the package importable on
    machines without the accelerator stack."""
    code = (
        "import sys; import repro; "
        "assert 'jax' not in sys.modules, 'import repro pulled in jax'; "
        "assert 'repro.api' not in sys.modules, 'facade import was eager'; "
        "fn = repro.run_census; "
        "assert 'jax' not in sys.modules, 'facade attribute pulled in jax'; "
        "assert callable(fn) and callable(repro.train_predictor)"
    )
    proc = subprocess.run(
        [sys.executable, "-c", code],
        env=_env(), capture_output=True, text=True, timeout=120,
    )
    assert proc.returncode == 0, proc.stderr


def test_facade_exports_match_api_all():
    import repro
    import repro.api as api

    assert set(api.__all__) <= set(dir(repro))
    for name in api.__all__:
        assert getattr(repro, name) is getattr(api, name)
