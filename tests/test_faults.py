"""Chaos hardening: deterministic fault injection, store integrity, fsck.

The contract under test is the ISSUE's acceptance bar: a census drained
under a seeded FaultPlan (torn appends, bitrot, dropped fsyncs, stalls,
kills) either commits records byte-identically or fails LOUDLY into a
state fsck can repair — after which a re-drain merges byte-identical to a
never-faulted run, with zero silently dropped records.
"""

import json
import logging
import os
import threading
import time

import pytest

from repro.core.faults import (
    PLAN_ENV,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    active_plan,
)
from repro.core.lease import (
    LEASE_ABSENT,
    LEASE_CORRUPT,
    LEASE_OK,
    LeaseLost,
    acquire_lease,
    acquire_lease_with_backoff,
    read_lease,
    read_lease_ex,
)
from repro.core.retry import RetryPolicy, with_retries
from repro.core.sweep import (
    LINE_CRC_MISMATCH,
    LINE_LEGACY,
    LINE_OK,
    LINE_UNDECODABLE,
    ShardStore,
    StoreDamaged,
    SweepSpec,
    merge_shards,
    parse_record_line,
    record_crc,
    run_shard,
    scan_damage,
    shard_counts,
    sweep_progress,
    write_merged,
)
from repro.launch.fsck import fsck_store
from repro.launch.queue import drain, open_queue


def _plan_spec(root, **overrides):
    kwargs = dict(
        name="chaos",
        families={"chain": {"count": 6, "n_matrices": [3], "lo": 16, "hi": 48}},
        n_shards=2,
        backend="cost_model",
        max_measurements=9,
        chunk_size=2,
        save_every=4,
    )
    kwargs.update(overrides)
    spec = SweepSpec(**kwargs)
    os.makedirs(root, exist_ok=True)
    spec.save(os.path.join(root, "spec.json"))
    return spec


def _drain_all(spec, root, faults=None):
    for s in range(spec.n_shards):
        run_shard(spec, root, s, faults=faults)


def _reference(tmp_path):
    ref = str(tmp_path / "ref")
    spec = _plan_spec(ref)
    _drain_all(spec, ref)
    return spec, ref, write_merged(spec, ref)


# -------------------------------------------------------------- FaultPlan ---

def test_fault_plan_schedules_on_exact_hit_counts():
    plan = FaultPlan([FaultSpec("store.append", "torn_write", 3)])
    assert plan.due("store.append") == []          # hit 1
    assert plan.due("store.append") == []          # hit 2
    armed = plan.due("store.append")               # hit 3: armed
    assert [f.op for f in armed] == ["torn_write"]
    assert plan.claim(armed[0]) is True
    assert plan.claim(armed[0]) is False           # exactly once
    assert plan.due("store.append") == []          # claimed: never re-arms
    assert plan.fired() == [armed[0].id]


def test_fault_plan_sites_are_independent_counters():
    plan = FaultPlan([
        FaultSpec("store.append", "torn_write", 2),
        FaultSpec("campaign.step", "stall", 1, arg=0.0),
    ])
    assert [f.site for f in plan.due("campaign.step")] == ["campaign.step"]
    assert plan.due("store.append") == []          # append count still 1


def test_fault_plan_claims_are_cross_process_via_scoreboard(tmp_path):
    path = str(tmp_path / "plan.json")
    FaultPlan([FaultSpec("store.append", "torn_write", 1)], seed=3).save(path)
    a, b = FaultPlan.load(path), FaultPlan.load(path)   # two "processes"
    fault_a = a.due("store.append")[0]
    fault_b = b.due("store.append")[0]
    assert a.claim(fault_a) is True
    assert b.claim(fault_b) is False               # a won the O_EXCL create
    assert a.fired() == b.fired() == [fault_a.id]


def test_fault_plan_rng_and_roundtrip_are_deterministic(tmp_path):
    path = str(tmp_path / "plan.json")
    plan = FaultPlan([FaultSpec("store.append", "corrupt_byte", 2, 0.5)],
                     seed=11)
    plan.save(path)
    again = FaultPlan.load(path)
    assert again.to_dict() == plan.to_dict()
    spec = plan.faults[0]
    assert (plan.rng(spec).randrange(10**9)
            == again.rng(again.faults[0]).randrange(10**9))


def test_fault_plan_validates_sites_ops_and_schedule():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultSpec("nowhere", "stall", 1)
    with pytest.raises(ValueError, match="unknown fault op"):
        FaultSpec("store.append", "explode", 1)
    with pytest.raises(ValueError, match="1-based"):
        FaultSpec("store.append", "torn_write", 0)
    with pytest.raises(ValueError, match="duplicate fault id"):
        FaultPlan([FaultSpec("store.append", "stall", 1, id="x"),
                   FaultSpec("store.fsync", "stall", 2, id="x")])


def test_active_plan_loads_from_environment(tmp_path, monkeypatch):
    path = str(tmp_path / "plan.json")
    FaultPlan([FaultSpec("lease.acquire", "io_error", 1)], seed=5).save(path)
    monkeypatch.delenv(PLAN_ENV, raising=False)
    assert active_plan() is None
    monkeypatch.setenv(PLAN_ENV, path)
    plan = active_plan()
    assert plan is not None and plan.seed == 5
    assert plan.state_dir == path + ".fired"       # shared scoreboard
    monkeypatch.delenv(PLAN_ENV)
    assert active_plan() is None


# ------------------------------------------------------------------ retry ---

def test_retry_delays_are_bounded_jittered_and_seeded():
    policy = RetryPolicy(attempts=5, base=0.05, cap=0.3, jitter=0.5)
    d1, d2 = policy.delays(seed="w1"), policy.delays(seed="w1")
    assert d1 == d2                                # same seed, same schedule
    assert policy.delays(seed="w2") != d1          # different worker differs
    assert len(d1) == 4
    for k, d in enumerate(d1):
        lo = min(0.3, 0.05 * 2 ** k)
        assert lo <= d <= lo * 1.5                 # jitter never unbounded


def test_with_retries_recovers_then_propagates_last_error():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"

    slept = []
    assert with_retries(flaky, policy=RetryPolicy(attempts=3, base=0.01),
                        seed="s", sleep=slept.append) == "ok"
    assert len(calls) == 3 and len(slept) == 2

    def broken():
        raise OSError("permanent")

    with pytest.raises(OSError, match="permanent"):
        with_retries(broken, policy=RetryPolicy(attempts=2, base=0.0),
                     seed="s", sleep=lambda _: None)


# -------------------------------------------------- injected store faults ---

def test_torn_append_crashes_then_resumes_byte_identical(tmp_path):
    _, ref, ref_merged = _reference(tmp_path)
    out = str(tmp_path / "chaos")
    spec = _plan_spec(out)
    plan = FaultPlan([FaultSpec("store.append", "torn_write", 1, 0.4)], seed=1)
    with pytest.raises(InjectedFault, match="torn append"):
        _drain_all(spec, out, faults=plan)
    # the torn batch never committed; resume recovers it exactly
    _drain_all(spec, out, faults=plan)
    assert (open(write_merged(spec, out), "rb").read()
            == open(ref_merged, "rb").read())


def test_dropped_fsync_still_commits_records(tmp_path):
    out = str(tmp_path / "s")
    spec = _plan_spec(out, fsync=True)
    plan = FaultPlan([FaultSpec("store.fsync", "drop_fsync", 1)], seed=2)
    _drain_all(spec, out, faults=plan)
    assert plan.fired()                            # the fsync was skipped...
    prog = sweep_progress(spec, out)
    assert prog["completed"] == prog["instances"]  # ...but the data is whole
    assert prog["damaged"] == 0


def test_transient_io_error_on_acquire_is_retried_away(tmp_path):
    path = str(tmp_path / "s.lease.json")
    plan = FaultPlan([FaultSpec("lease.acquire", "io_error", 1)], seed=4)
    with pytest.raises(OSError, match="injected io_error"):
        acquire_lease(path, "a:1:x", faults=plan)  # raw path crashes...
    fresh = FaultPlan([FaultSpec("lease.acquire", "io_error", 1)], seed=4)
    lease = acquire_lease_with_backoff(path, "a:1:x", faults=fresh)
    assert lease is not None                       # ...but backoff absorbs it
    assert fresh.fired()                           # the fault did fire
    lease.release()


def test_bitrot_mid_file_fails_loudly_everywhere(tmp_path):
    """One flipped byte in a committed record: the writer refuses, counts
    surface the damage, and merge refuses — nothing is silently dropped."""
    out = str(tmp_path / "s")
    spec = _plan_spec(out)
    _drain_all(spec, out)
    store = ShardStore(out, 0)
    with open(store.records_path, "r+b") as fh:
        fh.seek(5)
        fh.write(b"\x00")
    with pytest.raises(StoreDamaged, match="run fsck"):
        ShardStore(out, 0).open()                  # writer refuses
    scan = ShardStore(out, 0).open(readonly=True)
    assert scan.damaged == [(1, LINE_UNDECODABLE)]  # reader counts
    assert scan_damage(spec.n_shards, out) == {0: [(1, LINE_UNDECODABLE)]}
    # the O(1) manifest fast path cannot see pre-watermark bitrot; once the
    # manifest is gone/stale (the usual post-crash state) the full rescan
    # surfaces the damage in status too
    os.remove(store.manifest_path)
    assert shard_counts(ShardStore(out, 0))["damaged"] >= 1
    assert sweep_progress(spec, out)["damaged"] >= 1
    with pytest.raises(StoreDamaged, match="1 damaged record line"):
        merge_shards(spec, out)                    # merge refuses, with count
    assert merge_shards(spec, out, strict=False)   # escape hatch still exists


def test_checksum_catches_valid_json_with_wrong_payload(tmp_path):
    """Bitrot that still parses as JSON (the satellite's silent-skip bug
    could never see this) is caught by the per-record CRC."""
    rec = {"uid": "u1", "index": 0, "family": "chain", "winner": "a"}
    line = json.dumps(dict(rec, _crc=record_crc(rec)), sort_keys=True,
                      separators=(",", ":")).encode()
    assert parse_record_line(line + b"\n")[1] == LINE_OK
    tampered = line.replace(b'"winner":"a"', b'"winner":"b"')
    assert parse_record_line(tampered + b"\n")[1] == LINE_CRC_MISMATCH
    legacy = json.dumps(rec, sort_keys=True).encode()
    assert parse_record_line(legacy + b"\n")[1] == LINE_LEGACY
    assert parse_record_line(b'{"no": "uid"}\n')[1] == LINE_UNDECODABLE


# ------------------------------------------------------------------- fsck ---

def test_fsck_acceptance_corruption_to_byte_identical_merge(tmp_path):
    """The acceptance chain: torn append + bitrot -> loud refusal -> fsck
    (excise + quarantine + manifest rebuild) -> re-drain -> merge is
    byte-identical to the never-faulted reference."""
    _, ref, ref_merged = _reference(tmp_path)
    out = str(tmp_path / "chaos")
    spec = _plan_spec(out)
    plan = FaultPlan([
        FaultSpec("store.append", "torn_write", 1, 0.4),
        FaultSpec("store.append", "corrupt_byte", 2),
    ], seed=7)
    with pytest.raises(InjectedFault):
        _drain_all(spec, out, faults=plan)
    _drain_all(spec, out, faults=plan)             # resume; bitrot fires
    assert set(plan.fired()) == {f.id for f in plan.faults}
    with pytest.raises(StoreDamaged):
        write_merged(spec, out)

    report = fsck_store(out)
    kinds = {f.kind for f in report.findings}
    assert "mid_file_corruption" in kinds
    assert "manifest_drift" in kinds               # done flag cleared too
    assert report.remaining == 0
    qdir = os.path.join(out, "quarantine")
    assert os.path.exists(os.path.join(qdir, "damage-report.json"))
    quarantined = [f for f in os.listdir(qdir) if ".line-" in f]
    assert quarantined                             # damaged bytes preserved

    assert fsck_store(out).clean                   # idempotent
    _drain_all(spec, out)                          # re-runs ONLY the excised
    assert (open(write_merged(spec, out), "rb").read()
            == open(ref_merged, "rb").read())


def test_fsck_truncates_torn_tail_without_losing_records(tmp_path):
    out = str(tmp_path / "s")
    spec = _plan_spec(out)
    _drain_all(spec, out)
    store = ShardStore(out, 0)
    n_before = len(ShardStore(out, 0).open(readonly=True).records)
    with open(store.records_path, "ab") as fh:
        fh.write(b'{"uid": "half-written')       # kill mid-append
    report = fsck_store(out)
    assert [f.kind for f in report.findings
            if f.shard == 0 and f.kind == "torn_tail"]
    scan = ShardStore(out, 0).open(readonly=True)
    assert len(scan.records) == n_before and not scan.damaged


def test_fsck_rebuilds_drifted_manifest_from_records(tmp_path):
    out = str(tmp_path / "s")
    spec = _plan_spec(out)
    _drain_all(spec, out)
    store = ShardStore(out, 0)
    manifest = json.load(open(store.manifest_path))
    manifest["n_completed"] = 999                 # stale/foreign rewrite
    json.dump(manifest, open(store.manifest_path, "w"))
    report = fsck_store(out)
    assert [f for f in report.findings if f.kind == "manifest_drift"]
    fixed = json.load(open(store.manifest_path))
    assert fixed["n_completed"] == len(ShardStore(out, 0).open().records)
    assert fixed["done"] is True                  # no records lost: done kept


def test_fsck_handles_lease_and_engine_and_tmp_casualties(tmp_path):
    out = str(tmp_path / "s")
    spec = _plan_spec(out)
    store = ShardStore(out, 0)
    os.makedirs(out, exist_ok=True)
    with open(store.lease_path, "w") as fh:
        fh.write('{"owner": "half')               # corrupt lease
    with open(store.engine_path, "w") as fh:
        fh.write("not json")                      # corrupt engine state
    with open(os.path.join(out, "shard-0001.manifest.json.tmp"), "w") as fh:
        fh.write("{}")                            # orphaned atomic rename
    live = acquire_lease(ShardStore(out, 1).lease_path, "alive:1:x",
                         ttl=3600.0)
    report = fsck_store(out)
    kinds = {f.kind for f in report.findings}
    assert {"corrupt_lease", "corrupt_engine_state",
            "leftover_tmp", "live_lease"} <= kinds
    assert not os.path.exists(store.lease_path)   # shard stealable again
    assert not os.path.exists(store.engine_path)
    assert os.path.exists(ShardStore(out, 1).lease_path)  # live: untouched
    assert report.remaining == 1                  # the live-lease skip
    live.release()


def test_fsck_dry_run_reports_but_changes_nothing(tmp_path):
    out = str(tmp_path / "s")
    spec = _plan_spec(out)
    _drain_all(spec, out)
    store = ShardStore(out, 0)
    with open(store.records_path, "r+b") as fh:
        fh.seek(5)
        fh.write(b"\x00")
    before = open(store.records_path, "rb").read()
    report = fsck_store(out, dry_run=True)
    assert report.remaining > 0
    assert [f for f in report.findings if f.action.startswith("would_")]
    assert open(store.records_path, "rb").read() == before
    assert not os.path.exists(os.path.join(out, "quarantine"))


def test_fsck_quarantines_damaged_merged_artifact(tmp_path):
    out = str(tmp_path / "s")
    spec = _plan_spec(out)
    _drain_all(spec, out)
    write_merged(spec, out)
    merged = os.path.join(out, "merged.jsonl")
    with open(merged, "r+b") as fh:
        fh.seek(3)
        fh.write(b"\xff")
    report = fsck_store(out)
    assert [f for f in report.findings if f.kind == "damaged_merged"]
    assert not os.path.exists(merged)             # derived data: regenerate
    write_merged(spec, out)                       # regenerates cleanly
    assert fsck_store(out).clean


# -------------------------------------------------------- lease hardening ---

def test_corrupt_lease_reads_as_corrupt_and_is_stolen_with_warning(
        tmp_path, caplog):
    path = str(tmp_path / "s.lease.json")
    assert read_lease_ex(path) == (None, LEASE_ABSENT)
    with open(path, "w") as fh:
        fh.write('{"owner": "half')
    info, state = read_lease_ex(path)
    assert info is None and state == LEASE_CORRUPT
    with caplog.at_level(logging.WARNING, logger="repro.core.lease"):
        lease = acquire_lease(path, "thief:1:x")
    assert lease is not None                       # stale-equivalent: stolen
    assert any("corrupt" in r.message for r in caplog.records)
    info, state = read_lease_ex(path)
    assert state == LEASE_OK and info.owner == "thief:1:x"
    lease.release()


def test_lease_contention_backoff_exactly_one_winner_per_round(tmp_path):
    """N threads race acquire_lease_with_backoff: every round exactly one
    thread wins, the losers back off and return None (satellite c)."""
    path = str(tmp_path / "s.lease.json")
    n_threads, rounds = 8, 3
    for round_ in range(rounds):
        winners, barrier = [], threading.Barrier(n_threads)

        def race(i):
            barrier.wait()
            lease = acquire_lease_with_backoff(
                path, f"host{i}:1:r{round_}", ttl=30.0)
            if lease is not None:
                winners.append(lease)

        threads = [threading.Thread(target=race, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(winners) == 1, f"round {round_}: {len(winners)} winners"
        assert read_lease(path).owner == winners[0].owner
        winners[0].release()


def test_heartbeat_stall_loses_lease_to_takeover(tmp_path):
    """The duplicate-takeover race, scheduled: a heartbeat stall sleeps
    past the TTL, another host steals the shard, and the stalled owner
    gets LeaseLost instead of silently double-writing."""
    path = str(tmp_path / "s.lease.json")
    plan = FaultPlan([FaultSpec("lease.heartbeat", "stall", 1, arg=0.6)],
                     seed=9)
    victim = acquire_lease(path, "victim:1:x", ttl=0.3, faults=plan)
    assert victim is not None
    outcome = {}

    def stalled_beat():
        try:
            victim.heartbeat(force=True)
            outcome["result"] = "beat"
        except LeaseLost:
            outcome["result"] = "lost"

    t = threading.Thread(target=stalled_beat)
    t.start()
    time.sleep(0.45)                               # mid-stall, TTL expired
    thief = acquire_lease(path, "thief:2:y", ttl=30.0)
    assert thief is not None
    t.join()
    assert outcome["result"] == "lost"
    assert read_lease(path).owner == "thief:2:y"
    thief.release()


# --------------------------------------------------------- queue degrades ---

def _shard_done(out, shard):
    manifest = ShardStore(out, shard).read_manifest()
    return bool(manifest and manifest.get("done"))


def test_drain_skips_damaged_shard_and_recovers_after_fsck(tmp_path):
    out = str(tmp_path)
    spec = _plan_spec(out, n_shards=2)
    run_shard(spec, out, 0)                        # commit some records...
    store = ShardStore(out, 0)
    with open(store.records_path, "r+b") as fh:
        fh.seek(5)
        fh.write(b"\x00")                          # ...then rot one byte
    os.remove(store.manifest_path)                 # not marked done
    queue = open_queue(out)
    messages = []
    done = drain(queue, "host:1:a", poll=0.01, say=messages.append)
    assert done is False                           # damaged shard remains
    assert any("damaged" in m for m in messages)
    assert any("fsck" in m for m in messages)
    assert _shard_done(out, 1)                     # healthy shard drained
    assert not os.path.exists(store.lease_path)    # lease released, not held
    fsck_store(out)
    assert drain(queue, "host:1:a", poll=0.01) is True
    queue.merge()                                  # no refusal post-fsck


# ------------------------------------------------- merge crash resilience ---

def test_killed_merge_leaves_no_torn_store_and_reruns_identical(tmp_path):
    """SIGKILL during merge itself (satellite c): merge writes through a
    tmp + atomic rename, so a kill at ANY point leaves either the old
    bytes or the new bytes, never a torn merged.jsonl — simulated
    deterministically by strewing a half-written merge tmp around."""
    out = str(tmp_path / "s")
    spec = _plan_spec(out)
    _drain_all(spec, out)
    merged = write_merged(spec, out)
    good = open(merged, "rb").read()

    # a merge killed mid-write leaves only a torn tmp file
    os.remove(merged)
    with open(merged + ".tmp", "wb") as fh:
        fh.write(good[: len(good) // 2])           # torn half-merge
    report = fsck_store(out)                       # the orphan is swept up
    assert [f for f in report.findings if f.kind == "leftover_tmp"]
    assert not os.path.exists(merged + ".tmp")
    assert write_merged(spec, out) == merged       # re-run merges cleanly
    assert open(merged, "rb").read() == good       # byte-identical

    # re-running without fsck also recovers: the tmp is simply overwritten
    os.remove(merged)
    with open(merged + ".tmp", "wb") as fh:
        fh.write(good[: len(good) // 3])
    assert write_merged(spec, out) == merged
    assert open(merged, "rb").read() == good

    # a kill AFTER the rename but before cleanup: merged is already whole
    assert write_merged(spec, out) == merged
    assert open(merged, "rb").read() == good


def test_committed_final_line_bitrot_is_damage_not_torn_tail(tmp_path):
    """Bitrot on the LAST committed record of a done shard must not pass
    for an uncommitted torn tail: the manifest watermark covers it, so
    readers count it damaged, merge refuses, fsck clears `done`, and the
    queue re-drains the excised instance (regression: this used to strand
    the shard at done/0-records forever)."""
    _, _, ref_merged = _reference(tmp_path)
    good = open(ref_merged, "rb").read()
    root = str(tmp_path / "out")
    spec = _plan_spec(root)
    _drain_all(spec, root)

    # corrupt a byte of the FINAL line of shard 1 (keep its terminator)
    path = os.path.join(root, "shard-0001.jsonl")
    data = bytearray(open(path, "rb").read())
    final_start = data.rindex(b"\n", 0, len(data) - 1) + 1
    data[final_start + 5] ^= 0xFF
    open(path, "wb").write(bytes(data))

    ro = ShardStore(root, 1).open(readonly=True)
    assert ro.damaged, "committed final-line bitrot invisible to readers"
    with pytest.raises(StoreDamaged):
        ShardStore(root, 1).open()
    with pytest.raises(StoreDamaged, match="damaged record line"):
        merge_shards(spec, root)

    report = fsck_store(root)
    assert report.remaining == 0
    assert not ShardStore(root, 1).read_manifest().get("done"), \
        "fsck kept `done` on a shard that lost a committed record"

    # an UNCOMMITTED torn tail (past the watermark) still truncates freely
    with open(os.path.join(root, "shard-0000.jsonl"), "ab") as fh:
        fh.write(b'{"half of an append that never com')
    assert fsck_store(root).clean is False  # torn_tail finding, repaired

    _drain_all(spec, root)
    assert open(write_merged(spec, root), "rb").read() == good


# ------------------------------------------------ CLI chaos soak (scaled) ---

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _cli(module, args, extra_env=None):
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    for var in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS"):
        env.setdefault(var, "1")
    env.update(extra_env or {})
    return subprocess.run(
        [sys.executable, "-m", f"repro.launch.{module}"] + args,
        env=env, capture_output=True, text=True, timeout=300,
    )


def test_cli_chaos_drain_fsck_merge_byte_identical(tmp_path):
    """The acceptance soak, scaled down: a 2-host drain under a seeded
    fault plan (SIGKILL + torn append + bitrot + heartbeat stall), passes
    repeated with fsck until drained — merged output byte-identical to the
    fault-free run, every fault on the scoreboard, nothing silently lost."""
    grid = ["--chains", "8", "--chain-sizes", "3", "--lo", "16", "--hi", "64",
            "--families", "bilinear", "--sizes", "32", "--per-size", "2",
            "--shards", "4", "--max-measurements", "6",
            "--chunk-size", "2", "--save-every", "4"]
    straight, chaos = str(tmp_path / "straight"), str(tmp_path / "chaos")
    done = _cli("sweep", ["run", "--out", straight, "--workers", "1"] + grid)
    assert done.returncode == 0, done.stderr
    plan_cmd = _cli("sweep", ["plan", "--out", chaos] + grid)
    assert plan_cmd.returncode == 0, plan_cmd.stderr

    plan_path = str(tmp_path / "faults.json")
    FaultPlan([
        FaultSpec("store.append", "torn_write", 1, 0.5),
        FaultSpec("store.append", "corrupt_byte", 2),
        FaultSpec("campaign.step", "sigkill", 5),
        FaultSpec("lease.heartbeat", "stall", 3, arg=3.0),
    ], seed=2026).save(plan_path)
    chaos_env = {PLAN_ENV: plan_path}

    merged_ok = False
    for _ in range(8):
        fsck = _cli("fsck", ["--out", chaos])
        assert fsck.returncode in (0, 1), fsck.stderr
        res = _cli("queue", ["run", "--out", chaos, "--hosts", "2",
                             "--ttl", "2", "--heartbeat", "0.2",
                             "--poll", "0.1"], extra_env=chaos_env)
        if res.returncode == 0 and "merged" in res.stdout:
            merged_ok = True
            break
    assert merged_ok, f"chaos drain never converged:\n{res.stdout}\n{res.stderr}"

    fired = sorted(os.listdir(plan_path + ".fired"))
    assert len(fired) == 4, f"faults not all delivered: {fired}"
    assert (open(os.path.join(chaos, "merged.jsonl"), "rb").read()
            == open(os.path.join(straight, "merged.jsonl"), "rb").read())
    # final fsck: nothing left to repair (quarantine may hold old damage)
    assert _cli("fsck", ["--out", chaos]).returncode == 0
