"""AnomalyExplainer: decomposition exactness, machine registry, cause
recovery on the synthetic census (the acceptance scenario), kill/resume
byte-identity, and the CLI."""

import json
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core.sweep import SweepSpec, merge_shards, run_shard, synthetic_efficiencies
from repro.explain.attribution import attribute_algorithm, kernel_roofline
from repro.explain.classify import CAUSES, classify_anomaly, pick_winner_loser
from repro.explain.decompose import (
    KernelSpec,
    decompose_chain_dims,
    decompose_generalized,
    decompose_instance,
    kernel_name,
    kernels_from_record,
)
from repro.explain.runner import (
    ExplainSpec,
    explain_progress,
    explain_summary,
    explain_targets,
    merge_explained,
    resolve_machine,
    run_explain_shard,
)
from repro.roofline.terms import (
    DEFAULT_MACHINE,
    HBM_BW,
    PEAK_FLOPS,
    MachineSpec,
    get_machine,
    synthetic_machine,
)

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    for var in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS"):
        env.setdefault(var, "1")
    return env


# ---------------------------------------------------------- decomposition ---

def test_generalized_decomposition_is_flop_exact():
    from repro.expressions.generalized import FAMILIES

    for fam in ("gram", "distributive", "solve", "bilinear"):
        for n in (32, 64, 100):
            table = FAMILIES[fam](n=n).flops_table()
            kernels = decompose_generalized(fam, n)
            assert set(kernels) == set(table)
            for alg, ks in kernels.items():
                assert sum(k.flops for k in ks) == pytest.approx(
                    table[alg], rel=1e-12
                ), (fam, alg)


def test_chain_decomposition_is_flop_exact():
    from repro.expressions.chain import generate_chain_algorithms

    dims = (37, 91, 12, 55, 73)
    kernels = decompose_chain_dims(dims)
    algs = generate_chain_algorithms(dims)
    assert set(kernels) == {a.name for a in algs}
    for a in algs:
        assert sum(k.flops for k in kernels[a.name]) == float(a.flops)
        assert all(k.op == "gemm" for k in kernels[a.name])
        assert len(kernels[a.name]) == a.n_products


def test_kernel_spec_compact_roundtrip_and_labels():
    k = KernelSpec("gemm", (8, 4, 2))
    assert KernelSpec.from_compact(k.to_compact()) == k
    assert k.label == "gemm[8,4,2]"
    assert k.flops == 2.0 * 8 * 4 * 2
    assert kernel_name("alg0", 1, k) == "alg0::01.gemm"
    with pytest.raises(ValueError):
        KernelSpec("quantum_gemm", (8,))


def test_kernels_from_record_pointer_and_fallbacks():
    rec = {"family": "bilinear", "size": 32}
    by_alg = kernels_from_record(rec)                     # family fallback
    assert set(by_alg) == {"bilinear_left", "bilinear_right"}
    rec2 = {"family": "chain", "dims": [8, 4, 2, 6], "size": 5}
    assert kernels_from_record(rec2)                      # dims fallback
    rec3 = {"family": "bilinear", "size": 32,
            "params": {"size": 32, "seed": 0},
            "kernels": {"only": [["gemv", [32, 32]]]}}
    assert set(kernels_from_record(rec3)) == {"only"}     # pointer wins
    # an EMPTY pointer (chunk built pre-pointer, recorded post-upgrade)
    # must fall through to params, not return nothing
    rec4 = {"family": "bilinear", "size": 32, "kernels": {},
            "params": {"size": 32, "seed": 0}}
    assert set(kernels_from_record(rec4)) == {"bilinear_left", "bilinear_right"}


# ---------------------------------------------------- machines / roofline ---

def test_machine_registry_and_backcompat_aliases():
    tpu = get_machine("tpu-v5e")
    assert tpu is DEFAULT_MACHINE
    assert PEAK_FLOPS == tpu.peak_flops and HBM_BW == tpu.hbm_bw
    assert get_machine("cpu-1core").dispatch_overhead_s > 0
    with pytest.raises(KeyError):
        get_machine("abacus")
    rt = MachineSpec.from_dict(tpu.to_dict())
    assert rt == tpu


def test_synthetic_machine_predicts_pure_compute():
    m = synthetic_machine("sweep:test", 5e10)
    k = KernelSpec("gemm", (64, 64, 64))
    t, bound = kernel_roofline(k, m)
    assert t == pytest.approx(k.flops / 5e10)
    assert bound == "compute"
    # no memory system: bytes never dominate
    assert m.t_memory(1e18) == 0.0


def test_memory_bound_detection():
    m = MachineSpec("mem-starved", peak_flops=1e15, hbm_bw=1e6)
    t, bound = kernel_roofline(KernelSpec("gemv", (64, 64)), m)
    assert bound == "memory"


# -------------------------------------------------------------- classify ---

def _attr(alg, t_total, rows, machine):
    kernels = [KernelSpec(op, tuple(shape)) for op, shape, _ in rows]
    times = {
        kernel_name(alg, i, k): t for i, (k, (_, _, t)) in
        enumerate(zip(kernels, rows))
    }
    return attribute_algorithm(alg, t_total, kernels, times, machine)


def test_pick_winner_loser_both_reasons():
    base = {
        "uid": "u", "min_flops_algs": ["a0", "a1"],
        "ranks": {"a0": 1, "a1": 2, "b": 1},
        "mean_ranks": {"a0": 1.2, "a1": 2.0, "b": 1.0},
    }
    w, l = pick_winner_loser({**base, "reason": "min_flops_split"})
    assert (w, l) == ("b", "a1")  # best rank, then best mean rank, wins
    rec1 = {
        "uid": "u", "reason": "faster_outside_min_flops",
        "min_flops_algs": ["a0"],
        "ranks": {"a0": 2, "b": 1}, "mean_ranks": {"a0": 2.0, "b": 1.0},
    }
    assert pick_winner_loser(rec1) == ("b", "a0")
    with pytest.raises(ValueError):
        pick_winner_loser({
            "uid": "u", "reason": "none", "min_flops_algs": ["a0"],
            "ranks": {"a0": 1, "b": 2}, "mean_ranks": {"a0": 1.0, "b": 2.0},
        })


def test_classify_kernel_efficiency_and_dispatch():
    m = synthetic_machine("s", 1e9)
    rec = {"uid": "u", "reason": "faster_outside_min_flops"}
    # loser's single kernel runs 2x over the roof; winner at the roof
    w = _attr("w", 1.0e-3, [("gemm", (100, 100, 50), 1.0e-3)], m)
    l = _attr("l", 2.0e-3, [("gemm", (100, 100, 50), 2.0e-3)], m)
    e = classify_anomaly(rec, w, l)
    assert e.cause == "shape_kernel_efficiency"
    assert e.offending_algorithm == "l"
    assert e.offending_kernel == "gemm[100,100,50]"
    assert e.evidence == pytest.approx(1.0)
    # same kernels, but the gap lives between kernels (residual)
    l2 = _attr("l", 3.0e-3, [("gemm", (100, 100, 50), 1.0e-3)], m)
    e2 = classify_anomaly(rec, w, l2)
    assert e2.cause == "dispatch_overhead"
    assert e2.offending_kernel is None
    # memory-bound offender
    mm = MachineSpec("m", peak_flops=1e15, hbm_bw=1e6)
    w3 = _attr("w", 1.0e-3, [("gemv", (64, 64), 1.0e-3)], mm)
    l3 = _attr("l", 9.0e-3, [("gemv", (64, 64), 9.0e-3)], mm)
    e3 = classify_anomaly(rec, w3, l3)
    assert e3.cause == "memory_bound_segment"
    # no gap: the census ranking is not reproduced (evidence 0 without a
    # probe; the runner attaches the measured flip probability)
    e4 = classify_anomaly(rec, l, w)
    assert e4.cause == "not_reproducible" and e4.evidence == 0.0
    e5 = classify_anomaly(rec, l, w, flip_probability=0.75)
    assert e5.cause == "not_reproducible" and e5.evidence == 0.75


def test_classify_cache_reuse_pair_and_calibrated_roofline_split():
    m = synthetic_machine("s", 1e9)
    rec = {"uid": "u", "reason": "min_flops_split"}
    # winner's whole run beats its own kernel sum (negative residual):
    # adjacent kernels share cache; the pair with the largest handed-over
    # intermediate is named
    rows = [("gemm", (100, 100, 50), 1.0e-3), ("gemm", (100, 50, 100), 1.0e-3)]
    w = _attr("w", 1.2e-3, rows, m)
    l = _attr("l", 2.0e-3, rows, m)
    e = classify_anomaly(rec, w, l)
    assert e.cause == "cache_reuse_pair"
    assert e.offending_algorithm == "w"
    assert e.offending_kernel == "gemm[100,100,50]+gemm[100,50,100]"
    assert e.evidence == pytest.approx(1.0)
    # calibrated dispatch: both algorithms at their (dispatch-inclusive)
    # floors, the loser simply needs one more launch
    md = MachineSpec("d", peak_flops=1e12, hbm_bw=0.0,
                     dispatch_overhead_s=1e-6)
    t_k = 1e-6 + 2.0 * 100 * 100 * 50 / 1e12
    w2 = _attr("w", 2 * t_k, [("gemm", (100, 100, 50), t_k)] * 2, md)
    l2 = _attr("l", 3 * t_k, [("gemm", (100, 100, 50), t_k)] * 3, md)
    e2 = classify_anomaly(rec, w2, l2)
    assert e2.cause == "dispatch_overhead"
    # half the gap is the extra launch, the other half the extra math
    assert e2.evidence == pytest.approx(0.5)
    # calibrated memory: equal dispatch count, the loser's floor is bytes
    mm = MachineSpec("m", peak_flops=1e15, hbm_bw=1e8,
                     dispatch_overhead_s=1e-9)
    t_mem = 4.0 * (64 * 64 + 64 + 64) / 1e8
    w3 = _attr("w", 1e-6, [("dot", (64,), 1e-6)], mm)
    l3 = _attr("l", t_mem, [("gemv", (64, 64), t_mem)], mm)
    e3 = classify_anomaly(rec, w3, l3)
    assert e3.cause == "memory_bound_segment"
    assert e3.offending_kernel == "gemv[64,64]"


def test_classify_frequency_bimodality_takes_precedence():
    from repro.explain.distributions import SessionBimodality

    m = synthetic_machine("s", 1e9)
    rec = {"uid": "u", "reason": "min_flops_split"}
    w = _attr("w", 1.0e-3, [("gemm", (100, 100, 50), 1.0e-3)], m)
    l = _attr("l", 2.0e-3, [("gemm", (100, 100, 50), 2.0e-3)], m)
    bi = SessionBimodality(n_names=6, n_bimodal=5, mean_separation=30.0)
    e = classify_anomaly(rec, w, l, bimodality=bi)
    assert e.cause == "frequency_bimodality"
    assert e.evidence == pytest.approx(5 / 6)
    uni = SessionBimodality(n_names=6, n_bimodal=1, mean_separation=9.0)
    assert classify_anomaly(rec, w, l, bimodality=uni).cause == \
        "shape_kernel_efficiency"


def test_classify_insignificant_gap_needs_probe_confirmation():
    m = synthetic_machine("s", 1e9)
    rec = {"uid": "u", "reason": "min_flops_split"}
    w = _attr("w", 1.00e-3, [("gemm", (100, 100, 50), 1.00e-3)], m)
    l = _attr("l", 1.01e-3, [("gemm", (100, 100, 50), 1.01e-3)], m)
    # tiny gap, z below threshold, probe confirms the flip
    e = classify_anomaly(rec, w, l, gap_zscore=0.4, flip_probability=0.5)
    assert e.cause == "not_reproducible" and e.evidence == 0.5
    # same gap but the probe says the ranking holds: fall through to
    # the component logic (the whole gap is the kernel's excess here)
    e2 = classify_anomaly(rec, w, l, gap_zscore=0.4, flip_probability=0.0)
    assert e2.cause == "shape_kernel_efficiency"
    # significant gap never probes its way out
    e3 = classify_anomaly(rec, w, l, gap_zscore=25.0, flip_probability=0.9)
    assert e3.cause == "shape_kernel_efficiency"


# ----------------------------------------------------------- distributions ---

def test_mode_mixture_detects_two_frequency_modes():
    from repro.explain.distributions import mode_mixture

    rng = np.random.default_rng(7)
    base = np.exp(rng.normal(0.0, 0.01, 12))
    mask = np.array([True] * 4 + [False] * 8)
    bimodal = np.where(mask, base * 1.5, base)
    v = mode_mixture(bimodal)
    assert v.is_bimodal and v.minority == 4
    assert v.separation > 8.0
    assert v.mu_hi > v.mu_lo
    uni = mode_mixture(base)
    assert not uni.is_bimodal
    # a lone outlier is not a mode
    one = np.where(np.arange(12) == 0, base * 1.5, base)
    assert not mode_mixture(one).is_bimodal
    # exact two-level repeats (noiseless slow mode) separate infinitely
    v2 = mode_mixture([1.0] * 8 + [1.5] * 4)
    assert v2.is_bimodal and v2.separation > 1e6
    # degenerate sizes never crash
    assert not mode_mixture([1.0]).is_bimodal
    assert not mode_mixture([]).is_bimodal


def test_mode_mixture_false_positive_rate_on_unimodal_samples():
    from repro.explain.distributions import mode_mixture

    rng = np.random.default_rng(0)
    hits = sum(
        mode_mixture(np.exp(rng.normal(0.0, 0.02, 12))).is_bimodal
        for _ in range(500)
    )
    assert hits == 0, f"{hits}/500 unimodal sample sets flagged bimodal"


def test_session_bimodality_majority_vote():
    from repro.explain.distributions import session_bimodality

    rng = np.random.default_rng(3)

    def bimodal():
        x = np.exp(rng.normal(0.0, 0.01, 12))
        return np.where(rng.random(12) < 0.4, x * 1.5, x)

    def unimodal():
        return np.exp(rng.normal(0.0, 0.01, 12))

    s = session_bimodality({f"n{i}": bimodal() for i in range(6)})
    assert s.is_bimodal and s.share == 1.0 and s.mean_separation > 8.0
    s2 = session_bimodality(
        {**{f"b{i}": bimodal() for i in range(2)},
         **{f"u{i}": unimodal() for i in range(4)}}
    )
    assert not s2.is_bimodal and 0.0 < s2.share < 0.5
    assert not session_bimodality({}).is_bimodal


def test_median_gap_zscore():
    from repro.explain.distributions import median_gap_zscore

    rng = np.random.default_rng(5)
    w = 1.0 * np.exp(rng.normal(0.0, 0.02, 12))
    l = 2.0 * np.exp(rng.normal(0.0, 0.02, 12))
    gap, se, z = median_gap_zscore(w, l)
    assert gap == pytest.approx(1.0, rel=0.1) and se > 0 and z > 10
    # indistinguishable samples: |z| small
    _, _, z2 = median_gap_zscore(w, 1.0 * np.exp(rng.normal(0.0, 0.02, 12)))
    assert abs(z2) < 3
    # noiseless backend: exact tie is z=0, any gap is z=inf
    assert median_gap_zscore([1.0, 1.0], [1.0, 1.0])[2] == 0.0
    assert median_gap_zscore([1.0, 1.0], [2.0, 2.0])[2] == float("inf")


# -------------------------------------------------------------- calibration ---

def test_machine_eff_curve_interpolation_and_roundtrip():
    m = MachineSpec("c", peak_flops=1e12, hbm_bw=0.0,
                    eff_curve=((1e3, 0.1), (1e6, 1.0)))
    assert m.efficiency_at(1e2) == pytest.approx(0.1)   # clamped low
    assert m.efficiency_at(1e9) == pytest.approx(1.0)   # clamped high
    mid = m.efficiency_at(10 ** 4.5)                    # log-midpoint
    assert mid == pytest.approx(0.55)
    assert m.t_compute(1e3) == pytest.approx(1e3 / 1e11)
    # JSON round-trip keeps the curve (lists -> tuples normalised)
    rt = MachineSpec.from_dict(json.loads(json.dumps(m.to_dict())))
    assert rt == m
    # no curve = nominal peak (the historical behaviour)
    assert synthetic_machine("s", 1e9).t_compute(1e9) == pytest.approx(1.0)


def test_calibration_fit_recovers_synthetic_truth(tmp_path):
    from repro.explain.calibrate import (
        fit_calibration,
        load_calibrated_machine,
        micro_points_synthetic,
        synthetic_truth,
    )

    base = MachineSpec("cpu-test", peak_flops=5e10, hbm_bw=0.0)
    truth = synthetic_truth(base, dispatch_s=2e-6, eff_knee=64.0)
    points = micro_points_synthetic(truth, reps=25, seed=0, rel_sigma=0.01)
    res = fit_calibration(base, points)
    # a curved true efficiency bends the small-size points, so the linear
    # intercept carries an irreducible bias — dispatch and eff(flops) are
    # only jointly identifiable (with a flat truth the fit is exact, see
    # the tiny-instance acceptance test)
    assert res.dispatch_s == pytest.approx(2e-6, rel=0.35)
    assert res.r2 > 0.9
    # the fitted efficiency curve tracks eff(n) = n/(n+64) at the large
    # sizes (small ones are dispatch-dominated, so their math time — and
    # hence their efficiency — is poorly constrained by construction)
    for p in res.points:
        if p.n >= 64:
            assert p.efficiency == pytest.approx(p.n / (p.n + 64.0), rel=0.3)
    # calibrated spec round-trips through the save file
    path = str(tmp_path / "cal.json")
    res.save(path)
    loaded = load_calibrated_machine(path)
    assert loaded == res.machine
    assert loaded.dispatch_overhead_s == res.dispatch_s
    # the split is now meaningful below n=256: a tiny GEMM's floor is
    # mostly dispatch, a big one's is math
    tiny = loaded.t_compute(KernelSpec("gemm", (16, 16, 16)).flops)
    big = loaded.t_compute(KernelSpec("gemm", (256, 256, 256)).flops)
    assert loaded.dispatch_overhead_s > tiny
    assert loaded.dispatch_overhead_s < big


# --------------------------------------------- the census under explanation ---

#: Deterministic cost-model census with strong injected per-algorithm
#: efficiency factors (eff_sigma) and weak measurement noise — the
#: acceptance scenario's ground truth.
def _census_spec(**overrides):
    kwargs = dict(
        name="t",
        families={
            "chain": {"count": 20, "n_matrices": [3, 4], "lo": 24, "hi": 128},
            "bilinear": {"sizes": [32, 64], "per_size": 4},
        },
        n_shards=2,
        backend="cost_model",
        eff_sigma=0.25,
        noise_sigma=0.01,
        max_measurements=9,
        chunk_size=4,
        save_every=5,
    )
    kwargs.update(overrides)
    return SweepSpec(**kwargs)


@pytest.fixture(scope="module")
def census(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("census"))
    spec = _census_spec()
    spec.save(os.path.join(root, "spec.json"))
    for s in range(spec.n_shards):
        run_shard(spec, root, s)
    records = merge_shards(spec, root)
    anomalies = [r for r in records if r["is_anomaly"]]
    assert len(anomalies) >= 5, "fixture census must produce anomalies"
    return root, spec, records


def test_census_records_carry_explain_pointers(census):
    _, spec, records = census
    for r in records:
        assert r["params"], r["uid"]
        assert r["base_seed"] == spec.base_seed
        assert set(r["flops"]) == set(r["kernels"])
        # the pointer reproduces the pure-function decomposition
        assert r["kernels"] == {
            alg: [k.to_compact() for k in ks]
            for alg, ks in decompose_instance(r["family"], r["params"]).items()
        }


def test_explainer_recovers_injected_cause(census, tmp_path):
    """Acceptance: >= 90% of anomalies classified as shape-dependent kernel
    efficiency with the offending kernel identified, against the ground
    truth reconstructed from the synthetic machine's injected factors."""
    root, spec, records = census
    espec = ExplainSpec(census=root, n_shards=2, chunk_size=4, save_every=5)
    eroot = str(tmp_path / "explain")
    for s in range(espec.n_shards):
        run_explain_shard(espec, eroot, s)
    explained = merge_explained(espec, eroot)
    anomalies = [r for r in records if r["is_anomaly"]]
    assert [e["uid"] for e in explained] == [r["uid"] for r in anomalies]

    by_uid = {r["uid"]: r for r in records}
    n_cause = n_kernel = 0
    for e in explained:
        assert e["cause"] in CAUSES
        assert 0.0 <= e["evidence"] <= 1.0
        rec = by_uid[e["uid"]]
        if e["cause"] != "shape_kernel_efficiency":
            continue
        n_cause += 1
        # ground truth: redraw the injected efficiency factors and find the
        # kernel with the largest expected deviation from the roofline
        eff = synthetic_efficiencies(
            rec["flops"],
            np.random.default_rng([rec["base_seed"], rec["index"], 1]),
            spec.eff_sigma,
        )
        kernels = kernels_from_record(rec)
        expected = max(
            (
                (abs(k.flops * (eff[alg] - 1.0)), alg, k.label)
                for alg in (e["winner"], e["loser"])
                for k in kernels[alg]
            ),
            key=lambda t: t[0],
        )
        if (e["offending_algorithm"], e["offending_kernel"]) == expected[1:]:
            n_kernel += 1
    assert n_cause >= 0.9 * len(explained), (n_cause, len(explained))
    assert n_kernel >= 0.9 * n_cause, (n_kernel, n_cause)


def test_explain_resume_is_bit_identical(census, tmp_path):
    root, _, _ = census
    espec = ExplainSpec(census=root, n_shards=2, chunk_size=3, save_every=3)
    straight, chopped = str(tmp_path / "a"), str(tmp_path / "b")
    run_explain_shard(espec, straight, 0)
    for _ in range(300):
        run_explain_shard(espec, chopped, 0, max_steps=3)
        manifest = os.path.join(chopped, "shard-0000.manifest.json")
        if (os.path.exists(manifest)
                and json.load(open(manifest)).get("done")):
            break
    else:
        pytest.fail("explain shard did not finish in 300 slices")
    assert (open(os.path.join(chopped, "shard-0000.jsonl")).read()
            == open(os.path.join(straight, "shard-0000.jsonl")).read())


def test_explain_targets_and_progress(census, tmp_path):
    root, _, records = census
    espec = ExplainSpec(census=root, n_shards=3)
    _, targets = explain_targets(espec)
    assert [t["uid"] for t in targets] == [
        r["uid"] for r in records if r["is_anomaly"]
    ]
    eroot = str(tmp_path / "explain")
    prog = explain_progress(espec, eroot)
    assert prog["anomalies"] == len(targets) and prog["completed"] == 0
    run_explain_shard(espec, eroot, 1)
    prog = explain_progress(espec, eroot)
    assert prog["completed"] == prog["shards"][1]["done"] > 0


def test_resolve_machine_follows_backend(census):
    root, spec, _ = census
    espec = ExplainSpec(census=root)
    m = resolve_machine(espec, spec)
    assert m.peak_flops == spec.flop_rate and m.hbm_bw == 0.0
    espec2 = ExplainSpec(census=root, machine="tpu-v5e")
    assert resolve_machine(espec2, spec).name == "tpu-v5e"
    wall = _census_spec(backend="wall_clock")
    assert resolve_machine(espec, wall).name == "cpu-1core"


def test_explain_summary_and_tables(census, tmp_path):
    root, _, _ = census
    espec = ExplainSpec(census=root, n_shards=1)
    eroot = str(tmp_path / "explain")
    run_explain_shard(espec, eroot, 0)
    explained = merge_explained(espec, eroot)
    s = explain_summary(explained)
    assert s["total"] == len(explained)
    assert abs(sum(a["share"] for a in s["by_cause"].values()) - 1.0) < 1e-9
    assert 0.0 <= s["mean_evidence"] <= 1.0

    from repro.launch.report_md import explain_tables

    md = explain_tables(explained, name="t")
    assert "anomaly root causes" in md
    assert "| cause |" in md and "shape_kernel_efficiency" in md


# ------------------------------------------------ taxonomy v2 ground truth ---

def _run_census(root, **overrides):
    spec = _census_spec(**overrides)
    spec.save(os.path.join(root, "spec.json"))
    for s in range(spec.n_shards):
        run_shard(spec, root, s)
    return spec, merge_shards(spec, root)


def _run_explain(root, eroot, **espec_overrides):
    espec = ExplainSpec(census=root, n_shards=2, chunk_size=4, save_every=5,
                        **espec_overrides)
    for s in range(espec.n_shards):
        run_explain_shard(espec, eroot, s)
    return espec, merge_explained(espec, eroot)


def test_explainer_recovers_injected_bimodality(tmp_path):
    """Acceptance: anomalies of a turbo-regime (bimodal simulated) census
    come back >= 90% frequency_bimodality with evidence > 0 — the
    mode-mixture test sees the regime in the segment distributions."""
    root = str(tmp_path / "census")
    os.makedirs(root)
    spec, records = _run_census(
        root,
        families={
            "chain": {"count": 60, "n_matrices": [3, 4], "lo": 24, "hi": 128},
            "bilinear": {"sizes": [32, 48, 64], "per_size": 10},
        },
        backend="simulated", eff_sigma=0.02, noise_sigma=0.01,
        bimodal_shift=0.5, bimodal_prob=0.35, bimodal_frac=1.0,
        max_measurements=12,
    )
    anomalies = [r for r in records if r["is_anomaly"]]
    assert len(anomalies) >= 5, "bimodal census must produce anomalies"
    # eps < 0: every session runs its full budget, so each measured name
    # holds max_measurements samples for the mixture test
    _, explained = _run_explain(root, str(tmp_path / "explain"),
                                eps=-1.0, max_measurements=12)
    hits = [e for e in explained
            if e["cause"] == "frequency_bimodality" and e["evidence"] > 0]
    assert len(hits) >= 0.9 * len(explained), (len(hits), len(explained))
    for e in hits:
        assert e["bimodality"]["is_bimodal"]
        assert e["bimodality"]["mean_separation"] >= 8.0


def test_explainer_recovers_injected_cache_reuse_pair(tmp_path):
    """Acceptance: anomalies whose winner carries an injected whole-run
    cache-reuse saving (and whose loser does not) come back >= 90%
    cache_reuse_pair, with the pair named from the winner's kernels."""
    from repro.core.sweep import synthetic_instance_model

    root = str(tmp_path / "census")
    os.makedirs(root)
    spec, records = _run_census(
        root,
        families={
            "chain": {"count": 40, "n_matrices": [3, 4], "lo": 24, "hi": 128},
            "bilinear": {"sizes": [32, 48, 64], "per_size": 8},
        },
        eff_sigma=0.0, noise_sigma=0.01,
        cache_reuse_frac=0.5, cache_reuse_saving=0.4,
        max_measurements=12,
    )
    _, explained = _run_explain(root, str(tmp_path / "explain"))
    by_uid = {r["uid"]: r for r in records}
    truth = []
    for e in explained:
        r = by_uid[e["uid"]]
        model = synthetic_instance_model(
            spec, r["index"], r["flops"],
            {a: len(ks) for a, ks in r["kernels"].items()},
            base_seed=r["base_seed"],
        )
        if (model.cache_saving[e["winner"]] > 0
                and model.cache_saving[e["loser"]] == 0):
            truth.append(e)
    assert len(truth) >= 5, "census must produce winner-reused anomalies"
    hits = [e for e in truth
            if e["cause"] == "cache_reuse_pair" and e["evidence"] > 0]
    assert len(hits) >= 0.9 * len(truth), (len(hits), len(truth))
    for e in hits:
        # the pair is named, belongs to the winner, and is adjacent
        assert e["offending_algorithm"] == e["winner"]
        a, b = e["offending_kernel"].split("+")
        labels = [k["kernel"] for k in e["attribution"]["winner"]["kernels"]]
        i = labels.index(a)
        assert labels[i + 1] == b
        # and the winner's whole run beats its kernel sum
        assert e["attribution"]["winner"]["residual"] < 0


def test_explainer_flags_pure_noise_flips_not_reproducible(tmp_path):
    """Acceptance: anomalies of an eff_sigma=0 census (equal-FLOPs ties
    ranked on measurement noise alone) come back >= 90% not_reproducible,
    each backed by a probed flip probability > 0."""
    root = str(tmp_path / "census")
    os.makedirs(root)
    spec, records = _run_census(
        root,
        families={"bilinear": {"sizes": [32, 48, 64, 96], "per_size": 10}},
        eff_sigma=0.0, noise_sigma=0.05, max_measurements=12,
    )
    anomalies = [r for r in records if r["is_anomaly"]]
    assert len(anomalies) >= 5, "noise census must produce anomalies"
    _, explained = _run_explain(root, str(tmp_path / "explain"))
    hits = [e for e in explained
            if e["cause"] == "not_reproducible" and e["evidence"] > 0]
    assert len(hits) >= 0.9 * len(explained), (len(hits), len(explained))
    for e in hits:
        assert e["flip_probability"] is not None
        assert e["evidence"] == e["flip_probability"]


def test_explainer_calibrated_dispatch_split_on_tiny_instances(tmp_path):
    """Acceptance: a dispatch-dominated tiny-instance census is
    misattributed to kernel efficiency against the nominal (dispatch-free)
    roofline, and comes back >= 90% dispatch_overhead once the explain
    campaign reconciles against a machine calibrated from
    micro-measurements — the calibrated memory-vs-dispatch split."""
    from repro.explain.calibrate import (
        fit_calibration,
        micro_points_synthetic,
        synthetic_truth,
    )

    root = str(tmp_path / "census")
    os.makedirs(root)
    spec, records = _run_census(
        root,
        families={"solve": {"sizes": [8, 12, 16, 24, 32], "per_size": 6}},
        eff_sigma=0.0, noise_sigma=0.01, dispatch_s=2e-6,
        max_measurements=12,
    )
    anomalies = [r for r in records if r["is_anomaly"]]
    assert len(anomalies) >= 5, "dispatch census must produce anomalies"

    # uncalibrated: the per-kernel dispatch masquerades as inefficiency
    _, naive = _run_explain(root, str(tmp_path / "naive"))
    assert any(e["cause"] != "dispatch_overhead" for e in naive)

    # calibrate the census's machine from synthetic micro-measurements of
    # the same ground truth (flat efficiency, 2us dispatch), then explain
    # against the fitted spec
    base = MachineSpec(f"sweep:{spec.name}", peak_flops=spec.flop_rate,
                       hbm_bw=0.0)
    truth = synthetic_truth(base, dispatch_s=spec.dispatch_s, eff_knee=0.0)
    points = micro_points_synthetic(
        truth, sizes=(8, 12, 16, 24, 32, 48, 64, 96, 128),
        reps=25, seed=0, rel_sigma=0.01,
    )
    result = fit_calibration(base, points)
    assert result.dispatch_s == pytest.approx(spec.dispatch_s, rel=0.2)
    cal_path = str(tmp_path / "cal.json")
    result.save(cal_path)

    _, explained = _run_explain(root, str(tmp_path / "explain"),
                                machine_file=cal_path)
    hits = [e for e in explained
            if e["cause"] == "dispatch_overhead" and e["evidence"] > 0]
    assert len(hits) >= 0.9 * len(explained), (len(hits), len(explained))
    # the dispatch term of the roofline difference carries the gap
    for e in hits:
        assert e["components"]["roofline_dispatch"] > 0


# -------------------------------------------------------- CLI + kill/resume ---

#: Census grid for the CLI tests: enough anomalies that a mid-run SIGKILL
#: lands while explain shards are in flight.
CLI_CENSUS = [
    "--chains", "40", "--chain-sizes", "3,4", "--lo", "24", "--hi", "160",
    "--families", "bilinear", "--sizes", "32,64", "--per-size", "6",
    "--shards", "4", "--eff-sigma", "0.3", "--noise-sigma", "0.01",
    "--max-measurements", "9", "--chunk-size", "4", "--save-every", "5",
]
#: eps < 0 never converges: every explanation runs its full measurement
#: budget, keeping the campaign long enough to kill deterministically.
CLI_EXPLAIN = ["--eps", "-1.0", "--max-measurements", "24",
               "--shards", "4", "--chunk-size", "2", "--save-every", "4"]


def _cli(module, args, **kwargs):
    cmd = [sys.executable, "-m", module] + args
    return subprocess.run(
        cmd, env=_env(), capture_output=True, text=True, timeout=300, **kwargs
    )


@pytest.fixture(scope="module")
def cli_census(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("cli") / "census")
    done = _cli("repro.launch.sweep",
                ["run", "--out", out, "--workers", "2"] + CLI_CENSUS)
    assert done.returncode == 0, done.stderr
    return out


def test_cli_kill_resume_explain_identical(cli_census, tmp_path):
    """The acceptance scenario: multi-worker explain, SIGKILL of the whole
    process group mid-campaign, resume, merged explanations identical to an
    uninterrupted run."""
    straight, killed = str(tmp_path / "straight"), str(tmp_path / "killed")

    done = _cli("repro.launch.explain",
                ["run", "--census", cli_census, "--out", straight,
                 "--workers", "2"] + CLI_EXPLAIN)
    assert done.returncode == 0, done.stderr
    n_anoms = open(os.path.join(straight, "merged.jsonl")).read().count("\n")
    assert n_anoms >= 8, "census produced too few anomalies; enlarge CLI_CENSUS"

    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.explain", "run",
         "--census", cli_census, "--out", killed, "--workers", "2"]
        + CLI_EXPLAIN,
        env=_env(), start_new_session=True,
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
    )
    try:
        deadline = time.time() + 120
        while time.time() < deadline:
            if proc.poll() is not None:
                break
            jsonls = [f for f in os.listdir(killed)
                      if f.endswith(".jsonl")] if os.path.isdir(killed) else []
            if any(os.path.getsize(os.path.join(killed, f)) > 0 for f in jsonls):
                break
            time.sleep(0.005)
        was_running = proc.poll() is None
        os.killpg(proc.pid, signal.SIGKILL)
    finally:
        proc.wait()
    assert was_running, "explain finished before the kill; enlarge the grid"

    resumed = _cli("repro.launch.explain",
                   ["run", "--out", killed, "--workers", "2"])
    assert resumed.returncode == 0, resumed.stderr
    assert (open(os.path.join(killed, "merged.jsonl")).read()
            == open(os.path.join(straight, "merged.jsonl")).read())

    report = _cli("repro.launch.explain", ["report", "--out", killed])
    assert report.returncode == 0, report.stderr
    assert "anomaly root causes" in report.stdout


def test_cli_status_merge_and_plan_guard(cli_census, tmp_path):
    out = str(tmp_path / "explain")
    plan = _cli("repro.launch.explain",
                ["plan", "--census", cli_census, "--out", out, "--shards", "2"])
    assert plan.returncode == 0, plan.stderr
    assert "anomaly explanations over 2 shards" in plan.stdout
    # out == census would interleave census and explain shard files
    clash = _cli("repro.launch.explain",
                 ["plan", "--census", cli_census, "--out", cli_census])
    assert clash.returncode != 0
    run = _cli("repro.launch.explain", ["run", "--out", out, "--workers", "2"])
    assert run.returncode == 0, run.stderr
    status = _cli("repro.launch.explain", ["status", "--out", out])
    assert status.returncode == 0 and "anomalies explained" in status.stdout
    merge = _cli("repro.launch.explain", ["merge", "--out", out])
    assert merge.returncode == 0 and "explanations ->" in merge.stdout
    rj = _cli("repro.launch.explain", ["report", "--out", out, "--json"])
    assert rj.returncode == 0
    summary = json.loads(rj.stdout)
    assert summary["total"] > 0 and "by_cause" in summary


def test_cli_status_on_partially_merged_shard_store(cli_census, tmp_path):
    """`status` must stay truthful while the campaign is part-way done:
    some shards fully explained, one paused mid-chunk (engine state on
    disk), others untouched — and again after a partial `merge`."""
    out = str(tmp_path / "explain")
    plan = _cli("repro.launch.explain",
                ["plan", "--census", cli_census, "--out", out,
                 "--shards", "3"] + CLI_EXPLAIN[:2])
    assert plan.returncode == 0, plan.stderr
    # shard 0: complete; shard 1: paused mid-chunk; shard 2: untouched
    done = _cli("repro.launch.explain", ["work", "--out", out, "--shards", "0"])
    assert done.returncode == 0, done.stderr
    paused = _cli("repro.launch.explain",
                  ["work", "--out", out, "--shards", "1",
                   "--max-steps-per-shard", "3"])
    assert paused.returncode == 0, paused.stderr
    status = _cli("repro.launch.explain", ["status", "--out", out])
    assert status.returncode == 0, status.stderr
    lines = status.stdout.splitlines()
    assert "anomalies explained" in lines[0]
    shard_lines = [l for l in lines if "shard" in l]
    assert len(shard_lines) == 3
    import re

    counts = {}
    for line in shard_lines:
        m = re.search(r"shard\s+(\d+): (\d+)/(\d+)", line)
        counts[int(m.group(1))] = (int(m.group(2)), int(m.group(3)))
    assert counts[0][0] == counts[0][1] > 0      # complete
    assert counts[1][0] < counts[1][1]           # paused part-way
    assert "chunk in flight" in [l for l in shard_lines if "shard    1" in l][0]
    assert counts[2] == (0, counts[2][1])        # untouched
    # merging the partial store works and reports only completed shards
    merge = _cli("repro.launch.explain", ["merge", "--out", out])
    assert merge.returncode == 0, merge.stderr
    n_merged = int(merge.stdout.split("merged ")[1].split(" ")[0])
    assert n_merged == counts[0][0] + counts[1][0]
    # status is unchanged by the merge (shard JSONLs stay authoritative)
    status2 = _cli("repro.launch.explain", ["status", "--out", out])
    assert status2.returncode == 0, status2.stderr
    assert [l for l in status2.stdout.splitlines() if "shard" in l] == shard_lines


def test_cli_calibrate_synthetic_roundtrip(tmp_path):
    out_file = str(tmp_path / "cal.json")
    cal = _cli("repro.launch.explain",
               ["calibrate", "--out-file", out_file,
                "--backend", "synthetic", "--peak-flops", "5e10",
                "--machine", "synthcal", "--truth-dispatch-us", "2.0",
                "--truth-eff-knee", "64", "--reps", "25"])
    assert cal.returncode == 0, cal.stderr
    assert "dispatch" in cal.stdout and "--machine-file" in cal.stdout
    from repro.explain.calibrate import load_calibrated_machine

    m = load_calibrated_machine(out_file)
    assert m.name == "synthcal:calibrated"
    assert m.dispatch_overhead_s == pytest.approx(2e-6, rel=0.3)
    assert len(m.eff_curve) >= 3


def test_sweep_status_reports_running_anomaly_counts(cli_census):
    status = _cli("repro.launch.sweep", ["status", "--out", cli_census])
    assert status.returncode == 0, status.stderr
    assert "anomalies so far:" in status.stdout
    assert "chain=" in status.stdout and "bilinear=" in status.stdout
