"""AnomalyExplainer: decomposition exactness, machine registry, cause
recovery on the synthetic census (the acceptance scenario), kill/resume
byte-identity, and the CLI."""

import json
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core.sweep import SweepSpec, merge_shards, run_shard, synthetic_efficiencies
from repro.explain.attribution import attribute_algorithm, kernel_roofline
from repro.explain.classify import CAUSES, classify_anomaly, pick_winner_loser
from repro.explain.decompose import (
    KernelSpec,
    decompose_chain_dims,
    decompose_generalized,
    decompose_instance,
    kernel_name,
    kernels_from_record,
)
from repro.explain.runner import (
    ExplainSpec,
    explain_progress,
    explain_summary,
    explain_targets,
    merge_explained,
    resolve_machine,
    run_explain_shard,
)
from repro.roofline.terms import (
    DEFAULT_MACHINE,
    HBM_BW,
    PEAK_FLOPS,
    MachineSpec,
    get_machine,
    synthetic_machine,
)

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    for var in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS"):
        env.setdefault(var, "1")
    return env


# ---------------------------------------------------------- decomposition ---

def test_generalized_decomposition_is_flop_exact():
    from repro.expressions.generalized import FAMILIES

    for fam in ("gram", "distributive", "solve", "bilinear"):
        for n in (32, 64, 100):
            table = FAMILIES[fam](n=n).flops_table()
            kernels = decompose_generalized(fam, n)
            assert set(kernels) == set(table)
            for alg, ks in kernels.items():
                assert sum(k.flops for k in ks) == pytest.approx(
                    table[alg], rel=1e-12
                ), (fam, alg)


def test_chain_decomposition_is_flop_exact():
    from repro.expressions.chain import generate_chain_algorithms

    dims = (37, 91, 12, 55, 73)
    kernels = decompose_chain_dims(dims)
    algs = generate_chain_algorithms(dims)
    assert set(kernels) == {a.name for a in algs}
    for a in algs:
        assert sum(k.flops for k in kernels[a.name]) == float(a.flops)
        assert all(k.op == "gemm" for k in kernels[a.name])
        assert len(kernels[a.name]) == a.n_products


def test_kernel_spec_compact_roundtrip_and_labels():
    k = KernelSpec("gemm", (8, 4, 2))
    assert KernelSpec.from_compact(k.to_compact()) == k
    assert k.label == "gemm[8,4,2]"
    assert k.flops == 2.0 * 8 * 4 * 2
    assert kernel_name("alg0", 1, k) == "alg0::01.gemm"
    with pytest.raises(ValueError):
        KernelSpec("quantum_gemm", (8,))


def test_kernels_from_record_pointer_and_fallbacks():
    rec = {"family": "bilinear", "size": 32}
    by_alg = kernels_from_record(rec)                     # family fallback
    assert set(by_alg) == {"bilinear_left", "bilinear_right"}
    rec2 = {"family": "chain", "dims": [8, 4, 2, 6], "size": 5}
    assert kernels_from_record(rec2)                      # dims fallback
    rec3 = {"family": "bilinear", "size": 32,
            "params": {"size": 32, "seed": 0},
            "kernels": {"only": [["gemv", [32, 32]]]}}
    assert set(kernels_from_record(rec3)) == {"only"}     # pointer wins
    # an EMPTY pointer (chunk built pre-pointer, recorded post-upgrade)
    # must fall through to params, not return nothing
    rec4 = {"family": "bilinear", "size": 32, "kernels": {},
            "params": {"size": 32, "seed": 0}}
    assert set(kernels_from_record(rec4)) == {"bilinear_left", "bilinear_right"}


# ---------------------------------------------------- machines / roofline ---

def test_machine_registry_and_backcompat_aliases():
    tpu = get_machine("tpu-v5e")
    assert tpu is DEFAULT_MACHINE
    assert PEAK_FLOPS == tpu.peak_flops and HBM_BW == tpu.hbm_bw
    assert get_machine("cpu-1core").dispatch_overhead_s > 0
    with pytest.raises(KeyError):
        get_machine("abacus")
    rt = MachineSpec.from_dict(tpu.to_dict())
    assert rt == tpu


def test_synthetic_machine_predicts_pure_compute():
    m = synthetic_machine("sweep:test", 5e10)
    k = KernelSpec("gemm", (64, 64, 64))
    t, bound = kernel_roofline(k, m)
    assert t == pytest.approx(k.flops / 5e10)
    assert bound == "compute"
    # no memory system: bytes never dominate
    assert m.t_memory(1e18) == 0.0


def test_memory_bound_detection():
    m = MachineSpec("mem-starved", peak_flops=1e15, hbm_bw=1e6)
    t, bound = kernel_roofline(KernelSpec("gemv", (64, 64)), m)
    assert bound == "memory"


# -------------------------------------------------------------- classify ---

def _attr(alg, t_total, rows, machine):
    kernels = [KernelSpec(op, tuple(shape)) for op, shape, _ in rows]
    times = {
        kernel_name(alg, i, k): t for i, (k, (_, _, t)) in
        enumerate(zip(kernels, rows))
    }
    return attribute_algorithm(alg, t_total, kernels, times, machine)


def test_pick_winner_loser_both_reasons():
    base = {
        "uid": "u", "min_flops_algs": ["a0", "a1"],
        "ranks": {"a0": 1, "a1": 2, "b": 1},
        "mean_ranks": {"a0": 1.2, "a1": 2.0, "b": 1.0},
    }
    w, l = pick_winner_loser({**base, "reason": "min_flops_split"})
    assert (w, l) == ("b", "a1")  # best rank, then best mean rank, wins
    rec1 = {
        "uid": "u", "reason": "faster_outside_min_flops",
        "min_flops_algs": ["a0"],
        "ranks": {"a0": 2, "b": 1}, "mean_ranks": {"a0": 2.0, "b": 1.0},
    }
    assert pick_winner_loser(rec1) == ("b", "a0")
    with pytest.raises(ValueError):
        pick_winner_loser({
            "uid": "u", "reason": "none", "min_flops_algs": ["a0"],
            "ranks": {"a0": 1, "b": 2}, "mean_ranks": {"a0": 1.0, "b": 2.0},
        })


def test_classify_kernel_efficiency_and_dispatch():
    m = synthetic_machine("s", 1e9)
    rec = {"uid": "u", "reason": "faster_outside_min_flops"}
    # loser's single kernel runs 2x over the roof; winner at the roof
    w = _attr("w", 1.0e-3, [("gemm", (100, 100, 50), 1.0e-3)], m)
    l = _attr("l", 2.0e-3, [("gemm", (100, 100, 50), 2.0e-3)], m)
    e = classify_anomaly(rec, w, l)
    assert e.cause == "shape_kernel_efficiency"
    assert e.offending_algorithm == "l"
    assert e.offending_kernel == "gemm[100,100,50]"
    assert e.evidence == pytest.approx(1.0)
    # same kernels, but the gap lives between kernels (residual)
    l2 = _attr("l", 3.0e-3, [("gemm", (100, 100, 50), 1.0e-3)], m)
    e2 = classify_anomaly(rec, w, l2)
    assert e2.cause == "dispatch_overhead"
    assert e2.offending_kernel is None
    # memory-bound offender
    mm = MachineSpec("m", peak_flops=1e15, hbm_bw=1e6)
    w3 = _attr("w", 1.0e-3, [("gemv", (64, 64), 1.0e-3)], mm)
    l3 = _attr("l", 9.0e-3, [("gemv", (64, 64), 9.0e-3)], mm)
    e3 = classify_anomaly(rec, w3, l3)
    assert e3.cause == "memory_bound_segment"
    # no gap: honest unexplained
    e4 = classify_anomaly(rec, l, w)
    assert e4.cause == "unexplained" and e4.evidence == 0.0


# --------------------------------------------- the census under explanation ---

#: Deterministic cost-model census with strong injected per-algorithm
#: efficiency factors (eff_sigma) and weak measurement noise — the
#: acceptance scenario's ground truth.
def _census_spec(**overrides):
    kwargs = dict(
        name="t",
        families={
            "chain": {"count": 20, "n_matrices": [3, 4], "lo": 24, "hi": 128},
            "bilinear": {"sizes": [32, 64], "per_size": 4},
        },
        n_shards=2,
        backend="cost_model",
        eff_sigma=0.25,
        noise_sigma=0.01,
        max_measurements=9,
        chunk_size=4,
        save_every=5,
    )
    kwargs.update(overrides)
    return SweepSpec(**kwargs)


@pytest.fixture(scope="module")
def census(tmp_path_factory):
    root = str(tmp_path_factory.mktemp("census"))
    spec = _census_spec()
    spec.save(os.path.join(root, "spec.json"))
    for s in range(spec.n_shards):
        run_shard(spec, root, s)
    records = merge_shards(spec, root)
    anomalies = [r for r in records if r["is_anomaly"]]
    assert len(anomalies) >= 5, "fixture census must produce anomalies"
    return root, spec, records


def test_census_records_carry_explain_pointers(census):
    _, spec, records = census
    for r in records:
        assert r["params"], r["uid"]
        assert r["base_seed"] == spec.base_seed
        assert set(r["flops"]) == set(r["kernels"])
        # the pointer reproduces the pure-function decomposition
        assert r["kernels"] == {
            alg: [k.to_compact() for k in ks]
            for alg, ks in decompose_instance(r["family"], r["params"]).items()
        }


def test_explainer_recovers_injected_cause(census, tmp_path):
    """Acceptance: >= 90% of anomalies classified as shape-dependent kernel
    efficiency with the offending kernel identified, against the ground
    truth reconstructed from the synthetic machine's injected factors."""
    root, spec, records = census
    espec = ExplainSpec(census=root, n_shards=2, chunk_size=4, save_every=5)
    eroot = str(tmp_path / "explain")
    for s in range(espec.n_shards):
        run_explain_shard(espec, eroot, s)
    explained = merge_explained(espec, eroot)
    anomalies = [r for r in records if r["is_anomaly"]]
    assert [e["uid"] for e in explained] == [r["uid"] for r in anomalies]

    by_uid = {r["uid"]: r for r in records}
    n_cause = n_kernel = 0
    for e in explained:
        assert e["cause"] in CAUSES
        assert 0.0 <= e["evidence"] <= 1.0
        rec = by_uid[e["uid"]]
        if e["cause"] != "shape_kernel_efficiency":
            continue
        n_cause += 1
        # ground truth: redraw the injected efficiency factors and find the
        # kernel with the largest expected deviation from the roofline
        eff = synthetic_efficiencies(
            rec["flops"],
            np.random.default_rng([rec["base_seed"], rec["index"], 1]),
            spec.eff_sigma,
        )
        kernels = kernels_from_record(rec)
        expected = max(
            (
                (abs(k.flops * (eff[alg] - 1.0)), alg, k.label)
                for alg in (e["winner"], e["loser"])
                for k in kernels[alg]
            ),
            key=lambda t: t[0],
        )
        if (e["offending_algorithm"], e["offending_kernel"]) == expected[1:]:
            n_kernel += 1
    assert n_cause >= 0.9 * len(explained), (n_cause, len(explained))
    assert n_kernel >= 0.9 * n_cause, (n_kernel, n_cause)


def test_explain_resume_is_bit_identical(census, tmp_path):
    root, _, _ = census
    espec = ExplainSpec(census=root, n_shards=2, chunk_size=3, save_every=3)
    straight, chopped = str(tmp_path / "a"), str(tmp_path / "b")
    run_explain_shard(espec, straight, 0)
    for _ in range(300):
        run_explain_shard(espec, chopped, 0, max_steps=3)
        manifest = os.path.join(chopped, "shard-0000.manifest.json")
        if (os.path.exists(manifest)
                and json.load(open(manifest)).get("done")):
            break
    else:
        pytest.fail("explain shard did not finish in 300 slices")
    assert (open(os.path.join(chopped, "shard-0000.jsonl")).read()
            == open(os.path.join(straight, "shard-0000.jsonl")).read())


def test_explain_targets_and_progress(census, tmp_path):
    root, _, records = census
    espec = ExplainSpec(census=root, n_shards=3)
    _, targets = explain_targets(espec)
    assert [t["uid"] for t in targets] == [
        r["uid"] for r in records if r["is_anomaly"]
    ]
    eroot = str(tmp_path / "explain")
    prog = explain_progress(espec, eroot)
    assert prog["anomalies"] == len(targets) and prog["completed"] == 0
    run_explain_shard(espec, eroot, 1)
    prog = explain_progress(espec, eroot)
    assert prog["completed"] == prog["shards"][1]["done"] > 0


def test_resolve_machine_follows_backend(census):
    root, spec, _ = census
    espec = ExplainSpec(census=root)
    m = resolve_machine(espec, spec)
    assert m.peak_flops == spec.flop_rate and m.hbm_bw == 0.0
    espec2 = ExplainSpec(census=root, machine="tpu-v5e")
    assert resolve_machine(espec2, spec).name == "tpu-v5e"
    wall = _census_spec(backend="wall_clock")
    assert resolve_machine(espec, wall).name == "cpu-1core"


def test_explain_summary_and_tables(census, tmp_path):
    root, _, _ = census
    espec = ExplainSpec(census=root, n_shards=1)
    eroot = str(tmp_path / "explain")
    run_explain_shard(espec, eroot, 0)
    explained = merge_explained(espec, eroot)
    s = explain_summary(explained)
    assert s["total"] == len(explained)
    assert abs(sum(a["share"] for a in s["by_cause"].values()) - 1.0) < 1e-9
    assert 0.0 <= s["mean_evidence"] <= 1.0

    from repro.launch.report_md import explain_tables

    md = explain_tables(explained, name="t")
    assert "anomaly root causes" in md
    assert "| cause |" in md and "shape_kernel_efficiency" in md


# -------------------------------------------------------- CLI + kill/resume ---

#: Census grid for the CLI tests: enough anomalies that a mid-run SIGKILL
#: lands while explain shards are in flight.
CLI_CENSUS = [
    "--chains", "40", "--chain-sizes", "3,4", "--lo", "24", "--hi", "160",
    "--families", "bilinear", "--sizes", "32,64", "--per-size", "6",
    "--shards", "4", "--eff-sigma", "0.3", "--noise-sigma", "0.01",
    "--max-measurements", "9", "--chunk-size", "4", "--save-every", "5",
]
#: eps < 0 never converges: every explanation runs its full measurement
#: budget, keeping the campaign long enough to kill deterministically.
CLI_EXPLAIN = ["--eps", "-1.0", "--max-measurements", "24",
               "--shards", "4", "--chunk-size", "2", "--save-every", "4"]


def _cli(module, args, **kwargs):
    cmd = [sys.executable, "-m", module] + args
    return subprocess.run(
        cmd, env=_env(), capture_output=True, text=True, timeout=300, **kwargs
    )


@pytest.fixture(scope="module")
def cli_census(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("cli") / "census")
    done = _cli("repro.launch.sweep",
                ["run", "--out", out, "--workers", "2"] + CLI_CENSUS)
    assert done.returncode == 0, done.stderr
    return out


def test_cli_kill_resume_explain_identical(cli_census, tmp_path):
    """The acceptance scenario: multi-worker explain, SIGKILL of the whole
    process group mid-campaign, resume, merged explanations identical to an
    uninterrupted run."""
    straight, killed = str(tmp_path / "straight"), str(tmp_path / "killed")

    done = _cli("repro.launch.explain",
                ["run", "--census", cli_census, "--out", straight,
                 "--workers", "2"] + CLI_EXPLAIN)
    assert done.returncode == 0, done.stderr
    n_anoms = open(os.path.join(straight, "merged.jsonl")).read().count("\n")
    assert n_anoms >= 8, "census produced too few anomalies; enlarge CLI_CENSUS"

    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.explain", "run",
         "--census", cli_census, "--out", killed, "--workers", "2"]
        + CLI_EXPLAIN,
        env=_env(), start_new_session=True,
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
    )
    try:
        deadline = time.time() + 120
        while time.time() < deadline:
            if proc.poll() is not None:
                break
            jsonls = [f for f in os.listdir(killed)
                      if f.endswith(".jsonl")] if os.path.isdir(killed) else []
            if any(os.path.getsize(os.path.join(killed, f)) > 0 for f in jsonls):
                break
            time.sleep(0.005)
        was_running = proc.poll() is None
        os.killpg(proc.pid, signal.SIGKILL)
    finally:
        proc.wait()
    assert was_running, "explain finished before the kill; enlarge the grid"

    resumed = _cli("repro.launch.explain",
                   ["run", "--out", killed, "--workers", "2"])
    assert resumed.returncode == 0, resumed.stderr
    assert (open(os.path.join(killed, "merged.jsonl")).read()
            == open(os.path.join(straight, "merged.jsonl")).read())

    report = _cli("repro.launch.explain", ["report", "--out", killed])
    assert report.returncode == 0, report.stderr
    assert "anomaly root causes" in report.stdout


def test_cli_status_merge_and_plan_guard(cli_census, tmp_path):
    out = str(tmp_path / "explain")
    plan = _cli("repro.launch.explain",
                ["plan", "--census", cli_census, "--out", out, "--shards", "2"])
    assert plan.returncode == 0, plan.stderr
    assert "anomaly explanations over 2 shards" in plan.stdout
    # out == census would interleave census and explain shard files
    clash = _cli("repro.launch.explain",
                 ["plan", "--census", cli_census, "--out", cli_census])
    assert clash.returncode != 0
    run = _cli("repro.launch.explain", ["run", "--out", out, "--workers", "2"])
    assert run.returncode == 0, run.stderr
    status = _cli("repro.launch.explain", ["status", "--out", out])
    assert status.returncode == 0 and "anomalies explained" in status.stdout
    merge = _cli("repro.launch.explain", ["merge", "--out", out])
    assert merge.returncode == 0 and "explanations ->" in merge.stdout
    rj = _cli("repro.launch.explain", ["report", "--out", out, "--json"])
    assert rj.returncode == 0
    summary = json.loads(rj.stdout)
    assert summary["total"] > 0 and "by_cause" in summary


def test_sweep_status_reports_running_anomaly_counts(cli_census):
    status = _cli("repro.launch.sweep", ["status", "--out", cli_census])
    assert status.returncode == 0, status.stderr
    assert "anomalies so far:" in status.stdout
    assert "chain=" in status.stdout and "bilinear=" in status.stdout
