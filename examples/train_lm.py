"""End-to-end training driver: synthetic data -> sharded train loop ->
checkpoints -> resume, with the autotuner picking implementation variants.

Default trains a ~100M-param llama-style model for a few hundred steps on
the host mesh (CPU here; the same code path jits onto a TPU mesh):

    PYTHONPATH=src python examples/train_lm.py                  # ~100M
    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 40
    PYTHONPATH=src python examples/train_lm.py --resume         # from ckpt

Loss decreases on the structured synthetic stream (copy-chain signal).
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLM
from repro.distributed.sharding import batch_spec, make_plan, tree_shardings
from repro.launch.mesh import make_host_mesh
from repro.models import ForwardOptions, ModelConfig, init_lm_params
from repro.train.optimizer import AdamW, cosine_schedule
from repro.train.trainer import init_train_state, make_train_step

PRESETS = {
    # ~100M params: 12L x 768 with a 32k vocab (GPT-2-small-ish)
    "100m": ModelConfig(
        name="train-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        d_ff=2048, vocab_size=32768, dtype="float32", param_dtype="float32",
    ),
    "10m": ModelConfig(
        name="train-10m", n_layers=6, d_model=256, n_heads=8, n_kv_heads=4,
        d_ff=704, vocab_size=8192, dtype="float32", param_dtype="float32",
    ),
    "tiny": ModelConfig(
        name="train-tiny", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=352, vocab_size=1024, dtype="float32", param_dtype="float32",
    ),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="100m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    mesh = make_host_mesh()
    print(f"mesh: {mesh}")

    from repro.models.flops import param_counts

    pc = param_counts(cfg)
    print(f"model {cfg.name}: {pc.total/1e6:.1f}M params")

    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
    ))
    optimizer = AdamW(schedule=cosine_schedule(args.lr, 20, args.steps))
    opts = ForwardOptions(attn_impl="reference")
    step_fn = make_train_step(cfg, optimizer, opts)

    plan = make_plan(cfg, mesh, mode="train")
    params, axes = init_lm_params(cfg, jax.random.PRNGKey(0))
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    params = jax.device_put(params, tree_shardings(plan, axes, shapes))
    state = init_train_state(cfg, optimizer, params)

    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    start_step = 0
    if args.resume:
        restored = ckpt.restore_latest(state)
        if restored is not None:
            state, last_step, extra = restored
            start_step = int(extra.get("next_step", last_step + 1))
            print(f"resumed from step {last_step}")

    jstep = jax.jit(step_fn, donate_argnums=(0,))
    bspec = NamedSharding(mesh, batch_spec(mesh, args.batch, 1))
    t0 = time.time()
    with mesh:
        for step in range(start_step, args.steps):
            batch = {
                k: jax.device_put(v, bspec)
                for k, v in data.batch(step).items()
            }
            state, metrics = jstep(state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                toks = args.batch * args.seq
                dt = time.time() - t0
                print(
                    f"step {step:4d}  loss={float(metrics['loss']):.4f} "
                    f"nll={float(metrics['nll']):.4f} "
                    f"gnorm={float(metrics['grad_norm']):.2f} "
                    f"({toks*(step-start_step+1)/max(dt,1e-9)/1e3:.1f}k tok/s)"
                )
            if (step + 1) % args.ckpt_every == 0:
                ckpt.save(step, state, extra={"next_step": step + 1})
    ckpt.save(args.steps - 1, state, extra={"next_step": args.steps})
    print("done; checkpoint in", args.ckpt_dir)


if __name__ == "__main__":
    main()
