"""Serving example: prefill + batched token-by-token decode with KV caches.

Loads (or initialises) a smoke-scale model, prefills a batch of prompts and
generates continuations, demonstrating the cache layouts the decode_32k /
long_500k dry-run cells exercise at cluster scale.

    PYTHONPATH=src python examples/serve_lm.py --arch granite-8b --tokens 32
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.models import init_lm_params
from repro.serve.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if cfg.is_encoder_decoder:
        raise SystemExit("use whisper-style driving for enc-dec; pick an LM arch")
    params, _ = init_lm_params(cfg, jax.random.PRNGKey(0))

    engine = ServingEngine(
        cfg, params,
        max_len=args.prompt_len + args.tokens + 8,
        temperature=args.temperature,
    )
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    t0 = time.time()
    out = engine.generate(prompts, n_new=args.tokens)
    dt = time.time() - t0
    print(f"arch={args.arch} (smoke config) batch={args.batch}")
    print(f"generated {args.tokens} tokens/seq in {dt:.2f}s "
          f"({args.batch*args.tokens/dt:.1f} tok/s)")
    for row in range(min(2, args.batch)):
        print(f"  seq{row}: {list(map(int, out[row, args.prompt_len:]))[:16]} ...")


if __name__ == "__main__":
    main()
