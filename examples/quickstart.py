"""Quickstart: the paper's methodology in ~40 lines.

Ranks the six algorithms of the paper's anomaly instance of X = ABCD into
performance classes with real measurements, then runs the FLOPs-discriminant
test.

    PYTHONPATH=src python examples/quickstart.py [--full]
"""

import argparse

from repro.core import (
    WallClockTimer,
    flops_discriminant_test,
    initial_hypothesis_by_time,
    measure_and_rank,
    relative_flops,
)
from repro.expressions import (
    build_workloads,
    flops_table,
    get_instance,
    make_chain_inputs,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-size matrices")
    ap.add_argument("--instance", default="anomaly_331")
    args = ap.parse_args()

    inst = get_instance(args.instance, smoke=not args.full)
    algs = inst.algorithms()
    print(f"instance {inst.name} dims={inst.dims}: {len(algs)} algorithms")

    mats = make_chain_inputs(inst.dims)
    workloads = build_workloads(algs, mats, warmup=True)
    flops = flops_table(algs)
    rf = relative_flops(flops)

    timer = WallClockTimer(workloads)
    single = {name: timer.measure(name) for name in workloads}
    h0 = initial_hypothesis_by_time(single)
    print("h0 (single-run order):", " ".join(h0))

    result = measure_and_rank(h0, timer, m_per_iteration=3, eps=0.03,
                              max_measurements=30)
    print(f"converged={result.converged} after {result.measurements_per_alg} "
          "measurements/alg")
    for a in result.sequence:
        print(f"  rank {a.rank}  {a.name:12s} ({algs[int(a.name[9:])].label:20s}) "
              f"mean_rank={a.mean_rank:.2f}  RF={rf[a.name]:.2f}")

    report = flops_discriminant_test(result, flops)
    verdict = "ANOMALY: " + report.reason if report.is_anomaly else "valid discriminant"
    print(f"FLOPs test: {verdict}  (S_F = {', '.join(report.min_flops_algs)})")


if __name__ == "__main__":
    main()
