"""Anomaly hunting: sweep random chain instances, classify each with the
FLOPs-discriminant test, and report the anomaly rate — the experiment the
paper positions as the input to performance-model research (Sec. V: "verify
that there exists an abundance of anomalies").

All instances run as ONE interleaved ExperimentEngine campaign: each chain
instance is a measurement session, the scheduler spends iterations where
ranks are still moving, and the whole census persists to ``--state`` so a
killed hunt resumes (``--resume`` rebuilds the wall-clock workloads from
the same seeds and re-attaches them to the restored sessions).

    PYTHONPATH=src python examples/anomaly_hunt.py --n 12 --lo 32 --hi 256 \
        [--policy least_converged_first] [--max-steps N] \
        [--state /tmp/hunt.json] [--resume]
"""

import argparse

from repro.autotune import CampaignSite, rank_sites
from repro.core import WallClockTimer, filter_candidates, initial_hypothesis_by_time
from repro.expressions import (
    build_workloads,
    flops_table,
    make_chain_inputs,
    random_instance,
)

MAX_MEASUREMENTS = 24


def build_instance(seed: int, chain: int, lo: int, hi: int, resume: bool):
    """One seed's chain instance + measurement backend. On resume only the
    workload callables are needed (to re-attach timers to the restored
    sessions); the single-run filtering pass is skipped."""
    inst = random_instance(chain, lo, hi, seed=seed)
    algs = inst.algorithms()
    flops = flops_table(algs)
    mats = make_chain_inputs(inst.dims, seed=seed)
    workloads = build_workloads(algs, mats, warmup=True)
    timer = WallClockTimer(workloads)
    if resume:
        return inst, timer, flops, None, {}, ()
    single = {n: timer.measure(n) for n in workloads}
    cand = filter_candidates(flops, single, rt_threshold=1.5)
    h0 = [n for n in initial_hypothesis_by_time(single) if n in cand.names]
    return inst, timer, flops, h0, single, cand.dropped


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=12, help="instances to test")
    ap.add_argument("--lo", type=int, default=32)
    ap.add_argument("--hi", type=int, default=256)
    ap.add_argument("--chain", type=int, default=4, help="matrices per chain")
    ap.add_argument("--policy", default="least_converged_first",
                    choices=["round_robin", "least_converged_first"])
    ap.add_argument("--max-steps", type=int, default=None,
                    help="kill the campaign after N engine iterations")
    ap.add_argument("--state", default=None,
                    help="persist the campaign to this JSON file")
    ap.add_argument("--resume", action="store_true",
                    help="resume a killed campaign from --state")
    args = ap.parse_args()
    if args.resume and not args.state:
        ap.error("--resume requires --state")

    names, dims_of, timers, sites = [], {}, {}, []
    for seed in range(args.n):
        inst, timer, flops, h0, single, dropped = build_instance(
            seed, args.chain, args.lo, args.hi, args.resume
        )
        name = f"seed{seed}"
        names.append(name)
        dims_of[name] = inst.dims
        timers[name] = timer  # re-attached on --resume (wall-clock backend)
        sites.append(
            CampaignSite(
                name=name, timer=timer, flops=dict(flops), initial_order=h0,
                single_run_times=single, dropped=dropped, backend="wall-clock",
            )
        )

    if args.resume:
        reports = rank_sites(
            resume_from=args.state, timers=timers, max_steps=args.max_steps,
            save_path=args.state,
        )
    else:
        reports = rank_sites(
            sites, m_per_iteration=3, eps=0.03,
            max_measurements=MAX_MEASUREMENTS,
            policy=args.policy, max_steps=args.max_steps, save_path=args.state,
        )

    anomalies = 0
    for name in names:
        rep = reports.get(name)
        if rep is None:  # session never scheduled before the budget ran out
            print(f"dims={dims_of[name]}  (no iterations yet: resume to measure)")
            continue
        res, disc = rep.ranking, rep.discriminant
        anomalies += disc.is_anomaly
        tag = f"ANOMALY ({disc.reason})" if disc.is_anomaly else "ok"
        # not converged + budget left <-> the campaign was cut short, as
        # opposed to exhausting max_measurements without meeting eps
        interrupted = not res.converged and res.measurements_per_alg < MAX_MEASUREMENTS
        more = " (campaign interrupted: best-so-far)" if interrupted else ""
        print(f"dims={dims_of[name]}  N={res.measurements_per_alg:2d} "
              f"classes={max(res.ranks.values())}  {tag}{more}")

    print(f"\nanomaly rate: {anomalies}/{args.n} "
          f"({100.0*anomalies/args.n:.0f}%) at dims in [{args.lo}, {args.hi}]")
    print("(paper [5] reports ~0.4% at BLAS scale on 10-core MKL; small sizes"
          " on a noisy shared core are far more anomaly-prone)")
    if args.state:
        print(f"campaign state: {args.state}"
              + (" (resume with --resume)" if args.max_steps else ""))


if __name__ == "__main__":
    main()
