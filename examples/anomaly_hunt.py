"""Anomaly hunting: sweep random chain instances, classify each with the
FLOPs-discriminant test, and report the anomaly rate — the experiment the
paper positions as the input to performance-model research (Sec. V: "verify
that there exists an abundance of anomalies").

    PYTHONPATH=src python examples/anomaly_hunt.py --n 12 --lo 32 --hi 256
"""

import argparse
import time

from repro.core import (
    WallClockTimer,
    filter_candidates,
    flops_discriminant_test,
    initial_hypothesis_by_time,
    measure_and_rank,
)
from repro.expressions import (
    build_workloads,
    flops_table,
    make_chain_inputs,
    random_instance,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=12, help="instances to test")
    ap.add_argument("--lo", type=int, default=32)
    ap.add_argument("--hi", type=int, default=256)
    ap.add_argument("--chain", type=int, default=4, help="matrices per chain")
    args = ap.parse_args()

    anomalies = 0
    for seed in range(args.n):
        inst = random_instance(args.chain, args.lo, args.hi, seed=seed)
        algs = inst.algorithms()
        flops = flops_table(algs)
        mats = make_chain_inputs(inst.dims, seed=seed)
        workloads = build_workloads(algs, mats, warmup=True)
        timer = WallClockTimer(workloads)

        single = {n: timer.measure(n) for n in workloads}
        cand = filter_candidates(flops, single, rt_threshold=1.5)
        h0 = [n for n in initial_hypothesis_by_time(single) if n in cand.names]
        res = measure_and_rank(h0, timer, m_per_iteration=3, eps=0.03,
                               max_measurements=24)
        rep = flops_discriminant_test(res, flops)
        anomalies += rep.is_anomaly
        tag = f"ANOMALY ({rep.reason})" if rep.is_anomaly else "ok"
        print(f"dims={inst.dims}  N={res.measurements_per_alg:2d} "
              f"classes={max(res.ranks.values())}  {tag}")

    print(f"\nanomaly rate: {anomalies}/{args.n} "
          f"({100.0*anomalies/args.n:.0f}%) at dims in [{args.lo}, {args.hi}]")
    print("(paper [5] reports ~0.4% at BLAS scale on 10-core MKL; small sizes"
          " on a noisy shared core are far more anomaly-prone)")


if __name__ == "__main__":
    main()
