"""Anomaly hunting: sweep random chain instances, classify each with the
FLOPs-discriminant test, and report the anomaly rate — the experiment the
paper positions as the input to performance-model research (Sec. V: "verify
that there exists an abundance of anomalies").

This example is a thin wrapper over the stable Python facade
(:func:`repro.api.run_census` — the same operation as
``python -m repro census run``): the hunt is a one-shard census of the
chain family whose state lives under ``--out``, so a killed hunt resumes
exactly where it stopped by re-running the same command — and scaling up
is just switching to the umbrella CLI with more shards and workers.

    PYTHONPATH=src python examples/anomaly_hunt.py --n 12 --lo 32 --hi 256 \
        [--backend wall_clock|cost_model] [--max-steps N] [--out DIR]
"""

import argparse
import os
import tempfile

from repro.api import run_census
from repro.core.sweep import ShardStore, SweepSpec, census_summary

MAX_MEASUREMENTS = 24


def build_spec(args: argparse.Namespace) -> SweepSpec:
    """The hunt as a census spec: one shard over the chain family."""
    return SweepSpec(
        name="anomaly_hunt",
        families={
            "chain": {
                "count": args.n,
                "n_matrices": [args.chain],
                "lo": args.lo,
                "hi": args.hi,
            }
        },
        n_shards=1,
        backend=args.backend,
        max_measurements=MAX_MEASUREMENTS,
        policy=args.policy,
        chunk_size=max(args.n, 1),   # one interleaved campaign, like before
        save_every=10,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=12, help="instances to test")
    ap.add_argument("--lo", type=int, default=32)
    ap.add_argument("--hi", type=int, default=256)
    ap.add_argument("--chain", type=int, default=4, help="matrices per chain")
    ap.add_argument("--backend", default="wall_clock",
                    choices=["wall_clock", "cost_model", "simulated"],
                    help="real JAX measurements, or the deterministic "
                    "synthetic machine (bit-identical resume)")
    ap.add_argument("--policy", default="least_converged_first",
                    choices=["round_robin", "least_converged_first"])
    ap.add_argument("--max-steps", type=int, default=None,
                    help="pause the campaign after N engine iterations "
                    "(re-run the same command to resume)")
    ap.add_argument("--out", default=None,
                    help="sweep state directory (default: a fresh tempdir)")
    args = ap.parse_args()

    out = args.out or tempfile.mkdtemp(prefix="anomaly_hunt_")
    if os.path.exists(os.path.join(out, "spec.json")):
        # resuming: the facade takes the grid from disk; warn when this
        # command line's flags disagree with the planned census
        spec = SweepSpec.load(os.path.join(out, "spec.json"))
        if spec.to_dict() != build_spec(args).to_dict():
            print(f"# resuming the census planned in {out}/spec.json: grid "
                  "and backend flags from this command line are ignored "
                  "(use a fresh --out to start a different hunt)")
        spec = run_census(out, max_steps=args.max_steps)
    else:
        spec = run_census(out, build_spec(args), max_steps=args.max_steps)

    records = {r["uid"]: r for r in ShardStore(out, 0).open().records}
    done = 0
    for inst in spec.shard_instances(0):
        rep = records.get(inst.uid)
        if rep is None:
            print(f"{inst.uid}  (pending: re-run to resume the campaign)")
            continue
        done += 1
        tag = f"ANOMALY ({rep['reason']})" if rep["is_anomaly"] else "ok"
        more = "" if rep["converged"] else " (budget hit before convergence)"
        print(f"dims={rep['dims']}  N={rep['measurements_per_alg']:2d} "
              f"classes={rep['classes']}  {tag}{more}")

    grid = spec.families["chain"]
    if done:
        total = census_summary(list(records.values()))["total"]
        print(f"\nanomaly rate: {total['anomalies']}/{total['n']} "
              f"({100.0 * total['rate']:.0f}%) at dims in "
              f"[{grid['lo']}, {grid['hi']}]")
        print("(paper [5] reports ~0.4% at BLAS scale on 10-core MKL; small "
              "sizes on a noisy shared core are far more anomaly-prone)")
    print(f"census state: {out}"
          + (" (re-run with --out to resume)" if done < grid["count"] else ""))


if __name__ == "__main__":
    main()
