"""Variant autotuning walkthrough: the paper's methodology selecting
implementation variants inside the framework.

Runs three variant sites (MoE dispatch, attention implementation, SSD chunk
length), prints the full ranking pipeline per site — candidate filtering,
converged performance classes, FLOPs-discriminant verdict, selection.

    PYTHONPATH=src python examples/rank_algorithms.py
"""

import argparse

from repro.autotune import (
    attention_site,
    moe_dispatch_site,
    rank_site,
    ssd_chunk_site,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    args = ap.parse_args()
    s = args.scale

    sites = [
        moe_dispatch_site(tokens=int(2048 * s), d=256, e=16, top_k=2, d_ff=256),
        attention_site(b=2, s=int(1024 * s), h=8, kv=2, d=64),
        ssd_chunk_site(b=2, s=int(1024 * s), h=8, p=32, n=32, chunks=(64, 128, 256)),
    ]
    for site in sites:
        report = rank_site(site, max_measurements=18)
        print(report.summary())
        if report.dropped:
            print(f"  dropped by RT filter: {', '.join(report.dropped)}")
        print()


if __name__ == "__main__":
    main()
