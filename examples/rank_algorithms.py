"""Variant autotuning walkthrough: the paper's methodology selecting
implementation variants inside the framework.

Runs three variant sites (MoE dispatch, attention implementation, SSD chunk
length) as ONE interleaved ExperimentEngine campaign via ``rank_sites`` —
the scheduler spends Procedure-4 iterations on whichever site is farthest
from convergence — then prints the full ranking pipeline per site:
candidate filtering, converged performance classes, FLOPs-discriminant
verdict, selection.

    PYTHONPATH=src python examples/rank_algorithms.py \
        [--policy least_converged_first] [--max-steps N]

``--max-steps`` demonstrates a budgeted campaign: reports are best-so-far
(check ``converged`` per site) instead of blocking until every site stops.
"""

import argparse

from repro.autotune import (
    attention_site,
    moe_dispatch_site,
    rank_sites,
    ssd_chunk_site,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--policy", default="least_converged_first",
                    choices=["round_robin", "least_converged_first"])
    ap.add_argument("--max-steps", type=int, default=None,
                    help="stop the campaign after N engine iterations")
    args = ap.parse_args()
    s = args.scale

    sites = [
        moe_dispatch_site(tokens=int(2048 * s), d=256, e=16, top_k=2, d_ff=256),
        attention_site(b=2, s=int(1024 * s), h=8, kv=2, d=64),
        ssd_chunk_site(b=2, s=int(1024 * s), h=8, p=32, n=32, chunks=(64, 128, 256)),
    ]
    reports = rank_sites(
        sites, max_measurements=18, policy=args.policy, max_steps=args.max_steps
    )
    for site in sites:
        report = reports.get(site.name)
        if report is None:  # never scheduled before the step budget ran out
            print(f"site {site.name}: no iterations yet (raise --max-steps)\n")
            continue
        print(report.summary())
        if report.dropped:
            print(f"  dropped by RT filter: {', '.join(report.dropped)}")
        if not report.ranking.converged:
            print("  (not converged: campaign budget hit; ranks are best-so-far)")
        print()


if __name__ == "__main__":
    main()
