"""Explain census anomalies: census -> AnomalyExplainer -> cause table.

The paper stops at detecting anomalies; this example closes the loop. It
runs a small deterministic cost-model census (or reuses one you already
have), explains every anomaly, and prints the per-anomaly verdicts plus
the aggregated cause table — all through the stable Python facade
(:func:`repro.api.run_census` / :func:`repro.api.explain_census`, the
same operations as ``python -m repro census run`` /
``python -m repro explain run``).

    PYTHONPATH=src python examples/explain_anomalies.py
    PYTHONPATH=src python examples/explain_anomalies.py --census /tmp/census
    PYTHONPATH=src python examples/explain_anomalies.py --out /tmp/demo  # resumable

Both phases are killable: re-running the same command resumes the census
and the explanation campaign exactly where they stopped.
"""

import argparse
import os
import tempfile

from repro.api import explain_census, run_census
from repro.core.sweep import SweepSpec, merge_shards
from repro.explain.runner import explain_summary


def build_census(out: str, args: argparse.Namespace) -> str:
    """A one-shard chain+bilinear census with strong injected efficiency
    factors (so the equal-FLOPs regime splits often enough to explain)."""
    root = os.path.join(out, "census")
    if os.path.exists(os.path.join(root, "spec.json")):
        run_census(root)                       # resume whatever was planned
        return root
    run_census(
        root,
        SweepSpec(
            name="explain_demo",
            families={
                "chain": {"count": args.n, "n_matrices": [3, 4],
                          "lo": args.lo, "hi": args.hi},
                "bilinear": {"sizes": [32, 64], "per_size": 3},
            },
            n_shards=1,
            backend="cost_model",
            eff_sigma=args.eff_sigma,
            noise_sigma=0.01,
            max_measurements=12,
        ),
    )
    return root


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--census", default=None,
                    help="existing sweep --out dir (default: run a demo census)")
    ap.add_argument("--n", type=int, default=16, help="demo census chains")
    ap.add_argument("--lo", type=int, default=24)
    ap.add_argument("--hi", type=int, default=128)
    ap.add_argument("--eff-sigma", type=float, default=0.25,
                    help="injected per-algorithm efficiency spread")
    ap.add_argument("--out", default=None,
                    help="state directory (default: a fresh tempdir)")
    args = ap.parse_args()

    out = args.out or tempfile.mkdtemp(prefix="explain_demo_")
    census = args.census or build_census(out, args)
    sweep_spec = SweepSpec.load(os.path.join(census, "spec.json"))
    records = merge_shards(sweep_spec, census)
    anomalies = [r for r in records if r["is_anomaly"]]
    print(f"census: {len(records)} instances, {len(anomalies)} anomalies")
    if not anomalies:
        print("nothing to explain — try a larger --n or --eff-sigma")
        return

    explained = explain_census(
        census, os.path.join(out, "explain"),
        name="explain_demo", n_shards=1,
    )
    for e in explained:
        off = f"  <- {e['offending_kernel']} of {e['offending_algorithm']}" \
            if e["offending_kernel"] else ""
        print(f"{e['uid']:24s} {e['reason']:24s} -> {e['cause']} "
              f"(evidence {e['evidence']:.2f}){off}")

    s = explain_summary(explained)
    print(f"\n{s['total']} anomalies explained, mean evidence "
          f"{s['mean_evidence']:.2f}")
    for cause, a in s["by_cause"].items():
        print(f"  {cause:28s} {a['n']:3d}  ({100.0 * a['share']:.0f}%, "
              f"evidence {a['mean_evidence']:.2f})")
    print(f"state: {out} (re-run with --out to resume)")


if __name__ == "__main__":
    main()
