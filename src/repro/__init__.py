"""repro — a test for FLOPs as a discriminant for linear-algebra algorithms.

The package root stays import-light: the stable facade
(:mod:`repro.api`) is re-exported lazily via PEP 562, so
``import repro`` (and ``from repro import run_census``) never pulls jax,
and each facade call pays only for the subsystems it actually touches.
The CLI equivalent is the umbrella entrypoint ``python -m repro``
(:mod:`repro.launch.cli`).
"""

from typing import TYPE_CHECKING

#: the facade names ``from repro import X`` resolves through repro.api
_API_NAMES = (
    "run_census",
    "explain_census",
    "warm_oracle",
    "query",
    "train_predictor",
    "predict_ranks",
)

__all__ = ["api"] + list(_API_NAMES)

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.api import (  # noqa: F401
        explain_census,
        predict_ranks,
        query,
        run_census,
        train_predictor,
        warm_oracle,
    )


def __getattr__(name):
    if name in _API_NAMES:
        from repro import api

        return getattr(api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_API_NAMES))
