"""Serving engine: prefill + batched decode with KV-cache management.

``make_serve_step``/``make_prefill`` build the pure step functions the
launch layer jits with cache shardings from the distribution plan. The
``ServingEngine`` drives real token-by-token generation at smoke scale
(examples, tests) with greedy or temperature sampling.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import (
    ForwardOptions,
    ModelConfig,
    encdec_decode_step,
    encdec_prefill,
    init_encdec_state,
    init_lm_state,
    lm_decode_step,
    lm_prefill,
)

Pytree = Any


def make_serve_step(cfg: ModelConfig, opts: ForwardOptions = ForwardOptions()):
    """(params, state, tokens [b,1], cache_len) -> (logits [b,V], state)."""
    if cfg.is_encoder_decoder:
        def step(params, state, tokens, cache_len):
            return encdec_decode_step(cfg, params, state, tokens, cache_len, opts=opts)
        return step

    def step(params, state, tokens, cache_len):
        return lm_decode_step(cfg, params, state, tokens, cache_len, opts=opts)
    return step


def make_prefill(cfg: ModelConfig, opts: ForwardOptions = ForwardOptions()):
    if cfg.is_encoder_decoder:
        def prefill(params, state, enc_embeds):
            return encdec_prefill(cfg, params, state, enc_embeds, opts=opts)
        return prefill

    def prefill(params, state, tokens=None, embeds=None):
        return lm_prefill(cfg, params, state, tokens=tokens, embeds=embeds, opts=opts)
    return prefill


@dataclass
class ServingEngine:
    """Token-by-token generation driver (smoke scale)."""

    cfg: ModelConfig
    params: Pytree
    max_len: int = 256
    opts: ForwardOptions = ForwardOptions()
    temperature: float = 0.0
    _step: Optional[Callable] = field(default=None, repr=False)

    def __post_init__(self) -> None:
        self._step = jax.jit(make_serve_step(self.cfg, self.opts))
        self._prefill = jax.jit(make_prefill(self.cfg, self.opts))

    def generate(
        self,
        prompt_tokens: jax.Array,       # [b, s_prompt]
        n_new: int,
        seed: int = 0,
    ) -> jax.Array:
        """Greedy/temperature generation; returns [b, s_prompt + n_new]."""
        b, s_prompt = prompt_tokens.shape
        state = init_lm_state(self.cfg, b, self.max_len)
        logits, state = self._prefill(self.params, state, prompt_tokens[:, : s_prompt])
        key = jax.random.PRNGKey(seed)
        out = [prompt_tokens]
        last = self._sample(logits, key, 0)
        for t in range(n_new):
            out.append(last)
            if t == n_new - 1:
                break
            logits, state = self._step(
                self.params, state, last, jnp.int32(s_prompt + t)
            )
            last = self._sample(logits, key, t + 1)
        return jnp.concatenate(out, axis=1)

    def _sample(self, logits: jax.Array, key: jax.Array, t: int) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        k = jax.random.fold_in(key, t)
        return jax.random.categorical(k, logits / self.temperature)[:, None].astype(
            jnp.int32
        )
