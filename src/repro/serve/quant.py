"""Int8 KV-cache quantization — the decode-cell memory lever.

Decode is parameter+cache streaming bound (EXPERIMENTS.md §Roofline);
int8 K/V with per-(head, position) scales halves the cache stream vs bf16.
Mathematically this is an *approximation*, not an equivalent algorithm — so
the autotuner treats (bf16, int8) as a quality/perf trade site rather than
an equal-math variant set, and the tests bound the attention-output error
instead of asserting equality.

Layout: q8 [b, S, K, hd] int8 + scales [b, S, K] f32 (per head-position
amax scaling, KIVI-style post-RoPE).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_kv(k: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """[b, s, K, hd] -> (int8 payload, f32 scales [b, s, K])."""
    kf = k.astype(jnp.float32)
    amax = jnp.max(jnp.abs(kf), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(kf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def init_quant_kv_cache(
    batch: int, max_len: int, n_kv_heads: int, head_dim: int
) -> Dict[str, jax.Array]:
    return {
        "k_q": jnp.zeros((batch, max_len, n_kv_heads, head_dim), jnp.int8),
        "k_s": jnp.ones((batch, max_len, n_kv_heads), jnp.float32),
        "v_q": jnp.zeros((batch, max_len, n_kv_heads, head_dim), jnp.int8),
        "v_s": jnp.ones((batch, max_len, n_kv_heads), jnp.float32),
    }


def update_quant_kv_cache(
    cache: Dict[str, jax.Array],
    k_new: jax.Array,
    v_new: jax.Array,
    position: jax.Array,
) -> Dict[str, jax.Array]:
    kq, ks = quantize_kv(k_new)
    vq, vs = quantize_kv(v_new)
    upd = jax.lax.dynamic_update_slice_in_dim
    return {
        "k_q": upd(cache["k_q"], kq, position, axis=1),
        "k_s": upd(cache["k_s"], ks, position, axis=1),
        "v_q": upd(cache["v_q"], vq, position, axis=1),
        "v_s": upd(cache["v_s"], vs, position, axis=1),
    }


def quant_decode_attention(
    q: jax.Array,                  # [b, 1, H, hd]
    cache: Dict[str, jax.Array],
    cache_len: jax.Array,
    *,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
) -> jax.Array:
    """Decode attention over the int8 cache (dequant streamed per use).

    Bytes moved per token: (1 + 4/hd) per element vs 2 for bf16 — a 1.97x
    cache-stream reduction at hd=128.
    """
    from repro.models.attention import decode_attention

    k = dequantize_kv(cache["k_q"], cache["k_s"], q.dtype)
    v = dequantize_kv(cache["v_q"], cache["v_s"], q.dtype)
    return decode_attention(
        q, k, v, cache_len, window=window, logit_cap=logit_cap
    )
