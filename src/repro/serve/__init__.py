"""Serving: the inference engine and the ranking oracle.

Two unrelated kinds of "serve" live here, with very different import
costs, so everything is exported lazily (PEP 562):

* the model-serving engine (:mod:`repro.serve.engine`,
  :mod:`repro.serve.quant`) — imports jax;
* ranking-as-a-service (:mod:`repro.serve.oracle`,
  :mod:`repro.serve.cache`) — the census-backed dispatch oracle, which
  must stay importable without jax (its hot path is pure dict lookups
  over the cache).
"""

from typing import Any

_EXPORTS = {
    # jax-free: the oracle and its two-tier cache
    "RankingOracle": "repro.serve.oracle",
    "OracleQueue": "repro.serve.oracle",
    "hit_rate": "repro.serve.oracle",
    "default_machine_name": "repro.serve.oracle",
    "OracleCache": "repro.serve.cache",
    "OracleCacheSpec": "repro.serve.cache",
    "cache_key": "repro.serve.cache",
    "shard_of_key": "repro.serve.cache",
    "CONFIDENCE_MEASURED": "repro.serve.cache",
    "CONFIDENCE_BUCKETED": "repro.serve.cache",
    "CONFIDENCE_MODEL_ONLY": "repro.serve.cache",
    # jax: the inference engine
    "ServingEngine": "repro.serve.engine",
    "make_prefill": "repro.serve.engine",
    "make_serve_step": "repro.serve.engine",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str) -> Any:
    module = _EXPORTS.get(name)
    if module is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(module), name)
