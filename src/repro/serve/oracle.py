"""Ranking-as-a-service: the anomaly-aware algorithm dispatch oracle.

The Linear Algebra Mapping Problem survey shows production systems
(Julia, Armadillo, Linnea) dispatch algorithms on FLOPs alone; this
repo's census knows *when* that heuristic lies and its explainer knows
*why*. The :class:`RankingOracle` closes the loop into a query endpoint:

    oracle = RankingOracle.open("cache_root")
    verdict = oracle.query("gram", {"size": 96, "seed": 0})

answering "which algorithm, how confident, is this instance an anomaly"
for ``(family, params, machine)`` — singly or batched — from a two-tier
cache (:mod:`repro.serve.cache`) warmed out of merged census + explain
stores. Three confidence levels, strongest first:

``measured``
    The census measured THIS instance: the verdict's ranking is
    byte-identical to the census record's, per-rank confidence 1.0, and
    the anomaly verdict carries the explainer's cause when available.
``bucketed``
    The instance's ``(family, shape-bucket, machine)`` entry exists but
    this exact instance was never measured: the verdict aggregates the
    bucket's records — per-algorithm modal rank, vote-share confidence.
``learned_model``
    A cache miss answered by the TRAINED cost model
    (:mod:`repro.predict`, attached via ``OracleCacheSpec.model``):
    predicted times through the census's own candidate filter and
    discriminant rule, with the model's calibrated rank-flip confidence.
    Misses are still enqueued for background measurement.
``model_only``
    A true cache miss with no trained model attached (or a machine the
    model was not trained for): an analytic cost-model fallback (machine
    roofline + per-kernel dispatch) answers immediately, and the miss is
    durably enqueued for background measurement. The hot path NEVER
    blocks on a measurement.

The background side is :class:`OracleQueue` — the cache root registers
its own store kind (``ocache.json``, see :mod:`repro.core.stores`), so
any ordinary ``python -m repro.launch.queue work --out CACHE`` host
leases cache shards, measures enqueued misses under the census's own
spec (byte-identical records, deterministic backends), and folds them
into the cache; the next identical query answers ``measured``.

Query-path imports stay jax-free (family metadata and flops tables come
from the registry without building workloads); only a queue worker
draining wall-clock misses pays for jax.
"""

from __future__ import annotations

import json
import os
import zlib
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.configs.shapes import shape_bucket
from repro.core.family import InstanceSpec
from repro.core.sweep import (
    SweepSpec,
    _record_line,
    build_sweep_session,
    instance_entry,
    record_from_session,
)
from repro.roofline.terms import MACHINES, MachineSpec, get_machine, synthetic_machine

from .cache import (
    CONFIDENCE_BUCKETED,
    CONFIDENCE_LEARNED,
    CONFIDENCE_MEASURED,
    CONFIDENCE_MODEL_ONLY,
    SPEC_FILE,
    OracleCache,
    OracleCacheSpec,
    cache_key,
)

#: relative tolerance for collapsing analytic fallback times into one
#: rank class (the model has no measurement noise to separate them)
MODEL_REL_TOL = 0.02


def default_machine_name(spec: OracleCacheSpec, sweep: SweepSpec) -> str:
    """The machine label cache keys embed — the explainer's resolution
    rule: explicit registry pick, else the census's synthetic machine for
    deterministic backends, else the pinned-core host."""
    if spec.machine:
        return spec.machine
    if sweep.backend in ("cost_model", "simulated"):
        return f"sweep:{sweep.name}"
    return "cpu-1core"


def resolve_machine_spec(name: str, sweep: SweepSpec) -> MachineSpec:
    """The MachineSpec behind a machine label: registry entries by name,
    anything else modelled as the census's pure-compute synthetic."""
    if name in MACHINES:
        return get_machine(name)
    return synthetic_machine(name, sweep.flop_rate)


def _params_token(params: Mapping[str, Any]) -> str:
    return json.dumps(dict(params), sort_keys=True, separators=(",", ":"))


# --------------------------------------------------------------- the oracle ---


class RankingOracle:
    """The query endpoint over one cache root. Open once, query many:
    per-process lazy indices (census grid by params, family flops tables)
    make repeated queries pure dict lookups + at most one shard seek."""

    def __init__(self, root: str, cache: OracleCache) -> None:
        self.root = root
        self.cache = cache
        self.spec = cache.spec
        self.census_spec = SweepSpec.load(
            os.path.join(self.spec.census, "spec.json")
        )
        self.machine_name = default_machine_name(self.spec, self.census_spec)
        self._machines: Dict[str, MachineSpec] = {}
        #: (family, params token) -> (InstanceSpec, size)
        self._resolved: Dict[Tuple[str, str], Tuple[InstanceSpec, int]] = {}
        #: (family, params token) -> (flops, kernel counts)
        self._costed: Dict[Tuple[str, str], Tuple[Dict[str, float], Dict[str, int]]] = {}
        self._grid: Optional[Dict[Tuple[str, str], InstanceSpec]] = None
        #: lazily-opened trained predictor (spec.model); None until tried
        self._predictor: Optional[Any] = None
        self._predictor_tried = False

    @classmethod
    def open(cls, root: str) -> "RankingOracle":
        return cls(root, OracleCache.open(root))

    def reload(self) -> None:
        """Re-open the cache (pick up background refreshes)."""
        self.cache = OracleCache.open(self.root)

    # ----------------------------------------------------------- resolution ---

    def _census_grid(self) -> Dict[Tuple[str, str], InstanceSpec]:
        if self._grid is None:
            self._grid = {
                (inst.family, _params_token(inst.params)): inst
                for inst in self.census_spec.expand()
            }
        return self._grid

    def _resolve(self, family: str, params: Mapping[str, Any]) -> Tuple[InstanceSpec, int]:
        """(instance, size) for a query. Census-grid instances keep their
        real uid/index (the ``measured`` fast path and the byte-identity
        guarantee for re-measured misses); ad-hoc queries get a stable
        content-addressed uid outside the grid's index range."""
        token = _params_token(params)
        hit = self._resolved.get((family, token))
        if hit is not None:
            return hit
        inst = self._census_grid().get((family, token))
        if inst is None:
            crc = zlib.crc32(f"{family}:{token}".encode("utf-8")) & 0xFFFFFFFF
            inst = InstanceSpec(
                index=(1 << 32) + crc,
                uid=f"{family}-adhoc-{crc:08x}",
                family=family,
                params=dict(params),
            )
        if "size" in params:
            size = int(params["size"])
        else:
            _, desc, _ = instance_entry(inst)
            size = int(desc["size"])
        self._resolved[(family, token)] = (inst, size)
        return inst, size

    def _cost(self, inst: InstanceSpec) -> Tuple[Dict[str, float], Dict[str, int]]:
        token = (inst.family, _params_token(inst.params))
        hit = self._costed.get(token)
        if hit is None:
            flops, desc, _ = instance_entry(inst)
            hit = (
                {k: float(v) for k, v in flops.items()},
                {alg: len(ks) for alg, ks in desc["kernels"].items()},
            )
            self._costed[token] = hit
        return hit

    def _machine(self, name: str) -> MachineSpec:
        if name not in self._machines:
            self._machines[name] = resolve_machine_spec(name, self.census_spec)
        return self._machines[name]

    # -------------------------------------------------------------- queries ---

    def query(self, family: str, params: Mapping[str, Any], *,
              machine: Optional[str] = None, enqueue: bool = True) -> Dict[str, Any]:
        """One verdict. ``machine`` overrides the cache's default label;
        ``enqueue=False`` suppresses the miss queue (pure lookups)."""
        inst, size = self._resolve(family, params)
        machine_name = machine or self.machine_name
        bucket = shape_bucket(size, self.spec.per_octave)
        key = cache_key(family, bucket, machine_name)
        verdict: Dict[str, Any] = {
            "family": family,
            "params": dict(params),
            "uid": inst.uid,
            "index": inst.index,
            "machine": machine_name,
            "bucket": bucket,
            "key": key,
            "enqueued": False,
        }
        entry = self.cache.get(key)
        if entry is not None and inst.uid in entry.get("sources", {}):
            verdict.update(self._measured_verdict(entry, inst.uid))
        elif entry is not None:
            verdict.update(self._bucketed_verdict(entry))
        else:
            learned = self._learned_verdict(inst, machine_name)
            verdict.update(
                learned if learned is not None
                else self._model_verdict(inst, machine_name)
            )
            if enqueue:
                self.cache.enqueue_miss(
                    uid=inst.uid, index=inst.index, family=family,
                    params=inst.params, machine=machine_name, key=key,
                )
                verdict["enqueued"] = True
        return verdict

    def query_batch(self, queries: Sequence[Mapping[str, Any]], *,
                    machine: Optional[str] = None,
                    enqueue: bool = True) -> List[Dict[str, Any]]:
        """Verdicts for ``[{"family": ..., "params": ...}, ...]`` (each
        query may also carry its own ``machine`` override)."""
        return [
            self.query(
                str(q["family"]), q["params"],
                machine=q.get("machine") or machine, enqueue=enqueue,
            )
            for q in queries
        ]

    # ----------------------------------------------------- verdict builders ---

    @staticmethod
    def _measured_verdict(entry: Mapping[str, Any], uid: str) -> Dict[str, Any]:
        src = entry["sources"][uid]
        ranks = {alg: int(r) for alg, r in src["ranks"].items()}
        mean_ranks = {alg: float(v) for alg, v in src["mean_ranks"].items()}
        order = sorted(ranks, key=lambda a: (mean_ranks.get(a, ranks[a]), a))
        return {
            "confidence": CONFIDENCE_MEASURED,
            "cache_hit": True,
            "is_anomaly": bool(src["is_anomaly"]),
            "reason": src.get("reason", ""),
            "ranking": [
                {"alg": alg, "rank": ranks[alg],
                 "mean_rank": mean_ranks.get(alg, float(ranks[alg])),
                 "confidence": 1.0}
                for alg in order
            ],
            "ranks": ranks,
            "min_flops_algs": list(src.get("min_flops_algs", ())),
            "cause": src.get("cause"),
            "cause_evidence": src.get("cause_evidence"),
            "n_records": 1,
        }

    @staticmethod
    def _bucketed_verdict(entry: Mapping[str, Any]) -> Dict[str, Any]:
        return {
            "confidence": CONFIDENCE_BUCKETED,
            "cache_hit": True,
            "is_anomaly": bool(entry["is_anomaly"]),
            "reason": "",
            "ranking": [dict(r) for r in entry["ranking"]],
            "ranks": dict(entry["ranks"]),
            "min_flops_algs": list(entry["min_flops_algs"]),
            "cause": entry.get("cause"),
            "cause_evidence": entry.get("cause_evidence"),
            "n_records": int(entry["n_records"]),
            "anomaly_rate": float(entry.get("anomaly_rate", 0.0)),
        }

    def _learned(self) -> Optional[Any]:
        """The trained predictor behind ``spec.model``, opened once.
        A drifted/tampered model file raises
        :class:`~repro.predict.model.ModelDrift` on the first miss —
        loudly, instead of silently degrading to the analytic tier."""
        if not self._predictor_tried:
            self._predictor_tried = True
            if self.spec.model:
                from repro.predict.active import ActivePredictor

                self._predictor = ActivePredictor.open(
                    self.spec.model, self.census_spec, threshold=0.0,
                    machine=self.spec.machine,
                )
        return self._predictor

    def _learned_verdict(
        self, inst: InstanceSpec, machine_name: str
    ) -> Optional[Dict[str, Any]]:
        """The trained model's verdict for a miss, or ``None`` when no
        model is attached or the query targets a machine the model was
        not trained against (the analytic tier handles those)."""
        predictor = self._learned()
        if predictor is None or predictor.machine_name != machine_name:
            return None
        pred = predictor.predict(inst)
        order = sorted(pred.ranks, key=lambda a: (pred.ranks[a], a))
        return {
            "confidence": CONFIDENCE_LEARNED,
            "cache_hit": False,
            "is_anomaly": bool(pred.is_anomaly),
            "reason": pred.reason,
            "ranking": [
                {"alg": alg, "rank": pred.ranks[alg],
                 "mean_rank": float(pred.ranks[alg]),
                 "confidence": round(pred.confidence, 6)}
                for alg in order
            ],
            "ranks": dict(pred.ranks),
            "min_flops_algs": list(pred.min_flops_algs),
            "cause": None,
            "cause_evidence": None,
            "n_records": 0,
            "model_confidence": round(pred.confidence, 6),
            "flip_prob": round(pred.flip_prob, 6),
        }

    def _model_verdict(self, inst: InstanceSpec, machine_name: str) -> Dict[str, Any]:
        """The analytic fallback: machine compute time per algorithm plus
        per-kernel dispatch — answered from the family's flops tables, no
        measurement, no jax. Rank classes collapse times within
        :data:`MODEL_REL_TOL`; the anomaly rule is the census's (a
        min-FLOPs algorithm outside the best class)."""
        flops, kernel_counts = self._cost(inst)
        machine = self._machine(machine_name)
        dispatch = machine.dispatch_overhead_s + self.census_spec.dispatch_s
        times = {
            alg: machine.t_compute(flops[alg])
            + dispatch * kernel_counts.get(alg, 0)
            for alg in flops
        }
        order = sorted(times, key=lambda a: (times[a], a))
        ranks: Dict[str, int] = {}
        rank, base = 0, None
        for alg in order:
            if base is None or times[alg] > base * (1.0 + MODEL_REL_TOL):
                rank += 1
                base = times[alg]
            ranks[alg] = rank
        fmin = min(flops.values())
        tol = self.census_spec.flops_rel_tol
        min_flops_algs = sorted(
            alg for alg in flops if flops[alg] <= fmin * (1.0 + tol)
        )
        best_in_sf = min(ranks[alg] for alg in min_flops_algs)
        return {
            "confidence": CONFIDENCE_MODEL_ONLY,
            "cache_hit": False,
            "is_anomaly": best_in_sf > min(ranks.values()),
            "reason": "",
            "ranking": [
                {"alg": alg, "rank": ranks[alg],
                 "mean_rank": float(ranks[alg]), "confidence": None}
                for alg in order
            ],
            "ranks": ranks,
            "min_flops_algs": min_flops_algs,
            "cause": None,
            "cause_evidence": None,
            "n_records": 0,
        }


def hit_rate(verdicts: Sequence[Mapping[str, Any]]) -> float:
    """Fraction of verdicts served from the cache itself — strictly
    ``measured``/``bucketed``; a learned-model answer is still a cache
    miss (it will be measured in the background)."""
    if not verdicts:
        return 0.0
    hits = sum(
        1 for v in verdicts
        if v["confidence"] in (CONFIDENCE_MEASURED, CONFIDENCE_BUCKETED)
    )
    return hits / len(verdicts)


# ---------------------------------------------------------------- the queue ---


class OracleQueue:
    """A cache root as a drainable work queue (the third registered store
    kind). ``run_shard`` measures the shard's pending misses under the
    CENSUS's own spec — so for deterministic backends the refreshed entry
    sources are byte-identical to what the census itself would have
    recorded — and folds each into its cache entry. Duck-type and lease
    discipline match :class:`repro.launch.queue.SweepQueue`, so any
    ``queue work`` host (and fsck) handles cache roots unchanged."""

    kind = "oracle"

    def __init__(self, out: str) -> None:
        self.out = out
        self.spec = OracleCacheSpec.load(os.path.join(out, SPEC_FILE))
        self.n_shards = self.spec.n_shards
        self.cache = OracleCache.open(out)
        self.census_spec = SweepSpec.load(
            os.path.join(self.spec.census, "spec.json")
        )
        self.machine_name = default_machine_name(self.spec, self.census_spec)

    def shard_totals(self) -> List[int]:
        totals, _ = self.cache.miss_totals()
        return totals

    def run_shard(self, shard: int, *, heartbeat=None, max_steps=None,
                  progress=None) -> None:
        tell = progress or (lambda msg: None)
        steps = 0
        for miss in self.cache.pending(shard):
            inst = InstanceSpec(
                index=int(miss["index"]), uid=str(miss["uid"]),
                family=str(miss["family"]), params=dict(miss["params"]),
            )
            session = build_sweep_session(self.census_spec, inst)
            while not session.done:
                session.step()
                steps += 1
                if heartbeat is not None:
                    heartbeat()
                if max_steps is not None and steps >= max_steps:
                    # pause mid-miss: nothing committed, the deterministic
                    # session re-measures identically on the next pass
                    tell(f"oracle shard {shard}: paused before {miss['uid']}")
                    return
            if heartbeat is not None:
                heartbeat(True)
            record = record_from_session(session, self.census_spec)
            entry = self.cache.refresh_from_record(
                record, str(miss.get("machine") or self.machine_name)
            )
            tell(
                f"oracle shard {shard}: measured {miss['uid']} -> "
                f"{entry['key']} seq {entry['seq']}"
            )
        self.cache.mark_done(shard)

    def merge(self) -> str:
        """One JSONL of each key's latest entry (atomic)."""
        path = os.path.join(self.out, "merged.jsonl")
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            for key in self.cache.keys():
                entry = self.cache.get(key)
                if entry is not None:
                    fh.write(_record_line(entry))
        os.replace(tmp, path)
        return path

    def progress(self) -> Dict[str, int]:
        totals, pendings = self.cache.miss_totals()
        return {
            "completed": sum(totals) - sum(pendings),
            "total": sum(totals),
        }
