"""Two-tier ranking cache behind the serving oracle.

The census and the explainer answer "which algorithm wins, and why does
FLOPs lie here?" offline; :mod:`repro.serve.oracle` serves those answers
online. This module is the storage layer between the two worlds:

* **Tier 1** — an in-memory LRU of decoded cache entries (the hot path:
  a warmed key costs two dict lookups, no IO, no json).
* **Tier 2** — a persistent on-disk store of the same entries, one
  CRC-checksummed JSONL shard file per hash bucket, written through the
  census's own :class:`repro.core.sweep.ShardStore` so every durability
  idiom carries over unchanged: torn-tail truncation, mid-file damage
  refusal, slim manifests, leases, and fsck repair (the store registers
  its own :class:`repro.core.stores.StoreKind` — spec file
  ``ocache.json`` — so ``queue``/``fsck`` auto-detect cache roots).

Entries are keyed ``family|shape-bucket|machine`` — the shape bucket is
the repo's ONE bucketing rule (:func:`repro.configs.shapes.shape_bucket`,
shared with the census report tables), so an oracle answer and a report
row always agree about which bucket an instance belongs to. An entry
aggregates every census record that fell into its bucket (per-algorithm
modal rank + vote-share confidence) and keeps the per-record digests in
``sources``, so a query for an instance the census actually measured can
answer byte-identically to the census record instead of the aggregate.

Updates are append-only: a refreshed entry is appended with a bumped
``seq`` and the scan index keeps the latest — exactly the census's
"the JSONL is the source of truth" contract, which is what lets fsck
repair a damaged cache shard like any other shard.

Cache *misses* are durable too: :meth:`OracleCache.enqueue_miss` appends
the missed instance to a per-shard ``miss-NNNN.jsonl`` (same CRC'd line
format) and clears the shard's manifest ``done`` flag, which re-opens the
shard to the ordinary pull queue — any ``queue work`` host then measures
the miss under the census's own spec and refreshes the entry. The hot
path never waits on any of that.

This module stays jax-free: the serving path imports nothing heavier
than the census's store code.
"""

from __future__ import annotations

import dataclasses
import json
import os
import zlib
from collections import OrderedDict
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from repro.configs.shapes import shape_bucket
from repro.core.sweep import (
    LINE_CRC_MISMATCH,
    LINE_UNDECODABLE,
    ShardStore,
    _record_line,
    parse_record_line,
)

#: the cache root's detection marker (see repro.core.stores)
SPEC_FILE = "ocache.json"

#: verdict confidence levels, strongest first
CONFIDENCE_MEASURED = "measured"      #: this exact instance is in the cache
CONFIDENCE_BUCKETED = "bucketed"      #: its (family, bucket, machine) is
CONFIDENCE_LEARNED = "learned_model"  #: trained cost model answered the miss
CONFIDENCE_MODEL_ONLY = "model_only"  #: analytic cost-model fallback


# ----------------------------------------------------------------- the key ---


def cache_key(family: str, bucket: str, machine: str) -> str:
    """``family|bucket|machine``. Family names and machine names never
    contain ``|`` (enforced here), and bucket labels are ``[lo, hi)``."""
    for part in (family, machine):
        if "|" in part:
            raise ValueError(f"cache key part {part!r} contains '|'")
    return f"{family}|{bucket}|{machine}"


def split_key(key: str) -> Tuple[str, str, str]:
    family, bucket, machine = key.split("|", 2)
    return family, bucket, machine


def shard_of_key(key: str, n_shards: int) -> int:
    """Stable hash sharding — every host agrees where a key lives."""
    return zlib.crc32(key.encode("utf-8")) % max(1, n_shards)


# ---------------------------------------------------------------- the spec ---


@dataclasses.dataclass
class OracleCacheSpec:
    """One serving cache, declaratively: where its knowledge comes from
    (a census store, optionally an explain store) and how it is laid out.
    Saved as ``ocache.json`` in the cache root — the store-kind marker."""

    name: str = "oracle"
    #: the census store root this cache is warmed from (and whose
    #: ``spec.json`` defines how misses are measured)
    census: str = ""
    #: optional explain store root (attaches causes to anomaly verdicts)
    explain: str = ""
    #: MachineSpec registry name; empty = derive from the census backend
    #: (the explainer's rule: synthetic machine for cost_model/simulated,
    #: cpu-1core for wall_clock)
    machine: str = ""
    #: optional trained cost model JSON (``repro predict train``): cache
    #: misses consult it before the analytic roofline and answer with
    #: confidence ``learned_model``
    model: str = ""
    n_shards: int = 4
    #: tier-1 capacity (decoded entries held in memory per oracle process)
    lru_capacity: int = 4096
    #: sub-buckets per power-of-two octave in the shape-bucketing rule;
    #: 1 = the census report tables' historical power-of-two buckets
    per_octave: int = 1
    fsync: bool = False

    def __post_init__(self) -> None:
        if self.n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if self.lru_capacity < 1:
            raise ValueError("lru_capacity must be >= 1")
        if self.per_octave < 1:
            raise ValueError("per_octave must be >= 1")

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["version"] = 1
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "OracleCacheSpec":
        kwargs = {
            f.name: d[f.name] for f in dataclasses.fields(cls) if f.name in d
        }
        return cls(**kwargs)

    def save(self, path: str) -> str:
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(self.to_dict(), fh, indent=1, sort_keys=True)
        os.replace(tmp, path)
        return path

    @classmethod
    def load(cls, path: str) -> "OracleCacheSpec":
        with open(path) as fh:
            return cls.from_dict(json.load(fh))


# ------------------------------------------------------------- the entries ---


def source_digest(record: Mapping[str, Any],
                  explained: Optional[Mapping[str, Any]] = None) -> Dict[str, Any]:
    """The per-census-record slice an entry retains: enough to answer a
    ``measured`` query byte-identically to the census record's ranking,
    plus the explainer's cause when that record was explained."""
    digest: Dict[str, Any] = {
        "index": int(record["index"]),
        "size": int(record["size"]),
        "ranks": dict(record["ranks"]),
        "mean_ranks": {k: float(v) for k, v in record["mean_ranks"].items()},
        "is_anomaly": bool(record["is_anomaly"]),
        "reason": record.get("reason", ""),
        "min_flops_algs": list(record.get("min_flops_algs", ())),
        "cause": None,
        "cause_evidence": None,
        "offending_kernel": None,
    }
    if explained is not None:
        digest["cause"] = explained.get("cause")
        digest["cause_evidence"] = explained.get("evidence")
        digest["offending_kernel"] = explained.get("offending_kernel")
    return digest


def _modal(values: Sequence[Any]) -> Tuple[Any, float]:
    """(most common value, vote share); ties break to the smaller value
    so the aggregation is deterministic regardless of source order."""
    counts: Dict[Any, int] = {}
    for v in values:
        counts[v] = counts.get(v, 0) + 1
    winner = min(counts, key=lambda v: (-counts[v], v))
    return winner, counts[winner] / len(values)


def aggregate_entry(key: str, sources: Mapping[str, Mapping[str, Any]],
                    seq: int) -> Dict[str, Any]:
    """One cache entry from its per-record sources: per-algorithm modal
    rank with vote-share confidence, a ranking ordered by mean of
    mean-ranks, and the bucket-level anomaly verdict — the ISSUE's rule
    (min-FLOPs algorithm outside the best rank class ⇒ anomaly) applied
    to the modal ranks. Pure function of (key, sources, seq): warming
    twice from the same stores produces byte-identical entries."""
    family, bucket, machine = split_key(key)
    uids = sorted(sources)
    algs = sorted({alg for u in uids for alg in sources[u]["ranks"]})
    ranks: Dict[str, int] = {}
    confidence: Dict[str, float] = {}
    mean_ranks: Dict[str, float] = {}
    for alg in algs:
        votes = [int(sources[u]["ranks"][alg]) for u in uids
                 if alg in sources[u]["ranks"]]
        means = [float(sources[u]["mean_ranks"][alg]) for u in uids
                 if alg in sources[u]["mean_ranks"]]
        ranks[alg], confidence[alg] = _modal(votes)
        mean_ranks[alg] = sum(means) / len(means) if means else float(ranks[alg])
    ranking = [
        {"alg": alg, "rank": ranks[alg],
         "mean_rank": mean_ranks[alg], "confidence": confidence[alg]}
        for alg in sorted(algs, key=lambda a: (mean_ranks[a], a))
    ]
    min_flops_algs = sorted({
        alg for u in uids for alg in sources[u]["min_flops_algs"]
    })
    best_overall = min(ranks.values()) if ranks else 0
    best_in_sf = min(
        (ranks[a] for a in min_flops_algs if a in ranks), default=best_overall
    )
    anomalies = [u for u in uids if sources[u]["is_anomaly"]]
    causes = [sources[u]["cause"] for u in anomalies
              if sources[u].get("cause")]
    cause: Optional[str] = None
    cause_evidence: Optional[float] = None
    if causes:
        cause, _ = _modal(causes)
        evidences = [float(sources[u]["cause_evidence"] or 0.0)
                     for u in anomalies if sources[u].get("cause") == cause]
        cause_evidence = sum(evidences) / len(evidences)
    return {
        "uid": f"{key}#{seq:06d}",
        "key": key,
        "family": family,
        "bucket": bucket,
        "machine": machine,
        "seq": int(seq),
        "n_records": len(uids),
        "anomaly_rate": len(anomalies) / len(uids) if uids else 0.0,
        "is_anomaly": bool(min_flops_algs) and best_in_sf > best_overall,
        "ranking": ranking,
        "ranks": ranks,
        "min_flops_algs": min_flops_algs,
        "cause": cause,
        "cause_evidence": cause_evidence,
        "sources": {u: dict(sources[u]) for u in uids},
    }


# --------------------------------------------------------------- the cache ---


class OracleCache:
    """The two-tier store. :meth:`open` scans the shard JSONLs once and
    keeps only an offset index (key → latest entry's file position) plus
    per-key sequence counters — payloads stay on disk until a query
    promotes them into the LRU, so a million-entry cache opens in one
    pass and serves from O(lru_capacity) memory."""

    def __init__(self, root: str, spec: OracleCacheSpec) -> None:
        self.root = root
        self.spec = spec
        #: key -> (shard, byte offset, byte length) of the latest entry
        self._index: Dict[str, Tuple[int, int, int]] = {}
        self._seq: Dict[str, int] = {}
        self._lru: "OrderedDict[str, Dict[str, Any]]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        #: (shard, line_no, status) of damaged lines seen by the scan
        self.damaged: List[Tuple[int, int, str]] = []

    # ------------------------------------------------------------ lifecycle ---

    @classmethod
    def create(cls, root: str, spec: OracleCacheSpec) -> "OracleCache":
        os.makedirs(root, exist_ok=True)
        spec.save(os.path.join(root, SPEC_FILE))
        return cls.open(root)

    @classmethod
    def open(cls, root: str) -> "OracleCache":
        spec = OracleCacheSpec.load(os.path.join(root, SPEC_FILE))
        cache = cls(root, spec)
        cache._scan()
        return cache

    def _scan(self) -> None:
        self._index.clear()
        self._seq.clear()
        self._lru.clear()
        self.damaged = []
        for shard in range(self.spec.n_shards):
            path = ShardStore(self.root, shard).records_path
            try:
                with open(path, "rb") as fh:
                    data = fh.read()
            except OSError:
                continue
            offset = 0
            lines = data.splitlines(keepends=True)
            for i, line in enumerate(lines):
                if not line.endswith(b"\n"):
                    break  # torn tail: an append in flight or a kill
                rec, status = parse_record_line(line)
                if status in (LINE_UNDECODABLE, LINE_CRC_MISMATCH):
                    if i < len(lines) - 1:
                        self.damaged.append((shard, i + 1, status))
                    offset += len(line)
                    continue
                key = rec.get("key")
                seq = int(rec.get("seq", 0))
                if key and seq >= self._seq.get(key, -1):
                    self._seq[key] = seq
                    self._index[key] = (shard, offset, len(line))
                offset += len(line)

    # -------------------------------------------------------------- reading ---

    def __contains__(self, key: str) -> bool:
        return key in self._index

    def __len__(self) -> int:
        return len(self._index)

    def keys(self) -> List[str]:
        return sorted(self._index)

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        """Tier-1 lookup, falling through to a tier-2 seek+read. Returns
        None on a true miss (the caller's model-only fallback)."""
        entry = self._lru.get(key)
        if entry is not None:
            self._lru.move_to_end(key)
            self.hits += 1
            return entry
        pos = self._index.get(key)
        if pos is None:
            self.misses += 1
            return None
        shard, offset, length = pos
        path = ShardStore(self.root, shard).records_path
        with open(path, "rb") as fh:
            fh.seek(offset)
            line = fh.read(length)
        rec, status = parse_record_line(line)
        if rec is None or status in (LINE_UNDECODABLE, LINE_CRC_MISMATCH):
            # the indexed position rotted under us — treat as a miss and
            # drop the index entry; fsck repairs the shard
            self.damaged.append((shard, -1, status))
            del self._index[key]
            self.misses += 1
            return None
        self._promote(key, rec)
        self.hits += 1
        return rec

    def _promote(self, key: str, entry: Dict[str, Any]) -> None:
        self._lru[key] = entry
        self._lru.move_to_end(key)
        while len(self._lru) > self.spec.lru_capacity:
            self._lru.popitem(last=False)

    def stats(self) -> Dict[str, Any]:
        total = self.hits + self.misses
        return {
            "entries": len(self._index),
            "lru": len(self._lru),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
        }

    # -------------------------------------------------------------- writing ---

    def put_many(self, entries: Sequence[Mapping[str, Any]]) -> int:
        """Append entries to their shards (grouped: one writer open and
        one batch per shard), update the index/LRU. Returns the count."""
        by_shard: Dict[int, List[Dict[str, Any]]] = {}
        for entry in entries:
            by_shard.setdefault(
                shard_of_key(entry["key"], self.spec.n_shards), []
            ).append(dict(entry))
        written = 0
        for shard in sorted(by_shard):
            batch = by_shard[shard]
            store = ShardStore(self.root, shard, fsync=self.spec.fsync).open()
            store.append_records(batch)
            manifest = store.read_manifest() or {}
            end = int(manifest.get("records_bytes", 0))
            # walk the batch backwards from the committed end to recover
            # each appended line's file position (lines are canonical, so
            # re-serializing reproduces the committed byte lengths)
            for entry in reversed(batch):
                length = len(_record_line(entry).encode("utf-8"))
                end -= length
                key = entry["key"]
                self._index[key] = (shard, end, length)
                self._seq[key] = max(self._seq.get(key, -1), int(entry["seq"]))
                self._promote(key, entry)
            written += len(batch)
        return written

    def next_seq(self, key: str) -> int:
        return self._seq.get(key, -1) + 1

    # -------------------------------------------------------------- warming ---

    def warm(
        self,
        census_records: Sequence[Mapping[str, Any]],
        explain_records: Iterable[Mapping[str, Any]] = (),
        machine: str = "",
    ) -> int:
        """Build/refresh entries from merged census (+ explain) records.
        Idempotent: a key whose rebuilt sources match the stored entry is
        skipped, so re-warming from unchanged stores writes nothing."""
        explained = {str(r["uid"]): r for r in explain_records}
        grouped: Dict[str, Dict[str, Dict[str, Any]]] = {}
        for record in census_records:
            bucket = shape_bucket(int(record["size"]), self.spec.per_octave)
            key = cache_key(str(record["family"]), bucket, machine)
            uid = str(record["uid"])
            grouped.setdefault(key, {})[uid] = source_digest(
                record, explained.get(uid)
            )
        fresh: List[Dict[str, Any]] = []
        for key in sorted(grouped):
            sources = grouped[key]
            current = self.get(key)
            if current is not None:
                sources = {**current["sources"], **sources}
                if sources == current["sources"]:
                    rebuilt = aggregate_entry(key, sources, current["seq"])
                    if rebuilt == current:
                        continue
            fresh.append(aggregate_entry(key, sources, self.next_seq(key)))
        self.put_many(fresh)
        self.mark_clean_shards_done()
        return len(fresh)

    def refresh_from_record(self, record: Mapping[str, Any], machine: str,
                            explained: Optional[Mapping[str, Any]] = None) -> Dict[str, Any]:
        """Fold one freshly measured census record into its entry (the
        background queue's commit path) and return the new entry."""
        bucket = shape_bucket(int(record["size"]), self.spec.per_octave)
        key = cache_key(str(record["family"]), bucket, machine)
        current = self.get(key)
        sources = dict(current["sources"]) if current else {}
        sources[str(record["uid"])] = source_digest(record, explained)
        entry = aggregate_entry(key, sources, self.next_seq(key))
        self.put_many([entry])
        return entry

    # --------------------------------------------------------------- misses ---

    def miss_path(self, shard: int) -> str:
        return os.path.join(self.root, f"miss-{shard:04d}.jsonl")

    def enqueue_miss(self, *, uid: str, index: int, family: str,
                     params: Mapping[str, Any], machine: str, key: str) -> int:
        """Durably enqueue a missed instance for background measurement
        and re-open its shard to the pull queue. Small append + manifest
        touch — never a measurement; the hot path stays hot. Returns the
        shard the miss landed on."""
        shard = shard_of_key(key, self.spec.n_shards)
        line = _record_line({
            "uid": uid, "index": int(index), "family": family,
            "params": dict(params), "machine": machine, "key": key,
        })
        os.makedirs(self.root, exist_ok=True)
        with open(self.miss_path(shard), "a", encoding="utf-8") as fh:
            fh.write(line)
            fh.flush()
        self._clear_done(shard)
        return shard

    def _clear_done(self, shard: int) -> None:
        store = ShardStore(self.root, shard)
        manifest = store.read_manifest()
        if not manifest or not manifest.get("done"):
            return
        manifest["done"] = False
        tmp = store.manifest_path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(manifest, fh, indent=1, sort_keys=True)
        os.replace(tmp, store.manifest_path)

    def _miss_lines(self, shard: int) -> List[Dict[str, Any]]:
        try:
            with open(self.miss_path(shard), "rb") as fh:
                data = fh.read()
        except OSError:
            return []
        out: List[Dict[str, Any]] = []
        seen: set = set()
        for line in data.splitlines(keepends=True):
            if not line.endswith(b"\n"):
                break  # torn tail: an enqueue in flight
            rec, status = parse_record_line(line)
            if rec is None or status in (LINE_UNDECODABLE, LINE_CRC_MISMATCH):
                continue  # a damaged miss line only re-misses later
            if rec["uid"] in seen:
                continue
            seen.add(rec["uid"])
            out.append(rec)
        return out

    def pending(self, shard: int) -> List[Dict[str, Any]]:
        """Enqueued misses on ``shard`` not yet folded into their entry,
        deduped, in enqueue order — the background worker's work list."""
        out = []
        for miss in self._miss_lines(shard):
            entry = self.get(miss["key"])
            if entry is not None and miss["uid"] in entry.get("sources", {}):
                continue
            out.append(miss)
        return out

    def miss_totals(self) -> Tuple[List[int], List[int]]:
        """(distinct enqueued misses, still-pending misses) per shard."""
        totals, pendings = [], []
        for shard in range(self.spec.n_shards):
            totals.append(len(self._miss_lines(shard)))
            pendings.append(len(self.pending(shard)))
        return totals, pendings

    def mark_done(self, shard: int) -> None:
        ShardStore(self.root, shard, fsync=self.spec.fsync).open() \
            .write_manifest(done=True)

    def mark_clean_shards_done(self) -> None:
        """Flag every shard with no pending misses done, so a freshly
        warmed cache reads as a drained queue until something misses."""
        for shard in range(self.spec.n_shards):
            if not self.pending(shard):
                self.mark_done(shard)
