"""Pipeline parallelism: GPipe-style microbatch schedule over a stage axis.

Not part of the default mesh (DESIGN.md §5: the assigned cells fit without
PP and a stage axis strictly increases the collective term for them), but
required posture for >HBM models at 1000+ nodes. Implementation is
TPU-native: ``shard_map`` over a ``stage`` mesh axis with
``jax.lax.ppermute`` moving activations stage->stage+1; the classic GPipe
schedule runs M microbatches over S stages in M+S-1 ticks (bubble fraction
(S-1)/(M+S-1)).

``pipeline_apply`` is checked against the sequential reference in
tests/test_pipeline.py (exact equality at f32).
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.launch.compat import shard_map

Pytree = Any


def pipeline_apply(
    stage_fn: Callable[[Pytree, jax.Array], jax.Array],
    stage_params: Pytree,          # leaves stacked [S, ...]
    microbatches: jax.Array,       # [M, mb, ...] (same shape through stages)
    mesh: Mesh,
    stage_axis: str = "stage",
) -> jax.Array:
    """Run ``x -> stage_fn(p_S-1, ... stage_fn(p_0, x))`` pipelined.

    Returns [M, mb, ...] outputs. ``stage_fn`` must preserve the activation
    shape (standard for transformer blocks).
    """
    n_stages = mesh.shape[stage_axis]
    m = microbatches.shape[0]
    ticks = m + n_stages - 1

    param_specs = jax.tree.map(
        lambda _: PartitionSpec(stage_axis), stage_params
    )
    in_specs = (param_specs, PartitionSpec())          # microbatches replicated
    out_specs = PartitionSpec()                        # outputs replicated

    def per_stage(params_local: Pytree, micro: jax.Array) -> jax.Array:
        # params_local leaves: [1, ...] (this stage's slice)
        params_here = jax.tree.map(lambda p: p[0], params_local)
        stage_id = jax.lax.axis_index(stage_axis)
        mb_shape = micro.shape[1:]

        fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        # carry: (inflight activation for this stage, collected outputs)
        def body(carry, t):
            inflight, outputs = carry
            # stage 0 injects microbatch t (clamped reads are masked by the
            # commit window on the last stage)
            mb_idx = jnp.clip(t, 0, m - 1)
            x_in = jnp.where(stage_id == 0, microbatches[mb_idx], inflight)
            y = stage_fn(params_here, x_in)
            # last stage commits its result for microbatch (t - S + 1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            commit = jnp.logical_and(
                stage_id == n_stages - 1, t >= n_stages - 1
            )
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs,
                jnp.where(commit, y, outputs[out_idx]),
                out_idx,
                axis=0,
            )
            # move activations to the next stage
            nxt = jax.lax.ppermute(y, stage_axis, fwd_perm)
            return (nxt, outputs), None

        inflight0 = jnp.zeros(mb_shape, microbatches.dtype)
        outputs0 = jnp.zeros((m,) + mb_shape, microbatches.dtype)
        (_, outputs), _ = jax.lax.scan(
            body, (inflight0, outputs0), jnp.arange(ticks)
        )
        # only the last stage's `outputs` is real; broadcast via all_gather
        # so out_specs can be replicated.
        gathered = jax.lax.all_gather(outputs, stage_axis)   # [S, M, mb...]
        return gathered[n_stages - 1]

    fn = shard_map(
        per_stage, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )
    return fn(stage_params, microbatches)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """GPipe bubble overhead: (S-1) / (M + S - 1)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)
