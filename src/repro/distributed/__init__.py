"""repro.distributed — sharding plans, gradient compression, pipeline PP."""

from .compression import (
    ErrorFeedback,
    compressed_psum,
    dequantize_tree,
    quantize_int8,
    quantize_tree,
)
from .pipeline import bubble_fraction, pipeline_apply
from .sharding import (
    ShardingPlan,
    attention_strategy,
    batch_spec,
    cache_seq_spec,
    dp_axes,
    dp_size,
    expert_strategy,
    make_plan,
    state_specs,
    tp_size,
    tree_shardings,
)

__all__ = [
    "ErrorFeedback",
    "ShardingPlan",
    "attention_strategy",
    "batch_spec",
    "bubble_fraction",
    "cache_seq_spec",
    "compressed_psum",
    "dequantize_tree",
    "dp_axes",
    "dp_size",
    "expert_strategy",
    "make_plan",
    "pipeline_apply",
    "quantize_int8",
    "quantize_tree",
    "state_specs",
    "tp_size",
    "tree_shardings",
]
