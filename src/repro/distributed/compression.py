"""Gradient compression with error feedback (int8 / sign-SGD style).

At 1000+-node scale the cross-pod (DCN) gradient all-reduce is the scaling
bottleneck; 4x (int8) compression with error feedback keeps convergence
(Seide et al. 2014; Karimireddy et al. 2019 — EF-SGD). Two layers:

* pure quantisation ops (`quantize_int8` / `dequantize_int8`) — per-leaf
  symmetric scaling, exactly invertible modulo rounding;
* :class:`ErrorFeedback` — carries the quantisation residual into the next
  step so compression error does not accumulate (sum over steps telescopes);
* ``compressed_psum`` — a shard_map-level DP gradient sync that all-reduces
  int8 payloads (sum of dequantised shards) for explicit-DP deployments;
  the pjit path stays uncompressed (XLA owns its all-reduces) and the
  cross-pod axis is where this is wired in production.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


class QuantizedLeaf(NamedTuple):
    q: jax.Array        # int8 payload
    scale: jax.Array    # f32 scalar (per leaf)


def quantize_int8(x: jax.Array) -> QuantizedLeaf:
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return QuantizedLeaf(q=q, scale=scale)


def dequantize_int8(leaf: QuantizedLeaf) -> jax.Array:
    return leaf.q.astype(jnp.float32) * leaf.scale


def quantize_tree(tree: Pytree) -> Pytree:
    return jax.tree.map(quantize_int8, tree)


def dequantize_tree(tree: Pytree) -> Pytree:
    return jax.tree.map(
        dequantize_int8, tree, is_leaf=lambda x: isinstance(x, QuantizedLeaf)
    )


class ErrorFeedback:
    """e_{t+1} = g_t + e_t - Q(g_t + e_t); apply before quantising."""

    @staticmethod
    def init(grads: Pytree) -> Pytree:
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    @staticmethod
    def compress(
        grads: Pytree, residual: Pytree
    ) -> Tuple[Pytree, Pytree]:
        """Returns (quantized tree, new residual)."""
        corrected = jax.tree.map(
            lambda g, e: g.astype(jnp.float32) + e, grads, residual
        )
        quantized = quantize_tree(corrected)
        recon = dequantize_tree(quantized)
        new_residual = jax.tree.map(lambda c, r: c - r, corrected, recon)
        return quantized, new_residual


def compressed_psum(grads: Pytree, axis_name: str) -> Pytree:
    """shard_map-level DP sync: quantise locally, all-reduce, dequantise.

    Payload over the wire is int8 (4x smaller than f32). Precision note:
    psum of int8 payloads requires a shared scale — we use the max scale
    across the axis (one tiny f32 all-reduce), then sum int32-accumulated
    payloads.
    """

    def sync(g: jax.Array) -> jax.Array:
        leaf = quantize_int8(g)
        scale = jax.lax.pmax(leaf.scale, axis_name)
        # requantise against the shared scale so the sum is coherent
        q = jnp.clip(
            jnp.round(g.astype(jnp.float32) / scale), -127, 127
        ).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return total.astype(jnp.float32) * scale

    return jax.tree.map(sync, grads)
