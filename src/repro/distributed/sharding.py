"""Logical-axis sharding rules: DP/FSDP x TP (+ EP/SP) over (pod, data, model).

Models annotate parameters with *logical* axis names; this module maps them
to mesh axes per architecture and mode:

* ``embed``   -> FSDP over the data-parallel axes (pod, data) — ZeRO-style
  parameter + optimizer-state sharding;
* ``vocab``/``ffn``/``q_heads``/``heads``/``moe_ffn`` -> ``model`` (tensor /
  expert parallelism), subject to divisibility;
* attention strategy per arch (``head`` / ``head_q`` / ``sequence``): head
  counts that do not divide the model axis fall back gracefully (DESIGN §5);
* any rule whose axis sizes do not divide the dimension is dropped for that
  leaf (replicate fallback) — recorded for the dry-run report.

The mesh axes are data-parallel ``("pod", "data")`` and tensor ``"model"``;
single-pod meshes simply lack the ``pod`` axis — rules reference axes by
name and silently skip absent ones.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models import ModelConfig

AxisRule = Optional[Tuple[str, ...]]  # mesh axes assigned to a logical axis


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def tp_size(mesh: Mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)])) if dp_axes(mesh) else 1


def attention_strategy(cfg: ModelConfig, tp: int) -> str:
    """head: q+kv heads TP; head_q: q TP + replicated KV (broadcast GQA);
    sequence: sequence-parallel attention (no head sharding)."""
    if tp <= 1:
        return "head"
    if cfg.n_heads % tp == 0 and cfg.n_kv_heads % tp == 0:
        return "head"
    if cfg.n_heads % tp == 0:
        return "head_q"
    return "sequence"


def expert_strategy(cfg: ModelConfig, tp: int) -> str:
    """expert: experts over model (EP); tensor: per-expert d_ff over model."""
    if cfg.n_experts and cfg.n_experts % tp == 0:
        return "expert"
    return "tensor"


@dataclasses.dataclass
class ShardingPlan:
    mesh: Mesh
    rules: Dict[Optional[str], AxisRule]
    attention: str
    experts: str
    fallbacks: List[str] = dataclasses.field(default_factory=list)

    def spec_for(self, axes: Sequence[Optional[str]], shape: Sequence[int]) -> PartitionSpec:
        """PartitionSpec for one leaf, dropping non-dividing rules."""
        entries: List[AxisRule] = []
        for ax_name, dim in zip(axes, shape):
            rule = self.rules.get(ax_name)
            if rule is None:
                entries.append(None)
                continue
            present = tuple(a for a in rule if a in self.mesh.axis_names)
            if not present:
                entries.append(None)
                continue
            total = int(np.prod([self.mesh.shape[a] for a in present]))
            if dim % total != 0:
                # try prefixes (e.g. ("pod","data") -> ("pod",))
                chosen: AxisRule = None
                for k in range(len(present) - 1, 0, -1):
                    sub = present[:k]
                    t = int(np.prod([self.mesh.shape[a] for a in sub]))
                    if dim % t == 0:
                        chosen = sub
                        break
                if chosen is None:
                    self.fallbacks.append(
                        f"axis {ax_name!r} dim {dim} !% mesh{present} -> replicated"
                    )
                    entries.append(None)
                else:
                    self.fallbacks.append(
                        f"axis {ax_name!r} dim {dim} !% mesh{present} -> {chosen}"
                    )
                    entries.append(chosen)
            else:
                entries.append(present)
        return PartitionSpec(*entries)

    def sharding_for(self, axes, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(axes, shape))


def make_plan(
    cfg: ModelConfig,
    mesh: Mesh,
    mode: str = "train",          # train | prefill | decode
    zero3: bool = True,
) -> ShardingPlan:
    """Build the logical-axis -> mesh-axes rule table for (arch, mode)."""
    tp = tp_size(mesh)
    dpa = dp_axes(mesh)
    attn = attention_strategy(cfg, tp)
    exps = expert_strategy(cfg, tp)

    rules: Dict[Optional[str], AxisRule] = {
        None: None,
        "layers": None,                       # scan dim, never sharded
        "vocab": ("model",),
        "embed": dpa if zero3 else None,      # FSDP / ZeRO-3 storage shard
        "ffn": ("model",),
        "moe_ffn": ("model",) if exps == "tensor" else None,
        "experts": ("model",) if exps == "expert" else None,
        "heads": ("model",),                  # SSD heads
        "head_dim": None,
    }
    if attn == "head":
        rules["q_heads"] = ("model",)
        rules["kv_heads"] = ("model",)
    elif attn == "head_q":
        rules["q_heads"] = ("model",)
        rules["kv_heads"] = None              # replicated KV (broadcast GQA)
    else:  # sequence-parallel attention
        rules["q_heads"] = None
        rules["kv_heads"] = None

    return ShardingPlan(mesh=mesh, rules=rules, attention=attn, experts=exps)


def tree_shardings(plan: ShardingPlan, axes_tree: Any, shape_tree: Any) -> Any:
    """NamedSharding tree matching (axes, shapes) trees leaf-for-leaf."""
    return jax.tree.map(
        lambda axes, shape_struct: plan.sharding_for(
            axes,
            shape_struct.shape if hasattr(shape_struct, "shape") else shape_struct,
        ),
        axes_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


# --------------------------------------------------------- activations -----

def batch_spec(mesh: Mesh, global_batch: int, extra_dims: int = 1) -> PartitionSpec:
    """Shard the batch dim over (pod, data) when divisible, else replicate."""
    dpa = dp_axes(mesh)
    if dpa:
        total = int(np.prod([mesh.shape[a] for a in dpa]))
        if global_batch % total == 0:
            return PartitionSpec(dpa, *([None] * extra_dims))
        for k in range(len(dpa) - 1, 0, -1):
            t = int(np.prod([mesh.shape[a] for a in dpa[:k]]))
            if global_batch % t == 0:
                return PartitionSpec(dpa[:k], *([None] * extra_dims))
    return PartitionSpec(*([None] * (extra_dims + 1)))


def cache_seq_spec(mesh: Mesh, global_batch: int) -> PartitionSpec:
    """KV-cache sharding [b, S, K, hd]: batch over DP when divisible; the
    seq dim takes 'model' (+ the DP axes too when batch is too small —
    long-context decode with batch 1 shards S over every axis)."""
    dpa = dp_axes(mesh)
    dp_total = int(np.prod([mesh.shape[a] for a in dpa])) if dpa else 1
    if dpa and global_batch % dp_total == 0:
        return PartitionSpec(dpa, ("model",), None, None)
    return PartitionSpec(None, dpa + ("model",), None, None)


def state_specs(
    cfg: ModelConfig, plan: ShardingPlan, state_shapes: Any, global_batch: int
) -> Any:
    """Shardings for the decode-state pytree (KV caches / SSM states).

    KV caches [U, b, S, K, hd] -> batch over DP, seq over model.
    SSM states [U, b, h, p, n] -> batch over DP, heads over model.
    Conv states [U, b, k-1, c]  -> batch over DP, channels over model.
    """
    mesh = plan.mesh
    dpa = dp_axes(mesh)
    dp_total = int(np.prod([mesh.shape[a] for a in dpa])) if dpa else 1
    batch_ok = dpa and global_batch % dp_total == 0
    b_rule = dpa if batch_ok else None

    def spec_for_leaf(path: Tuple, leaf) -> NamedSharding:
        shape = leaf.shape
        names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        leafname = names[-1] if names else ""
        if leafname in ("k", "v") and any("kv" in str(n) for n in names):
            # [U, b, S, K, hd]
            seq_rule = ("model",) if batch_ok else (dpa + ("model",))
            seq_rule = _fit(mesh, seq_rule, shape[2])
            spec = PartitionSpec(None, _fit(mesh, b_rule, shape[1]), seq_rule, None, None)
        elif leafname == "ssm":
            h_rule = _fit(mesh, ("model",), shape[2])
            spec = PartitionSpec(None, _fit(mesh, b_rule, shape[1]), h_rule, None, None)
        elif names and "conv" in names:
            c_rule = _fit(mesh, ("model",), shape[3])
            spec = PartitionSpec(None, _fit(mesh, b_rule, shape[1]), None, c_rule)
        elif leafname in ("cross_k", "cross_v"):
            # [L, b, s_enc, K, hd]
            spec = PartitionSpec(None, _fit(mesh, b_rule, shape[1]), None, None, None)
        else:
            spec = PartitionSpec(*([None] * len(shape)))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(spec_for_leaf, state_shapes)


def _fit(mesh: Mesh, rule: AxisRule, dim: int) -> AxisRule:
    """Largest prefix of ``rule`` whose product divides ``dim``."""
    if rule is None:
        return None
    present = tuple(a for a in rule if a in mesh.axis_names)
    while present:
        total = int(np.prod([mesh.shape[a] for a in present]))
        if dim % total == 0:
            return present
        present = present[:-1]
    return None
