"""Ridge regression on log10-time — closed-form numpy solve, JSON on disk.

The model is deliberately tiny: eight analytic features, one linear
solve, no iterative fitting, no new dependencies. What it buys the census
is not accuracy on exotic workloads but *calibrated confidence*: the
training residual sigma is exactly the per-algorithm spread the features
cannot see (machine efficiency factors, cache effects), and that sigma is
what :mod:`repro.predict.active` turns into rank-flip probabilities.

Serialization contract: the JSON payload embeds the feature schema
(:data:`~repro.predict.features.FEATURE_NAMES` + version), the machine
label it was trained against, a SHA-256 digest of the training keys, and
a CRC of the payload itself. :meth:`RidgeModel.load` re-derives all of
them and raises :class:`ModelDrift` on any mismatch — a stale or
tampered model fails loudly instead of silently mis-gating a census.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .features import FEATURE_NAMES, FEATURE_VERSION, census_machine, training_rows

#: residual sigma floor (log10 units): a perfectly-fit training set must
#: not produce zero flip probabilities everywhere
MIN_SIGMA = 1e-6


class ModelDrift(RuntimeError):
    """A serialized model does not match this code's feature extraction
    (schema/version), its own integrity checksum, or the census it is
    being applied to. Retrain instead of predicting garbage."""


def train_set_digest(keys: Sequence[Tuple[str, str]]) -> str:
    """SHA-256 over the sorted ``uid|alg`` training keys — identifies WHAT
    the model was fitted on, independent of row order."""
    h = hashlib.sha256()
    for uid, alg in sorted(keys):
        h.update(f"{uid}|{alg}\n".encode("utf-8"))
    return h.hexdigest()


def fit_ridge(
    X: Sequence[Sequence[float]],
    y: Sequence[float],
    alpha: float = 1e-3,
) -> Tuple[List[float], float, float]:
    """Closed-form ridge: center features and target, solve the
    regularized normal equations, return ``(coef, intercept,
    residual_sigma)``. The intercept is unpenalized (centering does that
    for free); ``residual_sigma`` is the RMS training residual in log10
    units, floored at :data:`MIN_SIGMA`."""
    Xa = np.asarray(X, dtype=float)
    ya = np.asarray(y, dtype=float)
    if Xa.ndim != 2 or len(Xa) != len(ya) or len(Xa) == 0:
        raise ValueError("fit_ridge needs a non-empty (n, d) X and matching y")
    x_mean = Xa.mean(axis=0)
    y_mean = float(ya.mean())
    Xc = Xa - x_mean
    yc = ya - y_mean
    d = Xa.shape[1]
    coef = np.linalg.solve(
        Xc.T @ Xc + float(alpha) * np.eye(d), Xc.T @ yc
    )
    intercept = y_mean - float(x_mean @ coef)
    resid = ya - (Xa @ coef + intercept)
    sigma = max(float(np.sqrt(np.mean(resid ** 2))), MIN_SIGMA)
    return [float(c) for c in coef], float(intercept), sigma


@dataclass
class RidgeModel:
    """A trained predictor plus everything needed to refuse a bad load."""

    coef: List[float]
    intercept: float
    residual_sigma: float
    alpha: float
    n_train: int
    machine: str                                   #: machine label trained against
    train_digest: str = ""                         #: train_set_digest(keys)
    feature_names: Tuple[str, ...] = FEATURE_NAMES
    feature_version: int = FEATURE_VERSION
    n_skipped: int = 0                             #: wall-clock rows dropped at train time

    def __post_init__(self) -> None:
        self.feature_names = tuple(self.feature_names)
        if len(self.coef) != len(self.feature_names):
            raise ModelDrift(
                f"coefficient count {len(self.coef)} != feature count "
                f"{len(self.feature_names)}"
            )

    # ------------------------------------------------------- prediction ---

    def predict_one(self, vec: Sequence[float]) -> float:
        """Predicted log10 seconds for one feature vector."""
        if len(vec) != len(self.coef):
            raise ModelDrift(
                f"feature vector length {len(vec)} != model width "
                f"{len(self.coef)}"
            )
        return self.intercept + float(
            sum(c * float(v) for c, v in zip(self.coef, vec))
        )

    def predict_times(self, vecs: Mapping[str, Sequence[float]]) -> Dict[str, float]:
        """Predicted seconds per algorithm (de-logged)."""
        return {
            alg: 10.0 ** self.predict_one(vec)
            for alg, vec in sorted(vecs.items())
        }

    # ----------------------------------------------------- serialization ---

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["feature_names"] = list(self.feature_names)
        d["version"] = 1
        body = json.dumps(d, sort_keys=True, separators=(",", ":"))
        d["_crc"] = format(zlib.crc32(body.encode("utf-8")) & 0xFFFFFFFF, "08x")
        return d

    def save(self, path: str) -> str:
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(self.to_dict(), fh, indent=1, sort_keys=True)
        os.replace(tmp, path)
        return path

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "RidgeModel":
        body = {k: v for k, v in d.items() if k not in ("_crc",)}
        crc = format(
            zlib.crc32(
                json.dumps(body, sort_keys=True, separators=(",", ":"))
                .encode("utf-8")
            ) & 0xFFFFFFFF,
            "08x",
        )
        if d.get("_crc") != crc:
            raise ModelDrift(
                "model payload fails its own checksum — the file was "
                "edited or corrupted; retrain"
            )
        if int(d.get("feature_version", -1)) != FEATURE_VERSION:
            raise ModelDrift(
                f"model feature_version {d.get('feature_version')} != "
                f"this code's {FEATURE_VERSION}; retrain"
            )
        if tuple(d.get("feature_names", ())) != FEATURE_NAMES:
            raise ModelDrift(
                "model feature schema does not match this code's "
                f"FEATURE_NAMES; retrain ({d.get('feature_names')})"
            )
        kwargs = {
            f.name: d[f.name]
            for f in dataclasses.fields(cls)
            if f.name in d
        }
        return cls(**kwargs)

    @classmethod
    def load(cls, path: str) -> "RidgeModel":
        with open(path) as fh:
            return cls.from_dict(json.load(fh))


def train_model(
    spec: Any,
    records: Sequence[Mapping[str, Any]],
    machine: str = "",
    alpha: float = 1e-3,
) -> RidgeModel:
    """Fit a :class:`RidgeModel` from a merged census: features + targets
    via :func:`repro.predict.features.training_rows`, machine label via
    the serving oracle's resolution rule."""
    name, _ = census_machine(spec, machine)
    X, y, keys, n_skipped = training_rows(spec, records, machine)
    if not X:
        raise ValueError(
            "no trainable rows: the census holds only wall-clock records "
            "(no stored per-algorithm times) — train from a "
            "cost_model/simulated census"
        )
    coef, intercept, sigma = fit_ridge(X, y, alpha)
    return RidgeModel(
        coef=coef,
        intercept=intercept,
        residual_sigma=sigma,
        alpha=float(alpha),
        n_train=len(X),
        machine=name,
        train_digest=train_set_digest(keys),
        n_skipped=n_skipped,
    )
