"""Active-census gating: predict an instance's ranking, estimate how
likely the prediction is to flip, and skip measurement when it will not.

The acceptance logic mirrors the census end to end: predicted times go
through the same RT candidate filter, the same rank-class collapse idea
(times within :data:`PREDICT_REL_TOL` share a class), and the same
FLOPs-discriminant anomaly rule — so a ``predicted``-provenance record is
schema-compatible with a measured one and flows through merge, report,
explain targeting, and oracle warming unchanged.

Flip probability: the trained model's residual sigma is the log10-scale
spread the features cannot explain (the synthetic machine's per-algorithm
efficiency factors; on real machines, cache/instruction-order effects).
For each adjacent pair in the predicted time order the chance the TRUE
pair ordering disagrees with the predicted rank relation is a Gaussian
tail of the predicted gap against ``sigma * sqrt(2)``; the instance's
``flip_prob`` is the worst pair, and ``confidence = 1 - flip_prob``.
Equal-FLOPs algorithms whose predicted times coincide therefore get HIGH
flip probability (the census may well split them) and stay measured —
exactly the instances the paper's anomalies live in — while instances
separated by large FLOP gaps are skipped.

Everything is a pure function of ``(SweepSpec, model JSON, instance)``:
an active census emits byte-identical predicted records across SIGKILL
and resume, same as measured ones.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.scores import filter_candidates, min_flops_set, relative_flops
from repro.explain.decompose import kernels_from_compact

from .features import census_machine, instance_features
from .model import ModelDrift, RidgeModel

#: relative tolerance for collapsing predicted times into one rank class
#: (the model has no measurement noise to separate them) — matches the
#: serving oracle's analytic fallback
PREDICT_REL_TOL = 0.02

#: provenance marker on census records emitted without measurement
PROVENANCE_PREDICTED = "predicted"


def _phi(x: float) -> float:
    """Standard normal CDF."""
    return 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))


def rank_classes(times: Mapping[str, float], rel_tol: float = PREDICT_REL_TOL) -> Dict[str, int]:
    """Collapse times into 1-based rank classes: walking the sorted order,
    a new class opens when a time exceeds the class base by ``rel_tol``."""
    order = sorted(times, key=lambda a: (times[a], a))
    ranks: Dict[str, int] = {}
    rank, base = 0, None
    for alg in order:
        if base is None or times[alg] > base * (1.0 + rel_tol):
            rank += 1
            base = times[alg]
        ranks[alg] = rank
    return ranks


def pair_risks(
    times: Mapping[str, float],
    ranks: Mapping[str, int],
    sigma: float,
    rel_tol: float = PREDICT_REL_TOL,
) -> List[float]:
    """Per-adjacent-pair probability that the TRUE ranking relation
    disagrees with the predicted one. For a pair predicted in distinct
    classes the risk is that the true gap collapses or flips; for a pair
    predicted in the SAME class the risk is that the true times split —
    the anomaly-bearing case the census exists to catch."""
    order = sorted(times, key=lambda a: (times[a], a))
    thr = math.log10(1.0 + rel_tol)
    s = max(sigma, 1e-12) * math.sqrt(2.0)
    risks: List[float] = []
    for a, b in zip(order, order[1:]):
        gap = math.log10(times[b]) - math.log10(times[a])
        if ranks[a] == ranks[b]:
            # predicted tied: wrong if the true gap escapes [-thr, thr]
            inside = _phi((thr - gap) / s) - _phi((-thr - gap) / s)
            risks.append(max(0.0, min(1.0, 1.0 - inside)))
        else:
            # predicted split: wrong if the true gap falls back within thr
            risks.append(max(0.0, min(1.0, _phi((thr - gap) / s))))
    return risks


@dataclass(frozen=True)
class PredictedRanking:
    """One instance's model-predicted verdict (pre-gate)."""

    uid: str
    times: Dict[str, float]          #: predicted seconds per kept algorithm
    ranks: Dict[str, int]            #: 1-based rank classes over kept algs
    dropped: Tuple[str, ...]         #: RT-filtered (on predicted times)
    flip_prob: float                 #: worst adjacent-pair risk
    confidence: float                #: 1 - flip_prob
    is_anomaly: bool
    reason: str
    min_flops_algs: Tuple[str, ...]
    best_rank_in_sf: int
    best_rank_overall: int


class ActivePredictor:
    """A trained model bound to one census spec: per-instance predictions,
    the confidence gate, and ``predicted``-provenance records.

    Refuses (loudly, :class:`~repro.predict.model.ModelDrift`) to gate a
    census whose machine label differs from the one the model was trained
    against — cross-machine predictions are what the replay item is for,
    not the active gate."""

    def __init__(
        self,
        model: RidgeModel,
        spec: Any,
        threshold: Optional[float] = None,
        machine: str = "",
    ) -> None:
        name, mspec = census_machine(spec, machine)
        if model.machine != name:
            raise ModelDrift(
                f"model was trained against machine {model.machine!r} but "
                f"this census resolves to {name!r} — retrain (or pass the "
                "matching --machine)"
            )
        self.model = model
        self.spec = spec
        self.machine_name = name
        self.machine = mspec
        if threshold is None:
            threshold = float(getattr(spec, "predict_threshold", 0.95))
        if not 0.0 <= threshold <= 1.0:
            raise ValueError("threshold must be in [0, 1]")
        self.threshold = threshold

    @classmethod
    def open(
        cls,
        path: str,
        spec: Any,
        threshold: Optional[float] = None,
        machine: str = "",
    ) -> "ActivePredictor":
        return cls(RidgeModel.load(path), spec, threshold, machine)

    # ------------------------------------------------------- prediction ---

    def _entry(self, inst: Any) -> Tuple[Dict[str, float], Dict[str, Any]]:
        from repro.core.sweep import instance_entry

        flops, desc, _ = instance_entry(inst)
        return {k: float(v) for k, v in flops.items()}, desc

    def predict(self, inst: Any) -> PredictedRanking:
        """The model's verdict for one instance — same pipeline shape as a
        measured session: predict times, RT-filter candidates, collapse
        rank classes, run the FLOPs-discriminant rule."""
        flops, desc = self._entry(inst)
        vecs = instance_features(
            kernels_from_compact(desc["kernels"]), self.machine,
            self.spec.dispatch_s,
        )
        all_times = self.model.predict_times(vecs)
        cand = filter_candidates(
            flops, all_times,
            rt_threshold=self.spec.rt_threshold,
            flops_rel_tol=self.spec.flops_rel_tol,
        )
        times = {a: all_times[a] for a in cand.names}
        ranks = rank_classes(times)
        risks = pair_risks(times, ranks, self.model.residual_sigma)
        flip = max(risks, default=0.0)
        sf = tuple(
            n for n in min_flops_set(flops, rel_tol=self.spec.flops_rel_tol)
            if n in ranks
        )
        best_overall = min(ranks.values())
        best_in_sf = min(ranks[n] for n in sf) if sf else best_overall
        sf_ranks = {ranks[n] for n in sf}
        if best_in_sf > best_overall:
            is_anomaly, reason = True, "faster_outside_min_flops"
        elif len(sf_ranks) > 1:
            is_anomaly, reason = True, "min_flops_split"
        else:
            is_anomaly, reason = False, "none"
        return PredictedRanking(
            uid=inst.uid,
            times=times,
            ranks=ranks,
            dropped=tuple(cand.dropped),
            flip_prob=flip,
            confidence=1.0 - flip,
            is_anomaly=is_anomaly,
            reason=reason,
            min_flops_algs=sf,
            best_rank_in_sf=best_in_sf,
            best_rank_overall=best_overall,
        )

    def record(self, inst: Any, pred: Optional[PredictedRanking] = None) -> Dict[str, Any]:
        """A census-schema record for a predicted instance. Same fields as
        :func:`repro.core.sweep.record_from_session` plus ``provenance``
        and the prediction metadata — merge/report/explain/oracle consume
        it unchanged, and it is a pure function of (spec, model,
        instance), so resumed active censuses stay byte-identical."""
        if pred is None:
            pred = self.predict(inst)
        flops, desc = self._entry(inst)
        return {
            "uid": inst.uid,
            "index": int(inst.index),
            "family": inst.family,
            "size": desc["size"],
            "dims": desc["dims"],
            "params": dict(inst.params),
            "flops": flops,
            "kernels": desc["kernels"],
            "base_seed": int(self.spec.base_seed),
            "backend": self.spec.backend,
            "p": len(pred.ranks),
            "n_dropped": len(pred.dropped),
            "measurements_per_alg": 0,
            "iterations": 0,
            "converged": True,
            "classes": max(pred.ranks.values()),
            "is_anomaly": bool(pred.is_anomaly),
            "reason": pred.reason,
            "min_flops_algs": list(pred.min_flops_algs),
            "best_rank_in_sf": pred.best_rank_in_sf,
            "best_rank_overall": pred.best_rank_overall,
            "ranks": dict(pred.ranks),
            "mean_ranks": {a: float(r) for a, r in pred.ranks.items()},
            "relative_flops": relative_flops(flops),
            "provenance": PROVENANCE_PREDICTED,
            "predicted": {
                "confidence": round(pred.confidence, 6),
                "flip_prob": round(pred.flip_prob, 6),
                "model_digest": self.model.train_digest[:12],
            },
        }

    def gate(self, inst: Any) -> Optional[Dict[str, Any]]:
        """The campaign gate: a predicted record when the prediction's
        confidence clears the threshold, else ``None`` (measure it)."""
        pred = self.predict(inst)
        if pred.confidence >= self.threshold:
            return self.record(inst, pred)
        return None


def census_gate(spec: Any, instances: Mapping[str, Any]) -> Callable[[str], Optional[Dict[str, Any]]]:
    """The uid-keyed gate :func:`repro.core.sweep.run_shard` installs when
    ``spec.predictor_model`` is set."""
    predictor = ActivePredictor.open(
        spec.predictor_model, spec, threshold=spec.predict_threshold
    )
    return lambda uid: predictor.gate(instances[uid])


def prediction_errors(
    spec: Any,
    records: Sequence[Mapping[str, Any]],
    model: RidgeModel,
    machine: str = "",
) -> List[Dict[str, Any]]:
    """Per-record evaluation rows against a measured census (the
    pred-error report's input): absolute log10-time error per algorithm
    against the reconstructed deterministic ground truth, plus whether
    the predicted winner/anomaly verdict agrees with the census record.
    Wall-clock records score the verdict agreement only (no stored
    times)."""
    from repro.core.family import InstanceSpec
    from repro.core.sweep import synthetic_instance_model

    predictor = ActivePredictor(model, spec, threshold=0.0, machine=machine)
    rows: List[Dict[str, Any]] = []
    for rec in records:
        inst = InstanceSpec(
            index=int(rec["index"]), uid=str(rec["uid"]),
            family=str(rec["family"]), params=dict(rec["params"]),
        )
        pred = predictor.predict(inst)
        flops = {k: float(v) for k, v in rec["flops"].items()}
        err: Optional[float] = None
        if rec.get("backend", spec.backend) in ("cost_model", "simulated"):
            kernel_counts = {
                alg: len(ks) for alg, ks in rec.get("kernels", {}).items()
            }
            truth = synthetic_instance_model(
                spec, int(rec["index"]), flops, kernel_counts or None,
                base_seed=rec.get("base_seed"),
            )
            errs = [
                abs(math.log10(pred.times[a]) - math.log10(truth.costs[a]))
                for a in pred.times if a in truth.costs
            ]
            err = sum(errs) / len(errs) if errs else None
        rec_ranks = {a: int(r) for a, r in rec["ranks"].items()}
        best = min(rec_ranks.values())
        rec_winners = {a for a, r in rec_ranks.items() if r == best}
        pred_best = min(pred.ranks.values())
        pred_winners = {a for a, r in pred.ranks.items() if r == pred_best}
        rows.append({
            "uid": rec["uid"],
            "family": rec["family"],
            "size": rec["size"],
            "machine": predictor.machine_name,
            "abs_dlog10_t": err,
            "winner_match": bool(pred_winners & rec_winners),
            "anomaly_match": bool(pred.is_anomaly) == bool(rec["is_anomaly"]),
            "confidence": pred.confidence,
            "flip_prob": pred.flip_prob,
            "skipped": pred.confidence >= float(
                getattr(spec, "predict_threshold", 0.95)
            ),
            "provenance": rec.get("provenance", "measured"),
        })
    return rows
