"""Feature vectors for the learned cost model — jax-free by construction.

One (instance, algorithm) pair becomes one numeric vector built only from
what the census already knows analytically: the kernel decomposition
(:func:`repro.explain.decompose.kernels_from_record` — exact FLOPs and
byte traffic per :class:`~repro.explain.decompose.KernelSpec`) and the
machine's roofline terms (:class:`repro.roofline.terms.MachineSpec` —
compute time, memory time, per-kernel dispatch). No measurement happens
here; the extraction is a pure function of (record pointers, machine),
which is what lets an active census emit byte-identical predicted records
across kills and resumes.

Training targets come from :func:`training_rows`: on the deterministic
``cost_model``/``simulated`` backends every census record's measured
outcome is reconstructible bit-exactly from its rebuild pointers via
:func:`repro.core.sweep.synthetic_instance_model`, so the target is the
true log10 seconds per algorithm. Wall-clock records carry no stored
per-algorithm times (the census deliberately keeps wall time out of the
JSONL) and are skipped — counted, never silent.
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Mapping, Sequence, Tuple

from repro.explain.decompose import KernelSpec, kernels_from_record
from repro.roofline.terms import MACHINES, MachineSpec, get_machine, synthetic_machine

#: bump when the vector layout changes — serialized models embed it and
#: refuse to load against a different extraction (see repro.predict.model)
FEATURE_VERSION = 1

#: one name per vector slot, in order (the serialized feature schema)
FEATURE_NAMES: Tuple[str, ...] = (
    "log10_flops",            # total analytic FLOPs of the kernel sequence
    "log10_bytes",            # total memory traffic of the kernel sequence
    "log10_intensity",        # arithmetic intensity flops/bytes
    "kernel_count",           # kernels launched (the dispatch multiplier)
    "log10_max_kernel_flops", # heaviest single kernel
    "log10_t_compute",        # machine roofline compute time
    "log10_t_memory",         # machine roofline memory time
    "log10_t_roofline",       # max(compute, memory) + dispatch * kernels
)

#: log10 floor for quantities that can be exactly zero (e.g. memory time
#: on a pure-compute synthetic machine) — constant columns are harmless
#: under ridge, but log10(0) is not
_LOG_FLOOR = 1e-30


def _log10(x: float) -> float:
    return math.log10(max(float(x), _LOG_FLOOR))


def census_machine(spec: Any, machine: str = "") -> Tuple[str, MachineSpec]:
    """(label, MachineSpec) a census's predictions are costed against —
    the serving oracle's resolution rule: an explicit registry name wins,
    deterministic backends get the census's own pure-compute synthetic
    machine, wall clock falls back to the pinned host core."""
    name = machine
    if not name:
        if spec.backend in ("cost_model", "simulated"):
            name = f"sweep:{spec.name}"
        else:
            name = "cpu-1core"
    if name in MACHINES:
        return name, get_machine(name)
    return name, synthetic_machine(name, spec.flop_rate)


def kernel_features(
    kernels: Sequence[KernelSpec],
    machine: MachineSpec,
    dispatch_s: float = 0.0,
) -> List[float]:
    """The feature vector for ONE algorithm's kernel sequence on ONE
    machine, slots named by :data:`FEATURE_NAMES`. Values are exactly the
    decompose/roofline quantities (tests hold this to equality): FLOPs
    and bytes are sums of :attr:`KernelSpec.flops` / :attr:`KernelSpec.bytes`,
    times come from :meth:`MachineSpec.t_compute` / :meth:`t_memory`, and
    the dispatch term charges ``machine.dispatch_overhead_s + dispatch_s``
    once per kernel (the census's own dispatch model)."""
    flops = sum(k.flops for k in kernels)
    nbytes = sum(k.bytes for k in kernels)
    t_compute = machine.t_compute(flops)
    t_memory = machine.t_memory(nbytes)
    dispatch = (machine.dispatch_overhead_s + float(dispatch_s)) * len(kernels)
    return [
        _log10(flops),
        _log10(nbytes),
        _log10(flops / nbytes if nbytes else 0.0),
        float(len(kernels)),
        _log10(max((k.flops for k in kernels), default=0.0)),
        _log10(t_compute),
        _log10(t_memory),
        _log10(max(t_compute, t_memory) + dispatch),
    ]


def instance_features(
    kernels_by_alg: Mapping[str, Sequence[KernelSpec]],
    machine: MachineSpec,
    dispatch_s: float = 0.0,
) -> Dict[str, List[float]]:
    """Per-algorithm feature vectors for one instance's decomposition."""
    return {
        alg: kernel_features(ks, machine, dispatch_s)
        for alg, ks in sorted(kernels_by_alg.items())
    }


def record_features(
    record: Mapping[str, Any],
    machine: MachineSpec,
    dispatch_s: float = 0.0,
) -> Dict[str, List[float]]:
    """Per-algorithm feature vectors for one census record, resolved
    through the record's rebuild pointers (``kernels`` -> ``params`` ->
    ``dims``/``size`` fallback, exactly the explainer's rule)."""
    return instance_features(kernels_from_record(record), machine, dispatch_s)


def training_rows(
    spec: Any,
    records: Sequence[Mapping[str, Any]],
    machine: str = "",
) -> Tuple[List[List[float]], List[float], List[Tuple[str, str]], int]:
    """``(X, y, keys, n_skipped)`` from a merged census: one row per
    (record, algorithm), target ``y`` = true log10 seconds reconstructed
    from the record's rebuild pointers via the synthetic machine
    (deterministic backends only). ``keys`` is the parallel
    ``(uid, algorithm)`` list — the train-set digest hashes it.
    Wall-clock records (no stored per-algorithm times) are skipped and
    counted in ``n_skipped``; callers must surface the count."""
    from repro.core.sweep import synthetic_instance_model

    _, mspec = census_machine(spec, machine)
    X: List[List[float]] = []
    y: List[float] = []
    keys: List[Tuple[str, str]] = []
    n_skipped = 0
    for rec in records:
        if rec.get("backend", spec.backend) not in ("cost_model", "simulated"):
            n_skipped += 1
            continue
        kernels = kernels_from_record(rec)
        flops = {k: float(v) for k, v in rec["flops"].items()}
        kernel_counts = {alg: len(ks) for alg, ks in kernels.items()}
        model = synthetic_instance_model(
            spec, int(rec["index"]), flops, kernel_counts,
            base_seed=rec.get("base_seed"),
        )
        vecs = instance_features(kernels, mspec, spec.dispatch_s)
        for alg in sorted(model.costs):
            if alg not in vecs:
                continue
            X.append(vecs[alg])
            y.append(_log10(model.costs[alg]))
            keys.append((str(rec["uid"]), alg))
    return X, y, keys, n_skipped
