"""Learned cost model: predict per-algorithm times and ranks from the
census's own analytic metadata, so the census can go *active* — measure
only the instances whose predicted ranking is uncertain.

Every census record carries exact FLOPs, per-kernel shapes/bytes
(:mod:`repro.explain.decompose`), roofline terms
(:mod:`repro.roofline.terms`), and — on the deterministic backends — a
reconstructible measured outcome. That is a complete training set:

* :mod:`repro.predict.features` — jax-free feature vectors per
  (instance, algorithm) and training rows from merged census stores.
* :mod:`repro.predict.model` — ridge regression on log10-time with a
  closed-form numpy solve; JSON serialization carries the feature schema
  and a train-set digest so a drifted load fails loudly.
* :mod:`repro.predict.active` — per-instance rank prediction with a
  flip-probability estimate, the ``predicted``-provenance census records
  for confidently predicted instances, and the campaign gate
  ``census_gate`` that :func:`repro.core.sweep.run_shard` consults when
  ``SweepSpec.predictor_model`` is set.

Everything here is importable (and usable end to end) without jax.
"""

from .active import ActivePredictor, PredictedRanking, census_gate, prediction_errors
from .features import (
    FEATURE_NAMES,
    FEATURE_VERSION,
    census_machine,
    instance_features,
    kernel_features,
    record_features,
    training_rows,
)
from .model import ModelDrift, RidgeModel, fit_ridge, train_model

__all__ = [
    "FEATURE_NAMES", "FEATURE_VERSION", "kernel_features",
    "instance_features", "record_features", "training_rows",
    "census_machine", "ModelDrift", "RidgeModel", "fit_ridge",
    "train_model", "ActivePredictor", "PredictedRanking", "census_gate",
    "prediction_errors",
]
