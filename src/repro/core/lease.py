"""Filesystem leases — the pull-based work queue's mutual-exclusion layer.

A census (or explanation campaign) stored under one shared directory is
drained by any number of *hosts*: each host repeatedly picks an unfinished
shard, takes its **lease**, and drives it with the existing resumable
chunk/save/append machinery (:func:`repro.core.sweep.run_chunked_campaign`).
The lease protocol is deliberately tiny — one JSON file per shard on the
shared filesystem, no server, no sockets — because the hard part
(recovering a half-done shard byte-identically) is already solved by the
kill/resume contract: a lease takeover IS a resume.

Protocol (``shard-NNNN.lease.json`` next to the shard's JSONL):

* **Acquire** — atomic ``O_CREAT | O_EXCL`` create. Exactly one host wins;
  the file body records the owner token, acquisition time, last heartbeat
  and TTL.
* **Heartbeat** — the owner periodically rewrites the file (atomic
  tmp + rename), rate-limited to ``interval`` seconds. A heartbeat first
  re-reads the file and raises :class:`LeaseLost` if another owner took
  over — the losing host must stop writing to the shard immediately.
* **Expiry / takeover** — a lease whose heartbeat is older than ``ttl``
  seconds is *dead* (SIGKILLed host, lost VM, wedged process). A taker
  breaks it by renaming the stale file to a unique name (exactly one
  concurrent taker wins the rename) and then acquiring freshly. The new
  owner resumes the shard from its persisted engine state, so the merged
  result is byte-identical to an uninterrupted run (deterministic
  backends).
* **Release** — the owner removes the file (only if it still owns it).

Failure-model fine print: clocks across hosts must agree to well within
``ttl`` (the default 30 s tolerates ordinary NTP skew); a *live* host that
stalls longer than ``ttl`` (GC pause, NFS hiccup) can lose its lease to a
taker — it finds out at its next heartbeat (``LeaseLost``) and abandons
the shard, and because record appends are guarded by a heartbeat the
stale host never commits records after the takeover window closes.
"""

from __future__ import annotations

import json
import logging
import os
import socket
import time
import uuid
from dataclasses import dataclass
from typing import Any, Dict, Mapping, Optional, Tuple

from .faults import FaultPlan, active_plan
from .retry import LEASE_POLICY, RetryPolicy, with_retries

log = logging.getLogger(__name__)

#: Default seconds without a heartbeat before a lease counts as dead.
DEFAULT_TTL = 30.0
#: Default seconds between heartbeat file rewrites (must be << ttl).
DEFAULT_HEARTBEAT_INTERVAL = 5.0

#: States :func:`read_lease_ex` distinguishes.
LEASE_ABSENT = "absent"      #: no lease file
LEASE_OK = "ok"              #: well-formed lease file
LEASE_CORRUPT = "corrupt"    #: file exists but does not decode to a lease


class LeaseLost(RuntimeError):
    """The shard's lease is no longer ours — stop writing, move on."""


def default_owner() -> str:
    """A token unique per worker process: host, pid, and a random tail
    (two workers on one host — the CI simulation — must not collide)."""
    return f"{socket.gethostname()}:{os.getpid()}:{uuid.uuid4().hex[:8]}"


@dataclass(frozen=True)
class LeaseInfo:
    """A lease file's decoded contents (whoever owns it)."""

    owner: str
    acquired_at: float
    heartbeat_at: float
    ttl: float

    def age(self, now: Optional[float] = None) -> float:
        """Seconds since the last heartbeat."""
        return (time.time() if now is None else now) - self.heartbeat_at

    def expired(self, now: Optional[float] = None) -> bool:
        return self.age(now) > self.ttl

    def to_dict(self) -> Dict[str, Any]:
        return {
            "owner": self.owner,
            "acquired_at": self.acquired_at,
            "heartbeat_at": self.heartbeat_at,
            "ttl": self.ttl,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "LeaseInfo":
        return cls(
            owner=str(d["owner"]),
            acquired_at=float(d["acquired_at"]),
            heartbeat_at=float(d["heartbeat_at"]),
            ttl=float(d["ttl"]),
        )


def read_lease_ex(path: str) -> Tuple[Optional[LeaseInfo], str]:
    """The lease at ``path`` plus what we found: ``(info, "ok")``,
    ``(None, "absent")``, or ``(None, "corrupt")`` for a file that exists
    but does not decode to a lease — a torn/half-written file (possible
    only on filesystems without atomic rename) or bitrot. Corrupt is a
    distinct state because a corrupt lease carries no heartbeat: it can
    never expire on its own, so the steal path must treat it as
    stale-equivalent rather than wait on a TTL that will never tick."""
    try:
        with open(path) as fh:
            raw = fh.read()
    except FileNotFoundError:
        return None, LEASE_ABSENT
    except OSError:
        return None, LEASE_CORRUPT
    try:
        return LeaseInfo.from_dict(json.loads(raw)), LEASE_OK
    except (ValueError, KeyError, TypeError):
        return None, LEASE_CORRUPT


def read_lease(path: str) -> Optional[LeaseInfo]:
    """The lease at ``path``, or None when absent or unreadable (see
    :func:`read_lease_ex` for the three-way classification)."""
    return read_lease_ex(path)[0]


def _write_lease_file(path: str, info: LeaseInfo, *, exclusive: bool) -> None:
    tmp = f"{path}.tmp.{uuid.uuid4().hex[:8]}"
    with open(tmp, "w") as fh:
        json.dump(info.to_dict(), fh)
        fh.flush()
        os.fsync(fh.fileno())
    if exclusive:
        # link(2), not O_EXCL-then-write: the lease must appear with its
        # full contents atomically, or a racing reader sees a created-but-
        # empty file, classifies it corrupt, breaks it, and two acquirers
        # both win. link fails with FileExistsError exactly like O_EXCL.
        try:
            os.link(tmp, path)
        finally:
            os.remove(tmp)
    else:
        os.replace(tmp, path)


def _break_stale(path: str) -> None:
    """Remove a dead lease so the caller may retry an exclusive create.
    Breaking races with other takers: the rename succeeds for exactly one
    of them (the others get ENOENT and simply retry acquisition)."""
    grave = f"{path}.stale.{uuid.uuid4().hex[:8]}"
    try:
        os.rename(path, grave)
    except OSError:
        return  # somebody else broke (or the owner released) it first
    try:
        os.remove(grave)
    except OSError:
        pass


class Lease:
    """A HELD lease: heartbeat it while working, release it when done."""

    def __init__(self, path: str, info: LeaseInfo,
                 interval: float = DEFAULT_HEARTBEAT_INTERVAL,
                 faults: Optional[FaultPlan] = None) -> None:
        self.path = path
        self.owner = info.owner
        self.ttl = info.ttl
        self.interval = interval
        self.faults = faults if faults is not None else active_plan()
        self._last_beat = info.heartbeat_at

    def heartbeat(self, force: bool = False) -> None:
        """Refresh the lease file (rate-limited to ``interval`` seconds;
        ``force=True`` beats immediately — used right before record
        appends so a takeover can never interleave with a commit).

        Raises :class:`LeaseLost` when the file is gone or another owner
        holds it — the caller must abandon the shard without writing.
        """
        now = time.time()
        if not force and now - self._last_beat < self.interval:
            return
        if self.faults is not None:
            # a 'stall' here sleeps past the TTL *before* the ownership
            # re-check — the duplicate-takeover race, made schedulable
            self.faults.poke("lease.heartbeat")
        current = read_lease(self.path)
        if current is None or current.owner != self.owner:
            raise LeaseLost(
                f"lease {self.path} now belongs to "
                f"{current.owner if current else 'nobody'}"
            )
        _write_lease_file(
            self.path,
            LeaseInfo(self.owner, current.acquired_at, now, self.ttl),
            exclusive=False,
        )
        self._last_beat = now

    def release(self) -> None:
        """Drop the lease (no-op if it was already lost/taken over)."""
        current = read_lease(self.path)
        if current is not None and current.owner == self.owner:
            try:
                os.remove(self.path)
            except OSError:
                pass


def acquire_lease(
    path: str,
    owner: Optional[str] = None,
    *,
    ttl: float = DEFAULT_TTL,
    interval: float = DEFAULT_HEARTBEAT_INTERVAL,
    faults: Optional[FaultPlan] = None,
) -> Optional[Lease]:
    """Try to take the lease at ``path``. Returns a held :class:`Lease`,
    or None when a live owner holds it. A dead lease (heartbeat older than
    its recorded TTL) is broken and re-acquired in the same call, and a
    **corrupt** lease file (half-written JSON — it carries no heartbeat,
    so it would block the shard forever) is treated as stale-equivalent:
    broken immediately, with a warning logged."""
    owner = owner or default_owner()
    if faults is None:
        faults = active_plan()
    for _ in range(2):  # second pass: after breaking a stale lease
        if faults is not None:
            faults.poke("lease.acquire")  # 'io_error' → transient OSError
        now = time.time()
        info = LeaseInfo(owner=owner, acquired_at=now, heartbeat_at=now,
                         ttl=float(ttl))
        try:
            _write_lease_file(path, info, exclusive=True)
            return Lease(path, info, interval=interval, faults=faults)
        except FileExistsError:
            pass
        current, state = read_lease_ex(path)
        if state == LEASE_CORRUPT:
            log.warning(
                "lease %s is corrupt (half-written JSON) — treating as "
                "stale and stealing it", path,
            )
        elif current is not None and not current.expired():
            return None  # a live owner holds it
        # dead, corrupt, or released-under-us: break it, then retry once
        _break_stale(path)
    return None


def acquire_lease_with_backoff(
    path: str,
    owner: Optional[str] = None,
    *,
    ttl: float = DEFAULT_TTL,
    interval: float = DEFAULT_HEARTBEAT_INTERVAL,
    policy: RetryPolicy = LEASE_POLICY,
    faults: Optional[FaultPlan] = None,
) -> Optional[Lease]:
    """:func:`acquire_lease` wrapped in bounded, jitter-seeded retries.

    Retries cover *transient IO errors* (the shared filesystem hiccuped)
    AND contention losses (someone else holds a live lease): under a
    thundering herd every loser backs off on its own owner-seeded jitter
    schedule, so N hosts waking together do not re-collide in lockstep.
    Returns None once attempts are exhausted — the drain loop treats that
    exactly like a held lease and moves to the next shard."""
    owner = owner or default_owner()

    def attempt() -> Lease:
        got = acquire_lease(path, owner, ttl=ttl, interval=interval,
                            faults=faults)
        if got is None:
            raise _LeaseHeld(path)
        return got

    try:
        return with_retries(
            attempt,
            policy=policy,
            retry_on=(OSError, _LeaseHeld),
            seed=f"lease:{owner}:{path}",
            describe=f"acquire {path}",
            on_retry=lambda n, err, delay: log.debug(
                "lease %s attempt %d failed (%s); retrying in %.3fs",
                path, n, err, delay,
            ),
        )
    except _LeaseHeld:
        return None
    except OSError:
        log.warning("lease %s: acquisition kept failing with IO errors; "
                    "leaving the shard for another pass", path)
        return None


class _LeaseHeld(Exception):
    """Internal: someone else holds a live lease (retryable loss)."""
