"""Filesystem leases — the pull-based work queue's mutual-exclusion layer.

A census (or explanation campaign) stored under one shared directory is
drained by any number of *hosts*: each host repeatedly picks an unfinished
shard, takes its **lease**, and drives it with the existing resumable
chunk/save/append machinery (:func:`repro.core.sweep.run_chunked_campaign`).
The lease protocol is deliberately tiny — one JSON file per shard on the
shared filesystem, no server, no sockets — because the hard part
(recovering a half-done shard byte-identically) is already solved by the
kill/resume contract: a lease takeover IS a resume.

Protocol (``shard-NNNN.lease.json`` next to the shard's JSONL):

* **Acquire** — atomic ``O_CREAT | O_EXCL`` create. Exactly one host wins;
  the file body records the owner token, acquisition time, last heartbeat
  and TTL.
* **Heartbeat** — the owner periodically rewrites the file (atomic
  tmp + rename), rate-limited to ``interval`` seconds. A heartbeat first
  re-reads the file and raises :class:`LeaseLost` if another owner took
  over — the losing host must stop writing to the shard immediately.
* **Expiry / takeover** — a lease whose heartbeat is older than ``ttl``
  seconds is *dead* (SIGKILLed host, lost VM, wedged process). A taker
  breaks it by renaming the stale file to a unique name (exactly one
  concurrent taker wins the rename) and then acquiring freshly. The new
  owner resumes the shard from its persisted engine state, so the merged
  result is byte-identical to an uninterrupted run (deterministic
  backends).
* **Release** — the owner removes the file (only if it still owns it).

Failure-model fine print: clocks across hosts must agree to well within
``ttl`` (the default 30 s tolerates ordinary NTP skew); a *live* host that
stalls longer than ``ttl`` (GC pause, NFS hiccup) can lose its lease to a
taker — it finds out at its next heartbeat (``LeaseLost``) and abandons
the shard, and because record appends are guarded by a heartbeat the
stale host never commits records after the takeover window closes.
"""

from __future__ import annotations

import json
import os
import socket
import time
import uuid
from dataclasses import dataclass
from typing import Any, Dict, Mapping, Optional

#: Default seconds without a heartbeat before a lease counts as dead.
DEFAULT_TTL = 30.0
#: Default seconds between heartbeat file rewrites (must be << ttl).
DEFAULT_HEARTBEAT_INTERVAL = 5.0


class LeaseLost(RuntimeError):
    """The shard's lease is no longer ours — stop writing, move on."""


def default_owner() -> str:
    """A token unique per worker process: host, pid, and a random tail
    (two workers on one host — the CI simulation — must not collide)."""
    return f"{socket.gethostname()}:{os.getpid()}:{uuid.uuid4().hex[:8]}"


@dataclass(frozen=True)
class LeaseInfo:
    """A lease file's decoded contents (whoever owns it)."""

    owner: str
    acquired_at: float
    heartbeat_at: float
    ttl: float

    def age(self, now: Optional[float] = None) -> float:
        """Seconds since the last heartbeat."""
        return (time.time() if now is None else now) - self.heartbeat_at

    def expired(self, now: Optional[float] = None) -> bool:
        return self.age(now) > self.ttl

    def to_dict(self) -> Dict[str, Any]:
        return {
            "owner": self.owner,
            "acquired_at": self.acquired_at,
            "heartbeat_at": self.heartbeat_at,
            "ttl": self.ttl,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "LeaseInfo":
        return cls(
            owner=str(d["owner"]),
            acquired_at=float(d["acquired_at"]),
            heartbeat_at=float(d["heartbeat_at"]),
            ttl=float(d["ttl"]),
        )


def read_lease(path: str) -> Optional[LeaseInfo]:
    """The lease at ``path``, or None when absent/unreadable. A torn or
    half-written file (possible only on filesystems without atomic rename)
    reads as None — callers treat that like any other lease they do not
    own, and the TTL path eventually clears it via :func:`_break_stale`."""
    try:
        with open(path) as fh:
            return LeaseInfo.from_dict(json.load(fh))
    except (OSError, ValueError, KeyError, TypeError):
        return None


def _write_lease_file(path: str, info: LeaseInfo, *, exclusive: bool) -> None:
    if exclusive:
        fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        with os.fdopen(fd, "w") as fh:
            json.dump(info.to_dict(), fh)
            fh.flush()
            os.fsync(fh.fileno())
    else:
        tmp = f"{path}.tmp.{uuid.uuid4().hex[:8]}"
        with open(tmp, "w") as fh:
            json.dump(info.to_dict(), fh)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, path)


def _break_stale(path: str) -> None:
    """Remove a dead lease so the caller may retry an exclusive create.
    Breaking races with other takers: the rename succeeds for exactly one
    of them (the others get ENOENT and simply retry acquisition)."""
    grave = f"{path}.stale.{uuid.uuid4().hex[:8]}"
    try:
        os.rename(path, grave)
    except OSError:
        return  # somebody else broke (or the owner released) it first
    try:
        os.remove(grave)
    except OSError:
        pass


class Lease:
    """A HELD lease: heartbeat it while working, release it when done."""

    def __init__(self, path: str, info: LeaseInfo,
                 interval: float = DEFAULT_HEARTBEAT_INTERVAL) -> None:
        self.path = path
        self.owner = info.owner
        self.ttl = info.ttl
        self.interval = interval
        self._last_beat = info.heartbeat_at

    def heartbeat(self, force: bool = False) -> None:
        """Refresh the lease file (rate-limited to ``interval`` seconds;
        ``force=True`` beats immediately — used right before record
        appends so a takeover can never interleave with a commit).

        Raises :class:`LeaseLost` when the file is gone or another owner
        holds it — the caller must abandon the shard without writing.
        """
        now = time.time()
        if not force and now - self._last_beat < self.interval:
            return
        current = read_lease(self.path)
        if current is None or current.owner != self.owner:
            raise LeaseLost(
                f"lease {self.path} now belongs to "
                f"{current.owner if current else 'nobody'}"
            )
        _write_lease_file(
            self.path,
            LeaseInfo(self.owner, current.acquired_at, now, self.ttl),
            exclusive=False,
        )
        self._last_beat = now

    def release(self) -> None:
        """Drop the lease (no-op if it was already lost/taken over)."""
        current = read_lease(self.path)
        if current is not None and current.owner == self.owner:
            try:
                os.remove(self.path)
            except OSError:
                pass


def acquire_lease(
    path: str,
    owner: Optional[str] = None,
    *,
    ttl: float = DEFAULT_TTL,
    interval: float = DEFAULT_HEARTBEAT_INTERVAL,
) -> Optional[Lease]:
    """Try to take the lease at ``path``. Returns a held :class:`Lease`,
    or None when a live owner holds it. A dead lease (heartbeat older than
    its recorded TTL) is broken and re-acquired in the same call."""
    owner = owner or default_owner()
    for _ in range(2):  # second pass: after breaking a stale lease
        now = time.time()
        info = LeaseInfo(owner=owner, acquired_at=now, heartbeat_at=now,
                         ttl=float(ttl))
        try:
            _write_lease_file(path, info, exclusive=True)
            return Lease(path, info, interval=interval)
        except FileExistsError:
            pass
        current = read_lease(path)
        if current is not None and not current.expired():
            return None  # a live owner holds it
        # dead (or unreadable-and-abandoned): break it, then retry once
        _break_stale(path)
    return None
