"""Measurement backends for the ranking methodology.

The paper measures wall-clock execution times of Julia/MKL programs; the
methodology itself is agnostic to *where* the numbers come from. We keep the
measurement layer pluggable:

* :class:`WallClockTimer` — times a callable with ``time.perf_counter``
  (used at CPU/smoke scale; includes a warm-up phase "to exclude library
  overheads", paper Sec. I step 1 — for JAX this absorbs jit compilation).
* :class:`SimulatedTimer` — draws from controlled distributions. Used by the
  benchmarks to reproduce the paper's turbo-boost study: a *bimodal* profile
  models a processor alternating between frequency levels (paper Fig. 6).
* :class:`CostModelTimer` — deterministic time from a roofline/HLO cost model
  plus configurable noise; extends the methodology to compile-time variant
  selection where no hardware exists (dry-run scale).

All timers return seconds.
"""

from __future__ import annotations

import dataclasses
import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

import numpy as np


def rng_state(rng: np.random.Generator) -> Dict[str, Any]:
    """JSON-serializable state of a numpy Generator (exact-resume support)."""
    return rng.bit_generator.state


def rng_from_state(state: Mapping[str, Any]) -> np.random.Generator:
    rng = np.random.default_rng(0)
    rng.bit_generator.state = dict(state)
    return rng


class MeasurementStore:
    """Accumulates measurements per algorithm (the growing ``t_i`` sets).

    Columnar: each algorithm's measurements live in a growing ``float64``
    numpy buffer (amortized-doubling append), so the analysis layer
    (:class:`repro.core.comparison.QuantileTable`) can hand whole rows to one
    batched ``np.percentile`` call instead of re-materialising Python lists
    per pairwise comparison. A monotonically increasing :attr:`version`
    counter bumps on every mutation; quantile caches key on it.

    The public value types are unchanged — :meth:`get` / :meth:`as_mapping` /
    :meth:`to_dict` still speak ``List[float]`` (the same IEEE doubles, so
    serialized campaign state is byte-identical to the pre-columnar store).
    """

    def __init__(self) -> None:
        self._buf: Dict[str, np.ndarray] = {}
        self._len: Dict[str, int] = {}
        self._version = 0

    @property
    def version(self) -> int:
        """Mutation counter — bumps on add/shuffle; cache-invalidation key."""
        return self._version

    def add(self, name: str, values: Sequence[float]) -> None:
        vals = np.asarray([float(v) for v in values], dtype=np.float64)
        if name not in self._buf:
            self._buf[name] = np.empty(max(8, vals.size), dtype=np.float64)
            self._len[name] = 0
        n, buf = self._len[name], self._buf[name]
        if n + vals.size > buf.size:
            grown = np.empty(max(buf.size * 2, n + vals.size), dtype=np.float64)
            grown[:n] = buf[:n]
            self._buf[name] = buf = grown
        buf[n : n + vals.size] = vals
        self._len[name] = n + vals.size
        self._version += 1

    def row(self, name: str) -> np.ndarray:
        """Read-only view of an algorithm's measurements (no copy).

        Read-only is enforced: writes must go through :meth:`add` /
        :meth:`shuffle` so the version counter keeps quantile caches honest.
        """
        view = self._buf[name][: self._len[name]]
        view.setflags(write=False)
        return view

    def count(self, name: str) -> int:
        return self._len.get(name, 0)

    def names(self) -> List[str]:
        return list(self._buf)

    def get(self, name: str) -> List[float]:
        if name not in self._buf:
            return []
        return self.row(name).tolist()

    def counts(self) -> Dict[str, int]:
        return dict(self._len)

    def min_count(self) -> int:
        if not self._len:
            return 0
        return min(self._len.values())

    def shuffle(self, rng: np.random.Generator) -> None:
        """Shuffle each algorithm's measurements in place.

        The paper shuffles measurements before every mean-rank computation so
        that frequency-mode clusters mix fairly across algorithms
        (Sec. IV, "Effect of Turbo boost"). Quantiles are order-independent,
        but downstream consumers that subsample rely on this.

        Vectorized: one ``rng.permutation`` per row applied by fancy
        indexing — the RNG call sequence (and therefore every resumed
        campaign) is identical to the historical per-element reorder.
        """
        for name, buf in self._buf.items():
            row = buf[: self._len[name]]
            perm = rng.permutation(len(row))
            row[:] = row[perm]
        self._version += 1

    def as_mapping(self) -> Mapping[str, List[float]]:
        """Legacy list-of-floats view (built on demand; the fast path reads
        :meth:`rows` / :meth:`row` instead)."""
        return {name: self.row(name).tolist() for name in self._buf}

    def __contains__(self, name: str) -> bool:
        return name in self._buf

    def to_dict(self) -> Dict[str, Any]:
        """JSON-serializable snapshot (engine persistence, reanalysis)."""
        return {"measurements": {k: self.row(k).tolist() for k in self._buf}}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "MeasurementStore":
        store = cls()
        for name, values in d["measurements"].items():
            store.add(name, values)
        return store


class Timer:
    """Protocol: measure(name) -> one execution time in seconds."""

    def measure(self, name: str) -> float:  # pragma: no cover - interface
        raise NotImplementedError

    def measure_many(self, name: str, m: int) -> List[float]:
        return [self.measure(name) for _ in range(m)]

    def warmup(self, name: str, reps: int = 1) -> None:
        for _ in range(reps):
            self.measure(name)

    def snapshot(self) -> Any:
        """Opaque rollback token for transactional measurement batches
        (None for stateless backends). Stateful backends (RNG-driven)
        override so an interrupted batch can be undone, keeping persisted
        campaign state consistent for bit-identical resume."""
        return None

    def restore(self, snap: Any) -> None:
        return None


class WallClockTimer(Timer):
    """Times real callables.

    Parameters
    ----------
    workloads:
        name -> zero-arg callable executing the algorithm once. For JAX
        workloads the callable must block on the result
        (``jax.block_until_ready``) — :mod:`repro.expressions.algorithms`
        builders do this. The first measurement of each workload verifies
        the contract (see below); ``check_blocking=False`` opts out.

    A workload that dispatches asynchronously and returns before the result
    is ready (the classic jit-without-``block_until_ready`` mistake) would
    silently time Python dispatch instead of the algorithm and corrupt the
    whole campaign. The first time each workload is measured, if its return
    value exposes ``block_until_ready`` the timer blocks on it *after*
    stopping the clock: when that post-call block costs as much as the
    timed call itself, the workload is not blocking and the timer refuses
    to measure it (loudly, with the offending name).

    Minimum-measurable-time guard: a workload whose single call completes
    in less than ``min_time_s`` (default :data:`MIN_MEASURABLE_S`) would
    measure mostly clock granularity and Python dispatch, not the
    algorithm — exactly the regime of small-shape kernel segments. Each
    workload is calibrated on its first measurement: if one call is under
    the floor, subsequent samples time an inner loop of ``r`` calls and
    report the mean per-call time, with ``r`` chosen so the timed region
    clears the floor (capped at :data:`MAX_INNER_REPEATS`). The chosen
    counts are surfaced via :attr:`inner_repeats` so records can carry
    them. ``min_time_s=0`` disables the guard (every ``r`` is 1).
    """

    #: Post-call block must exceed BOTH the timed call and this floor
    #: (seconds) before a sample counts as suspicious — a ready result's
    #: ``block_until_ready`` returns in microseconds, so honest workloads
    #: sit orders of magnitude below the floor.
    NONBLOCKING_FLOOR_S = 1e-4
    #: A workload is rejected only after this many *consecutive* suspicious
    #: samples: a single scheduler/GC stall inside an honest workload's
    #: post-call block must not abort a whole campaign, while a genuinely
    #: async workload is suspicious every time.
    NONBLOCKING_ATTEMPTS = 3
    #: Default minimum timed-region length (seconds): ~1000x the perf
    #: counter's resolution and comfortably above a single Python-call
    #: dispatch, so sub-floor workloads get inner-repeated.
    MIN_MEASURABLE_S = 1e-4
    #: Inner-repeat ceiling — bounds the cost of measuring a pathologically
    #: fast (or mis-calibrated) workload.
    MAX_INNER_REPEATS = 1024

    def __init__(
        self,
        workloads: Mapping[str, Callable[[], object]],
        check_blocking: bool = True,
        min_time_s: Optional[float] = None,
    ):
        self._workloads = dict(workloads)
        self._check_blocking = check_blocking
        self._blocking_checked: set = set()
        self._min_time_s = (
            self.MIN_MEASURABLE_S if min_time_s is None else float(min_time_s)
        )
        self._inner_repeats: Dict[str, int] = {}

    @property
    def inner_repeats(self) -> Dict[str, int]:
        """Calibrated inner-repeat count per workload measured so far (1 =
        the workload clears the floor in a single call)."""
        return dict(self._inner_repeats)

    def _checked_first_measure(self, name: str, fn: Callable[[], object]) -> float:
        for attempt in range(self.NONBLOCKING_ATTEMPTS):
            t0 = time.perf_counter()
            out = fn()
            t_call = time.perf_counter() - t0
            block = getattr(out, "block_until_ready", None)
            if not callable(block):
                return t_call
            t1 = time.perf_counter()
            block()
            t_block = time.perf_counter() - t1
            if t_block <= t_call or t_block <= self.NONBLOCKING_FLOOR_S:
                return t_call  # blocked internally; result was already ready
        raise RuntimeError(
            f"workload {name!r} is not blocking: across "
            f"{self.NONBLOCKING_ATTEMPTS} samples the call returned "
            f"(last: {t_call*1e6:.0f}us) before its result was ready "
            f"(post-call block_until_ready took {t_block*1e6:.0f}us) — wrap "
            "the workload so it blocks on the computed value "
            "(jax.block_until_ready) before WallClockTimer measures it"
        )

    def measure(self, name: str) -> float:
        return self.measure_many(name, 1)[0]

    def _calibrate(self, name: str, fn: Callable[[], object]) -> int:
        """First-touch calibration: one timed call (doubling as the
        blocking-contract check) decides the inner-repeat count. The
        calibration sample is discarded — a sub-floor single-call sample
        must not be mixed in with the mean-of-``r`` samples it mandates."""
        if self._check_blocking and name not in self._blocking_checked:
            self._blocking_checked.add(name)
            t = self._checked_first_measure(name, fn)
        else:
            t0 = time.perf_counter()
            fn()
            t = time.perf_counter() - t0
        r = 1
        if self._min_time_s > 0.0 and t < self._min_time_s:
            r = min(self.MAX_INNER_REPEATS,
                    max(1, math.ceil(self._min_time_s / max(t, 1e-9))))
        self._inner_repeats[name] = int(r)
        return int(r)

    def measure_many(self, name: str, m: int) -> List[float]:
        """Batched sampling: one workload lookup (and one calibration /
        blocking-contract check, ever) per workload — the per-sample loop
        is just clock/call/clock, or clock/r-calls/clock divided by ``r``
        for workloads under the minimum-measurable floor."""
        fn = self._workloads[name]
        out: List[float] = []
        if m <= 0:
            return out
        r = self._inner_repeats.get(name)
        if r is None:
            r = self._calibrate(name, fn)
        perf = time.perf_counter
        if r == 1:
            while len(out) < m:
                t0 = perf()
                fn()
                out.append(perf() - t0)
            return out
        while len(out) < m:
            t0 = perf()
            for _ in range(r):
                fn()
            out.append((perf() - t0) / r)
        return out


@dataclass
class NoiseProfile:
    """Distribution spec for :class:`SimulatedTimer`.

    ``base`` is the true cost. ``rel_sigma`` scales lognormal noise.
    ``bimodal_shift``/``bimodal_prob`` model a slow frequency mode: with
    probability ``bimodal_prob`` the sample is multiplied by
    ``1 + bimodal_shift`` (paper Fig. 6: two clusters at the distribution
    ends).
    """

    base: float
    rel_sigma: float = 0.02
    bimodal_shift: float = 0.0
    bimodal_prob: float = 0.0
    outlier_prob: float = 0.0
    outlier_scale: float = 3.0


class SimulatedTimer(Timer):
    """Samples are drawn in vectorized batches: :meth:`measure_many` makes
    one RNG call per distribution component (``m`` lognormal factors, then
    ``m`` bimodal coin flips, then ``m`` outlier coin flips) instead of
    interleaving three scalar draws per sample. For a given RNG state a
    batch of ``m`` is one transaction — ``snapshot()``/``restore()`` around
    it keeps interrupted campaigns bit-identical on resume. A pure-lognormal
    profile consumes exactly the stream the historical scalar loop did;
    bimodal/outlier profiles consume the same *number* of draws in batched
    order."""

    def __init__(
        self,
        profiles: Mapping[str, NoiseProfile],
        seed: int = 0,
    ) -> None:
        self._profiles = dict(profiles)
        self._rng = np.random.default_rng(seed)

    def measure(self, name: str) -> float:
        return self.measure_many(name, 1)[0]

    def measure_many(self, name: str, m: int) -> List[float]:
        p = self._profiles[name]
        t = p.base * np.exp(self._rng.normal(0.0, p.rel_sigma, m))
        if p.bimodal_prob > 0.0:
            mask = self._rng.random(m) < p.bimodal_prob
            t = np.where(mask, t * (1.0 + p.bimodal_shift), t)
        if p.outlier_prob > 0.0:
            mask = self._rng.random(m) < p.outlier_prob
            t = np.where(mask, t * p.outlier_scale, t)
        return t.tolist()

    def snapshot(self) -> Any:
        return rng_state(self._rng)

    def restore(self, snap: Any) -> None:
        self._rng = rng_from_state(snap)


class CostModelTimer(Timer):
    """Deterministic cost-model times with optional measurement noise.

    ``costs`` maps algorithm name -> predicted seconds (e.g. a roofline
    estimate from the compiled dry-run). With ``rel_sigma == 0`` comparisons
    degenerate to exact ordering, which is the correct semantics for a
    deterministic model: the three-way comparison then declares equivalence
    only for exactly equal predictions.
    """

    def __init__(
        self,
        costs: Mapping[str, float],
        rel_sigma: float = 0.0,
        seed: int = 0,
    ) -> None:
        self._costs = dict(costs)
        self._rel_sigma = rel_sigma
        self._rng = np.random.default_rng(seed)

    def measure(self, name: str) -> float:
        return self.measure_many(name, 1)[0]

    def measure_many(self, name: str, m: int) -> List[float]:
        """One batched RNG draw for the whole sample block (the noiseless
        model touches no RNG at all, exactly like the scalar path)."""
        t = float(self._costs[name])
        if self._rel_sigma > 0.0:
            return (t * np.exp(self._rng.normal(0.0, self._rel_sigma, m))).tolist()
        return [t] * m

    def snapshot(self) -> Any:
        return rng_state(self._rng)

    def restore(self, snap: Any) -> None:
        self._rng = rng_from_state(snap)


class DetachedTimer(Timer):
    """Placeholder for sessions restored without a measurement backend
    (e.g. a wall-clock campaign loaded on another host). Ranking existing
    data works; any attempt to *measure* fails loudly."""

    def __init__(self, names: Sequence[str] = ()) -> None:
        self.names = tuple(names)

    def measure(self, name: str) -> float:
        raise RuntimeError(
            "session has no measurement backend attached; rebuild the "
            "workloads and pass timers=/workloads= to ExperimentEngine.load "
            "(or call session.attach_timer)"
        )


def timer_to_dict(timer: Timer) -> Dict[str, Any]:
    """Serialize a timer. Simulated and cost-model backends round-trip
    exactly (RNG state included), which is what makes kill/resume campaigns
    bit-identical to uninterrupted runs. Wall-clock backends record their
    workload names only — the callables must be re-attached on load."""
    if isinstance(timer, SimulatedTimer):
        return {
            "kind": "simulated",
            "profiles": {
                name: dataclasses.asdict(p) for name, p in timer._profiles.items()
            },
            "rng_state": rng_state(timer._rng),
        }
    if isinstance(timer, CostModelTimer):
        return {
            "kind": "cost_model",
            "costs": dict(timer._costs),
            "rel_sigma": timer._rel_sigma,
            "rng_state": rng_state(timer._rng),
        }
    if isinstance(timer, WallClockTimer):
        return {"kind": "wall_clock", "workloads": sorted(timer._workloads)}
    return {"kind": "opaque", "type": type(timer).__name__}


def timer_from_dict(
    d: Mapping[str, Any], workloads: Optional[Mapping[str, Callable[[], object]]] = None
) -> Timer:
    """Inverse of :func:`timer_to_dict`. ``workloads`` re-attaches callables
    for wall-clock backends; without it a :class:`DetachedTimer` is returned
    so ranking-as-is still works."""
    kind = d.get("kind", "opaque")
    if kind == "simulated":
        timer = SimulatedTimer(
            {name: NoiseProfile(**p) for name, p in d["profiles"].items()}
        )
        timer._rng = rng_from_state(d["rng_state"])
        return timer
    if kind == "cost_model":
        timer = CostModelTimer(d["costs"], rel_sigma=float(d["rel_sigma"]))
        timer._rng = rng_from_state(d["rng_state"])
        return timer
    if kind == "wall_clock":
        names = d.get("workloads", ())
        if workloads is not None:
            missing = [n for n in names if n not in workloads]
            if missing:
                raise ValueError(f"workloads missing for {missing}")
            return WallClockTimer(workloads)
        return DetachedTimer(names)
    if workloads is not None:
        return WallClockTimer(workloads)
    return DetachedTimer()
