"""The FLOPs-as-discriminant test (the paper's titular contribution).

Let ``S_F`` be the set of algorithms with the least FLOP count and let the
ranking methodology (Procedure 4) assign every algorithm a performance class.
FLOPs are a **valid discriminant** for the instance iff all members of
``S_F`` obtain the best rank *and* no non-member strictly beats them;
otherwise the instance is an **anomaly** (paper Sec. I):

1. anomaly if some algorithm outside ``S_F`` exhibits noticeably better
   performance than those in ``S_F`` — i.e. ``S_F`` is not a valid
   representative of the fastest algorithms;
2. otherwise anomaly if members of ``S_F`` land in different performance
   classes — one cannot randomly pick from ``S_F``.

Anomalies are the instances worth investigating for root causes (and the
instances where a performance model can beat FLOP-count selection).
"""

from __future__ import annotations

from typing import Mapping, Optional, Sequence

from .scores import min_flops_set, relative_flops
from .types import DiscriminantReport, RankingResult


def flops_discriminant_test(
    ranking: RankingResult,
    flops: Mapping[str, float],
    flops_rel_tol: float = 0.0,
) -> DiscriminantReport:
    """Classify an instance as FLOPs-discriminable or anomalous.

    Parameters
    ----------
    ranking:
        Output of Procedure 4 over the candidate set. Every algorithm in
        ``flops`` need not appear (candidate filtering may have dropped slow
        high-FLOPs variants — dropped algorithms cannot beat ``S_F`` by
        construction, their single-run RT exceeded the threshold).
    flops:
        Analytic FLOP count per algorithm (full set).
    """
    ranks = ranking.ranks
    sf_all = min_flops_set(flops, rel_tol=flops_rel_tol)
    sf = tuple(n for n in sf_all if n in ranks)
    if not sf:
        raise ValueError(
            "no minimum-FLOPs algorithm present in the ranking; the candidate "
            "set must always include S_F"
        )

    best_rank_overall = min(ranks.values())
    best_rank_in_sf = min(ranks[n] for n in sf)
    sf_ranks = {ranks[n] for n in sf}

    if best_rank_in_sf > best_rank_overall:
        # Condition 1: someone outside S_F is in a strictly better class.
        reason = "faster_outside_min_flops"
        is_anomaly = True
    elif len(sf_ranks) > 1:
        # Condition 2: S_F itself splits across performance classes.
        reason = "min_flops_split"
        is_anomaly = True
    else:
        reason = "none"
        is_anomaly = False

    return DiscriminantReport(
        is_anomaly=is_anomaly,
        reason=reason,
        min_flops_algs=sf,
        best_rank_in_sf=best_rank_in_sf,
        best_rank_overall=best_rank_overall,
        ranks=dict(ranks),
        relative_flops=relative_flops(flops),
    )
