"""Relative-FLOPs / Relative-Time scores and candidate filtering.

Paper Eq. (2):  RF_i = (F_i - F_min) / F_min
Paper Eq. (3):  RT_i = (T_i - T_min) / T_min

and the candidate-set construction of Sec. I (steps 1-3) / Sec. IV (last
paragraph): with hundreds of generated variants it is too expensive to
measure everything repeatedly, so the set ``S`` to be ranked is

    S = { algs with minimal FLOPs }  ∪  { algs with RT_i < threshold }

where RT is computed from a *single* warm run of each algorithm.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Sequence, Tuple


def relative_flops(flops: Mapping[str, float]) -> Dict[str, float]:
    """RF_i for every algorithm (Eq. 2)."""
    if not flops:
        return {}
    f_min = min(flops.values())
    if f_min <= 0:
        raise ValueError("FLOP counts must be positive")
    return {k: (v - f_min) / f_min for k, v in flops.items()}


def relative_times(times: Mapping[str, float]) -> Dict[str, float]:
    """RT_i for every algorithm (Eq. 3)."""
    if not times:
        return {}
    t_min = min(times.values())
    if t_min <= 0:
        raise ValueError("execution times must be positive")
    return {k: (v - t_min) / t_min for k, v in times.items()}


def min_flops_set(flops: Mapping[str, float], rel_tol: float = 0.0) -> Tuple[str, ...]:
    """``S_F``: all algorithms whose FLOP count is minimal.

    ``rel_tol`` admits algorithms within a relative tolerance of the minimum
    (the paper speaks of "nearly identical" FLOP counts; exact ties are the
    default).
    """
    f_min = min(flops.values())
    return tuple(
        sorted(k for k, v in flops.items() if v <= f_min * (1.0 + rel_tol))
    )


@dataclass(frozen=True)
class CandidateSet:
    names: Tuple[str, ...]          # the reduced set S, deduplicated, stable order
    min_flops: Tuple[str, ...]      # S_F ⊆ S
    relative_flops: Dict[str, float]
    relative_times: Dict[str, float]
    dropped: Tuple[str, ...]        # algorithms filtered out


def filter_candidates(
    flops: Mapping[str, float],
    single_run_times: Mapping[str, float],
    rt_threshold: float = 1.5,
    flops_rel_tol: float = 0.0,
) -> CandidateSet:
    """Construct the candidate set S (paper Sec. I steps 1-3).

    All min-FLOPs algorithms are always kept; additionally any algorithm with
    single-run ``RT_i < rt_threshold`` is kept (default threshold 1.5, the
    value suggested in Sec. IV).
    """
    if set(flops) != set(single_run_times):
        raise ValueError("flops and single_run_times must cover the same algorithms")
    rf = relative_flops(flops)
    rt = relative_times(single_run_times)
    sf = min_flops_set(flops, rel_tol=flops_rel_tol)

    keep: List[str] = []
    for name in flops:
        if name in sf or rt[name] < rt_threshold:
            keep.append(name)
    keep_sorted = tuple(sorted(keep, key=lambda n: single_run_times[n]))
    dropped = tuple(sorted(set(flops) - set(keep)))
    return CandidateSet(
        names=keep_sorted,
        min_flops=sf,
        relative_flops=rf,
        relative_times=rt,
        dropped=dropped,
    )


def initial_hypothesis_by_time(single_run_times: Mapping[str, float]) -> List[str]:
    """``h_0`` ordered by increasing single-run execution time (Sec. I step 4)."""
    return sorted(single_run_times, key=lambda n: single_run_times[n])


def initial_hypothesis_by_flops(flops: Mapping[str, float]) -> List[str]:
    """``h_0`` ordered by increasing FLOP count (alternative mentioned Sec. V)."""
    return sorted(flops, key=lambda n: flops[n])
