"""Rank-merging bubble sort (paper Procedure 2, ``SortAlgs``).

Sorts a sequence of algorithms with the three-way comparator
(:mod:`repro.core.comparison`), assigning *performance classes*: equivalent
algorithms share a rank. Positions hold ranks (the rank array is positional,
non-decreasing left-to-right, starts at 1, adjacent steps <= 1); swaps move
algorithm indices while the update rules repair the positional ranks.

Rank-update rules
-----------------
Let ``r`` be the positional rank array and let the comparison at positions
``(j, j+1)`` return:

* ``alg[j+1]`` faster  ->  swap the algorithm indices. If ``r[j+1] == r[j]``
  the swap *breaks a tie*: a new class boundary appears after position ``j``.
* equivalent           ->  no swap. If ``r[j+1] != r[j]`` the classes merge:
  decrement ``r[j+1:]`` by 1.
* ``alg[j]`` faster    ->  nothing.

Paper discrepancy (documented in DESIGN.md §7 and tested in
``tests/test_ranking.py``): for the tie-break case the paper's *pseudocode*
says "increment ranks r_{j+1}, ..., r_p by 1", but its worked example (Fig. 4)
and twice-stated final answer increment only the *remainder of the broken tie
class* (positions after ``j`` whose rank still equals the old tied value).
Running the literal rule on Fig. 4 yields final ranks ``[1, 1, 2, 3]``; the
figure states ``[1, 1, 2, 2]``. We default to the figure-consistent rule
(``tie_break="class"``) and keep the literal rule available
(``tie_break="literal"``) for comparison studies.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Mapping, Sequence, Tuple

from .comparison import QuantileTable, compare_measurements
from .types import Outcome, QuantileRange

# Comparator signature: (name_i, name_j) -> Outcome
Comparator = Callable[[str, str], Outcome]


def make_measurement_comparator(
    measurements: Mapping[str, Sequence[float]],
    qrange: QuantileRange,
) -> Comparator:
    """Build a Procedure-1 comparator over a measurement table (recomputes
    both quantile windows from raw vectors per call — the legacy path)."""

    def cmp(name_i: str, name_j: str) -> Outcome:
        return compare_measurements(
            measurements[name_i], measurements[name_j], qrange[0], qrange[1]
        )

    return cmp


def make_table_comparator(
    table: QuantileTable,
    qrange: QuantileRange,
) -> Comparator:
    """Build a Procedure-1 comparator over a pre-batched
    :class:`~repro.core.comparison.QuantileTable` — each comparison is two
    float reads instead of four ``np.percentile`` computations."""
    q_lower, q_upper = float(qrange[0]), float(qrange[1])

    def cmp(name_i: str, name_j: str) -> Outcome:
        return table.compare(name_i, name_j, q_lower, q_upper)

    return cmp


def sort_algorithms(
    order: Sequence[str],
    comparator: Comparator,
    tie_break: str = "class",
    memoize: bool = True,
) -> Tuple[List[str], List[int]]:
    """Procedure 2: bubble sort with the three-way comparison.

    Parameters
    ----------
    order:
        Initial hypothesis ``h_0`` (best-first guess).
    comparator:
        Three-way comparison; called as ``comparator(a, b)`` and interpreted
        from ``a``'s perspective (``BETTER`` means ``a`` is faster).
    tie_break:
        ``"class"`` (default, figure-consistent) or ``"literal"`` (pseudocode
        rule) — see module docstring.
    memoize:
        Cache comparison outcomes per (a, b) pair for the duration of this
        sort. Bubble-sort passes re-compare identical pairs whose underlying
        data cannot have changed mid-sort, so for a deterministic comparator
        (any measurement- or table-backed one) memoization changes nothing
        but the cost. Disable only for stateful comparators.

    Returns
    -------
    (names, ranks):
        ``names`` sorted best-first; ``ranks[k]`` is the performance class of
        ``names[k]`` (1-based, shared ranks allowed).
    """
    if tie_break not in ("class", "literal"):
        raise ValueError(f"unknown tie_break rule: {tie_break!r}")
    names: List[str] = list(order)
    p = len(names)
    ranks: List[int] = list(range(1, p + 1))
    if p <= 1:
        return names, ranks[:p]

    if memoize:
        raw = comparator
        seen: Dict[Tuple[str, str], Outcome] = {}

        def comparator(a: str, b: str) -> Outcome:  # noqa: F811
            key = (a, b)
            out = seen.get(key)
            if out is None:
                out = seen[key] = raw(a, b)
            return out

    for k in range(p):
        for j in range(p - k - 1):
            out = comparator(names[j], names[j + 1])
            if out is Outcome.WORSE:
                # alg at j+1 is faster: swap algorithm indices.
                names[j], names[j + 1] = names[j + 1], names[j]
                if ranks[j + 1] == ranks[j]:
                    # Tie broken: the element bubbled out of its class.
                    old = ranks[j + 1]
                    if tie_break == "literal":
                        for m in range(j + 1, p):
                            ranks[m] += 1
                    else:  # "class": only the remainder of the broken class
                        m = j + 1
                        while m < p and ranks[m] == old:
                            ranks[m] += 1
                            m += 1
            elif out is Outcome.EQUIVALENT:
                if ranks[j + 1] != ranks[j]:
                    # Classes merge; shift every later class down.
                    for m in range(j + 1, p):
                        ranks[m] -= 1
            # BETTER: alg at j already faster; leave ranks as they are.
    _check_rank_invariants(ranks)
    return names, ranks


def sort_by_measurements(
    order: Sequence[str],
    measurements: Mapping[str, Sequence[float]],
    qrange: QuantileRange,
    tie_break: str = "class",
    memoize: bool = True,
) -> Tuple[List[str], List[int]]:
    """Procedure 2 specialised to a measurement table + quantile range."""
    return sort_algorithms(
        order, make_measurement_comparator(measurements, qrange), tie_break, memoize
    )


def sort_by_table(
    order: Sequence[str],
    table: QuantileTable,
    qrange: QuantileRange,
    tie_break: str = "class",
) -> Tuple[List[str], List[int]]:
    """Procedure 2 specialised to a batched quantile table (the fast path)."""
    return sort_algorithms(order, make_table_comparator(table, qrange), tie_break)


def ranks_as_dict(names: Sequence[str], ranks: Sequence[int]) -> Dict[str, int]:
    return dict(zip(names, ranks))


def _check_rank_invariants(ranks: Sequence[int]) -> None:
    """Positional ranks: start at 1, non-decreasing, adjacent step <= 1."""
    if not ranks:
        return
    if ranks[0] != 1:
        raise AssertionError(f"rank invariant violated: first rank {ranks[0]} != 1")
    for a, b in zip(ranks, ranks[1:]):
        if b < a or b - a > 1:
            raise AssertionError(f"rank invariant violated: adjacent pair ({a}, {b})")
