"""AlgorithmFamily — the census's one algorithm-source seam.

The paper's methodology ranks *any* set of FLOP-equivalent algorithms; the
census should therefore not hard-code where algorithms come from. This
module is the single registry every layer resolves through:

* :class:`SweepSpec` validation and grid expansion (:mod:`repro.core.sweep`)
* the planner's CLI grid flags (:mod:`repro.launch.sweep`)
* the explainer's kernel decomposition (:mod:`repro.explain.decompose`)
  and whole-algorithm re-measurement (:mod:`repro.explain.runner`)
* the markdown reports' family annotations (:mod:`repro.launch.report_md`)

An :class:`AlgorithmFamily` supplies, for one family name:

``expand_grid``
    deterministic grid expansion into :class:`InstanceSpec` rows (stable
    uids; global indices are assigned by the sweep after concatenation).
``entry``
    the instance's analytic FLOP table, descriptive meta (size, dims, and
    the per-algorithm kernel decomposition — the explainer's rebuild
    pointer), and a lazy workload builder. Everything except the builder
    must be computable WITHOUT importing jax: the deterministic cost-model
    hooks (:func:`repro.core.sweep.synthetic_instance_model`) consume only
    the FLOP table and kernel counts, so cost-model census workers never
    build a single jax array.
``decompose``
    kernels per algorithm purely from the instance's ``params`` row — the
    explainer's offline rebuild path (no jax, no re-measurement).
``explain_workloads``
    jitted+warmed whole-algorithm workloads for only the algorithms an
    explanation involves (families with large enumerations override this
    to build selectively).
``grid_from_args``
    the family's slice of the planner's CLI namespace (None = the family
    is not part of this plan).

Five synthetic families (the paper's chain plus the beyond-chain identity
families) are registered here bit-identically to their pre-registry
implementations, alongside ``kernel_variants`` — the first *measured*
family, whose algorithms are kernel variants (Pallas matmul tile shapes,
fused vs unfused attention, SSD chunk lengths) of the same math, wrapping
the autotuner's :class:`~repro.autotune.variants.VariantSite` objects.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

#: (flops table, descriptive meta, workload-builder thunk) — the shape
#: `instance_entry` has always returned.
Entry = Tuple[Dict[str, float], Dict[str, Any], Callable[[], Dict[str, Callable[[], Any]]]]


@dataclass(frozen=True)
class InstanceSpec:
    """One census row: an expression instance, fully determined by JSON."""

    index: int                #: position in the expanded grid (global order)
    uid: str                  #: stable identifier, unique within the sweep
    family: str               #: a registered family name
    params: Dict[str, Any]    #: family-specific (dims / size / seed)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "index": self.index, "uid": self.uid,
            "family": self.family, "params": dict(self.params),
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "InstanceSpec":
        return cls(
            index=int(d["index"]), uid=str(d["uid"]),
            family=str(d["family"]), params=dict(d["params"]),
        )


class AlgorithmFamily:
    """Base class: one source of FLOP-comparable algorithm sets."""

    #: registry key; also the ``family`` field of every record it produces
    name: str = ""
    #: one-line description (report footnotes, CLI help)
    description: str = ""

    # ------------------------------------------------------------- grid ---

    def expand_grid(self, grid: Mapping[str, Any]) -> List[InstanceSpec]:
        """Deterministic expansion of this family's grid dict into
        InstanceSpec rows with ``index=0`` placeholders (the sweep assigns
        global indices after concatenating all families)."""
        raise NotImplementedError

    def grid_from_args(self, args: Any) -> Optional[Dict[str, Any]]:
        """This family's grid dict from the planner's argparse namespace,
        or None when the arguments exclude the family from the plan."""
        return None

    # --------------------------------------------------------- instances ---

    def entry(self, inst: InstanceSpec) -> Entry:
        """(flops table, meta, workload-builder). ``meta`` must carry
        ``size`` (scalar for the census's size buckets), ``dims`` (or
        None) and ``kernels`` (compact per-algorithm decomposition). Only
        calling the returned builder may import jax."""
        raise NotImplementedError

    def decompose(self, params: Mapping[str, Any]) -> Dict[str, Any]:
        """KernelSpecs per algorithm, purely from the params row."""
        raise NotImplementedError

    def explain_workloads(
        self, inst: InstanceSpec, involved: Sequence[str]
    ) -> Dict[str, Callable[[], Any]]:
        """Jitted+warmed workloads for ONLY the involved algorithms.
        Default: build the full instance and filter — fine for families
        with a handful of variants; families that enumerate dozens of
        algorithms override this to compile selectively."""
        _, _, build_workloads = self.entry(inst)
        whole = build_workloads()
        return {alg: whole[alg] for alg in involved}


# --------------------------------------------------------------- registry ---


_REGISTRY: Dict[str, AlgorithmFamily] = {}


def register_family(family: AlgorithmFamily) -> AlgorithmFamily:
    """Register (or replace) a family under its ``name``."""
    if not family.name:
        raise ValueError("family must define a non-empty name")
    _REGISTRY[family.name] = family
    return family


def get_family(name: str) -> AlgorithmFamily:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown algorithm family {name!r}; one of {family_names()}"
        ) from None


def family_names() -> Tuple[str, ...]:
    """Registered family names, in registration order."""
    return tuple(_REGISTRY)


# ---------------------------------------------------------- chain family ---


class ChainFamily(AlgorithmFamily):
    """The paper's Expression 1: matrix-chain parenthesizations x
    instruction orders (:mod:`repro.expressions.instances`)."""

    name = "chain"
    description = (
        "matrix-chain parenthesizations x instruction orders "
        "(the paper's Expression 1), random dims per instance"
    )

    def expand_grid(self, grid: Mapping[str, Any]) -> List[InstanceSpec]:
        count = int(grid.get("count", 0))
        n_list = [int(n) for n in grid.get("n_matrices", [4])]
        lo, hi = int(grid.get("lo", 32)), int(grid.get("hi", 512))
        out: List[InstanceSpec] = []
        for i in range(count):
            n = n_list[i % len(n_list)]
            out.append(InstanceSpec(
                index=0,
                uid=f"chain-n{n}-i{i:05d}",
                family="chain",
                params={"n_matrices": n, "lo": lo, "hi": hi, "seed": i},
            ))
        return out

    def grid_from_args(self, args: Any) -> Optional[Dict[str, Any]]:
        if int(getattr(args, "chains", 0)) <= 0:
            return None
        return {
            "count": args.chains, "n_matrices": args.chain_sizes,
            "lo": args.lo, "hi": args.hi,
        }

    def entry(self, inst: InstanceSpec) -> Entry:
        """Expression generators are imported lazily so cost-model workers
        never build a single jax array. ``meta["kernels"]`` carries the
        per-algorithm kernel decomposition (computed here, where the
        enumerated algorithms already exist) — the AnomalyExplainer's
        rebuild pointer."""
        from repro.explain.decompose import decompose_chain, kernels_to_compact
        from repro.expressions.chain import flops_table
        from repro.expressions.instances import random_instance

        p = inst.params
        chain = random_instance(
            int(p["n_matrices"]), int(p["lo"]), int(p["hi"]), seed=int(p["seed"])
        )
        algs = chain.algorithms()
        flops = flops_table(algs)
        dims = list(chain.dims)
        size = int(round(float(np.exp(np.mean(np.log(dims))))))  # geometric mean
        kernels = kernels_to_compact(
            {a.name: decompose_chain(dims, a.steps) for a in algs}
        )

        def build_workloads() -> Dict[str, Callable[[], Any]]:
            from repro.expressions.algorithms import build_workloads as bw
            from repro.expressions.algorithms import make_chain_inputs

            mats = make_chain_inputs(chain.dims, seed=int(p["seed"]))
            return bw(algs, mats, warmup=True)

        meta = {"size": size, "dims": dims, "kernels": kernels}
        return flops, meta, build_workloads

    def decompose(self, params: Mapping[str, Any]) -> Dict[str, Any]:
        from repro.explain.decompose import _chain_instance_dims, decompose_chain_dims

        dims = _chain_instance_dims(
            int(params["n_matrices"]), int(params["lo"]), int(params["hi"]),
            int(params["seed"]),
        )
        return decompose_chain_dims(dims)

    def explain_workloads(
        self, inst: InstanceSpec, involved: Sequence[str]
    ) -> Dict[str, Callable[[], Any]]:
        """A chain instance enumerates dozens of algorithms; compiling all
        of them to extract a winner/loser pair would dominate every
        wall-clock explanation, so chains build the involved thunks
        selectively."""
        from repro.expressions.algorithms import build_algorithm_fn, make_chain_inputs
        from repro.expressions.instances import random_instance

        p = inst.params
        chain = random_instance(
            int(p["n_matrices"]), int(p["lo"]), int(p["hi"]), seed=int(p["seed"])
        )
        algs = {a.name: a for a in chain.algorithms()}
        mats = make_chain_inputs(chain.dims, seed=int(p["seed"]))
        out: Dict[str, Callable[[], Any]] = {}
        for alg in involved:
            fn = build_algorithm_fn(algs[alg], mats, jit=True)
            fn()  # warm up: jit compilation must not land in a timed region
            out[alg] = fn
        return out


# ---------------------------------------------------- generalized families ---


class GeneralizedFamily(AlgorithmFamily):
    """A beyond-chain identity family from
    :mod:`repro.expressions.generalized` (gram / distributive / solve /
    bilinear): ``per_size`` seeded instances at each grid size."""

    def __init__(self, name: str, description: str) -> None:
        self.name = name
        self.description = description

    def expand_grid(self, grid: Mapping[str, Any]) -> List[InstanceSpec]:
        sizes = [int(s) for s in grid.get("sizes", ())]
        per_size = int(grid.get("per_size", 1))
        out: List[InstanceSpec] = []
        for size in sizes:
            for s in range(per_size):
                out.append(InstanceSpec(
                    index=0,
                    uid=f"{self.name}-n{size}-s{s:03d}",
                    family=self.name,
                    params={"size": size, "seed": s},
                ))
        return out

    def grid_from_args(self, args: Any) -> Optional[Dict[str, Any]]:
        return {"sizes": args.sizes, "per_size": args.per_size}

    def entry(self, inst: InstanceSpec) -> Entry:
        from repro.explain.decompose import decompose_generalized, kernels_to_compact
        from repro.expressions.generalized import FAMILIES as GEN

        p = inst.params
        size = int(p["size"])
        family = GEN[inst.family](n=size)
        flops = family.flops_table()
        kernels = kernels_to_compact(decompose_generalized(inst.family, size))

        def build_workloads() -> Dict[str, Callable[[], Any]]:
            return family.workloads(size, seed=int(p["seed"]), warmup=True)

        meta = {"size": size, "dims": None, "kernels": kernels}
        return flops, meta, build_workloads

    def decompose(self, params: Mapping[str, Any]) -> Dict[str, Any]:
        from repro.explain.decompose import decompose_generalized

        return decompose_generalized(self.name, int(params["size"]))


# ------------------------------------------------- kernel_variants family ---

#: sites the family can census, in CLI order
KERNEL_SITES = ("matmul", "attention", "ssd")


def _kernel_site_config(site: str, size: int) -> Dict[str, Any]:
    """Pure (no-jax) per-site metadata at one grid size: algorithm names,
    the shared-math kernel decomposition, and the VariantSite constructor
    arguments. The decomposition describes the *shared math* once — every
    variant computes the same function, so every variant carries the same
    kernel list and the same analytic FLOP count (FLOP-identical by
    construction; implementation overhead — masked blocks, chunk-quadratic
    terms, tile padding — is exactly what the census measures).
    """
    from repro.explain.decompose import KernelSpec

    size = int(size)
    if site == "matmul":
        # Pallas GEMM tile shapes (+ the XLA dot baseline): 2mkn exactly,
        # for every tiling
        m = k = n = size
        blocks = [(b, b, b) for b in (16, 32, 64) if b <= size] or [(size,) * 3]
        names = [f"blocks_{bm}x{bn}x{bk}" for bm, bn, bk in blocks] + ["xla_dot"]
        return {
            "names": names,
            "kernels": [KernelSpec("gemm", (m, k, n))],
            "site_kwargs": {"m": m, "k": k, "n": n, "blocks": blocks},
        }
    if site == "attention":
        # fused (chunked flash-style) vs unfused reference blocks: the
        # shared math is the scores GEMM + the output GEMM, batch*heads
        # folded into the row dimension
        b, h, kv, d = 1, 2, 1, 16
        s = size
        names = ["reference_grouped", "reference_broadcast", "chunked_flash"]
        return {
            "names": names,
            "kernels": [
                KernelSpec("gemm", (b * h * s, d, s)),   # scores  Q @ K^T
                KernelSpec("gemm", (b * h * s, s, d)),   # output  P @ V
            ],
            "site_kwargs": {"b": b, "s": s, "h": h, "kv": kv, "d": d},
        }
    if site == "ssd":
        # Mamba-2 SSD chunk lengths: the shared math at the reference
        # chunk q0 — intra-chunk scores (C @ B^T), their application to x,
        # and the two state GEMMs (build B^T x, apply C) — aggregated over
        # batch*heads*tokens
        b, h, p, n = 1, 2, 8, 8
        s = size
        chunks = [c for c in (8, 16, 32, 64) if c <= s and s % c == 0]
        if len(chunks) < 2:
            raise ValueError(
                f"kernel_variants ssd site needs >= 2 chunk lengths dividing "
                f"size {s} (have {chunks}); use a size that is a multiple of 16"
            )
        q0 = chunks[0]
        return {
            "names": [f"chunk_{q}" for q in chunks],
            "kernels": [
                KernelSpec("gemm", (b * h * s, n, q0)),  # scores   C @ B^T
                KernelSpec("gemm", (b * h * s, q0, p)),  # apply    G @ X
                KernelSpec("gemm", (b * h * s, n, p)),   # state    B^T @ X
                KernelSpec("gemm", (b * h * s, p, n)),   # output   S @ C
            ],
            "site_kwargs": {"b": b, "s": s, "h": h, "p": p, "n": n,
                            "chunks": chunks},
        }
    raise ValueError(f"unknown kernel site {site!r}; one of {KERNEL_SITES}")


class KernelVariantsFamily(AlgorithmFamily):
    """The repo's own kernels as a census family: every algorithm is a
    kernel variant of the same math (Pallas matmul tile shapes, fused vs
    unfused attention blocks, SSD chunk lengths), wrapping the autotuner's
    :func:`~repro.autotune.variants` sites. All variants of an instance
    share one analytic FLOP count and one kernel decomposition (the shared
    math), so the whole instance sits in ``S_F`` and **every** rank
    difference is an anomaly the explainer must attribute. Metadata is
    jax-free; only building workloads imports jax — measured through the
    ``wall_clock`` backend (``interpret`` mode on CPU, compiled on
    GPU/TPU), while the deterministic backends exercise the same grid
    through the synthetic cost hooks."""

    name = "kernel_variants"
    description = (
        "the repo's Pallas/JAX kernel variants (matmul tiles, fused vs "
        "unfused attention, SSD chunk lengths) — FLOP-identical by "
        "construction, censused on wall clock"
    )

    def expand_grid(self, grid: Mapping[str, Any]) -> List[InstanceSpec]:
        sites = [str(x) for x in grid.get("sites", KERNEL_SITES)]
        sizes = [int(s) for s in grid.get("sizes", ())]
        per_size = int(grid.get("per_size", 1))
        interpret = bool(grid.get("interpret", True))
        out: List[InstanceSpec] = []
        for site in sites:
            if site not in KERNEL_SITES:
                raise ValueError(
                    f"unknown kernel site {site!r}; one of {KERNEL_SITES}"
                )
            for size in sizes:
                _kernel_site_config(site, size)  # validate shape constraints
                for s in range(per_size):
                    out.append(InstanceSpec(
                        index=0,
                        uid=f"kernel_variants-{site}-n{size}-s{s:03d}",
                        family=self.name,
                        params={"site": site, "size": size, "seed": s,
                                "interpret": interpret},
                    ))
        return out

    def grid_from_args(self, args: Any) -> Optional[Dict[str, Any]]:
        sites = [s for s in getattr(args, "kernel_sites", "").split(",") if s]
        return {
            "sites": sites or list(KERNEL_SITES),
            "sizes": args.sizes,
            "per_size": args.per_size,
            "interpret": not bool(getattr(args, "kernel_native", False)),
        }

    def entry(self, inst: InstanceSpec) -> Entry:
        from repro.explain.decompose import kernels_to_compact

        p = inst.params
        site, size = str(p["site"]), int(p["size"])
        cfg = _kernel_site_config(site, size)
        shared = sum(k.flops for k in cfg["kernels"])
        flops = {name: shared for name in cfg["names"]}
        kernels = kernels_to_compact(
            {name: list(cfg["kernels"]) for name in cfg["names"]}
        )

        def build_workloads() -> Dict[str, Callable[[], Any]]:
            variant_site = self._build_site(site, cfg, bool(p.get("interpret", True)))
            return variant_site.workloads(seed=int(p["seed"]), warmup=True)

        meta = {"size": size, "dims": None, "kernels": kernels}
        return flops, meta, build_workloads

    @staticmethod
    def _build_site(site: str, cfg: Mapping[str, Any], interpret: bool):
        """The wrapped VariantSite (imports jax — workload build time only)."""
        kw = cfg["site_kwargs"]
        if site == "matmul":
            from repro.autotune.variants import matmul_blocks_site

            return matmul_blocks_site(interpret=interpret, **kw)
        if site == "attention":
            from repro.autotune.variants import attention_site

            return attention_site(**kw)
        from repro.autotune.variants import ssd_chunk_site

        return ssd_chunk_site(**kw)

    def decompose(self, params: Mapping[str, Any]) -> Dict[str, Any]:
        cfg = _kernel_site_config(str(params["site"]), int(params["size"]))
        return {name: list(cfg["kernels"]) for name in cfg["names"]}


# ------------------------------------------------------- the default seam ---

register_family(ChainFamily())
register_family(GeneralizedFamily(
    "gram", "A^T A B — gram product, left/right/syrk associations"))
register_family(GeneralizedFamily(
    "distributive", "(A + B) C — factored vs expanded distribution"))
register_family(GeneralizedFamily(
    "solve", "A^-1 b — explicit inverse vs LU vs Cholesky solve"))
register_family(GeneralizedFamily(
    "bilinear", "x^T A y — left-first vs right-first association"))
register_family(KernelVariantsFamily())
