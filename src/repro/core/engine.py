"""ExperimentEngine — schedulable pools of measurement sessions.

ELAPS-style separation of experiment *specification* (a
:class:`~repro.core.session.MeasurementSession` per expression instance)
from *execution* (this scheduler) and *storage* (JSON persistence). The
engine owns many sessions and interleaves single Procedure-4 iterations
across them under a pluggable policy:

* ``round_robin`` — fair cycling; every pending session advances in turn.
* ``least_converged_first`` — always step the session farthest from
  convergence (largest ``||dx - dy||/p``; unstarted sessions first). Spends
  the measurement budget where the rank landscape is still moving.
* ``until_deadline`` — least-converged ordering under a mandatory wall-time
  budget (``deadline_s``): the campaign stops scheduling when the budget is
  spent, whatever each session's state; results report best-so-far ranks.

``save()``/``load()`` persist every session's measurement store, iteration
history, convergence state and (for simulated / cost-model backends) timer
RNG state — a killed campaign resumes bit-identical to an uninterrupted
run. Wall-clock campaigns resume by re-attaching workloads via the
``timers=``/``workloads=`` arguments of :meth:`ExperimentEngine.load`.

Each session carries its own batched quantile table across the campaign
(see :class:`~repro.core.comparison.QuantileTable`): interleaving does not
discard analysis work, because the table keys on the session store's
version counter and only the stepped session's store mutates. Per-iteration
analysis cost is visible on each session's ``analysis_seconds``.
"""

from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, Iterator, List, Mapping, Optional, Tuple

from .measure import Timer
from .session import MeasurementSession
from .types import IterationRecord, RankingResult

#: Scheduling policies understood by :class:`ExperimentEngine`.
POLICIES = ("round_robin", "least_converged_first", "until_deadline")


class ExperimentEngine:
    """A campaign: many sessions, one scheduler, one persistence root."""

    def __init__(
        self,
        policy: str = "round_robin",
        deadline_s: Optional[float] = None,
    ) -> None:
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; expected one of {POLICIES}")
        self.policy = policy
        self.deadline_s = deadline_s
        self.steps_taken = 0
        self._sessions: Dict[str, MeasurementSession] = {}
        self._cursor = 0  # round-robin position
        self._started_at: Optional[float] = None

    # --------------------------------------------------------- sessions ---

    def add_session(self, session: MeasurementSession) -> MeasurementSession:
        if session.name in self._sessions:
            raise ValueError(f"duplicate session name {session.name!r}")
        self._sessions[session.name] = session
        return session

    def session(self, name: str) -> MeasurementSession:
        return self._sessions[name]

    @property
    def sessions(self) -> Tuple[MeasurementSession, ...]:
        return tuple(self._sessions.values())

    @property
    def session_names(self) -> List[str]:
        return list(self._sessions)

    def __len__(self) -> int:
        return len(self._sessions)

    def __contains__(self, name: str) -> bool:
        return name in self._sessions

    def __iter__(self) -> Iterator[MeasurementSession]:
        return iter(self.sessions)

    def pending(self) -> List[MeasurementSession]:
        return [s for s in self._sessions.values() if not s.done]

    @property
    def done(self) -> bool:
        return not self.pending()

    # -------------------------------------------------------- scheduling ---

    def _budget_exhausted(self) -> bool:
        if self.deadline_s is None or self._started_at is None:
            return False
        return (time.monotonic() - self._started_at) >= self.deadline_s

    def _select(self) -> Optional[MeasurementSession]:
        names = list(self._sessions)
        if not names:
            return None
        if self.policy == "round_robin":
            k = len(names)
            for i in range(k):
                idx = (self._cursor + i) % k
                s = self._sessions[names[idx]]
                if not s.done:
                    self._cursor = (idx + 1) % k
                    return s
            return None
        # least_converged_first / until_deadline: farthest from convergence
        # (norm is inf before a session's first iteration, so fresh sessions
        # are scheduled before any refinement happens).
        pend = self.pending()
        if not pend:
            return None
        return max(pend, key=lambda s: s.norm)

    def step(self) -> Optional[Tuple[str, IterationRecord]]:
        """One scheduling decision: pick a session, run one iteration.
        Returns ``(session_name, iteration_record)`` or ``None`` when the
        campaign is finished (or its time budget is spent)."""
        if self._started_at is None:
            self._started_at = time.monotonic()
        if self._budget_exhausted():
            return None
        session = self._select()
        if session is None:
            return None
        rec = session.step()
        if rec is None:  # defensive: session raced to done
            return None
        self.steps_taken += 1
        return session.name, rec

    def run(
        self,
        max_steps: Optional[int] = None,
        deadline_s: Optional[float] = None,
    ) -> Dict[str, RankingResult]:
        """Drive the campaign until done / ``max_steps`` / the deadline."""
        if deadline_s is not None:
            self.deadline_s = deadline_s
        if self.policy == "until_deadline" and self.deadline_s is None:
            raise ValueError("until_deadline policy requires deadline_s")
        self._started_at = time.monotonic()
        steps = 0
        while max_steps is None or steps < max_steps:
            if self.step() is None:
                break
            steps += 1
        return self.results()

    def results(self) -> Dict[str, RankingResult]:
        """Best-so-far rankings, strictly side-effect free: sessions that
        were never scheduled (no measurements yet) are omitted rather than
        measured, so reading results never perturbs a resumable campaign."""
        return {
            name: s.result(measure_if_needed=False)
            for name, s in self._sessions.items()
            if s.can_rank()
        }

    # ------------------------------------------------------- persistence ---

    def to_dict(self, include_timers: bool = True) -> Dict[str, Any]:
        return {
            "version": 1,
            "policy": self.policy,
            "deadline_s": self.deadline_s,
            "steps_taken": self.steps_taken,
            "cursor": self._cursor,
            "sessions": [
                s.to_dict(include_timer=include_timers)
                for s in self._sessions.values()
            ],
        }

    def save(self, path: str, include_timers: bool = True) -> str:
        """Atomically persist the whole campaign to JSON."""
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(self.to_dict(include_timers=include_timers), fh, indent=1)
        os.replace(tmp, path)
        return path

    @classmethod
    def from_dict(
        cls,
        d: Mapping[str, Any],
        timers: Optional[Mapping[str, Timer]] = None,
        workloads: Optional[Mapping[str, Mapping[str, Callable[[], object]]]] = None,
        vectorized: bool = True,
    ) -> "ExperimentEngine":
        engine = cls(policy=d["policy"], deadline_s=d.get("deadline_s"))
        engine.steps_taken = int(d.get("steps_taken", 0))
        engine._cursor = int(d.get("cursor", 0))
        timers = timers or {}
        workloads = workloads or {}
        for sd in d["sessions"]:
            name = sd["name"]
            engine.add_session(
                MeasurementSession.from_dict(
                    sd,
                    timer=timers.get(name),
                    workloads=workloads.get(name),
                    vectorized=vectorized,
                )
            )
        return engine

    @classmethod
    def load(
        cls,
        path: str,
        timers: Optional[Mapping[str, Timer]] = None,
        workloads: Optional[Mapping[str, Mapping[str, Callable[[], object]]]] = None,
        vectorized: bool = True,
    ) -> "ExperimentEngine":
        """Resume a campaign. ``timers`` maps session name -> Timer for
        backends that do not serialize (wall-clock); ``workloads`` maps
        session name -> {algorithm: thunk} as a convenience for the same.
        ``vectorized`` picks the analysis path for the resumed sessions —
        a process choice, not campaign state; both settings resume any
        saved campaign bit-identically."""
        with open(path) as fh:
            d = json.load(fh)
        return cls.from_dict(d, timers=timers, workloads=workloads,
                             vectorized=vectorized)
