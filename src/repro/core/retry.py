"""Bounded exponential backoff with seeded jitter — graceful degradation.

The distributed census runs on shared, messy filesystems: an append can
hit a transient ``EIO``/``ESTALE``, a lease create can collide with a
dozen hosts waking at once. The policy here is deliberately boring and
*bounded* — a worker either recovers within ``attempts`` tries or gives
the error back to a layer that can re-enqueue the work; nothing retries
forever, and nothing sleeps unjittered (synchronized retry storms are how
one NFS hiccup becomes a thundering herd).

Jitter is **seeded**: two workers derive different-but-reproducible delay
sequences from their owner tokens, so contention tests (N threads racing
one lease) are deterministic test cases, not timing lotteries.
"""

from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple, Type, TypeVar

T = TypeVar("T")


@dataclass(frozen=True)
class RetryPolicy:
    """``attempts`` total tries; sleep ``min(cap, base * 2**k)`` scaled by
    ``1 + U(0, jitter)`` between them."""

    attempts: int = 5
    base: float = 0.05
    cap: float = 2.0
    jitter: float = 0.5

    def __post_init__(self) -> None:
        if self.attempts < 1:
            raise ValueError("attempts must be >= 1")
        if self.base < 0 or self.cap < 0 or self.jitter < 0:
            raise ValueError("base/cap/jitter must be >= 0")

    def delays(self, seed: Optional[object] = None) -> List[float]:
        """The ``attempts - 1`` sleeps this policy would take, jittered by
        an RNG seeded from ``seed`` (reproducible per worker token)."""
        rng = random.Random(None if seed is None else str(seed))
        return [
            min(self.cap, self.base * (2.0 ** k)) * (1.0 + rng.random() * self.jitter)
            for k in range(self.attempts - 1)
        ]


#: Store IO (appends, manifest rewrites): a few quick tries, fail fast —
#: the work queue re-enqueues the shard if the filesystem stays broken.
STORE_IO_POLICY = RetryPolicy(attempts=3, base=0.02, cap=0.5)
#: Lease acquisition: slightly longer tail, contention is expected.
LEASE_POLICY = RetryPolicy(attempts=5, base=0.02, cap=0.5)


def with_retries(
    fn: Callable[[], T],
    *,
    policy: RetryPolicy = STORE_IO_POLICY,
    retry_on: Tuple[Type[BaseException], ...] = (OSError,),
    seed: Optional[object] = None,
    describe: str = "operation",
    on_retry: Optional[Callable[[int, BaseException, float], None]] = None,
    sleep: Callable[[float], None] = time.sleep,
) -> T:
    """Run ``fn`` with bounded, jittered retries on ``retry_on`` errors.

    The last failure propagates unwrapped once attempts are exhausted —
    callers see the real exception, annotated by ``on_retry`` logs rather
    than a new wrapper type. ``sleep`` is injectable for tests."""
    delays = policy.delays(seed)
    for attempt in range(policy.attempts):
        try:
            return fn()
        except retry_on as err:
            if attempt >= policy.attempts - 1:
                raise
            delay = delays[attempt]
            if on_retry is not None:
                on_retry(attempt + 1, err, delay)
            if delay > 0:
                sleep(delay)
    raise AssertionError(f"unreachable: {describe}")  # pragma: no cover
