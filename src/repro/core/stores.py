"""Store-kind registry — one seam for "what campaign lives in this dir?".

The work queue (:mod:`repro.launch.queue`) and fsck
(:mod:`repro.launch.fsck`) both need to answer the same question about a
store root: which campaign kind planned it, where is its spec, how many
shards does it have, and how is it drained. Both used to hard-code the
two known kinds (``spec.json`` = sweep, ``espec.json`` = explain) in an
if/elif each — which meant a third campaign kind would silently fall into
the wrong drain path (or, worse, a root holding *both* spec files would
silently drain as a sweep). This registry makes the kinds first-class:

* :func:`detect_store_kind` resolves a root to its registered
  :class:`StoreKind` (None when no spec file is present) and refuses —
  :class:`AmbiguousStore` — when more than one kind's spec file exists,
  instead of picking by registration order.
* each kind carries ``load_n_shards`` (fsck's shard-count probe) and
  ``make_queue`` (the queue's drainable adapter factory), so neither
  consumer enumerates kinds itself.

The built-in kinds (sweep, explain, and the serving oracle's ranking
cache — ``ocache.json``) are registered at import time; a future kind
(e.g. a replay campaign) registers itself here and both the queue and
fsck pick it up with zero changes.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple


class AmbiguousStore(ValueError):
    """A store root holds spec files for MORE than one registered kind —
    auto-detection refuses to guess which campaign owns the shards."""


@dataclass(frozen=True)
class StoreKind:
    """One campaign kind a store directory can hold."""

    name: str                                   #: e.g. "sweep" / "explain"
    spec_file: str                              #: detection marker, e.g. "spec.json"
    #: spec-declared shard count for a root (may raise OSError/ValueError/
    #: KeyError/TypeError when the spec itself is damaged — fsck falls back
    #: to scanning shard files)
    load_n_shards: Callable[[str], int] = field(repr=False, compare=False,
                                                default=lambda out: 0)
    #: drainable queue adapter for a root (duck-typed: n_shards/out/
    #: shard_totals/run_shard/merge/progress — see repro.launch.queue)
    make_queue: Callable[[str], Any] = field(repr=False, compare=False,
                                             default=lambda out: None)

    def spec_path(self, out: str) -> str:
        return os.path.join(out, self.spec_file)


_REGISTRY: Dict[str, StoreKind] = {}


def register_store_kind(kind: StoreKind) -> StoreKind:
    """Register (or replace) a kind under its ``name``. Spec filenames
    must be unique across kinds — they are the detection markers."""
    for other in _REGISTRY.values():
        if other.name != kind.name and other.spec_file == kind.spec_file:
            raise ValueError(
                f"store kind {kind.name!r} reuses spec file "
                f"{kind.spec_file!r} already claimed by {other.name!r}"
            )
    _REGISTRY[kind.name] = kind
    return kind


def store_kinds() -> Tuple[StoreKind, ...]:
    """Registered kinds, in registration order."""
    return tuple(_REGISTRY.values())


def detect_store_kind(out: str) -> Optional[StoreKind]:
    """The kind whose spec file the root holds; None when no kind matches.
    A root matching MORE than one kind raises :class:`AmbiguousStore` —
    draining someone else's shards under the wrong spec is unrecoverable,
    so detection never guesses."""
    present = [k for k in _REGISTRY.values()
               if os.path.exists(k.spec_path(out))]
    if len(present) > 1:
        names = ", ".join(f"{k.name} ({k.spec_file})" for k in present)
        raise AmbiguousStore(
            f"{out} holds spec files for multiple campaign kinds: {names} "
            "— remove the stale one before draining"
        )
    return present[0] if present else None


# ---------------------------------------------------------- built-in kinds ---
# Lazy imports inside the callables: stores.py must stay importable from
# both repro.core.sweep consumers and repro.launch without cycles, and a
# shard-count probe must not pay the explain subsystem's import.


def _sweep_n_shards(out: str) -> int:
    from repro.core.sweep import SweepSpec

    return SweepSpec.load(os.path.join(out, "spec.json")).n_shards


def _sweep_queue(out: str) -> Any:
    from repro.launch.queue import SweepQueue

    return SweepQueue(out)


def _explain_n_shards(out: str) -> int:
    from repro.explain.runner import ExplainSpec

    return ExplainSpec.load(os.path.join(out, "espec.json")).n_shards


def _explain_queue(out: str) -> Any:
    from repro.launch.queue import ExplainQueue

    return ExplainQueue(out)


def _oracle_n_shards(out: str) -> int:
    from repro.serve.cache import SPEC_FILE, OracleCacheSpec

    return OracleCacheSpec.load(os.path.join(out, SPEC_FILE)).n_shards


def _oracle_queue(out: str) -> Any:
    from repro.serve.oracle import OracleQueue

    return OracleQueue(out)


register_store_kind(StoreKind(
    name="sweep", spec_file="spec.json",
    load_n_shards=_sweep_n_shards, make_queue=_sweep_queue,
))
register_store_kind(StoreKind(
    name="explain", spec_file="espec.json",
    load_n_shards=_explain_n_shards, make_queue=_explain_queue,
))
register_store_kind(StoreKind(
    name="oracle", spec_file="ocache.json",
    load_n_shards=_oracle_n_shards, make_queue=_oracle_queue,
))
