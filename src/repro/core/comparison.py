"""Three-way algorithm comparison (paper Procedure 1, ``CompareAlgs``).

Two sets of time measurements are compared through a quantile range
``(q_lower, q_upper)``:

* ``alg_i`` is *better* than ``alg_j``   iff  ``Q_hi(t_i) < Q_lo(t_j)``
* ``alg_i`` is *worse*  than ``alg_j``   iff  ``Q_hi(t_j) < Q_lo(t_i)``
* otherwise the two are *equivalent* — their measurement distributions
  overlap inside the chosen quantile window.

The comparison is distribution-free: no normality or unimodality assumption
is made, which is what lets the same machinery handle multi-modal
(turbo-boost) measurement profiles (paper Sec. IV).

Two evaluation paths share these semantics:

* :func:`compare_measurements` — the paper-literal pairwise form; computes
  both quantile windows from raw measurement vectors on every call.
* :class:`QuantileTable` — the vectorized form; computes **all**
  (algorithm × quantile-bound) percentiles of a columnar
  :class:`~repro.core.measure.MeasurementStore` in one batched
  ``np.percentile`` call per row-length group, caches them keyed on the
  store's version counter, and answers each three-way comparison from two
  float reads. ``np.percentile`` applies the identical interpolation
  arithmetic per (row, q) whether called scalar or batched, so the table is
  bit-identical to the pairwise path (enforced by the golden-equality
  tests).
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .types import Outcome, QuantileRange

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .measure import MeasurementStore


def quantile_window(t: Sequence[float], q_lower: float, q_upper: float) -> tuple:
    """Return ``(Q_lo, Q_hi)`` of measurement vector ``t``.

    Uses linear interpolation between order statistics (NumPy default), which
    is well-defined down to N == 1 (both quantiles collapse to the value).
    """
    arr = np.asarray(t, dtype=np.float64)
    if arr.size == 0:
        raise ValueError("cannot compare an algorithm with zero measurements")
    lo = float(np.percentile(arr, q_lower))
    hi = float(np.percentile(arr, q_upper))
    return lo, hi


def compare_measurements(
    t_i: Sequence[float],
    t_j: Sequence[float],
    q_lower: float,
    q_upper: float,
) -> Outcome:
    """Procedure 1: three-way comparison of two measurement sets."""
    _validate_range(q_lower, q_upper)
    i_lo, i_hi = quantile_window(t_i, q_lower, q_upper)
    j_lo, j_hi = quantile_window(t_j, q_lower, q_upper)
    if i_hi < j_lo:
        return Outcome.BETTER
    if j_hi < i_lo:
        return Outcome.WORSE
    return Outcome.EQUIVALENT


def compare_range(
    t_i: Sequence[float],
    t_j: Sequence[float],
    qrange: QuantileRange,
) -> Outcome:
    """Convenience wrapper taking the ``(q_lower, q_upper)`` tuple."""
    return compare_measurements(t_i, t_j, qrange[0], qrange[1])


def _validate_range(q_lower: float, q_upper: float) -> None:
    if not (0.0 < q_lower < q_upper < 100.0):
        raise ValueError(
            f"quantile range must satisfy 0 < q_lower < q_upper < 100, "
            f"got ({q_lower}, {q_upper})"
        )


class QuantileTable:
    """All quantile windows of a measurement store, batched and cached.

    One Procedure-3 pass over ``p`` algorithms and ``R`` quantile ranges asks
    for O(p²·R) windows when evaluated pairwise inside the bubble sort; every
    one of them is a read from this (p × bounds) table, which costs a single
    batched ``np.percentile`` per group of equal-length rows. The table
    refreshes lazily and is invalidated by the store's monotonically
    increasing ``version``, so it can be held across a whole Procedure-4
    step (or an entire engine campaign) and recomputes exactly once per
    store mutation epoch.

    Rows with zero measurements are excluded; asking for their window raises
    ``ValueError`` like :func:`quantile_window` does.
    """

    def __init__(self, store: "MeasurementStore", bounds: Sequence[float]) -> None:
        uniq = sorted({float(b) for b in bounds})
        for b in uniq:
            if not (0.0 < b < 100.0):
                raise ValueError(f"quantile bound must be in (0, 100), got {b}")
        self._store = store
        self._bounds = tuple(uniq)
        self._col = {b: i for i, b in enumerate(self._bounds)}
        self._version: Optional[int] = None
        self._table: Dict[str, np.ndarray] = {}

    @classmethod
    def from_ranges(
        cls, store: "MeasurementStore", ranges: Sequence[QuantileRange]
    ) -> "QuantileTable":
        """Table covering every bound of a quantile ladder (plus, typically,
        the reporting range)."""
        return cls(store, [b for r in ranges for b in r])

    @property
    def bounds(self) -> Tuple[float, ...]:
        return self._bounds

    def refresh(self) -> None:
        """Recompute if (and only if) the store changed since the last read."""
        version = self._store.version
        if version == self._version:
            return
        groups: Dict[int, List[str]] = {}
        for name in self._store.names():
            n = self._store.count(name)
            if n > 0:
                groups.setdefault(n, []).append(name)
        qs = np.asarray(self._bounds, dtype=np.float64)
        table: Dict[str, np.ndarray] = {}
        for names in groups.values():
            mat = np.stack([self._store.row(nm) for nm in names])
            pct = np.percentile(mat, qs, axis=1)  # (n_bounds, n_rows)
            for i, nm in enumerate(names):
                table[nm] = pct[:, i]
        self._table = table
        self._version = version

    def window(self, name: str, q_lower: float, q_upper: float) -> tuple:
        """``(Q_lo, Q_hi)`` — bit-identical to :func:`quantile_window` on the
        same row, but two float reads from the batched table."""
        self.refresh()
        try:
            row = self._table[name]
        except KeyError:
            raise ValueError(
                f"cannot compare algorithm {name!r} with zero measurements"
            ) from None
        try:
            return float(row[self._col[q_lower]]), float(row[self._col[q_upper]])
        except KeyError as e:
            raise KeyError(
                f"quantile bound {e.args[0]} not in table bounds {self._bounds}"
            ) from None

    def compare(
        self, name_i: str, name_j: str, q_lower: float, q_upper: float
    ) -> Outcome:
        """Procedure 1 through the table (same semantics as
        :func:`compare_measurements`)."""
        _validate_range(q_lower, q_upper)
        i_lo, i_hi = self.window(name_i, q_lower, q_upper)
        j_lo, j_hi = self.window(name_j, q_lower, q_upper)
        if i_hi < j_lo:
            return Outcome.BETTER
        if j_hi < i_lo:
            return Outcome.WORSE
        return Outcome.EQUIVALENT

    def compare_range(
        self, name_i: str, name_j: str, qrange: QuantileRange
    ) -> Outcome:
        return self.compare(name_i, name_j, qrange[0], qrange[1])
