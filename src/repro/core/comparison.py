"""Three-way algorithm comparison (paper Procedure 1, ``CompareAlgs``).

Two sets of time measurements are compared through a quantile range
``(q_lower, q_upper)``:

* ``alg_i`` is *better* than ``alg_j``   iff  ``Q_hi(t_i) < Q_lo(t_j)``
* ``alg_i`` is *worse*  than ``alg_j``   iff  ``Q_hi(t_j) < Q_lo(t_i)``
* otherwise the two are *equivalent* — their measurement distributions
  overlap inside the chosen quantile window.

The comparison is distribution-free: no normality or unimodality assumption
is made, which is what lets the same machinery handle multi-modal
(turbo-boost) measurement profiles (paper Sec. IV).
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from .types import Outcome, QuantileRange


def quantile_window(t: Sequence[float], q_lower: float, q_upper: float) -> tuple:
    """Return ``(Q_lo, Q_hi)`` of measurement vector ``t``.

    Uses linear interpolation between order statistics (NumPy default), which
    is well-defined down to N == 1 (both quantiles collapse to the value).
    """
    arr = np.asarray(t, dtype=np.float64)
    if arr.size == 0:
        raise ValueError("cannot compare an algorithm with zero measurements")
    lo = float(np.percentile(arr, q_lower))
    hi = float(np.percentile(arr, q_upper))
    return lo, hi


def compare_measurements(
    t_i: Sequence[float],
    t_j: Sequence[float],
    q_lower: float,
    q_upper: float,
) -> Outcome:
    """Procedure 1: three-way comparison of two measurement sets."""
    if not (0.0 < q_lower < q_upper < 100.0):
        raise ValueError(
            f"quantile range must satisfy 0 < q_lower < q_upper < 100, "
            f"got ({q_lower}, {q_upper})"
        )
    i_lo, i_hi = quantile_window(t_i, q_lower, q_upper)
    j_lo, j_hi = quantile_window(t_j, q_lower, q_upper)
    if i_hi < j_lo:
        return Outcome.BETTER
    if j_hi < i_lo:
        return Outcome.WORSE
    return Outcome.EQUIVALENT


def compare_range(
    t_i: Sequence[float],
    t_j: Sequence[float],
    qrange: QuantileRange,
) -> Outcome:
    """Convenience wrapper taking the ``(q_lower, q_upper)`` tuple."""
    return compare_measurements(t_i, t_j, qrange[0], qrange[1])
