"""Convergence-driven incremental measurement (paper Procedure 4,
``MeasureAndRank``).

Statistically sound comparison needs many repetitions, but measuring every
variant many times is expensive — the paper's loop adds only ``M`` (2–3)
measurements per algorithm per iteration, recomputes the mean ranks over the
quantile ladder, and stops when the *shape* of the rank landscape stabilises:

    x    = mean ranks, sorted ascending
    dx   = convolution(x, [1, -1])          (first differences)
    stop when  ||dx - dy||_2 / p  <  eps    (dy = previous iteration's dx)

or when ``N`` reaches the user budget ``max``.

The loop body lives in :class:`repro.core.session.MeasurementSession`
(one ``step()`` per iteration, fully serializable); this module keeps the
original blocking driver with its exact public signature. Campaigns over
many instances go through :class:`repro.core.engine.ExperimentEngine`
instead of calling this in a loop.
"""

from __future__ import annotations

from typing import Optional, Sequence

from .measure import MeasurementStore, Timer
from .session import (  # re-exported for backwards compatibility
    MeasurementSession,
    convergence_norm,
    first_differences,
)
from .types import (
    DEFAULT_QUANTILE_RANGES,
    REPORT_QUANTILE_RANGE,
    QuantileRange,
    RankingResult,
)

__all__ = [
    "convergence_norm",
    "first_differences",
    "measure_and_rank",
]


def measure_and_rank(
    initial_order: Sequence[str],
    timer: Timer,
    m_per_iteration: int = 3,
    eps: float = 0.03,
    max_measurements: int = 30,
    quantile_ranges: Sequence[QuantileRange] = DEFAULT_QUANTILE_RANGES,
    report_range: QuantileRange = REPORT_QUANTILE_RANGE,
    tie_break: str = "class",
    store: Optional[MeasurementStore] = None,
    shuffle_seed: Optional[int] = 0,
) -> RankingResult:
    """Procedure 4 — blocking drive of a single measurement session.

    Parameters
    ----------
    initial_order:
        ``h_0`` — e.g. algorithms sorted by single-run execution time
        (paper Sec. I step 4) or by FLOP count.
    timer:
        Measurement backend (wall-clock, simulated, or cost model).
    m_per_iteration, eps, max_measurements:
        ``M``, ``eps``, ``max`` of the paper (defaults = paper Sec. IV).
    store:
        Optional pre-populated measurement store (warm-start); new
        measurements are appended to it. A store that already holds >= 1
        measurement per algorithm at (or past) the budget is ranked as-is —
        no measurements are taken beyond ``max_measurements``.
    shuffle_seed:
        Seed for the pre-iteration shuffle (None disables shuffling).

    Returns
    -------
    RankingResult with the final ``s_[25,75]`` sequence, mean ranks,
    convergence flag and full per-iteration history.
    """
    session = MeasurementSession(
        "measure_and_rank",
        initial_order,
        timer,
        m_per_iteration=m_per_iteration,
        eps=eps,
        max_measurements=max_measurements,
        quantile_ranges=quantile_ranges,
        report_range=report_range,
        tie_break=tie_break,
        store=store,
        shuffle_seed=shuffle_seed,
    )
    return session.run_to_convergence()
