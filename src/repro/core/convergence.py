"""Convergence-driven incremental measurement (paper Procedure 4,
``MeasureAndRank``).

Statistically sound comparison needs many repetitions, but measuring every
variant many times is expensive — the paper's loop adds only ``M`` (2–3)
measurements per algorithm per iteration, recomputes the mean ranks over the
quantile ladder, and stops when the *shape* of the rank landscape stabilises:

    x    = mean ranks, sorted ascending
    dx   = convolution(x, [1, -1])          (first differences)
    stop when  ||dx - dy||_2 / p  <  eps    (dy = previous iteration's dx)

or when ``N`` reaches the user budget ``max``.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from .meanrank import mean_ranks
from .measure import MeasurementStore, Timer
from .types import (
    DEFAULT_QUANTILE_RANGES,
    REPORT_QUANTILE_RANGE,
    IterationRecord,
    QuantileRange,
    RankedAlgorithm,
    RankingResult,
)


def first_differences(x: Sequence[float]) -> np.ndarray:
    """``convolution(x, [1, -1], step=1)`` — adjacent mean-rank deltas."""
    arr = np.asarray(x, dtype=np.float64)
    if arr.size < 2:
        return np.zeros(0, dtype=np.float64)
    return arr[1:] - arr[:-1]


def convergence_norm(dx: np.ndarray, dy: np.ndarray, p: int) -> float:
    """``||dx - dy||_2 / p`` (paper's stopping criterion)."""
    if dx.shape != dy.shape:
        raise ValueError(f"dx/dy shape mismatch: {dx.shape} vs {dy.shape}")
    if p <= 0:
        raise ValueError("p must be positive")
    return float(np.linalg.norm(dx - dy) / p)


def measure_and_rank(
    initial_order: Sequence[str],
    timer: Timer,
    m_per_iteration: int = 3,
    eps: float = 0.03,
    max_measurements: int = 30,
    quantile_ranges: Sequence[QuantileRange] = DEFAULT_QUANTILE_RANGES,
    report_range: QuantileRange = REPORT_QUANTILE_RANGE,
    tie_break: str = "class",
    store: Optional[MeasurementStore] = None,
    shuffle_seed: Optional[int] = 0,
) -> RankingResult:
    """Procedure 4.

    Parameters
    ----------
    initial_order:
        ``h_0`` — e.g. algorithms sorted by single-run execution time
        (paper Sec. I step 4) or by FLOP count.
    timer:
        Measurement backend (wall-clock, simulated, or cost model).
    m_per_iteration, eps, max_measurements:
        ``M``, ``eps``, ``max`` of the paper (defaults = paper Sec. IV).
    store:
        Optional pre-populated measurement store (warm-start); new
        measurements are appended to it.
    shuffle_seed:
        Seed for the pre-iteration shuffle (None disables shuffling).

    Returns
    -------
    RankingResult with the final ``s_[25,75]`` sequence, mean ranks,
    convergence flag and full per-iteration history.
    """
    order: List[str] = list(initial_order)
    p = len(order)
    if p == 0:
        raise ValueError("need at least one algorithm")
    store = store if store is not None else MeasurementStore()
    rng = np.random.default_rng(shuffle_seed) if shuffle_seed is not None else None

    history: List[IterationRecord] = []
    dy = np.ones(max(p - 1, 0), dtype=np.float64)  # paper: initialize dy_j <- 1
    norm = float("inf")
    converged = False
    n = store.min_count()

    last_result = None
    while n < max_measurements:
        for name in order:
            store.add(name, timer.measure_many(name, m_per_iteration))
        n = store.min_count()
        if rng is not None:
            store.shuffle(rng)

        mr = mean_ranks(
            order,
            store.as_mapping(),
            quantile_ranges=quantile_ranges,
            report_range=report_range,
            tie_break=tie_break,
        )
        last_result = mr
        x = np.asarray(mr.ordered_mean_ranks(), dtype=np.float64)
        dx = first_differences(x)
        norm = convergence_norm(dx, dy, p)
        dy = dx
        order = list(mr.order)  # h_0 <- ordering from s_[25,75]

        history.append(
            IterationRecord(
                measurements_per_alg=n,
                order=tuple(mr.order),
                ranks=tuple(mr.ranks),
                mean_ranks=tuple(mr.mean_ranks[name] for name in mr.order),
                norm=norm,
            )
        )
        if norm < eps:
            converged = True
            break

    if last_result is None:
        # max_measurements smaller than one iteration's worth: measure once.
        for name in order:
            store.add(name, timer.measure_many(name, max(1, m_per_iteration)))
        last_result = mean_ranks(
            order,
            store.as_mapping(),
            quantile_ranges=quantile_ranges,
            report_range=report_range,
            tie_break=tie_break,
        )
        n = store.min_count()

    sequence = [
        RankedAlgorithm(name=name, rank=rank, mean_rank=last_result.mean_ranks[name])
        for name, rank in zip(last_result.order, last_result.ranks)
    ]
    return RankingResult(
        sequence=sequence,
        mean_ranks=dict(last_result.mean_ranks),
        measurements_per_alg=n,
        converged=converged,
        history=history,
    )
