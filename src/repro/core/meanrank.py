"""Mean rank across a quantile ladder (paper Procedure 3, ``MeanRanks``).

A single quantile range either over-merges (wide ranges such as ``(5, 95)``
cover the distribution tails, so everything overlaps) or over-splits (narrow
ranges such as ``(35, 65)`` curtail the tails and tiny shifts become
"significant"). Procedure 3 therefore re-runs the rank-merging sort
(Procedure 2) on *each* range of a ladder and averages the per-algorithm
ranks; the mean rank quantifies relative shifts that the single
``(q25, q75)`` report cannot resolve (paper Table III).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Sequence, Tuple

from .ranking import sort_by_measurements
from .types import (
    DEFAULT_QUANTILE_RANGES,
    REPORT_QUANTILE_RANGE,
    QuantileRange,
)


@dataclass
class MeanRankResult:
    """Ranks at the reporting range + mean ranks across the ladder."""

    order: List[str]                 # sequence from the reporting range, best-first
    ranks: List[int]                 # performance classes at the reporting range
    mean_ranks: Dict[str, float]     # mr' per algorithm
    per_range: Dict[QuantileRange, Dict[str, int]]  # full Table-III style data

    def ordered_mean_ranks(self) -> List[float]:
        """Mean ranks sorted ascending — the ``x`` vector of Procedure 4."""
        return sorted(self.mean_ranks.values())

    def sequence(self) -> List[Tuple[str, int, float]]:
        return [
            (n, r, self.mean_ranks[n]) for n, r in zip(self.order, self.ranks)
        ]


def mean_ranks(
    order: Sequence[str],
    measurements: Mapping[str, Sequence[float]],
    quantile_ranges: Sequence[QuantileRange] = DEFAULT_QUANTILE_RANGES,
    report_range: QuantileRange = REPORT_QUANTILE_RANGE,
    tie_break: str = "class",
) -> MeanRankResult:
    """Procedure 3.

    Runs Procedure 2 once per quantile range (always from the same initial
    hypothesis ``order``, as in the paper), accumulates per-algorithm ranks,
    and reports the sequence at ``report_range`` together with the mean rank
    of every algorithm.

    If ``report_range`` is not a member of ``quantile_ranges`` it is evaluated
    additionally (but not averaged), so callers may e.g. use the left-tail
    ladder for means while still reporting at the IQR.
    """
    per_range: Dict[QuantileRange, Dict[str, int]] = {}
    totals: Dict[str, float] = {name: 0.0 for name in order}

    for qrange in quantile_ranges:
        names, ranks = sort_by_measurements(order, measurements, qrange, tie_break)
        table = dict(zip(names, ranks))
        per_range[qrange] = table
        for name in order:
            totals[name] += table[name]

    n_ranges = len(quantile_ranges)
    mr = {name: totals[name] / n_ranges for name in order}

    if report_range in per_range:
        # Re-derive the order at the reporting range.
        rep_names, rep_ranks = sort_by_measurements(
            order, measurements, report_range, tie_break
        )
    else:
        rep_names, rep_ranks = sort_by_measurements(
            order, measurements, report_range, tie_break
        )
        per_range = dict(per_range)  # report range shown but not averaged

    return MeanRankResult(
        order=rep_names,
        ranks=rep_ranks,
        mean_ranks=mr,
        per_range=per_range,
    )
