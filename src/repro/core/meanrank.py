"""Mean rank across a quantile ladder (paper Procedure 3, ``MeanRanks``).

A single quantile range either over-merges (wide ranges such as ``(5, 95)``
cover the distribution tails, so everything overlaps) or over-splits (narrow
ranges such as ``(35, 65)`` curtail the tails and tiny shifts become
"significant"). Procedure 3 therefore re-runs the rank-merging sort
(Procedure 2) on *each* range of a ladder and averages the per-algorithm
ranks; the mean rank quantifies relative shifts that the single
``(q25, q75)`` report cannot resolve (paper Table III).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from .comparison import QuantileTable
from .ranking import sort_by_measurements, sort_by_table
from .types import (
    DEFAULT_QUANTILE_RANGES,
    REPORT_QUANTILE_RANGE,
    QuantileRange,
)


@dataclass
class MeanRankResult:
    """Ranks at the reporting range + mean ranks across the ladder."""

    order: List[str]                 # sequence from the reporting range, best-first
    ranks: List[int]                 # performance classes at the reporting range
    mean_ranks: Dict[str, float]     # mr' per algorithm
    # Full Table-III style data; always includes report_range (averaged only
    # when it is a ladder member).
    per_range: Dict[QuantileRange, Dict[str, int]]

    def ordered_mean_ranks(self) -> List[float]:
        """Mean ranks sorted ascending — the ``x`` vector of Procedure 4."""
        return sorted(self.mean_ranks.values())

    def sequence(self) -> List[Tuple[str, int, float]]:
        return [
            (n, r, self.mean_ranks[n]) for n, r in zip(self.order, self.ranks)
        ]


def mean_ranks(
    order: Sequence[str],
    measurements: Optional[Mapping[str, Sequence[float]]],
    quantile_ranges: Sequence[QuantileRange] = DEFAULT_QUANTILE_RANGES,
    report_range: QuantileRange = REPORT_QUANTILE_RANGE,
    tie_break: str = "class",
    *,
    table: Optional[QuantileTable] = None,
    memoize: bool = True,
) -> MeanRankResult:
    """Procedure 3.

    Runs Procedure 2 once per quantile range (always from the same initial
    hypothesis ``order``, as in the paper), accumulates per-algorithm ranks,
    and reports the sequence at ``report_range`` together with the mean rank
    of every algorithm. When ``report_range`` is a member of
    ``quantile_ranges`` its Procedure-2 sort is computed once and reused for
    the report; otherwise the report range is evaluated additionally — shown
    in ``per_range`` but not averaged — so callers may e.g. use the
    left-tail ladder for means while still reporting at the IQR.

    Comparison backends (identical results, different cost):

    * ``table`` — a :class:`~repro.core.comparison.QuantileTable`; every
      window of the whole ladder comes from one batched ``np.percentile``
      pass, and each pairwise comparison is two float reads. ``measurements``
      may then be ``None``; the table must cover every bound of
      ``quantile_ranges`` and ``report_range``.
    * ``measurements`` — the paper-literal pairwise path; quantile windows
      are recomputed from raw vectors per comparison (``memoize=False``
      reproduces the historical O(p²·R) percentile cost exactly).
    """
    if table is not None:
        def sorter(qrange: QuantileRange) -> Tuple[List[str], List[int]]:
            return sort_by_table(order, table, qrange, tie_break)
    elif measurements is not None:
        def sorter(qrange: QuantileRange) -> Tuple[List[str], List[int]]:
            return sort_by_measurements(
                order, measurements, qrange, tie_break, memoize
            )
    else:
        raise ValueError("mean_ranks needs either measurements or table")

    per_range: Dict[QuantileRange, Dict[str, int]] = {}
    totals: Dict[str, float] = {name: 0.0 for name in order}

    for qrange in quantile_ranges:
        names, ranks = sorter(qrange)
        rank_table = dict(zip(names, ranks))
        per_range[qrange] = rank_table
        for name in order:
            totals[name] += rank_table[name]

    n_ranges = len(quantile_ranges)
    mr = {name: totals[name] / n_ranges for name in order}

    if report_range in per_range:
        # Reuse the report range's already-computed sort: dicts preserve the
        # best-first insertion order, so the sequence reconstructs exactly.
        rank_table = per_range[report_range]
        rep_names, rep_ranks = list(rank_table), list(rank_table.values())
    else:
        rep_names, rep_ranks = sorter(report_range)
        per_range[report_range] = dict(zip(rep_names, rep_ranks))

    return MeanRankResult(
        order=rep_names,
        ranks=rep_ranks,
        mean_ranks=mr,
        per_range=per_range,
    )
