"""Deterministic fault injection — every failure mode a reproducible test.

A census that survives SIGKILL in CI only by luck is not fault-tolerant; it
is untested. This module turns the failure modes the distributed census
must survive — torn partial appends, mid-file byte corruption, dropped
fsyncs, lease-heartbeat stalls, worker kills, transient IO errors — into a
**scheduled, seeded plan** that fires at named injection *sites* on exact
hit counts, so a chaos run is a test case you can re-run, not a CI flake
you hope reproduces.

Sites (where the plumbing consults the plan):

``store.append``
    :meth:`repro.core.sweep.ShardStore.append_records`, once per record
    batch. Ops: ``torn_write`` (commit only a prefix of the batch, then
    crash), ``corrupt_byte`` (flip one byte of an *earlier, committed*
    record — bitrot), ``io_error`` (one transient ``OSError`` — exercises
    the retry path).
``store.fsync``
    the fsync call of a record batch. Op: ``drop_fsync`` (skip it — the
    power-loss window).
``campaign.step``
    every engine step of :func:`repro.core.sweep.run_chunked_campaign`.
    Ops: ``sigkill`` (the worker dies mid-campaign, lease left behind),
    ``stall`` (a GC/NFS-style pause).
``lease.heartbeat``
    every :meth:`repro.core.lease.Lease.heartbeat` call. Op: ``stall``
    (sleep past the TTL so another host steals the shard — the
    duplicate-takeover race).
``lease.acquire``
    inside :func:`repro.core.lease.acquire_lease`. Op: ``io_error``.

Scheduling: each process counts its own hits per site; a fault is *due*
once the counter reaches its ``at``. Whether it then *fires* is decided by
a claim — an ``O_EXCL`` file create in the plan's scoreboard directory —
so across any number of worker processes each fault fires **exactly
once**, and a crashed-and-resumed chaos drain does not re-fire faults it
already delivered. Single-process plans (unit tests) use an in-memory
scoreboard and are fully deterministic. Randomness (which byte to
corrupt) comes from a per-fault RNG seeded by ``(plan seed, fault id)``.

Workers pick a plan up from the environment (``REPRO_FAULT_PLAN`` = path
to a plan JSON) via :func:`active_plan`, so the same injection reaches
every subprocess of a multi-host drain without threading a flag through
every CLI. No env var, no plan, zero overhead — the production path never
pays for chaos it did not ask for.
"""

from __future__ import annotations

import json
import os
import random
import signal
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence

#: Environment variable naming a fault-plan JSON file for this process
#: (and, transitively, every worker subprocess it spawns).
PLAN_ENV = "REPRO_FAULT_PLAN"

#: Every site the plumbing consults.
SITES = (
    "store.append", "store.fsync", "campaign.step",
    "lease.heartbeat", "lease.acquire",
)

#: Ops with generic semantics (performed by :meth:`FaultPlan.poke`); the
#: site-specific ops (torn_write / corrupt_byte / drop_fsync) are executed
#: by the site itself, which owns the file handles involved.
GENERIC_OPS = ("sigkill", "stall", "io_error")
OPS = GENERIC_OPS + ("torn_write", "corrupt_byte", "drop_fsync")


class InjectedFault(RuntimeError):
    """An injected crash. Deliberately NOT caught anywhere in the stack —
    it must take the worker down exactly like the real failure would."""


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: fire ``op`` at the ``at``-th hit of ``site``."""

    site: str
    op: str
    at: int            #: 1-based process-local hit count that arms the fault
    arg: float = 0.0   #: op-specific (stall seconds; torn-write keep-fraction)
    id: str = ""       #: unique within the plan (scoreboard key)

    def __post_init__(self) -> None:
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; one of {SITES}")
        if self.op not in OPS:
            raise ValueError(f"unknown fault op {self.op!r}; one of {OPS}")
        if self.at < 1:
            raise ValueError("fault 'at' is a 1-based hit count (>= 1)")


class FaultPlan:
    """A seeded schedule of faults plus the exactly-once claim machinery.

    ``state_dir`` (optional) makes claims durable and cross-process: a
    fault is claimed by atomically creating ``<state_dir>/<fault id>``.
    Without it, claims live in this process only — the unit-test mode.
    """

    def __init__(self, faults: Sequence[FaultSpec], seed: int = 0,
                 state_dir: Optional[str] = None) -> None:
        specs: List[FaultSpec] = []
        seen_ids = set()
        for i, f in enumerate(faults):
            fid = f.id or f"f{i:02d}-{f.site}-{f.op}-at{f.at}"
            if fid in seen_ids:
                raise ValueError(f"duplicate fault id {fid!r}")
            seen_ids.add(fid)
            specs.append(FaultSpec(f.site, f.op, f.at, f.arg, fid))
        self.faults = tuple(specs)
        self.seed = int(seed)
        self.state_dir = state_dir
        self._hits: Dict[str, int] = {}
        self._claimed: set = set()
        if state_dir:
            os.makedirs(state_dir, exist_ok=True)

    # ------------------------------------------------------- scheduling ---

    def due(self, site: str) -> List[FaultSpec]:
        """Count one hit at ``site``; return the faults now armed there
        (hit count reached, not yet claimed). The caller must
        :meth:`claim` each one it actually executes — a fault whose
        precondition is unmet (e.g. nothing committed yet to corrupt)
        stays armed for the next hit."""
        n = self._hits.get(site, 0) + 1
        self._hits[site] = n
        return [
            f for f in self.faults
            if f.site == site and n >= f.at and not self._is_claimed(f)
        ]

    def _is_claimed(self, spec: FaultSpec) -> bool:
        if spec.id in self._claimed:
            return True
        if self.state_dir:
            return os.path.exists(os.path.join(self.state_dir, spec.id))
        return False

    def claim(self, spec: FaultSpec) -> bool:
        """Atomically claim ``spec`` for this process. Exactly one claimer
        across every process sharing ``state_dir`` wins; the fault fires
        only in the winner."""
        if spec.id in self._claimed:
            return False
        if self.state_dir:
            try:
                fd = os.open(os.path.join(self.state_dir, spec.id),
                             os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            except FileExistsError:
                self._claimed.add(spec.id)
                return False
            os.close(fd)
        self._claimed.add(spec.id)
        return True

    def fired(self) -> List[str]:
        """Ids of every fault claimed so far (all processes, when durable)."""
        if self.state_dir:
            try:
                return sorted(os.listdir(self.state_dir))
            except OSError:
                return []
        return sorted(self._claimed)

    def rng(self, spec: FaultSpec) -> random.Random:
        """The fault's private RNG — a pure function of (plan seed, id),
        so a re-run corrupts the same byte."""
        return random.Random(f"{self.seed}:{spec.id}")

    # -------------------------------------------------------- execution ---

    def perform(self, spec: FaultSpec) -> None:
        """Execute a generic op (``sigkill`` / ``stall`` / ``io_error``)."""
        if spec.op == "sigkill":
            os.kill(os.getpid(), signal.SIGKILL)
        elif spec.op == "stall":
            time.sleep(spec.arg or 1.0)
        elif spec.op == "io_error":
            raise OSError(f"injected io_error at {spec.site} ({spec.id})")
        else:
            raise ValueError(f"op {spec.op!r} is site-specific, not generic")

    def poke(self, site: str) -> List[FaultSpec]:
        """Hit ``site``: claim-and-perform every due generic fault, return
        the due *site-specific* ones for the caller to execute (after
        claiming). This is the one-liner the plumbing calls."""
        custom: List[FaultSpec] = []
        for spec in self.due(site):
            if spec.op in GENERIC_OPS:
                if self.claim(spec):
                    self.perform(spec)
            else:
                custom.append(spec)
        return custom

    # ------------------------------------------------------ persistence ---

    def to_dict(self) -> Dict[str, Any]:
        return {
            "seed": self.seed,
            "faults": [
                {"site": f.site, "op": f.op, "at": f.at, "arg": f.arg,
                 "id": f.id}
                for f in self.faults
            ],
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any],
                  state_dir: Optional[str] = None) -> "FaultPlan":
        faults = [
            FaultSpec(
                site=str(f["site"]), op=str(f["op"]), at=int(f["at"]),
                arg=float(f.get("arg", 0.0)), id=str(f.get("id", "")),
            )
            for f in d.get("faults", ())
        ]
        return cls(faults, seed=int(d.get("seed", 0)), state_dir=state_dir)

    def save(self, path: str) -> str:
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(self.to_dict(), fh, indent=1, sort_keys=True)
        os.replace(tmp, path)
        return path

    @classmethod
    def load(cls, path: str, state_dir: Optional[str] = None) -> "FaultPlan":
        """Load a plan file. The default scoreboard lives NEXT TO the plan
        (``<path>.fired/``) so every process naming the same plan file
        shares one exactly-once ledger."""
        with open(path) as fh:
            d = json.load(fh)
        if state_dir is None:
            state_dir = path + ".fired"
        return cls.from_dict(d, state_dir=state_dir)


_active: Optional[FaultPlan] = None
_active_src: Optional[str] = None


def active_plan() -> Optional[FaultPlan]:
    """The process-wide plan named by ``$REPRO_FAULT_PLAN``, or None.
    Loaded once per process (workers are short-lived; the scoreboard, not
    this cache, carries cross-process state)."""
    global _active, _active_src
    src = os.environ.get(PLAN_ENV) or None
    if src != _active_src:
        _active_src = src
        _active = FaultPlan.load(src) if src else None
    return _active
