"""DiscriminantSweep — a sharded, resumable census of the FLOPs test.

The paper's headline experiment is not one ranking but a *census* (Sec.
IV-V, Figs. 5-7): sweep many instances of many expression families, run the
FLOPs-discriminant test on each, and report the anomaly rate by instance
size and family. This module promotes that experiment to a first-class
subsystem on top of the :class:`~repro.core.engine.ExperimentEngine`:

* :class:`SweepSpec` — a JSON-serializable grid over expression families
  (paper chains via :mod:`repro.expressions.instances` *and* the
  beyond-chain families of :mod:`repro.expressions.generalized`), expanded
  deterministically into :class:`InstanceSpec` rows and partitioned
  round-robin into ``n_shards`` independent shards.
* :class:`ShardStore` — one append-only JSONL results file per shard plus a
  manifest. Records are appended in whole fsync'd batches; on open, a torn
  trailing line (SIGKILL mid-append) is truncated away, so the JSONL is the
  authoritative completed-set and a killed sweep resumes exactly where it
  stopped.
* :func:`run_shard` — drives a shard's instances in chunks; each chunk is
  one interleaved engine campaign whose state (measurement stores, timer
  RNG, quantile-ladder history) is persisted every ``save_every`` steps via
  the bit-identical session save/load, so resumed results are *identical*
  to an uninterrupted run for the deterministic backends.
* :func:`merge_shards` / :func:`census_summary` — the merge/triage layer:
  dedup by instance, order by grid index, and aggregate anomaly rates by
  family and instance size.

Measurement backends (``SweepSpec.backend``):

``cost_model``
    Deterministic synthetic machine: each algorithm's predicted time is its
    analytic FLOP count over ``flop_rate``, times a per-algorithm machine
    efficiency factor (lognormal, ``eff_sigma``) drawn from an
    instance-seeded RNG — modelling the cache/instruction-order effects
    that make equal-FLOPs algorithms genuinely differ — measured through a
    :class:`~repro.core.measure.CostModelTimer` with lognormal measurement
    noise (``noise_sigma``). Fully serializable: kill/resume is
    bit-identical.
``simulated``
    Same synthetic costs through a :class:`~repro.core.measure.SimulatedTimer`
    (optionally bimodal, reproducing the paper's turbo-boost regime). Also
    bit-identical on resume.
``wall_clock``
    Real JAX executions of the instance's algorithms. Resumable (no
    completed instance is re-measured) but new measurements are real time,
    so resumed runs are statistically — not bitwise — equivalent.

Everything here is importable without jax; expression generators are
imported lazily inside the builders (workers pay the jax import only when
they build instances, and only the wall-clock backend executes any).
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import time
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .discriminant import flops_discriminant_test
from .engine import ExperimentEngine
from .family import InstanceSpec, family_names, get_family
from .faults import FaultPlan, InjectedFault, active_plan
from .measure import CostModelTimer, NoiseProfile, SimulatedTimer, Timer, WallClockTimer
from .retry import STORE_IO_POLICY, with_retries
from .scores import filter_candidates, initial_hypothesis_by_time
from .session import MeasurementSession

__all__ = [  # InstanceSpec re-exported: it moved to repro.core.family
    "BACKENDS", "InstanceSpec", "SweepSpec", "ShardStore", "StoreDamaged",
    "instance_entry", "build_timer", "build_sweep_session",
    "record_from_session", "run_chunked_campaign", "run_shard",
    "merge_shards", "write_merged", "census_summary", "sweep_progress",
]

#: Backends a sweep can measure with. The first two serialize their RNG
#: state, which is what makes kill/resume bit-identical.
BACKENDS = ("cost_model", "simulated", "wall_clock")


@dataclass
class SweepSpec:
    """The whole census, declaratively: family grids + campaign knobs.

    ``families`` maps a family name to its grid parameters:

    * ``chain``: ``{"count": int, "n_matrices": [int, ...], "lo": int,
      "hi": int}`` — ``count`` random chain instances cycling through the
      ``n_matrices`` list, dims uniform in ``[lo, hi]``.
    * generalized families: ``{"sizes": [int, ...], "per_size": int}`` —
      ``per_size`` seeded instances at each size.

    The expansion (and everything downstream: instance seeds, synthetic
    machine, shard assignment) is a pure function of this spec, so any
    worker anywhere produces the same census rows for the same spec.
    """

    name: str = "census"
    families: Dict[str, Dict[str, Any]] = field(default_factory=dict)
    n_shards: int = 8
    backend: str = "cost_model"
    # synthetic machine (cost_model / simulated backends)
    flop_rate: float = 5e10
    eff_sigma: float = 0.05
    noise_sigma: float = 0.02
    bimodal_shift: float = 0.0
    bimodal_prob: float = 0.0
    #: fraction of instances whose measurement distributions go bimodal
    #: (turbo/frequency regime ground truth for the explainer's
    #: mode-mixture test). 1.0 = every instance (the historical behaviour
    #: when bimodal_prob > 0); the per-instance gate draws from entropy
    #: stream 4, so which instances are bimodal is reconstructible from
    #: (base_seed, index) alone.
    bimodal_frac: float = 1.0
    #: inter-kernel cache-reuse injection: with probability
    #: ``cache_reuse_frac`` (per algorithm, entropy stream 5) an
    #: algorithm's *whole-run* time is cut by ``cache_reuse_saving`` —
    #: adjacent kernels sharing cache — while its isolated kernel segments
    #: keep their full cost, so the explainer sees a negative residual.
    cache_reuse_frac: float = 0.0
    cache_reuse_saving: float = 0.0
    #: fixed per-kernel-launch overhead (seconds) the synthetic machine
    #: charges between kernels of a whole-algorithm run AND once per
    #: isolated segment — at tiny sizes this dominates and algorithms with
    #: more kernels lose (the paper's dispatch-bound regime).
    dispatch_s: float = 0.0
    # campaign (Procedure 4 / engine)
    m_per_iteration: int = 3
    eps: float = 0.03
    max_measurements: int = 24
    rt_threshold: float = 1.5
    flops_rel_tol: float = 0.0
    policy: str = "least_converged_first"
    chunk_size: int = 8
    save_every: int = 25
    base_seed: int = 0
    #: fsync record batches. SIGKILL-survival never needs this (the page
    #: cache outlives the process); enable it when the census must survive
    #: power loss / host crash too. Off by default: fsync serializes all
    #: workers behind the journal on many filesystems.
    fsync: bool = False
    #: active-census gate: path to a trained :mod:`repro.predict` model
    #: (JSON). When set, instances whose predicted ranking confidence
    #: clears ``predict_threshold`` are emitted as
    #: ``provenance="predicted"`` records WITHOUT measurement; the rest
    #: measure normally. Living in the spec (not a CLI flag) means every
    #: worker and queue host applies the same gate, and predicted records
    #: stay a pure function of (spec, model file) — byte-identical across
    #: kills and resumes like everything else in the store.
    predictor_model: str = ""
    #: minimum predicted ranking confidence (1 - worst rank-flip
    #: probability) required to skip an instance's measurement.
    predict_threshold: float = 0.95

    def __post_init__(self) -> None:
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; one of {BACKENDS}")
        if self.n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if not 0.0 <= self.bimodal_frac <= 1.0:
            raise ValueError("bimodal_frac must be in [0, 1]")
        if not 0.0 <= self.cache_reuse_frac <= 1.0:
            raise ValueError("cache_reuse_frac must be in [0, 1]")
        if not 0.0 <= self.cache_reuse_saving < 1.0:
            raise ValueError("cache_reuse_saving must be in [0, 1)")
        if self.dispatch_s < 0.0:
            raise ValueError("dispatch_s must be >= 0")
        if not 0.0 <= self.predict_threshold <= 1.0:
            raise ValueError("predict_threshold must be in [0, 1]")
        unknown = set(self.families) - set(family_names())
        if unknown:
            raise ValueError(
                f"unknown families {sorted(unknown)}; one of {family_names()}"
            )

    # -------------------------------------------------------- expansion ---

    def expand(self) -> List[InstanceSpec]:
        """The full census grid, in deterministic global order: each
        registered family expands its own grid dict; the sweep concatenates
        (sorted by family name), checks uid uniqueness, and assigns global
        indices."""
        out: List[InstanceSpec] = []
        for family in sorted(self.families):
            out.extend(get_family(family).expand_grid(self.families[family]))
        uids = [i.uid for i in out]
        if len(set(uids)) != len(uids):
            dupes = sorted({u for u in uids if uids.count(u) > 1})
            raise ValueError(
                f"grid expands to duplicate instance uids {dupes[:5]} — "
                "deduplicate the family sizes/counts (the shard store keys "
                "records by uid, so duplicates could never all complete)"
            )
        return [
            dataclasses.replace(inst, index=i) for i, inst in enumerate(out)
        ]

    def shard_of(self, inst: InstanceSpec) -> int:
        """Round-robin by grid index: adjacent (similar-cost) instances land
        on different shards, so shards stay balanced."""
        return inst.index % self.n_shards

    def shard_instances(self, shard: int) -> List[InstanceSpec]:
        if not 0 <= shard < self.n_shards:
            raise ValueError(f"shard {shard} out of range [0, {self.n_shards})")
        return [i for i in self.expand() if self.shard_of(i) == shard]

    # ------------------------------------------------------ persistence ---

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["version"] = 1
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "SweepSpec":
        kwargs = {
            f.name: d[f.name] for f in dataclasses.fields(cls) if f.name in d
        }
        return cls(**kwargs)

    def save(self, path: str) -> str:
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(self.to_dict(), fh, indent=1, sort_keys=True)
        os.replace(tmp, path)
        return path

    @classmethod
    def load(cls, path: str) -> "SweepSpec":
        with open(path) as fh:
            return cls.from_dict(json.load(fh))


# ------------------------------------------------------ instance builders ---


def _instance_entropy(spec: SweepSpec, inst: InstanceSpec, stream: int) -> List[int]:
    """Deterministic, collision-free RNG entropy for one instance: distinct
    ``stream`` values give independent streams (machine efficiency vs
    measurement noise vs shuffle)."""
    return [int(spec.base_seed), int(inst.index), int(stream)]


def synthetic_efficiencies(
    names: Iterable[str],
    rng: np.random.Generator,
    eff_sigma: float,
) -> Dict[str, float]:
    """The synthetic machine's frozen per-algorithm lognormal efficiency
    factors, drawn in sorted-name order (the reproducibility contract: any
    consumer that replays the same RNG over the same names recovers the
    same factors — the AnomalyExplainer uses this to reconstruct the
    injected ground truth without touching the census timers)."""
    return {
        name: math.exp(float(rng.normal(0.0, eff_sigma)))
        for name in sorted(names)
    }


def synthetic_costs(
    flops: Mapping[str, float],
    rng: np.random.Generator,
    flop_rate: float,
    eff_sigma: float,
) -> Dict[str, float]:
    """Predicted seconds per algorithm on the synthetic machine: FLOPs over
    peak rate, times a frozen per-algorithm lognormal efficiency factor.
    The factor models what the paper attributes anomalies to — equal-FLOPs
    algorithms differing in cache behaviour and instruction order — and is
    part of the *machine*, not the measurement noise: it is drawn once per
    instance (in sorted algorithm order, so it is reproducible) and held
    fixed across all measurements."""
    eff = synthetic_efficiencies(flops, rng, eff_sigma)
    return {
        name: float(flops[name]) / flop_rate * eff[name]
        for name in sorted(flops)
    }


def synthetic_cache_reuse(
    names: Iterable[str],
    rng: np.random.Generator,
    reuse_frac: float,
    reuse_saving: float,
) -> Dict[str, float]:
    """Per-algorithm whole-run saving fractions from inter-kernel cache
    reuse, drawn in sorted-name order (same reproducibility contract as
    :func:`synthetic_efficiencies`: replaying the RNG over the same names
    recovers the ground truth). An algorithm with a nonzero saving runs its
    *whole* program ``1 - saving`` times the sum of its kernel costs —
    adjacent kernels hand data over in cache — which the explainer observes
    as a negative attribution residual."""
    if reuse_frac <= 0.0 or reuse_saving <= 0.0:
        return {name: 0.0 for name in sorted(names)}
    return {
        name: reuse_saving if float(rng.random()) < reuse_frac else 0.0
        for name in sorted(names)
    }


@dataclass(frozen=True)
class SyntheticInstanceModel:
    """Everything the synthetic machine decided about ONE instance, rebuilt
    purely from ``(spec knobs, base_seed, index)`` — the census measures
    through it, and the explainer reconstructs it as ground truth."""

    costs: Dict[str, float]            #: whole-algorithm predicted seconds
    efficiencies: Dict[str, float]     #: per-algorithm lognormal factors
    cache_saving: Dict[str, float]     #: per-algorithm whole-run saving
    bimodal: bool                      #: does this instance's timer go bimodal?


def synthetic_instance_model(
    spec: SweepSpec,
    index: int,
    flops: Mapping[str, float],
    kernel_counts: Optional[Mapping[str, int]] = None,
    base_seed: Optional[int] = None,
) -> SyntheticInstanceModel:
    """The synthetic machine's frozen per-instance state. Entropy streams:
    1 = efficiency factors, 4 = bimodal gate, 5 = cache-reuse coins (2/3
    belong to the measurement-noise/shuffle seeds; the explainer uses 11+).
    Streams are only consumed when their knob is active, so censuses with
    default knobs stay byte-identical to pre-knob ones.

    Whole-algorithm cost = ``flops/rate * eff * (1 - cache_saving)`` plus
    ``dispatch_s`` per kernel; isolated segments (the explainer's
    re-measurement) cost ``kernel_flops/rate * eff`` plus ONE dispatch each,
    so dispatch cancels in the residual while cache reuse surfaces as a
    negative one."""
    base = spec.base_seed if base_seed is None else int(base_seed)
    eff = synthetic_efficiencies(
        flops, np.random.default_rng([base, int(index), 1]), spec.eff_sigma
    )
    reuse = synthetic_cache_reuse(
        flops,
        np.random.default_rng([base, int(index), 5]),
        spec.cache_reuse_frac,
        spec.cache_reuse_saving,
    )
    bimodal = spec.bimodal_prob > 0.0 and spec.bimodal_shift != 0.0
    if bimodal and spec.bimodal_frac < 1.0:
        gate = np.random.default_rng([base, int(index), 4])
        bimodal = float(gate.random()) < spec.bimodal_frac
    costs: Dict[str, float] = {}
    for name in sorted(flops):
        c = float(flops[name]) / spec.flop_rate * eff[name]
        if reuse[name] > 0.0:
            c *= 1.0 - reuse[name]
        if spec.dispatch_s > 0.0:
            if kernel_counts is None:
                raise ValueError(
                    "dispatch_s > 0 needs per-algorithm kernel counts"
                )
            c += spec.dispatch_s * int(kernel_counts[name])
        costs[name] = c
    return SyntheticInstanceModel(
        costs=costs, efficiencies=eff, cache_saving=reuse, bimodal=bimodal
    )


def instance_entry(inst: InstanceSpec):
    """(flops table, descriptive meta, workload-builder thunk) for one
    instance — resolved through the :mod:`repro.core.family` registry."""
    return get_family(inst.family).entry(inst)


def build_timer(spec: SweepSpec, inst: InstanceSpec, flops: Mapping[str, float],
                build_workloads: Callable[[], Dict[str, Callable[[], Any]]],
                kernel_counts: Optional[Mapping[str, int]] = None) -> Timer:
    """The instance's measurement backend, fully derived from the spec."""
    if spec.backend == "wall_clock":
        return WallClockTimer(build_workloads())
    model = synthetic_instance_model(spec, inst.index, flops, kernel_counts)
    noise_seed = np.random.default_rng(
        _instance_entropy(spec, inst, 2)
    ).integers(0, 2**63 - 1)
    if spec.backend == "cost_model":
        return CostModelTimer(
            model.costs, rel_sigma=spec.noise_sigma, seed=int(noise_seed)
        )
    profiles = {
        name: NoiseProfile(
            base=cost,
            rel_sigma=spec.noise_sigma,
            bimodal_shift=spec.bimodal_shift if model.bimodal else 0.0,
            bimodal_prob=spec.bimodal_prob if model.bimodal else 0.0,
        )
        for name, cost in model.costs.items()
    }
    return SimulatedTimer(profiles, seed=int(noise_seed))


def build_sweep_session(spec: SweepSpec, inst: InstanceSpec) -> MeasurementSession:
    """Paper Sec. I steps 1-4 for one census instance: single warm run per
    algorithm, RT candidate filtering, initial hypothesis by time, then a
    resumable Procedure-4 session. The FLOP table and filter decisions ride
    in ``session.meta`` so the discriminant verdict survives engine
    save/load without re-deriving the instance."""
    flops, desc, build_workloads = instance_entry(inst)
    kernel_counts = {alg: len(ks) for alg, ks in desc["kernels"].items()}
    timer = build_timer(spec, inst, flops, build_workloads, kernel_counts)
    single = {name: timer.measure(name) for name in flops}
    cand = filter_candidates(
        flops, single,
        rt_threshold=spec.rt_threshold, flops_rel_tol=spec.flops_rel_tol,
    )
    h0 = [n for n in initial_hypothesis_by_time(single) if n in cand.names]
    shuffle_seed = int(
        np.random.default_rng(_instance_entropy(spec, inst, 3)).integers(0, 2**31 - 1)
    )
    return MeasurementSession(
        inst.uid,
        h0,
        timer,
        m_per_iteration=spec.m_per_iteration,
        eps=spec.eps,
        max_measurements=spec.max_measurements,
        shuffle_seed=shuffle_seed,
        meta={
            "uid": inst.uid,
            "index": inst.index,
            "family": inst.family,
            "size": desc["size"],
            "dims": desc["dims"],
            "params": dict(inst.params),
            "flops": {k: float(v) for k, v in flops.items()},
            "kernels": desc["kernels"],
            "dropped": list(cand.dropped),
            "backend": spec.backend,
            "base_seed": spec.base_seed,
        },
    )


def record_from_session(session: MeasurementSession, spec: SweepSpec) -> Dict[str, Any]:
    """One census JSONL record (DiscriminantReport + ranking digest).

    Deliberately contains *only* deterministic fields — no wall times, no
    hostnames — so an interrupted-and-resumed sweep merges byte-identical
    to an uninterrupted one (the kill/resume tests diff the files).

    The ``params`` / ``flops`` / ``kernels`` / ``base_seed`` fields are the
    AnomalyExplainer's pointers: together they rebuild the instance — its
    algorithms, kernel segments, and (for the deterministic backends) the
    synthetic machine's injected efficiency factors — without re-expanding
    the grid or re-running any census measurement."""
    meta = session.meta
    ranking = session.result(measure_if_needed=False)
    disc = flops_discriminant_test(
        ranking, {k: float(v) for k, v in meta["flops"].items()},
        flops_rel_tol=spec.flops_rel_tol,
    )
    record = {
        "uid": meta["uid"],
        "index": int(meta["index"]),
        "family": meta["family"],
        "size": meta["size"],
        "dims": meta["dims"],
        "params": dict(meta.get("params", {})),
        "flops": {k: float(v) for k, v in meta["flops"].items()},
        "kernels": meta.get("kernels", {}),
        "base_seed": int(meta.get("base_seed", spec.base_seed)),
        "backend": meta.get("backend", spec.backend),
        "p": len(ranking.sequence),
        "n_dropped": len(meta.get("dropped", ())),
        "measurements_per_alg": ranking.measurements_per_alg,
        "iterations": len(ranking.history),
        "converged": ranking.converged,
        "classes": max(ranking.ranks.values()),
        "is_anomaly": bool(disc.is_anomaly),
        "reason": disc.reason,
        "min_flops_algs": list(disc.min_flops_algs),
        "best_rank_in_sf": disc.best_rank_in_sf,
        "best_rank_overall": disc.best_rank_overall,
        "ranks": disc.ranks,
        "mean_ranks": {k: float(v) for k, v in ranking.mean_ranks.items()},
        "relative_flops": {k: float(v) for k, v in disc.relative_flops.items()},
    }
    if spec.backend == "wall_clock":
        # the WallClockTimer's chosen inner-repeat counts (the
        # minimum-measurable-time guard) — real-time metadata, so only on
        # the backend whose records are never byte-compared across resumes
        repeats = getattr(session.timer, "inner_repeats", None)
        if repeats:
            record["inner_repeats"] = {
                name: int(r) for name, r in sorted(repeats.items())
                if name in meta["flops"]
            }
    return record


# -------------------------------------------------------------- the store ---


class StoreDamaged(RuntimeError):
    """A shard store holds committed-but-unreadable data (mid-file
    corruption, checksum mismatch). Raised instead of silently skipping
    records: a census missing rows it *thinks* it has is worse than a
    failed merge. Run ``fsck`` (``python -m repro.launch.fsck --out DIR``)
    to classify, repair, and quarantine the damage, then re-drain."""


def record_crc(record: Mapping[str, Any]) -> str:
    """CRC32 (hex) of the record's canonical serialization, excluding the
    ``_crc`` field itself — idempotent, so re-serializing a stored record
    reproduces the same line bytes."""
    body = {k: v for k, v in record.items() if k != "_crc"}
    data = json.dumps(body, sort_keys=True, separators=(",", ":"))
    return format(zlib.crc32(data.encode("utf-8")) & 0xFFFFFFFF, "08x")


def _record_line(record: Mapping[str, Any]) -> str:
    rec = dict(record)
    rec["_crc"] = record_crc(rec)
    return json.dumps(rec, sort_keys=True, separators=(",", ":")) + "\n"


#: line classification statuses (shared with fsck)
LINE_OK = "ok"                    #: parsed, CRC present and matching
LINE_LEGACY = "legacy"            #: parsed, no ``_crc`` field (pre-CRC shard)
LINE_UNDECODABLE = "undecodable"  #: not valid JSON / not UTF-8
LINE_CRC_MISMATCH = "crc_mismatch"  #: parsed but fails its own checksum


def parse_record_line(line: bytes) -> Tuple[Optional[Dict[str, Any]], str]:
    """Decode one committed JSONL line into ``(record, status)``. Records
    without ``_crc`` are tolerated (legacy shards); a present-but-wrong
    ``_crc`` is damage even when the JSON parses."""
    try:
        rec = json.loads(line.decode("utf-8"))
    except (ValueError, UnicodeDecodeError):
        return None, LINE_UNDECODABLE
    if not isinstance(rec, dict) or "uid" not in rec:
        return None, LINE_UNDECODABLE
    if "_crc" not in rec:
        return rec, LINE_LEGACY
    if rec["_crc"] != record_crc(rec):
        return rec, LINE_CRC_MISMATCH
    return rec, LINE_OK


class ShardStore:
    """Append-only JSONL census records for ONE shard, plus a manifest.

    Crash contract: records are appended in whole fsync'd batches and the
    manifest is rewritten atomically afterwards. The JSONL itself is the
    source of truth on resume — :meth:`open` truncates a torn trailing line
    (kill mid-append) and recomputes the manifest, so the completed set
    never contains a half-written record and never loses a whole one.

    The manifest is *slim*: counts, committed byte length, a rolling CRC32
    of the committed bytes, and per-family tallies — O(1) in shard size,
    so each append rewrites a few hundred bytes instead of re-serializing
    every completed uid, and status polls (:func:`shard_counts`) answer
    from it without parsing the JSONL.

    Integrity contract: every record carries a ``_crc`` field (CRC32 of
    its canonical serialization; absent on legacy shards and tolerated).
    On open, a torn *trailing* line is truncated away as before, but a
    damaged line in the middle of the file — bitrot, a foreign write, a
    filesystem bug — is **damage**, not noise: a writer refuses to touch
    the shard (:class:`StoreDamaged`, fsck repairs it) and a read-only
    consumer counts the damaged lines in :attr:`damaged` so merge can
    fail loudly instead of silently dropping records.
    """

    def __init__(self, root: str, shard: int, fsync: bool = False,
                 faults: Optional[FaultPlan] = None) -> None:
        self.root = root
        self.shard = shard
        self.fsync = fsync
        self.faults = faults
        self.records_path = os.path.join(root, f"shard-{shard:04d}.jsonl")
        self.manifest_path = os.path.join(root, f"shard-{shard:04d}.manifest.json")
        self.engine_path = os.path.join(root, f"shard-{shard:04d}.engine.json")
        self.timings_path = os.path.join(root, f"shard-{shard:04d}.timings.json")
        self.lease_path = os.path.join(root, f"shard-{shard:04d}.lease.json")
        self._records: List[Dict[str, Any]] = []
        self._uids: set = set()
        self._by_family: Dict[str, Dict[str, int]] = {}
        self._records_bytes = 0
        self._records_crc = 0
        #: (line_no, status) of committed-but-unreadable lines (readonly)
        self.damaged: List[Tuple[int, str]] = []
        self._opened = False

    # ---------------------------------------------------------- reading ---

    def open(self, readonly: bool = False) -> "ShardStore":
        """Load (and crash-recover) the shard's records.

        A torn trailing line (SIGKILL mid-append) is always *ignored*; it
        is physically truncated only when ``readonly`` is False. Read-only
        consumers (status / merge / report) may run concurrently with a
        live worker, and what looks like a torn tail to them may be that
        worker's append in flight — only the shard's owning worker, which
        is single per shard, may rewrite the file. A damaged final line
        that the manifest watermark already covers is NOT a torn tail —
        it was a committed record (last-line bitrot) and is treated
        exactly like mid-file damage.

        Mid-file damage (an undecodable or checksum-failing line that is
        NOT the final line) raises :class:`StoreDamaged` for a writer —
        appending past silent damage would hide it behind fresh records —
        and is skipped-but-counted (:attr:`damaged`) for read-only
        consumers, so status can report it and merge can refuse."""
        if not readonly:
            os.makedirs(self.root, exist_ok=True)
        self._records = []
        self._uids = set()
        self._by_family = {}
        self._records_bytes = 0
        self._records_crc = 0
        self.damaged = []
        if os.path.exists(self.records_path):
            with open(self.records_path, "rb") as fh:
                data = fh.read()
            lines = data.splitlines(keepends=True)
            # a damaged FINAL line is a torn (uncommitted, droppable) tail
            # only when it lies past the manifest's byte watermark; one the
            # manifest already committed is last-line bitrot — real damage.
            # Safe under a concurrent writer: its in-flight append is by
            # definition past the watermark (manifest commits afterwards).
            manifest = self.read_manifest()
            try:
                watermark = int((manifest or {}).get("records_bytes", 0))
            except (TypeError, ValueError):
                watermark = 0
            pos = 0
            good_end = 0
            contiguous = True  # no damage seen yet: prefix is truncat-able
            for i, line in enumerate(lines):
                pos += len(line)
                last = i == len(lines) - 1
                committed = pos <= watermark
                if not line.endswith(b"\n"):
                    if committed:
                        if not readonly:
                            raise StoreDamaged(
                                f"{self.records_path}: line {i + 1} lost "
                                "its terminator inside the committed "
                                "region (last-line bitrot) — run fsck "
                                "before writing to this shard"
                            )
                        self.damaged.append((i + 1, LINE_UNDECODABLE))
                        contiguous = False
                    break  # torn tail: the batch never committed
                rec, status = parse_record_line(line)
                if status in (LINE_UNDECODABLE, LINE_CRC_MISMATCH):
                    if last and not committed:
                        break  # a torn tail that happens to end in \n
                    if not readonly:
                        raise StoreDamaged(
                            f"{self.records_path}: line {i + 1} is "
                            f"{status} mid-file — run fsck before writing "
                            "to this shard"
                        )
                    self.damaged.append((i + 1, status))
                    contiguous = False
                    continue
                self._records.append(rec)
                self._uids.add(rec["uid"])
                self._tally(rec)
                self._records_crc = zlib.crc32(line, self._records_crc)
                if contiguous:
                    good_end += len(line)
            self._records_bytes = good_end
            if good_end < len(data) and not readonly and not self.damaged:
                with open(self.records_path, "r+b") as fh:
                    fh.truncate(good_end)
        self._opened = True
        return self

    @property
    def records(self) -> List[Dict[str, Any]]:
        self._ensure_open()
        return list(self._records)

    def completed_uids(self) -> List[str]:
        self._ensure_open()
        return [r["uid"] for r in self._records]

    def _ensure_open(self) -> None:
        if not self._opened:
            raise RuntimeError("ShardStore.open() must be called first")

    def _tally(self, rec: Mapping[str, Any]) -> None:
        fam = self._by_family.setdefault(
            str(rec.get("family", "?")), {"done": 0, "anomalies": 0}
        )
        fam["done"] += 1
        if rec.get("is_anomaly"):
            fam["anomalies"] += 1
        # skipped-instance accounting is part of the manifest contract:
        # an active census must never hide how much it did not measure.
        # The key appears only when predicted records exist, so manifests
        # of ordinary censuses keep their historical shape.
        if rec.get("provenance") == "predicted":
            fam["predicted"] = fam.get("predicted", 0) + 1

    # ---------------------------------------------------------- writing ---

    def append_records(self, records: Sequence[Mapping[str, Any]]) -> int:
        """Append a batch (skipping already-present uids) as ONE serialized
        write, fsync if configured, refresh the slim manifest. Returns the
        number actually appended.

        Transient ``OSError`` is retried with bounded backoff; before each
        (re)try the file is truncated back to the committed watermark, so
        a half-written first attempt can never leave garbage in front of
        the retried batch."""
        self._ensure_open()
        fresh = [dict(r) for r in records if r["uid"] not in self._uids]
        if fresh:
            data = "".join(_record_line(r) for r in fresh).encode("utf-8")
            with_retries(
                lambda: self._commit_batch(data),
                policy=STORE_IO_POLICY,
                seed=f"append:{self.records_path}",
                describe=f"append to {self.records_path}",
            )
            self._records.extend(fresh)
            for r in fresh:
                self._uids.add(r["uid"])
                self._tally(r)
            self._records_bytes += len(data)
            self._records_crc = zlib.crc32(data, self._records_crc)
        self.write_manifest()
        return len(fresh)

    def _commit_batch(self, data: bytes) -> None:
        """One append attempt: truncate away any previous failed attempt,
        write the whole batch, flush (fsync if configured). Fault-injection
        sites ``store.append`` (torn_write / corrupt_byte / io_error) and
        ``store.fsync`` (drop_fsync) live here."""
        specs = self.faults.poke("store.append") if self.faults else []
        with open(self.records_path, "ab") as fh:
            if fh.tell() > self._records_bytes:
                fh.truncate(self._records_bytes)
            for spec in specs:
                if spec.op == "torn_write" and self.faults.claim(spec):
                    cut = max(1, min(len(data) - 1,
                                     int(len(data) * (spec.arg or 0.5))))
                    fh.write(data[:cut])
                    fh.flush()
                    raise InjectedFault(
                        f"torn append after {cut}/{len(data)} bytes "
                        f"({spec.id})"
                    )
            fh.write(data)
            fh.flush()
            if self.fsync:
                dropped = self.faults.poke("store.fsync") if self.faults else []
                if not any(s.op == "drop_fsync" and self.faults.claim(s)
                           for s in dropped):
                    os.fsync(fh.fileno())
        # bitrot simulation: flip one byte of an EARLIER, committed record
        # (only after something is committed — stays armed until then)
        for spec in specs:
            if (spec.op == "corrupt_byte" and self._records_bytes > 0
                    and self.faults.claim(spec)):
                offset = self.faults.rng(spec).randrange(self._records_bytes)
                with open(self.records_path, "r+b") as fh:
                    fh.seek(offset)
                    if fh.read(1) == b"\n":
                        offset = max(0, offset - 1)
                    fh.seek(offset)
                    fh.write(b"\x00")

    def write_manifest(self, done: Optional[bool] = None) -> None:
        self._ensure_open()
        manifest = {
            "shard": self.shard,
            "n_completed": len(self._records),
            "records_bytes": self._records_bytes,
            "records_crc32": format(self._records_crc & 0xFFFFFFFF, "08x"),
            "by_family": self._by_family,
        }
        if done is not None:
            manifest["done"] = bool(done)

        def commit() -> None:
            tmp = self.manifest_path + ".tmp"
            with open(tmp, "w") as fh:
                json.dump(manifest, fh, indent=1, sort_keys=True)
                fh.flush()
                if self.fsync:
                    os.fsync(fh.fileno())
            os.replace(tmp, self.manifest_path)

        with_retries(
            commit,
            policy=STORE_IO_POLICY,
            seed=f"manifest:{self.manifest_path}",
            describe=f"manifest rewrite {self.manifest_path}",
        )

    def read_manifest(self) -> Optional[Dict[str, Any]]:
        """The on-disk manifest (no open() needed), or None."""
        try:
            with open(self.manifest_path) as fh:
                return json.load(fh)
        except (OSError, ValueError):
            return None

    # ----------------------------------------------------- engine state ---

    def has_engine_state(self) -> bool:
        return os.path.exists(self.engine_path)

    def clear_engine_state(self) -> None:
        if os.path.exists(self.engine_path):
            os.remove(self.engine_path)

    # ---------------------------------------------------------- timings ---

    def add_timings(self, delta: Mapping[str, float]) -> None:
        """Accumulate wall-clock stage timings into the shard's sidecar
        timings file (load + add + atomic replace). Advisory only — wall
        times live here, NOT in the records, so the JSONL stays
        byte-identical across kills, resumes, and host takeovers."""
        totals: Dict[str, float] = {}
        try:
            with open(self.timings_path) as fh:
                totals = {k: float(v) for k, v in json.load(fh).items()}
        except (OSError, ValueError):
            totals = {}
        for k, v in delta.items():
            totals[k] = totals.get(k, 0.0) + float(v)
        tmp = self.timings_path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(totals, fh, indent=1, sort_keys=True)
        os.replace(tmp, self.timings_path)


def shard_counts(store: ShardStore) -> Dict[str, Any]:
    """Done/anomaly tallies for one shard WITHOUT parsing its whole JSONL.

    Served from the slim manifest, then a tail-scan of only the bytes a
    live worker appended past the manifest's ``records_bytes`` watermark
    (the manifest commits after the JSONL, so the watermark always sits on
    a committed line boundary; a torn tail line is skipped). Falls back to
    the authoritative full parse for legacy manifests (pre-watermark
    format) or when the file shrank under the watermark (foreign rewrite).
    """
    manifest = store.read_manifest()
    legacy = (
        manifest is None
        or "records_bytes" not in manifest
        or "by_family" not in manifest
    )
    if not legacy:
        try:
            size = os.path.getsize(store.records_path)
        except OSError:
            size = 0
        base = int(manifest["records_bytes"])
        if size < base:
            legacy = True  # file shrank: manifest is stale, rescan
    if legacy:
        n_done = 0
        n_damaged = 0
        by_family: Dict[str, Dict[str, int]] = {}
        done_flag = bool(manifest.get("done")) if manifest else False
        if os.path.exists(store.records_path):
            scan = ShardStore(store.root, store.shard).open(readonly=True)
            n_done = len(scan._records)
            n_damaged = len(scan.damaged)
            by_family = scan._by_family
        return {"done": n_done, "by_family": by_family,
                "done_flag": done_flag, "damaged": n_damaged}
    n_done = int(manifest["n_completed"])
    n_damaged = 0
    by_family = {
        f: {"done": int(c.get("done", 0)),
            "anomalies": int(c.get("anomalies", 0)),
            **({"predicted": int(c["predicted"])} if "predicted" in c else {})}
        for f, c in manifest["by_family"].items()
    }
    if size > base:
        with open(store.records_path, "rb") as fh:
            fh.seek(base)
            tail = fh.read()
        lines = tail.splitlines(keepends=True)
        for i, line in enumerate(lines):
            if not line.endswith(b"\n"):
                break
            rec, status = parse_record_line(line)
            if status in (LINE_UNDECODABLE, LINE_CRC_MISMATCH):
                if i == len(lines) - 1:
                    break  # an append in flight; not yet damage
                n_damaged += 1
                continue
            n_done += 1
            fam = by_family.setdefault(
                str(rec.get("family", "?")), {"done": 0, "anomalies": 0}
            )
            fam["done"] += 1
            if rec.get("is_anomaly"):
                fam["anomalies"] += 1
            if rec.get("provenance") == "predicted":
                fam["predicted"] = fam.get("predicted", 0) + 1
    return {
        "done": n_done,
        "by_family": by_family,
        "done_flag": bool(manifest.get("done", False)),
        "damaged": n_damaged,
    }


# -------------------------------------------------------------- the runner ---


def _wall_clock_timers(
    spec: SweepSpec, instances: Mapping[str, InstanceSpec], uids: Iterable[str]
) -> Dict[str, Timer]:
    """Rebuild wall-clock backends for a resumed engine chunk (callables do
    not serialize; everything derives from the spec)."""
    timers: Dict[str, Timer] = {}
    for uid in uids:
        inst = instances[uid]
        flops, _, build_workloads = instance_entry(inst)
        timers[uid] = WallClockTimer(build_workloads())
    return timers


def run_chunked_campaign(
    store: ShardStore,
    todo_uids: Sequence[str],
    build_session: Callable[[str], MeasurementSession],
    record_fn: Callable[[MeasurementSession], Dict[str, Any]],
    *,
    chunk_size: int,
    save_every: int,
    policy: str = "least_converged_first",
    rebuild_timers: Optional[Callable[[Sequence[str]], Dict[str, Timer]]] = None,
    max_steps: Optional[int] = None,
    progress: Optional[Callable[[str], None]] = None,
    label: str = "shard",
    heartbeat: Optional[Callable[..., None]] = None,
    timings: Optional[Dict[str, float]] = None,
    faults: Optional[FaultPlan] = None,
    predictor: Optional[Callable[[str], Optional[Dict[str, Any]]]] = None,
) -> bool:
    """The shared chunk/resume/save/append driver behind every sharded
    campaign (census shards AND anomaly explanations — one copy of the
    kill/resume state machine, not one per subsystem).

    ``todo_uids`` (minus the store's completed set) is processed in chunks
    of ``chunk_size``; each chunk is one interleaved
    :class:`~repro.core.engine.ExperimentEngine` campaign built by
    ``build_session(uid)``. Engine state persists every ``save_every``
    steps and at every chunk boundary; a completed chunk appends
    ``record_fn(session)`` rows to the store's JSONL and drops the engine
    state. Any kill point therefore resumes losing at most ``save_every``
    engine steps of *work* and zero steps of *determinism* (serialized
    timer RNG state replays the lost steps bit-identically for the
    cost_model / simulated backends). ``rebuild_timers`` re-attaches
    non-serializable (wall-clock) backends on resume. Returns True when
    every uid completed, False when paused on the ``max_steps`` budget.

    ``heartbeat`` (the work-queue hook) is called once per session build
    and engine step, and as ``heartbeat(True)`` immediately before every
    record append — :meth:`repro.core.lease.Lease.heartbeat` fits the
    shape. An exception it raises (``LeaseLost``) aborts the shard BEFORE
    the commit, so a taken-over shard never gets records from two owners.

    ``timings``, if given, accumulates wall-clock stage seconds in place:
    ``build_s`` (session construction — decomposition, workload setup),
    ``step_s`` (engine measurement + mean-rank analysis), ``record_s``
    (record_fn — discriminant / classification), ``append_s`` (store I/O),
    plus ``steps`` / ``records`` counts. Pure observability — nothing here
    feeds back into measurements or records.

    ``faults`` is the chaos hook: the ``campaign.step`` injection site is
    poked once per engine step (sigkill / stall ops — see
    :mod:`repro.core.faults`).

    ``predictor`` is the active-census gate: called once per todo uid
    BEFORE any chunk is built, it returns either a complete
    ``provenance="predicted"`` record (the instance is recorded without
    measurement) or ``None`` (measure it normally). Predicted records
    commit through the ordinary append path — CRC'd, deduped,
    manifest-tallied — and the gate runs before chunking on every
    (re)entry, so a killed active census resumes byte-identically: the
    remaining todo re-predicts to the same records, and engine chunks
    only ever contain gate-rejected uids. The skipped count is announced
    via ``progress`` and lands in the manifest's per-family ``predicted``
    tallies — never silent.
    """
    say = progress or (lambda msg: None)
    beat = heartbeat or (lambda *a: None)
    t = timings if timings is not None else {}
    completed = set(store.completed_uids())
    total = len(todo_uids)
    todo = [u for u in todo_uids if u not in completed]
    steps_left = max_steps

    if predictor is not None and todo:
        t0 = time.perf_counter()
        predicted: List[Dict[str, Any]] = []
        remaining: List[str] = []
        for uid in todo:
            beat()
            rec = predictor(uid)
            if rec is None:
                remaining.append(uid)
            else:
                predicted.append(rec)
        t["predict_s"] = t.get("predict_s", 0.0) + (time.perf_counter() - t0)
        if predicted:
            beat(True)  # prove ownership right before the commit
            t0 = time.perf_counter()
            store.append_records(predicted)
            t["append_s"] = t.get("append_s", 0.0) + (time.perf_counter() - t0)
            t["predicted"] = t.get("predicted", 0.0) + len(predicted)
            completed.update(r["uid"] for r in predicted)
            say(f"{label}: {len(predicted)}/{total} instances predicted "
                f"without measurement ({len(remaining)} to measure)")
        todo = remaining

    while True:
        engine: Optional[ExperimentEngine] = None
        if store.has_engine_state():
            try:
                with open(store.engine_path) as fh:
                    state = json.load(fh)
                timers = None
                if rebuild_timers is not None:
                    names = [s["name"] for s in state["sessions"]]
                    timers = rebuild_timers(names)
                engine = ExperimentEngine.load(store.engine_path, timers=timers)
            except (ValueError, KeyError, TypeError):
                # corrupt in-flight state (bitrot; engine.save is atomic so
                # a kill can't cause this): rebuilding the chunk from the
                # todo list replays it bit-identically for the
                # deterministic backends — drop the state, warn, rebuild
                say(f"{label}: corrupt engine state discarded (chunk will "
                    "be re-run deterministically)")
                store.clear_engine_state()
                continue
            chunk_uids = engine.session_names
            if all(uid in completed for uid in chunk_uids):
                # killed between record append and state cleanup
                store.clear_engine_state()
                continue
            say(f"{label}: resuming chunk of {len(chunk_uids)}")
        else:
            chunk = todo[:chunk_size]
            if not chunk:
                break
            engine = ExperimentEngine(policy=policy)
            t0 = time.perf_counter()
            for uid in chunk:
                beat()
                engine.add_session(build_session(uid))
            t["build_s"] = t.get("build_s", 0.0) + (time.perf_counter() - t0)
            engine.save(store.engine_path)
            chunk_uids = engine.session_names
            say(f"{label}: new chunk of {len(chunk)} "
                f"({len(completed)}/{total} done)")

        since_save = 0
        while not engine.done:
            if steps_left is not None and steps_left <= 0:
                engine.save(store.engine_path)
                say(f"{label}: paused (step budget)")
                return False
            if faults is not None:
                faults.poke("campaign.step")
            beat()
            t0 = time.perf_counter()
            stepped = engine.step()
            t["step_s"] = t.get("step_s", 0.0) + (time.perf_counter() - t0)
            if stepped is None:
                break
            t["steps"] = t.get("steps", 0.0) + 1
            since_save += 1
            if steps_left is not None:
                steps_left -= 1
            if since_save >= save_every:
                engine.save(store.engine_path)
                since_save = 0

        t0 = time.perf_counter()
        records = [record_fn(engine.session(uid)) for uid in chunk_uids]
        t["record_s"] = t.get("record_s", 0.0) + (time.perf_counter() - t0)
        t["records"] = t.get("records", 0.0) + len(records)
        beat(True)  # prove ownership right before the commit
        t0 = time.perf_counter()
        store.append_records(records)
        t["append_s"] = t.get("append_s", 0.0) + (time.perf_counter() - t0)
        store.clear_engine_state()
        completed.update(chunk_uids)
        todo = [u for u in todo if u not in completed]

    store.write_manifest(done=True)
    say(f"{label}: done ({len(completed)}/{total})")
    return True


def run_shard(
    spec: SweepSpec,
    root: str,
    shard: int,
    *,
    max_steps: Optional[int] = None,
    progress: Optional[Callable[[str], None]] = None,
    heartbeat: Optional[Callable[..., None]] = None,
    faults: Optional[FaultPlan] = None,
) -> ShardStore:
    """Run (or resume) one shard of the census to completion — the census
    instantiation of :func:`run_chunked_campaign` (see there for the
    persistence/resume contract). ``max_steps`` bounds the engine steps
    this call takes (the shard is left resumable mid-chunk) — used by
    tests and deadline-driven callers. ``heartbeat`` is the work-queue
    lease hook (see :func:`run_chunked_campaign`). ``faults`` defaults to
    the environment's chaos plan (:func:`repro.core.faults.active_plan`).
    """
    if faults is None:
        faults = active_plan()
    store = ShardStore(root, shard, fsync=spec.fsync, faults=faults).open()
    instances = {i.uid: i for i in spec.shard_instances(shard)}
    rebuild = None
    if spec.backend == "wall_clock":
        rebuild = lambda uids: _wall_clock_timers(spec, instances, uids)
    predictor = None
    if spec.predictor_model:
        # lazy: repro.predict imports back into this module
        from repro.predict.active import census_gate

        predictor = census_gate(spec, instances)
    timings: Dict[str, float] = {}
    run_chunked_campaign(
        store,
        list(instances),
        lambda uid: build_sweep_session(spec, instances[uid]),
        lambda session: record_from_session(session, spec),
        chunk_size=spec.chunk_size,
        save_every=spec.save_every,
        policy=spec.policy,
        rebuild_timers=rebuild,
        max_steps=max_steps,
        progress=progress,
        label=f"shard {shard}",
        heartbeat=heartbeat,
        timings=timings,
        faults=faults,
        predictor=predictor,
    )
    if timings:
        store.add_timings(timings)
    return store


# ------------------------------------------------------------ merge/triage ---


def scan_damage(n_shards: int, root: str) -> Dict[int, List[Tuple[int, str]]]:
    """Committed-but-unreadable lines per shard: ``{shard: [(line_no,
    status), ...]}`` for shards with damage. The authoritative full check
    behind merge's refusal and the status damage counts."""
    found: Dict[int, List[Tuple[int, str]]] = {}
    for shard in range(n_shards):
        store = ShardStore(root, shard).open(readonly=True)
        if store.damaged:
            found[shard] = list(store.damaged)
    return found


def merge_shards(spec: SweepSpec, root: str, *, strict: bool = True) -> List[Dict[str, Any]]:
    """All shard records, deduped by uid, in global grid order.

    ``strict`` (the default) refuses to merge a store containing mid-file
    damage: silently skipping undecodable lines would publish a census
    that is missing rows it was told it has. Run fsck, then merge."""
    seen: Dict[str, Dict[str, Any]] = {}
    damaged: Dict[int, int] = {}
    for shard in range(spec.n_shards):
        store = ShardStore(root, shard).open(readonly=True)
        if store.damaged:
            damaged[shard] = len(store.damaged)
        for r in store.records:
            seen.setdefault(r["uid"], r)
    if damaged and strict:
        detail = ", ".join(f"shard {s}: {n} line(s)"
                           for s, n in sorted(damaged.items()))
        raise StoreDamaged(
            f"{root} holds {sum(damaged.values())} damaged record line(s) "
            f"({detail}) — refusing to merge past silent data loss; run "
            f"`python -m repro.launch.fsck --out {root}` first"
        )
    return sorted(seen.values(), key=lambda r: r["index"])


def write_merged(spec: SweepSpec, root: str, path: Optional[str] = None) -> str:
    """Write the merged census as one JSONL (atomic), return the path."""
    path = path or os.path.join(root, "merged.jsonl")
    records = merge_shards(spec, root)
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        for r in records:
            fh.write(_record_line(r))
    os.replace(tmp, path)
    return path


def size_bucket(size: int) -> str:
    """Power-of-two size bucket label, e.g. ``[128, 256)`` — delegates to the
    repo's one shape-bucketing rule (`repro.configs.shapes.shape_bucket`) at
    one bucket per octave, so report tables and the oracle cache agree."""
    from repro.configs.shapes import shape_bucket

    return shape_bucket(size)


def census_summary(records: Sequence[Mapping[str, Any]]) -> Dict[str, Any]:
    """Anomaly-rate aggregates: overall, by family, by size bucket, and by
    family x size — the numbers behind the paper's Figs. 5-7."""

    def agg(rows: Sequence[Mapping[str, Any]]) -> Dict[str, Any]:
        n = len(rows)
        anom = [r for r in rows if r["is_anomaly"]]
        reasons: Dict[str, int] = {}
        for r in anom:
            reasons[r["reason"]] = reasons.get(r["reason"], 0) + 1
        return {
            "n": n,
            "anomalies": len(anom),
            "rate": (len(anom) / n) if n else 0.0,
            "reasons": reasons,
            "converged": sum(1 for r in rows if r["converged"]),
            "predicted": sum(
                1 for r in rows if r.get("provenance") == "predicted"
            ),
        }

    by_family: Dict[str, Any] = {}
    for fam in sorted({r["family"] for r in records}):
        by_family[fam] = agg([r for r in records if r["family"] == fam])
    by_size: Dict[str, Any] = {}
    for bucket in sorted(
        {size_bucket(r["size"]) for r in records},
        key=lambda b: int(b[1:].split(",")[0]),
    ):
        by_size[bucket] = agg(
            [r for r in records if size_bucket(r["size"]) == bucket]
        )
    by_family_size: Dict[str, Any] = {}
    for fam, fam_agg in by_family.items():
        rows = [r for r in records if r["family"] == fam]
        by_family_size[fam] = {
            bucket: agg([r for r in rows if size_bucket(r["size"]) == bucket])
            for bucket in sorted(
                {size_bucket(r["size"]) for r in rows},
                key=lambda b: int(b[1:].split(",")[0]),
            )
        }
    return {
        "total": agg(list(records)),
        "by_family": by_family,
        "by_size": by_size,
        "by_family_size": by_family_size,
    }


def sweep_progress(spec: SweepSpec, root: str) -> Dict[str, Any]:
    """Completed / total per shard, plus running anomaly tallies per family
    (the ``plan``/``run``/``status`` lines). A long census surfaces its
    anomaly landscape here, before any ``merge`` — the explain subsystem's
    "is there anything to explain yet" probe.

    Counts come from the slim shard manifests (plus a tail-scan of records
    appended since each manifest committed — :func:`shard_counts`), so a
    status poll costs O(shards), not O(records): it no longer re-parses
    every shard JSONL, and the grid is expanded once, not once per shard.
    """
    instances = spec.expand()
    totals = [0] * spec.n_shards
    for inst in instances:
        totals[spec.shard_of(inst)] += 1
    per_shard = []
    total_done = 0
    anomalies = 0
    total_damaged = 0
    total_predicted = 0
    per_family: Dict[str, Dict[str, int]] = {}
    for shard in range(spec.n_shards):
        store = ShardStore(root, shard)
        counts = shard_counts(store)
        shard_anom = 0
        shard_pred = 0
        for fam_name, fam_counts in counts["by_family"].items():
            fam = per_family.setdefault(
                fam_name, {"done": 0, "anomalies": 0, "predicted": 0}
            )
            fam["done"] += fam_counts["done"]
            fam["anomalies"] += fam_counts["anomalies"]
            fam["predicted"] += fam_counts.get("predicted", 0)
            shard_anom += fam_counts["anomalies"]
            shard_pred += fam_counts.get("predicted", 0)
        in_flight = os.path.exists(store.engine_path)
        per_shard.append({
            "shard": shard, "done": counts["done"], "total": totals[shard],
            "anomalies": shard_anom, "predicted": shard_pred,
            "in_flight_chunk": in_flight,
            "damaged": counts.get("damaged", 0),
        })
        total_done += counts["done"]
        anomalies += shard_anom
        total_predicted += shard_pred
        total_damaged += counts.get("damaged", 0)
    return {
        "name": spec.name,
        "instances": len(instances),
        "completed": total_done,
        "anomalies": anomalies,
        "damaged": total_damaged,
        "predicted": total_predicted,
        "by_family": per_family,
        "shards": per_shard,
    }
