"""Resumable measurement sessions — Procedure 4 one step at a time.

The paper's ``MeasureAndRank`` is an *iterative campaign*: add ``M``
measurements per algorithm, recompute mean ranks over the quantile ladder,
stop when the rank landscape stabilises. The original implementation ran
that loop to convergence in one blocking call, which makes it impossible to
interleave many expression instances, persist progress, or resume after a
kill. :class:`MeasurementSession` factors the loop body out:

* ``step()`` — exactly one Procedure-4 iteration (measure, shuffle, mean
  ranks, convergence norm, hypothesis update);
* ``done`` — the loop condition (converged, or measurement budget spent);
* ``result()`` — the final :class:`~repro.core.types.RankingResult`,
  including the warm-start path: a store that already holds >= 1
  measurement per algorithm is ranked as-is instead of re-measured past
  the budget;
* ``to_dict()`` / ``from_dict()`` — full JSON state (store, iteration
  history, convergence state, RNG states) for kill/resume campaigns.

:func:`repro.core.convergence.measure_and_rank` is now a thin driver over a
single session; :class:`repro.core.engine.ExperimentEngine` schedules many
sessions as one campaign.
"""

from __future__ import annotations

import math
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence

import numpy as np

from .comparison import QuantileTable
from .meanrank import MeanRankResult, mean_ranks
from .measure import (
    MeasurementStore,
    Timer,
    rng_from_state,
    rng_state,
    timer_from_dict,
    timer_to_dict,
)
from .types import (
    DEFAULT_QUANTILE_RANGES,
    REPORT_QUANTILE_RANGE,
    IterationRecord,
    QuantileRange,
    RankedAlgorithm,
    RankingResult,
)


def first_differences(x: Sequence[float]) -> np.ndarray:
    """``convolution(x, [1, -1], step=1)`` — adjacent mean-rank deltas."""
    arr = np.asarray(x, dtype=np.float64)
    if arr.size < 2:
        return np.zeros(0, dtype=np.float64)
    return arr[1:] - arr[:-1]


def convergence_norm(dx: np.ndarray, dy: np.ndarray, p: int) -> float:
    """``||dx - dy||_2 / p`` (paper's stopping criterion)."""
    if dx.shape != dy.shape:
        raise ValueError(f"dx/dy shape mismatch: {dx.shape} vs {dy.shape}")
    if p <= 0:
        raise ValueError("p must be positive")
    return float(np.linalg.norm(dx - dy) / p)


def _record_to_dict(rec: IterationRecord) -> Dict[str, Any]:
    return {
        "measurements_per_alg": rec.measurements_per_alg,
        "order": list(rec.order),
        "ranks": list(rec.ranks),
        "mean_ranks": list(rec.mean_ranks),
        "norm": rec.norm,
    }


def _record_from_dict(d: Mapping[str, Any]) -> IterationRecord:
    return IterationRecord(
        measurements_per_alg=int(d["measurements_per_alg"]),
        order=tuple(d["order"]),
        ranks=tuple(int(r) for r in d["ranks"]),
        mean_ranks=tuple(float(m) for m in d["mean_ranks"]),
        norm=float(d["norm"]),
    )


class MeasurementSession:
    """One expression instance under the paper's measurement campaign.

    Wraps (algorithms, timer, store) and exposes the Procedure-4 loop body
    as ``step()``. All loop state (current hypothesis ``order``, previous
    differences ``dy``, convergence norm, iteration history) lives on the
    session and serializes to JSON, so a campaign can be killed after any
    iteration and resumed bit-identically (timer RNG state included for
    simulated/cost-model backends).

    Analysis runs vectorized by default: the session holds one
    :class:`~repro.core.comparison.QuantileTable` over its columnar store
    (all ladder bounds + the reporting range, batched into a single
    ``np.percentile`` pass per iteration, invalidated by store version), so
    a whole Procedure-4 step does O(p·R) percentile work instead of
    O(p²·R). ``vectorized=False`` keeps the paper-literal pairwise
    evaluation; both paths produce identical results and identical
    serialized state (golden-equality tested).

    ``meta`` is a JSON-serializable scratch dict for campaign owners (the
    autotuner stores FLOP tables and single-run times there).
    """

    def __init__(
        self,
        name: str,
        initial_order: Sequence[str],
        timer: Timer,
        *,
        m_per_iteration: int = 3,
        eps: float = 0.03,
        max_measurements: int = 30,
        quantile_ranges: Sequence[QuantileRange] = DEFAULT_QUANTILE_RANGES,
        report_range: QuantileRange = REPORT_QUANTILE_RANGE,
        tie_break: str = "class",
        store: Optional[MeasurementStore] = None,
        shuffle_seed: Optional[int] = 0,
        meta: Optional[Dict[str, Any]] = None,
        vectorized: bool = True,
    ) -> None:
        order = list(initial_order)
        if not order:
            raise ValueError("need at least one algorithm")
        self.name = name
        self.initial_order = list(order)
        self.m_per_iteration = m_per_iteration
        self.eps = eps
        self.max_measurements = max_measurements
        self.quantile_ranges = tuple(
            (float(lo), float(hi)) for lo, hi in quantile_ranges
        )
        self.report_range = (float(report_range[0]), float(report_range[1]))
        self.tie_break = tie_break
        self.meta: Dict[str, Any] = dict(meta or {})

        self._timer = timer
        self._order: List[str] = order
        self._p = len(order)
        self._store = store if store is not None else MeasurementStore()
        self._shuffle_seed = shuffle_seed
        self._shuffle_rng = (
            np.random.default_rng(shuffle_seed) if shuffle_seed is not None else None
        )
        self._dy = np.ones(max(self._p - 1, 0), dtype=np.float64)
        self._norm = float("inf")
        self._converged = False
        self._history: List[IterationRecord] = []
        self._fallback: Optional[IterationRecord] = None
        # Analysis fast path: one QuantileTable held across the session's
        # whole lifetime, recomputed lazily when the store version moves.
        # Deliberately NOT serialized — the vectorized and legacy paths
        # produce identical state, so persisted JSON stays byte-equal.
        self._vectorized = vectorized
        self._qtable: Optional[QuantileTable] = None
        self._analysis_seconds: List[float] = []

    # ------------------------------------------------------------ state ---

    @property
    def store(self) -> MeasurementStore:
        return self._store

    @property
    def timer(self) -> Timer:
        return self._timer

    @property
    def order(self) -> List[str]:
        """Current hypothesis ``h`` (updated after every iteration)."""
        return list(self._order)

    @property
    def history(self) -> List[IterationRecord]:
        return list(self._history)

    @property
    def iterations(self) -> int:
        return len(self._history)

    @property
    def converged(self) -> bool:
        return self._converged

    @property
    def norm(self) -> float:
        """Latest convergence norm (``inf`` before the first iteration)."""
        return self._norm

    @property
    def measurements_per_alg(self) -> int:
        return self._store.min_count()

    @property
    def done(self) -> bool:
        """Loop condition of Procedure 4: converged or budget spent."""
        return self._converged or self.measurements_per_alg >= self.max_measurements

    def attach_timer(self, timer: Timer) -> None:
        """Re-attach a measurement backend (after :meth:`from_dict` of a
        session whose timer was not serializable, e.g. wall-clock)."""
        self._timer = timer

    @property
    def vectorized(self) -> bool:
        """True when analysis runs through the batched quantile table."""
        return self._vectorized

    @property
    def analysis_seconds(self) -> List[float]:
        """Wall seconds the *analysis* (Procedure 3 over the ladder) took in
        each iteration run by this process — the quantity
        ``benchmarks/bench_rank_scaling.py`` sweeps. Not serialized: timings
        are an artifact of this host, not campaign state."""
        return list(self._analysis_seconds)

    # --------------------------------------------------------- analysis ---

    def _table(self) -> QuantileTable:
        """The session's quantile table: every bound of the ladder plus the
        reporting range, cached across steps, invalidated by store version."""
        if self._qtable is None:
            self._qtable = QuantileTable.from_ranges(
                self._store, (*self.quantile_ranges, self.report_range)
            )
        return self._qtable

    def _mean_ranks(self) -> MeanRankResult:
        """One Procedure-3 pass over the current store, timed.

        The vectorized path (default) flows the batched quantile table
        through every Procedure-2 sort of the ladder; ``vectorized=False``
        reproduces the historical pairwise evaluation (unmemoized, one
        ``np.percentile`` pair per comparison) bit-for-bit — the golden
        tests hold the two paths equal.
        """
        t0 = time.perf_counter()
        if self._vectorized:
            mr = mean_ranks(
                self._order,
                None,
                quantile_ranges=self.quantile_ranges,
                report_range=self.report_range,
                tie_break=self.tie_break,
                table=self._table(),
            )
        else:
            mr = mean_ranks(
                self._order,
                self._store.as_mapping(),
                quantile_ranges=self.quantile_ranges,
                report_range=self.report_range,
                tie_break=self.tie_break,
                memoize=False,
            )
        self._analysis_seconds.append(time.perf_counter() - t0)
        return mr

    # ------------------------------------------------------------- loop ---

    def step(self) -> Optional[IterationRecord]:
        """One Procedure-4 iteration; returns its record, or None if done.

        The measurement phase is transactional: the batch is buffered and
        the timer's RNG snapshot restored if it is interrupted, so a save
        taken after the exception persists a whole-iteration boundary and
        resume stays bit-identical to an uninterrupted run.
        """
        if self.done:
            return None
        snap = self._timer.snapshot()
        try:
            batch = [
                (name, self._timer.measure_many(name, self.m_per_iteration))
                for name in self._order
            ]
        except BaseException:
            self._timer.restore(snap)
            raise
        for name, values in batch:
            self._store.add(name, values)
        n = self._store.min_count()
        if self._shuffle_rng is not None:
            self._store.shuffle(self._shuffle_rng)

        mr = self._mean_ranks()
        x = np.asarray(mr.ordered_mean_ranks(), dtype=np.float64)
        dx = first_differences(x)
        self._norm = convergence_norm(dx, self._dy, self._p)
        self._dy = dx
        self._order = list(mr.order)  # h <- ordering from the report range

        rec = IterationRecord(
            measurements_per_alg=n,
            order=tuple(mr.order),
            ranks=tuple(mr.ranks),
            mean_ranks=tuple(mr.mean_ranks[name] for name in mr.order),
            norm=self._norm,
        )
        self._history.append(rec)
        if self._norm < self.eps:
            self._converged = True
        return rec

    def run_to_convergence(self) -> RankingResult:
        """Blocking drive — the original ``measure_and_rank`` semantics."""
        while not self.done:
            self.step()
        return self.result()

    # ----------------------------------------------------------- result ---

    def _rank_existing_or_measure_once(self) -> IterationRecord:
        """Zero-iteration fallback. A warm-started store that already holds
        >= 1 measurement per algorithm is ranked as-is (no measurement past
        the budget); only algorithms with NO data get one batch."""
        missing = [n for n in self._order if len(self._store.get(n)) == 0]
        for name in missing:
            self._store.add(
                name, self._timer.measure_many(name, max(1, self.m_per_iteration))
            )
        mr = self._mean_ranks()
        rec = IterationRecord(
            measurements_per_alg=self._store.min_count(),
            order=tuple(mr.order),
            ranks=tuple(mr.ranks),
            mean_ranks=tuple(mr.mean_ranks[name] for name in mr.order),
            norm=self._norm,
        )
        self._fallback = rec
        return rec

    def can_rank(self) -> bool:
        """True if a ranking exists without taking any new measurement."""
        return (
            bool(self._history)
            or self._fallback is not None
            or all(len(self._store.get(n)) > 0 for n in self._order)
        )

    def result(self, measure_if_needed: bool = True) -> RankingResult:
        """Ranking from the latest completed iteration (or the warm-start /
        measure-once fallback when no iteration ever ran).

        With ``measure_if_needed=False`` the call is guaranteed side-effect
        free: it raises instead of measuring when a never-stepped session
        has algorithms without data (schedulers use this so that reading
        intermediate results never perturbs a resumable campaign).
        """
        if self._history:
            rec = self._history[-1]
        elif self._fallback is not None:
            rec = self._fallback
        else:
            if not measure_if_needed and not self.can_rank():
                raise RuntimeError(
                    f"session {self.name!r} has no measurements to rank yet"
                )
            rec = self._rank_existing_or_measure_once()
        sequence = [
            RankedAlgorithm(name=name, rank=rank, mean_rank=m)
            for name, rank, m in zip(rec.order, rec.ranks, rec.mean_ranks)
        ]
        return RankingResult(
            sequence=sequence,
            mean_ranks=dict(zip(rec.order, rec.mean_ranks)),
            measurements_per_alg=self._store.min_count(),
            converged=self._converged,
            history=list(self._history),
        )

    # -------------------------------------------------------- persistence ---

    def to_dict(self, include_timer: bool = True) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "version": 1,
            "name": self.name,
            "initial_order": list(self.initial_order),
            "order": list(self._order),
            "m_per_iteration": self.m_per_iteration,
            "eps": self.eps,
            "max_measurements": self.max_measurements,
            "quantile_ranges": [list(q) for q in self.quantile_ranges],
            "report_range": list(self.report_range),
            "tie_break": self.tie_break,
            "store": self._store.to_dict(),
            "dy": [float(v) for v in self._dy],
            "norm": None if math.isinf(self._norm) else self._norm,
            "converged": self._converged,
            "history": [_record_to_dict(r) for r in self._history],
            "shuffle_seed": self._shuffle_seed,
            "shuffle_rng_state": (
                rng_state(self._shuffle_rng) if self._shuffle_rng is not None else None
            ),
            "meta": self.meta,
        }
        if include_timer:
            d["timer"] = timer_to_dict(self._timer)
        return d

    @classmethod
    def from_dict(
        cls,
        d: Mapping[str, Any],
        timer: Optional[Timer] = None,
        workloads: Optional[Mapping[str, Any]] = None,
        vectorized: bool = True,
    ) -> "MeasurementSession":
        """Rebuild a session. ``timer`` overrides the serialized backend;
        wall-clock backends need ``workloads`` (or a later
        :meth:`attach_timer`) before the next ``step()``. ``vectorized`` is
        an analysis-path choice of the *process*, not campaign state — it is
        never serialized, and either setting resumes any saved session
        bit-identically."""
        if timer is None:
            timer = timer_from_dict(d.get("timer") or {"kind": "opaque"}, workloads)
        session = cls(
            d["name"],
            d["initial_order"],
            timer,
            vectorized=vectorized,
            m_per_iteration=int(d["m_per_iteration"]),
            eps=float(d["eps"]),
            max_measurements=int(d["max_measurements"]),
            quantile_ranges=[tuple(q) for q in d["quantile_ranges"]],
            report_range=tuple(d["report_range"]),
            tie_break=d["tie_break"],
            store=MeasurementStore.from_dict(d["store"]),
            shuffle_seed=d.get("shuffle_seed"),
            meta=d.get("meta"),
        )
        session._order = list(d["order"])
        session._dy = np.asarray(d["dy"], dtype=np.float64)
        session._norm = float("inf") if d["norm"] is None else float(d["norm"])
        session._converged = bool(d["converged"])
        session._history = [_record_from_dict(r) for r in d["history"]]
        state = d.get("shuffle_rng_state")
        if state is not None:
            session._shuffle_rng = rng_from_state(state)
        return session
