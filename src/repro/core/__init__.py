"""repro.core — the paper's contribution.

Statistically-sound ranking of mathematically equivalent algorithms into
performance classes (Sankaran & Bientinesi 2022), plus the test for FLOPs as
a discriminant. Backend-agnostic: measurements may come from wall-clock
timing, simulation, or a compiled-artifact cost model.
"""

from .comparison import (
    QuantileTable,
    compare_measurements,
    compare_range,
    quantile_window,
)
from .convergence import (
    convergence_norm,
    first_differences,
    measure_and_rank,
)
from .discriminant import flops_discriminant_test
from .engine import POLICIES, ExperimentEngine
from .meanrank import MeanRankResult, mean_ranks
from .measure import (
    CostModelTimer,
    DetachedTimer,
    MeasurementStore,
    NoiseProfile,
    SimulatedTimer,
    Timer,
    WallClockTimer,
    timer_from_dict,
    timer_to_dict,
)
from .session import MeasurementSession
from .sweep import (
    InstanceSpec,
    ShardStore,
    SweepSpec,
    build_sweep_session,
    census_summary,
    merge_shards,
    run_shard,
    write_merged,
)
from .ranking import (
    make_measurement_comparator,
    make_table_comparator,
    ranks_as_dict,
    sort_algorithms,
    sort_by_measurements,
    sort_by_table,
)
from .scores import (
    CandidateSet,
    filter_candidates,
    initial_hypothesis_by_flops,
    initial_hypothesis_by_time,
    min_flops_set,
    relative_flops,
    relative_times,
)
from .types import (
    DEFAULT_QUANTILE_RANGES,
    FAST_MODE_QUANTILE_RANGES,
    REPORT_QUANTILE_RANGE,
    DiscriminantReport,
    IterationRecord,
    Outcome,
    QuantileRange,
    RankedAlgorithm,
    RankingResult,
)

__all__ = [
    "CandidateSet",
    "CostModelTimer",
    "DEFAULT_QUANTILE_RANGES",
    "DetachedTimer",
    "DiscriminantReport",
    "ExperimentEngine",
    "FAST_MODE_QUANTILE_RANGES",
    "InstanceSpec",
    "IterationRecord",
    "MeanRankResult",
    "MeasurementSession",
    "MeasurementStore",
    "NoiseProfile",
    "Outcome",
    "POLICIES",
    "QuantileRange",
    "QuantileTable",
    "RankedAlgorithm",
    "RankingResult",
    "REPORT_QUANTILE_RANGE",
    "ShardStore",
    "SimulatedTimer",
    "SweepSpec",
    "Timer",
    "WallClockTimer",
    "build_sweep_session",
    "census_summary",
    "compare_measurements",
    "compare_range",
    "convergence_norm",
    "filter_candidates",
    "first_differences",
    "flops_discriminant_test",
    "initial_hypothesis_by_flops",
    "initial_hypothesis_by_time",
    "make_measurement_comparator",
    "make_table_comparator",
    "mean_ranks",
    "measure_and_rank",
    "merge_shards",
    "min_flops_set",
    "quantile_window",
    "ranks_as_dict",
    "relative_flops",
    "relative_times",
    "run_shard",
    "sort_algorithms",
    "write_merged",
    "sort_by_measurements",
    "sort_by_table",
    "timer_from_dict",
    "timer_to_dict",
]
