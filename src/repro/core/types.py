"""Core datatypes for the algorithm-ranking methodology.

Implements the vocabulary of Sankaran & Bientinesi, "A Test for FLOPs as a
Discriminant for Linear Algebra Algorithms" (2022): three-way comparison
outcomes, ranked sequences with shared ranks (performance classes), and the
result record of the convergence-driven measurement loop (Procedure 4).
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


class Outcome(enum.Enum):
    """Result of the three-way comparison (paper Procedure 1)."""

    BETTER = "better"          # alg_i < alg_j   (i is faster)
    WORSE = "worse"            # alg_i > alg_j   (i is slower)
    EQUIVALENT = "equivalent"  # alg_i ~ alg_j   (distributions overlap)

    def flipped(self) -> "Outcome":
        if self is Outcome.BETTER:
            return Outcome.WORSE
        if self is Outcome.WORSE:
            return Outcome.BETTER
        return Outcome.EQUIVALENT


# A quantile range (q_lower, q_upper), percentages in (0, 100).
QuantileRange = Tuple[float, float]

#: Quantile ladder used throughout the paper (Table III).
DEFAULT_QUANTILE_RANGES: Tuple[QuantileRange, ...] = (
    (5.0, 95.0),
    (10.0, 90.0),
    (15.0, 85.0),
    (20.0, 80.0),
    (25.0, 75.0),
    (30.0, 70.0),
    (35.0, 65.0),
)

#: Left-tail quantile set used for the turbo-boost / fast-frequency-mode
#: analysis (paper Sec. IV, "Effect of Turbo boost").
FAST_MODE_QUANTILE_RANGES: Tuple[QuantileRange, ...] = (
    (5.0, 50.0),
    (15.0, 45.0),
    (20.0, 40.0),
    (25.0, 35.0),
)

#: Default reporting range — (q25, q75), the IQR, standard for outlier
#: detection (paper Sec. III, Procedure 3 discussion).
REPORT_QUANTILE_RANGE: QuantileRange = (25.0, 75.0)


@dataclass(frozen=True)
class RankedAlgorithm:
    """One entry of the sorted sequence ``s`` (paper Sec. III)."""

    name: str
    rank: int                      # performance class; shared ranks allowed
    mean_rank: Optional[float] = None


@dataclass
class RankingResult:
    """Output of Procedure 4 (``MeasureAndRank``).

    Attributes
    ----------
    sequence:
        ``s_[25,75]`` — algorithms ordered best-first with their ranks at the
        reporting quantile range.
    mean_ranks:
        ``mr'`` — mean rank per algorithm across the quantile ladder.
    measurements_per_alg:
        ``N`` when the loop stopped.
    converged:
        True if the stopping criterion ``||dx - dy|| / p < eps`` fired (as
        opposed to hitting the measurement budget ``max``).
    history:
        Per-iteration record of (N, mean-rank vector in sequence order,
        convergence norm) for analysis/benchmarks.
    """

    sequence: List[RankedAlgorithm]
    mean_ranks: Dict[str, float]
    measurements_per_alg: int
    converged: bool
    history: List["IterationRecord"] = field(default_factory=list)

    @property
    def names_in_order(self) -> List[str]:
        return [a.name for a in self.sequence]

    @property
    def ranks(self) -> Dict[str, int]:
        return {a.name: a.rank for a in self.sequence}

    def best_class(self) -> List[str]:
        """Names of all algorithms in performance class 1."""
        return [a.name for a in self.sequence if a.rank == 1]

    def rank_of(self, name: str) -> int:
        for a in self.sequence:
            if a.name == name:
                return a.rank
        raise KeyError(name)


@dataclass(frozen=True)
class IterationRecord:
    measurements_per_alg: int
    order: Tuple[str, ...]
    ranks: Tuple[int, ...]
    mean_ranks: Tuple[float, ...]
    norm: float


@dataclass(frozen=True)
class DiscriminantReport:
    """Result of the FLOPs-as-discriminant test (paper Sec. I & IV).

    ``is_anomaly`` is True iff FLOPs fail to discriminate:
      reason == "faster_outside_min_flops":  an algorithm outside S_F obtained
          a strictly better performance class than the best member of S_F
          (condition 1 in the paper's Sec. I enumeration);
      reason == "min_flops_split":  members of S_F landed in different
          performance classes, so one cannot pick randomly from S_F
          (condition 2).
    """

    is_anomaly: bool
    reason: str                     # "none" | the two anomaly reasons above
    min_flops_algs: Tuple[str, ...]  # S_F
    best_rank_in_sf: int
    best_rank_overall: int
    ranks: Dict[str, int]
    relative_flops: Dict[str, float]
