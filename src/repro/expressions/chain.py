"""Matrix-chain algorithm generation (paper Expression 1 substrate).

For ``X = M_1 M_2 ... M_n`` every *parenthesization* (full binary tree over
the chain) is a mathematically equivalent variant, and every *linear
extension* of a tree's internal nodes (instruction order) is a distinct
algorithm: e.g. ``(AB)(CD)`` yields two algorithms — compute ``AB`` before or
after ``CD`` (paper Sec. I: "At least six algorithms can be implemented from
the five variants").

This module enumerates trees (Catalan(n-1) of them), their instruction
orders, and exact GEMM FLOP counts (2·m·k·n per product; the paper's Fig. 1
quotes cost = FLOPs/2). It also provides the classic dynamic-programming
optimum for cross-checking that the enumerated minimum matches.
"""

from __future__ import annotations

import functools
import itertools
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Sequence, Tuple, Union

# A parenthesization tree: leaf = matrix index (int); internal = (left, right).
Tree = Union[int, Tuple["Tree", "Tree"]]

#: A single GEMM instruction: (dest_id, lhs_id, rhs_id). Operand ids are
#: either leaf indices ("M0", "M1", ...) or earlier dest ids ("T0", ...).
Step = Tuple[str, str, str]


def enumerate_trees(n: int) -> List[Tree]:
    """All full binary trees over leaves 0..n-1 (Catalan(n-1) trees)."""
    if n < 1:
        raise ValueError("need at least one matrix")

    @functools.lru_cache(maxsize=None)
    def build(i: int, j: int) -> Tuple[Tree, ...]:
        if i == j:
            return (i,)
        out: List[Tree] = []
        for k in range(i, j):
            for left in build(i, k):
                for right in build(k + 1, j):
                    out.append((left, right))
        return tuple(out)

    return list(build(0, n - 1))


def tree_dims(tree: Tree, dims: Sequence[int]) -> Tuple[int, int]:
    """(rows, cols) of the subexpression; ``dims`` has length n_matrices+1."""
    if isinstance(tree, int):
        return dims[tree], dims[tree + 1]
    (lr, _), (_, rc) = tree_dims(tree[0], dims), tree_dims(tree[1], dims)
    return lr, rc


def tree_flops(tree: Tree, dims: Sequence[int]) -> int:
    """Exact GEMM FLOPs of the parenthesization (2·m·k·n per product)."""
    if isinstance(tree, int):
        return 0
    left, right = tree
    lf = tree_flops(left, dims)
    rf = tree_flops(right, dims)
    (m, k) = tree_dims(left, dims)
    (_, n) = tree_dims(right, dims)
    return lf + rf + 2 * m * k * n


def tree_label(tree: Tree) -> str:
    """Human-readable parenthesization, e.g. ``((M0 M1) M2)``; uses letters
    A.. for chains up to 26 matrices."""
    letters = "ABCDEFGHIJKLMNOPQRSTUVWXYZ"

    def render(t: Tree) -> str:
        if isinstance(t, int):
            return letters[t] if t < len(letters) else f"M{t}"
        return f"({render(t[0])}{render(t[1])})"

    s = render(tree)
    return s[1:-1] if s.startswith("(") and s.endswith(")") else s


def _internal_nodes(tree: Tree) -> List[Tuple[Tree, Tree, Tree]]:
    """Post-order list of internal nodes as (node, left, right)."""
    out: List[Tuple[Tree, Tree, Tree]] = []

    def walk(t: Tree) -> None:
        if isinstance(t, int):
            return
        walk(t[0])
        walk(t[1])
        out.append((t, t[0], t[1]))

    walk(tree)
    return out


def linear_extensions(tree: Tree) -> List[Tuple[int, ...]]:
    """All valid instruction orders of the tree's internal nodes.

    Nodes are identified by their index in the post-order list; an order is
    valid iff every node appears after both of its internal children.
    Chains of practical length have few extensions (<= 2 for n=4), but the
    enumeration is general.
    """
    nodes = _internal_nodes(tree)
    index = {id(node): i for i, (node, _, _) in enumerate(nodes)}
    deps: List[set] = []
    for node, left, right in nodes:
        d = set()
        if not isinstance(left, int):
            d.add(index[id(left)])
        if not isinstance(right, int):
            d.add(index[id(right)])
        deps.append(d)

    k = len(nodes)
    results: List[Tuple[int, ...]] = []

    def backtrack(done: Tuple[int, ...], remaining: set) -> None:
        if not remaining:
            results.append(done)
            return
        for i in sorted(remaining):
            if deps[i] <= set(done):
                backtrack(done + (i,), remaining - {i})

    backtrack((), set(range(k)))
    return results


@dataclass(frozen=True)
class ChainAlgorithm:
    """One executable algorithm: a parenthesization + an instruction order."""

    name: str                  # "algorithm3"
    tree: Tree
    label: str                 # e.g. "(AB)(CD) [order CD,AB]"
    steps: Tuple[Step, ...]    # GEMM sequence, dests "T0","T1",...
    flops: int
    out_dims: Tuple[int, int]

    @property
    def n_products(self) -> int:
        return len(self.steps)


def algorithms_for_tree(
    tree: Tree, dims: Sequence[int], start_index: int
) -> List[ChainAlgorithm]:
    """All algorithms (instruction orders) of one parenthesization."""
    nodes = _internal_nodes(tree)
    node_ids = {id(node): i for i, (node, _, _) in enumerate(nodes)}
    flops = tree_flops(tree, dims)
    out_dims = tree_dims(tree, dims)
    base_label = tree_label(tree)

    def operand_name(t: Tree, order_pos: Dict[int, int]) -> str:
        if isinstance(t, int):
            return f"M{t}"
        return f"T{order_pos[node_ids[id(t)]]}"

    algs: List[ChainAlgorithm] = []
    for ext_no, ext in enumerate(linear_extensions(tree)):
        order_pos = {node_idx: pos for pos, node_idx in enumerate(ext)}
        steps: List[Step] = []
        for pos, node_idx in enumerate(ext):
            node, left, right = nodes[node_idx]
            steps.append(
                (
                    f"T{pos}",
                    operand_name(left, order_pos),
                    operand_name(right, order_pos),
                )
            )
        order_suffix = "" if ext_no == 0 else f" [order {ext_no}]"
        algs.append(
            ChainAlgorithm(
                name=f"algorithm{start_index + ext_no}",
                tree=tree,
                label=base_label + order_suffix,
                steps=tuple(steps),
                flops=flops,
                out_dims=out_dims,
            )
        )
    return algs


def generate_chain_algorithms(dims: Sequence[int]) -> List[ChainAlgorithm]:
    """Every algorithm for the chain instance ``dims`` (len = n_matrices+1).

    Algorithms are numbered in (FLOPs, tree-enumeration, extension) order so
    that ``algorithm0`` always computes the least FLOPs — mirroring the
    paper's convention that the minimum-FLOPs variants carry the low indices.
    """
    n = len(dims) - 1
    trees = enumerate_trees(n)
    # Stable sort trees by FLOPs so min-FLOPs algorithms get low indices.
    trees.sort(key=lambda t: tree_flops(t, dims))
    algs: List[ChainAlgorithm] = []
    idx = 0
    for tree in trees:
        tree_algs = algorithms_for_tree(tree, dims, idx)
        algs.extend(tree_algs)
        idx += len(tree_algs)
    return algs


def dp_optimal_flops(dims: Sequence[int]) -> int:
    """Classic O(n^3) matrix-chain DP; exact GEMM FLOPs (2·m·k·n units).

    Used as an oracle: the enumerated minimum must equal this.
    """
    n = len(dims) - 1
    cost = [[0] * n for _ in range(n)]
    for span in range(1, n):
        for i in range(n - span):
            j = i + span
            cost[i][j] = min(
                cost[i][k] + cost[k + 1][j] + 2 * dims[i] * dims[k + 1] * dims[j + 1]
                for k in range(i, j)
            )
    return cost[0][n - 1]


def flops_table(algs: Sequence[ChainAlgorithm]) -> Dict[str, float]:
    return {a.name: float(a.flops) for a in algs}
