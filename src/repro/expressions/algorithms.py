"""Executable JAX implementations of chain algorithms.

Each :class:`~repro.expressions.chain.ChainAlgorithm` lowers to a sequence of
``jnp.dot`` calls executed in the algorithm's instruction order. The builder
returns a zero-argument callable that blocks on the result
(``block_until_ready``), suitable for :class:`repro.core.WallClockTimer`.

Note on instruction order under XLA: independent GEMMs inside one jitted
function may be reordered by the compiler, so two instruction orders of the
same parenthesization typically compile to identical HLO — i.e. they are
*equivalent algorithms*, which is exactly the situation the paper's
three-way comparison is designed to detect (they should land in one
performance class). The ``jit=False`` mode executes ops eagerly in the given
order for settings where order effects (cache warmth) are under study.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .chain import ChainAlgorithm, Step


def make_chain_inputs(
    dims: Sequence[int],
    dtype: jnp.dtype = jnp.float32,
    seed: int = 0,
) -> List[jax.Array]:
    """Concrete random matrices M0..M_{n-1} for a chain instance."""
    keys = jax.random.split(jax.random.PRNGKey(seed), len(dims) - 1)
    return [
        jax.random.normal(keys[i], (dims[i], dims[i + 1]), dtype=dtype)
        / np.sqrt(dims[i + 1])
        for i in range(len(dims) - 1)
    ]


def _execute_steps(
    steps: Sequence[Step], operands: Dict[str, jax.Array]
) -> jax.Array:
    env = dict(operands)
    last = None
    for dest, lhs, rhs in steps:
        env[dest] = jnp.dot(env[lhs], env[rhs])
        last = env[dest]
    assert last is not None
    return last


def build_algorithm_fn(
    alg: ChainAlgorithm,
    matrices: Sequence[jax.Array],
    jit: bool = True,
) -> Callable[[], jax.Array]:
    """Zero-arg callable running one algorithm to completion."""
    operands = {f"M{i}": m for i, m in enumerate(matrices)}

    if jit:
        def fn(*mats: jax.Array) -> jax.Array:
            ops = {f"M{i}": m for i, m in enumerate(mats)}
            return _execute_steps(alg.steps, ops)

        jitted = jax.jit(fn)
        mats = tuple(matrices)

        def run() -> jax.Array:
            return jax.block_until_ready(jitted(*mats))

        return run

    def run_eager() -> jax.Array:
        return jax.block_until_ready(_execute_steps(alg.steps, operands))

    return run_eager


def build_workloads(
    algs: Sequence[ChainAlgorithm],
    matrices: Sequence[jax.Array],
    jit: bool = True,
    warmup: bool = True,
) -> Dict[str, Callable[[], jax.Array]]:
    """name -> callable table for :class:`repro.core.WallClockTimer`.

    With ``warmup=True`` each callable is executed once here so that jit
    compilation ("library overheads", paper Sec. I step 1) never lands inside
    a timed region.
    """
    table: Dict[str, Callable[[], jax.Array]] = {}
    for alg in algs:
        fn = build_algorithm_fn(alg, matrices, jit=jit)
        if warmup:
            fn()
        table[alg.name] = fn
    return table


def reference_product(matrices: Sequence[jax.Array]) -> jax.Array:
    """Left-to-right oracle product for correctness checks."""
    out = matrices[0]
    for m in matrices[1:]:
        out = jnp.dot(out, m)
    return out


def verify_algorithms(
    algs: Sequence[ChainAlgorithm],
    matrices: Sequence[jax.Array],
    rtol: float = 1e-4,
    atol: float = 1e-4,
) -> None:
    """Assert every algorithm computes the same product (mathematical
    equivalence — distinct parenthesizations differ only by fp rounding)."""
    ref = np.asarray(reference_product(matrices), dtype=np.float64)
    for alg in algs:
        out = np.asarray(build_algorithm_fn(alg, matrices, jit=False)())
        np.testing.assert_allclose(out, ref, rtol=rtol, atol=atol, err_msg=alg.name)
