"""repro.expressions — Linnea-like variant generation for linear algebra.

Enumerates mathematically equivalent algorithms (parenthesizations ×
instruction orders, plus beyond-chain identity families) with exact analytic
FLOP counts and executable JAX implementations. This is the substrate the
paper's ranking methodology is demonstrated on.

The package imports lazily (PEP 562): the *analytic* layer (``chain``,
``instances``, family FLOP tables) is pure numpy, and jax is only imported
when an executable workload is actually built. DiscriminantSweep census
workers on the cost-model backend therefore start without paying the jax
import at all.
"""

from typing import TYPE_CHECKING

#: attribute name -> defining submodule
_EXPORTS = {
    # algorithms (imports jax)
    "build_algorithm_fn": "algorithms",
    "build_workloads": "algorithms",
    "make_chain_inputs": "algorithms",
    "reference_product": "algorithms",
    "verify_algorithms": "algorithms",
    # chain (pure python/numpy)
    "ChainAlgorithm": "chain",
    "algorithms_for_tree": "chain",
    "dp_optimal_flops": "chain",
    "enumerate_trees": "chain",
    "flops_table": "chain",
    "generate_chain_algorithms": "chain",
    "linear_extensions": "chain",
    "tree_dims": "chain",
    "tree_flops": "chain",
    "tree_label": "chain",
    # generalized (jax deferred to workload build time)
    "FAMILIES": "generalized",
    "ExpressionFamily": "generalized",
    "ExpressionVariant": "generalized",
    "bilinear_family": "generalized",
    "distributive_family": "generalized",
    "gram_family": "generalized",
    "solve_family": "generalized",
    # instances (numpy only)
    "ANOMALY_331": "instances",
    "FIG3_75": "instances",
    "INSTANCE_A": "instances",
    "INSTANCE_B": "instances",
    "PAPER_INSTANCES": "instances",
    "SMOKE_INSTANCES": "instances",
    "ChainInstance": "instances",
    "get_instance": "instances",
    "instance_grid": "instances",
    "random_instance": "instances",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    if name in _EXPORTS:
        import importlib

        module = importlib.import_module(f".{_EXPORTS[name]}", __name__)
        value = getattr(module, name)
        globals()[name] = value  # cache for subsequent lookups
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))


if TYPE_CHECKING:  # pragma: no cover - static analysis only
    from .algorithms import (
        build_algorithm_fn,
        build_workloads,
        make_chain_inputs,
        reference_product,
        verify_algorithms,
    )
    from .chain import (
        ChainAlgorithm,
        algorithms_for_tree,
        dp_optimal_flops,
        enumerate_trees,
        flops_table,
        generate_chain_algorithms,
        linear_extensions,
        tree_dims,
        tree_flops,
        tree_label,
    )
    from .generalized import (
        FAMILIES,
        ExpressionFamily,
        ExpressionVariant,
        bilinear_family,
        distributive_family,
        gram_family,
        solve_family,
    )
    from .instances import (
        ANOMALY_331,
        FIG3_75,
        INSTANCE_A,
        INSTANCE_B,
        PAPER_INSTANCES,
        SMOKE_INSTANCES,
        ChainInstance,
        get_instance,
        instance_grid,
        random_instance,
    )
