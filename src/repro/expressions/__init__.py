"""repro.expressions — Linnea-like variant generation for linear algebra.

Enumerates mathematically equivalent algorithms (parenthesizations ×
instruction orders, plus beyond-chain identity families) with exact analytic
FLOP counts and executable JAX implementations. This is the substrate the
paper's ranking methodology is demonstrated on.
"""

from .algorithms import (
    build_algorithm_fn,
    build_workloads,
    make_chain_inputs,
    reference_product,
    verify_algorithms,
)
from .chain import (
    ChainAlgorithm,
    algorithms_for_tree,
    dp_optimal_flops,
    enumerate_trees,
    flops_table,
    generate_chain_algorithms,
    linear_extensions,
    tree_dims,
    tree_flops,
    tree_label,
)
from .generalized import (
    FAMILIES,
    ExpressionFamily,
    ExpressionVariant,
    bilinear_family,
    distributive_family,
    gram_family,
    solve_family,
)
from .instances import (
    ANOMALY_331,
    FIG3_75,
    INSTANCE_A,
    INSTANCE_B,
    PAPER_INSTANCES,
    SMOKE_INSTANCES,
    ChainInstance,
    get_instance,
    instance_grid,
    random_instance,
)

__all__ = [
    "ANOMALY_331",
    "ChainAlgorithm",
    "ChainInstance",
    "ExpressionFamily",
    "ExpressionVariant",
    "FAMILIES",
    "FIG3_75",
    "INSTANCE_A",
    "INSTANCE_B",
    "PAPER_INSTANCES",
    "SMOKE_INSTANCES",
    "algorithms_for_tree",
    "bilinear_family",
    "build_algorithm_fn",
    "build_workloads",
    "distributive_family",
    "dp_optimal_flops",
    "enumerate_trees",
    "flops_table",
    "generate_chain_algorithms",
    "get_instance",
    "gram_family",
    "instance_grid",
    "linear_extensions",
    "make_chain_inputs",
    "random_instance",
    "reference_product",
    "solve_family",
    "tree_dims",
    "tree_flops",
    "tree_label",
    "verify_algorithms",
]
