"""Beyond-chain linear-algebra expression families.

Linnea-class generators emit variants for general expressions, not just
chains. We implement a small set of families whose variant spaces exercise
different mathematical identities (the paper's Sec. II situates chains within
this broader LAMP space):

* ``GramFamily``     — ``X = A Aᵀ B``: associativity + symmetry (``(AAᵀ)B``
  vs ``A(AᵀB)``; syrk-style half-FLOPs accounting for the symmetric product).
* ``DistributiveFamily`` — ``X = (A + B) C`` vs ``AC + BC``: distributivity
  *changes* the FLOP count (one GEMM vs two) — a family where FLOPs should
  discriminate strongly.
* ``SolveFamily``    — ``x = A⁻¹ b``: explicit inverse + GEMV vs LU solve —
  the canonical "never invert" example; FLOPs 2n³(inv) + 2n² vs ~(2/3)n³.
* ``BilinearFamily`` — ``y = uᵀ M v``: ``(uᵀM)v`` vs ``uᵀ(Mv)`` — equal
  FLOPs for square M, different memory-access patterns (row vs column
  traversal): the equal-FLOPs regime again.

Each family yields named variants with analytic FLOP counts and JAX
callables, pluggable into the same ranking pipeline as the chains.

jax is imported lazily, at workload-build time: constructing a family and
reading its FLOP table is pure python/numpy, so analytic consumers (the
DiscriminantSweep cost-model backend, FLOP-count tests) never pay the jax
import.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class ExpressionVariant:
    name: str
    label: str
    flops: float
    build: Callable[..., Callable[[], Any]]  # (*arrays) -> thunk


@dataclass(frozen=True)
class ExpressionFamily:
    name: str
    variants: Tuple[ExpressionVariant, ...]
    make_inputs: Callable[[int, int], List[Any]]  # (size, seed)

    def flops_table(self) -> Dict[str, float]:
        return {v.name: v.flops for v in self.variants}

    def workloads(
        self, size: int, seed: int = 0, warmup: bool = True
    ) -> Dict[str, Callable[[], Any]]:
        arrays = self.make_inputs(size, seed)
        table: Dict[str, Callable[[], Any]] = {}
        for v in self.variants:
            thunk = v.build(*arrays)
            if warmup:
                thunk()
            table[v.name] = thunk
        return table


def _jit_thunk(fn: Callable[..., Any], *arrays: Any) -> Callable[[], Any]:
    import jax

    jitted = jax.jit(fn)
    jax.block_until_ready(jitted(*arrays))  # compile outside timed region

    def run() -> Any:
        return jax.block_until_ready(jitted(*arrays))

    return run


# ----------------------------------------------------------------- Gram ----

def gram_family(n: int, k: int) -> ExpressionFamily:
    """``X = A Aᵀ B`` with A: n×k, B: n×n."""

    def inputs(size: int, seed: int) -> List[Any]:
        import jax
        import jax.numpy as jnp

        kk = max(1, int(k * size / n))
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        a = jax.random.normal(k1, (size, kk), jnp.float32) / np.sqrt(kk)
        b = jax.random.normal(k2, (size, size), jnp.float32) / np.sqrt(size)
        return [a, b]

    def left_first(a: Any, b: Any) -> Callable[[], Any]:
        return _jit_thunk(lambda a, b: (a @ a.T) @ b, a, b)

    def right_first(a: Any, b: Any) -> Callable[[], Any]:
        return _jit_thunk(lambda a, b: a @ (a.T @ b), a, b)

    def left_syrk(a: Any, b: Any) -> Callable[[], Any]:
        # Symmetric rank-k update semantics: same math; in BLAS syrk halves
        # the FLOPs of AAᵀ. XLA has no syrk — the *analytic* count differs,
        # which is the interesting case for the discriminant test.
        return _jit_thunk(lambda a, b: (a @ a.T) @ b, a, b)

    # FLOP accounting at the nominal size n (scaled at measurement time the
    # ratios are invariant, which is all RF needs).
    f_gemm_aat = 2 * n * n * k
    f_gemm_ab = 2 * n * n * n
    f_atb = 2 * k * n * n
    f_a_atb = 2 * n * k * n
    variants = (
        ExpressionVariant("gram_left", "(AAt)B", f_gemm_aat + f_gemm_ab, left_first),
        ExpressionVariant("gram_right", "A(AtB)", f_atb + f_a_atb, right_first),
        ExpressionVariant(
            "gram_left_syrk", "syrk(A)B", f_gemm_aat / 2 + f_gemm_ab, left_syrk
        ),
    )
    return ExpressionFamily("gram", variants, inputs)


# -------------------------------------------------------- Distributive ----

def distributive_family(n: int) -> ExpressionFamily:
    """``X = (A + B) C`` vs ``AC + BC`` (A, B, C: n×n)."""

    def inputs(size: int, seed: int) -> List[Any]:
        import jax
        import jax.numpy as jnp

        keys = jax.random.split(jax.random.PRNGKey(seed), 3)
        return [
            jax.random.normal(kk, (size, size), jnp.float32) / np.sqrt(size)
            for kk in keys
        ]

    def factored(a, b, c):
        return _jit_thunk(lambda a, b, c: (a + b) @ c, a, b, c)

    def expanded(a, b, c):
        return _jit_thunk(lambda a, b, c: a @ c + b @ c, a, b, c)

    variants = (
        ExpressionVariant("dist_factored", "(A+B)C", n * n + 2 * n**3, factored),
        ExpressionVariant("dist_expanded", "AC+BC", 4 * n**3 + n * n, expanded),
    )
    return ExpressionFamily("distributive", variants, inputs)


# ---------------------------------------------------------------- Solve ----

def solve_family(n: int) -> ExpressionFamily:
    """``x = A⁻¹ b``: explicit inverse vs LU solve (A: n×n SPD-ish)."""

    def inputs(size: int, seed: int) -> List[Any]:
        import jax
        import jax.numpy as jnp

        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        a = jax.random.normal(k1, (size, size), jnp.float32) / np.sqrt(size)
        a = a @ a.T + size * jnp.eye(size, dtype=jnp.float32)  # well-conditioned
        b = jax.random.normal(k2, (size,), jnp.float32)
        return [a, b]

    def via_inverse(a, b):
        import jax.numpy as jnp

        return _jit_thunk(lambda a, b: jnp.linalg.inv(a) @ b, a, b)

    def via_solve(a, b):
        import jax.numpy as jnp

        return _jit_thunk(lambda a, b: jnp.linalg.solve(a, b), a, b)

    def via_cholesky(a, b):
        import jax.scipy
        import jax.numpy as jnp

        def f(a, b):
            l = jnp.linalg.cholesky(a)
            y = jax.scipy.linalg.solve_triangular(l, b, lower=True)
            return jax.scipy.linalg.solve_triangular(l.T, y, lower=False)

        return _jit_thunk(f, a, b)

    variants = (
        ExpressionVariant("solve_inverse", "inv(A)b", 2.0 * n**3 + 2.0 * n * n, via_inverse),
        ExpressionVariant("solve_lu", "solve(A,b)", (2.0 / 3.0) * n**3 + 2.0 * n * n, via_solve),
        ExpressionVariant("solve_chol", "chol-solve", (1.0 / 3.0) * n**3 + 2.0 * n * n, via_cholesky),
    )
    return ExpressionFamily("solve", variants, inputs)


# ------------------------------------------------------------- Bilinear ----

def bilinear_family(n: int) -> ExpressionFamily:
    """``y = uᵀ M v``: row-major vs column-major traversal, equal FLOPs."""

    def inputs(size: int, seed: int) -> List[Any]:
        import jax
        import jax.numpy as jnp

        keys = jax.random.split(jax.random.PRNGKey(seed), 3)
        u = jax.random.normal(keys[0], (size,), jnp.float32)
        m = jax.random.normal(keys[1], (size, size), jnp.float32) / np.sqrt(size)
        v = jax.random.normal(keys[2], (size,), jnp.float32)
        return [u, m, v]

    def left(u, m, v):
        return _jit_thunk(lambda u, m, v: (u @ m) @ v, u, m, v)

    def right(u, m, v):
        return _jit_thunk(lambda u, m, v: u @ (m @ v), u, m, v)

    f = 2.0 * n * n + 2.0 * n
    variants = (
        ExpressionVariant("bilinear_left", "(utM)v", f, left),
        ExpressionVariant("bilinear_right", "ut(Mv)", f, right),
    )
    return ExpressionFamily("bilinear", variants, inputs)


FAMILIES: Dict[str, Callable[..., ExpressionFamily]] = {
    "gram": lambda n=512: gram_family(n, max(1, n // 4)),
    "distributive": lambda n=512: distributive_family(n),
    "solve": lambda n=512: solve_family(n),
    "bilinear": lambda n=1024: bilinear_family(n),
}
