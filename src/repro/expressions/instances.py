"""Named chain instances from the paper + instance generators.

Paper instances of Expression 1 (``X = ABCD``, tuple ``(m, n, k, l, q)``):

* ``ANOMALY_331`` — ``(331, 279, 338, 854, 497)``: observed as an anomaly in
  Lopez et al. (ICPP 2022) and re-examined in Sec. I / Fig. 7b.
* ``FIG3_75`` — ``(75, 75, 8, 75, 75)``: the worked three-class example
  (Fig. 3, Tables II/III).
* ``INSTANCE_A`` — ``(1000, 1000, 500, 1000, 1000)`` (Sec. IV, Fig. 5a).
* ``INSTANCE_B`` — ``(1000, 1000, 1000, 1000, 1000)`` (Sec. IV, Fig. 5b):
  all parenthesizations cost identical FLOPs — the pure equal-FLOPs regime.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .chain import ChainAlgorithm, generate_chain_algorithms

#: The paper prints the anomaly tuple as (331, 279, 338, 854, 497) (Sec. I;
#: Fig. 7b prints 336 for the third entry — the paper is internally
#: inconsistent). Generating the chain with the tuple read directly gives
#: RF = [0, 0, .03, .06, .16, .30], which does NOT match the paper's
#: Table I RF = [0, 0, .04, .11, .27, .32]. An exhaustive search over dim
#: permutations shows the paper's RF values are reproduced *exactly*
#: (error 0.00 on all six values) by the chain dims below — the mirrored
#: reading of the tuple with the trailing pair swapped, i.e. the convention
#: used by Lopez et al. (ICPP 2022) where the instance was first reported.
#: We keep the paper's tuple for reference and generate from the effective
#: dims so Table I/Fig. 7b RF values reproduce exactly.
ANOMALY_331_PAPER_TUPLE: Tuple[int, ...] = (331, 279, 338, 854, 497)
ANOMALY_331: Tuple[int, ...] = (497, 854, 338, 331, 279)
FIG3_75: Tuple[int, ...] = (75, 75, 8, 75, 75)
INSTANCE_A: Tuple[int, ...] = (1000, 1000, 500, 1000, 1000)
INSTANCE_B: Tuple[int, ...] = (1000, 1000, 1000, 1000, 1000)

PAPER_INSTANCES: Dict[str, Tuple[int, ...]] = {
    "anomaly_331": ANOMALY_331,
    "fig3_75": FIG3_75,
    "instance_A": INSTANCE_A,
    "instance_B": INSTANCE_B,
}

#: Scaled-down variants for CI/smoke (same FLOP *ratios*, ~64x less work).
SMOKE_INSTANCES: Dict[str, Tuple[int, ...]] = {
    "anomaly_331": (124, 214, 85, 83, 70),
    "fig3_75": (38, 38, 4, 38, 38),
    "instance_A": (250, 250, 125, 250, 250),
    "instance_B": (250, 250, 250, 250, 250),
}


@dataclass(frozen=True)
class ChainInstance:
    name: str
    dims: Tuple[int, ...]

    @property
    def n_matrices(self) -> int:
        return len(self.dims) - 1

    def algorithms(self) -> List[ChainAlgorithm]:
        return generate_chain_algorithms(self.dims)


def get_instance(name: str, smoke: bool = False) -> ChainInstance:
    table = SMOKE_INSTANCES if smoke else PAPER_INSTANCES
    if name not in table:
        raise KeyError(f"unknown instance {name!r}; known: {sorted(table)}")
    return ChainInstance(name=name, dims=table[name])


def random_instance(
    n_matrices: int = 4,
    lo: int = 50,
    hi: int = 1200,
    seed: int = 0,
) -> ChainInstance:
    """Random chain instance (for anomaly-hunting sweeps)."""
    rng = np.random.default_rng(seed)
    dims = tuple(int(d) for d in rng.integers(lo, hi + 1, size=n_matrices + 1))
    return ChainInstance(name=f"random_{seed}", dims=dims)


def instance_grid(
    n_matrices: int = 4,
    sizes: Sequence[int] = (64, 128, 256),
) -> List[ChainInstance]:
    """Small cartesian grid of instances (benchmark sweeps)."""
    out: List[ChainInstance] = []
    for i, a in enumerate(sizes):
        for j, b in enumerate(sizes):
            dims = tuple(
                a if t % 2 == 0 else b for t in range(n_matrices + 1)
            )
            out.append(ChainInstance(name=f"grid_{a}x{b}", dims=dims))
    return out
