"""Modality frontend stubs (per the brief: ``[audio]``/``[vlm]`` entries
specify the transformer BACKBONE only; the frontend provides precomputed
frame/patch embeddings).

The stubs define the *interface* (shapes/dtypes of the precomputed
embeddings) plus a deterministic synthetic generator so smoke tests and
examples can run end-to-end. ``input_specs`` in the launch layer builds
ShapeDtypeStructs from these for the dry-run.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig


@dataclass(frozen=True)
class VisionStubSpec:
    """LLaVA-NeXT anyres tiling: base 336px grid (24x24 patches = 576) plus
    up to 4 sub-tiles -> <= 2880 patch embeddings per image. The stub hands
    the backbone already-projected patch embeddings [n_patches, d_model]."""

    patches_per_tile: int = 576
    max_tiles: int = 5

    @property
    def max_patches(self) -> int:
        return self.patches_per_tile * self.max_tiles


@dataclass(frozen=True)
class AudioStubSpec:
    """Whisper conv frontend: log-mel [3000, 80] -> two conv1d (stride 1, 2)
    -> 1500 frame embeddings. The stub hands the encoder the 1500 x d_model
    frame embeddings directly."""

    n_frames: int = 1500


def vision_patch_embeds(
    cfg: ModelConfig, batch: int, n_patches: int, seed: int = 0
) -> jax.Array:
    """Synthetic precomputed patch embeddings [b, n_patches, d_model]."""
    key = jax.random.PRNGKey(seed)
    return 0.02 * jax.random.normal(
        key, (batch, n_patches, cfg.d_model), jnp.dtype(cfg.dtype)
    )


def audio_frame_embeds(
    cfg: ModelConfig, batch: int, n_frames: int, seed: int = 0
) -> jax.Array:
    """Synthetic precomputed frame embeddings [b, n_frames, d_model]."""
    key = jax.random.PRNGKey(seed)
    return 0.02 * jax.random.normal(
        key, (batch, n_frames, cfg.d_model), jnp.dtype(cfg.dtype)
    )


def merge_vision_embeds(
    cfg: ModelConfig,
    token_embeds: jax.Array,     # [b, s, d] — text token embeddings
    patch_embeds: jax.Array,     # [b, p, d] — precomputed patch embeddings
    patch_offset: int = 0,
) -> jax.Array:
    """Splice patch embeddings into the token-embedding sequence at a fixed
    offset (static layout: <patches><text>, the common packed-VLM layout)."""
    b, s, d = token_embeds.shape
    p = patch_embeds.shape[1]
    if p > s - patch_offset:
        raise ValueError(f"{p} patches do not fit in seq {s} at offset {patch_offset}")
    return jax.lax.dynamic_update_slice(
        token_embeds, patch_embeds.astype(token_embeds.dtype), (0, patch_offset, 0)
    )
