"""repro.models — composable JAX model stack for all assigned architectures."""

from .attention import (
    attention,
    attention_chunked,
    attention_local_chunked,
    attention_reference,
    decode_attention,
    init_kv_cache,
    update_kv_cache,
)
from .blocks import apply_sublayer, init_unit, init_unit_state
from .config import FFNKind, LayerKind, ModelConfig, SublayerSpec
from .flops import ParamCounts, decode_flops, param_counts, prefill_flops, training_flops
from .frontend import (
    AudioStubSpec,
    VisionStubSpec,
    audio_frame_embeds,
    merge_vision_embeds,
    vision_patch_embeds,
)
from .layers import P, Params, split_params
from .mamba2 import apply_mamba, ssd_chunked, ssd_reference
from .model import (
    ForwardOptions,
    encdec_decode_step,
    encdec_forward,
    encdec_prefill,
    init_encdec_params,
    init_encdec_state,
    init_lm_params,
    init_lm_state,
    lm_decode_step,
    lm_forward,
    lm_prefill,
)
from .moe import apply_moe, moe_dense, moe_gather

__all__ = [
    "AudioStubSpec",
    "FFNKind",
    "ForwardOptions",
    "LayerKind",
    "ModelConfig",
    "P",
    "ParamCounts",
    "Params",
    "SublayerSpec",
    "VisionStubSpec",
    "apply_mamba",
    "apply_moe",
    "apply_sublayer",
    "attention",
    "attention_chunked",
    "attention_local_chunked",
    "attention_reference",
    "audio_frame_embeds",
    "decode_attention",
    "decode_flops",
    "encdec_decode_step",
    "encdec_forward",
    "encdec_prefill",
    "init_encdec_params",
    "init_encdec_state",
    "init_kv_cache",
    "init_lm_params",
    "init_lm_state",
    "init_unit",
    "init_unit_state",
    "lm_decode_step",
    "lm_forward",
    "lm_prefill",
    "merge_vision_embeds",
    "moe_dense",
    "moe_gather",
    "param_counts",
    "prefill_flops",
    "split_params",
    "ssd_chunked",
    "ssd_reference",
    "training_flops",
    "update_kv_cache",
    "vision_patch_embeds",
]
