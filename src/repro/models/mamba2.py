"""Mamba-2 mixer via SSD (state-space duality), chunked for TPU.

The SSD algorithm (Dao & Gu, arXiv:2405.21060) splits the sequence into
chunks of length Q: within a chunk the recurrence is evaluated in its
*dual* quadratic (attention-like) form — dense [Q, Q] einsums that map onto
the MXU — while a single ``lax.scan`` over chunk *states* [h, p, n] carries
the recurrence between chunks. Total cost O(s·Q·p + s·p·n) instead of the
O(s²) of the naive dual form or the s-step scan of the primal form.

TPU adaptation notes (DESIGN.md §2): chunk length is a VMEM/MXU tile choice
(default 256, a multiple of 128); the inter-chunk scan has length s/Q so the
HLO stays small; heads shard over the "model" mesh axis, batch over "data".

The chunk length is *mathematically inert* (any Q gives the same result up
to fp reassociation) — i.e. equal-FLOPs variants, the paper's regime; the
autotuner ranks chunk sizes with the ranking methodology.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import P, Params, normal_init, ones_init, zeros_init, param_dtype


def init_mamba(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = param_dtype(cfg)
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    k = cfg.ssm_conv_kernel
    keys = jax.random.split(key, 10)
    out_std = 0.02 / np.sqrt(2 * cfg.n_layers)
    # dt bias init: softplus^-1 of dt in [1e-3, 1e-1] (mamba2 default range)
    rng = np.random.default_rng(42)
    dt_init = np.exp(
        rng.uniform(np.log(1e-3), np.log(1e-1), size=(h,))
    ).astype(np.float32)
    dt_bias = np.log(np.expm1(dt_init))
    a_init = rng.uniform(1.0, 16.0, size=(h,)).astype(np.float32)
    return {
        "wz": normal_init(keys[0], (d, di), ("embed", "ffn"), dt),
        "wx": normal_init(keys[1], (d, di), ("embed", "ffn"), dt),
        "wB": normal_init(keys[2], (d, g * n), ("embed", None), dt),
        "wC": normal_init(keys[3], (d, g * n), ("embed", None), dt),
        "wdt": normal_init(keys[4], (d, h), ("embed", "heads"), dt),
        "conv_x": normal_init(keys[5], (k, di), (None, "ffn"), dt, 0.1),
        "conv_B": normal_init(keys[6], (k, g * n), (None, None), dt, 0.1),
        "conv_C": normal_init(keys[7], (k, g * n), (None, None), dt, 0.1),
        "A_log": P(jnp.asarray(np.log(a_init)), ("heads",)),
        "D": ones_init((h,), ("heads",), jnp.float32),
        "dt_bias": P(jnp.asarray(dt_bias), ("heads",)),
        "norm": ones_init((di,), ("ffn",), dt),
        "wo": normal_init(keys[8], (di, d), ("ffn", "embed"), dt, out_std),
    }


def _causal_conv(x: jax.Array, w: jax.Array, state: Optional[jax.Array] = None):
    """Depthwise causal conv along seq. x [b, s, c], w [k, c].

    Returns (y [b, s, c], new_state [b, k-1, c]) — state carries the last
    k-1 inputs for decode.
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # [b, s+k-1, c]
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    new_state = xp[:, -(k - 1) :, :]
    return jax.nn.silu(y), new_state


def _segsum_decay(log_a: jax.Array) -> jax.Array:
    """L[i, j] = exp(sum_{j<t<=i} log_a_t) for i >= j, else 0.

    log_a [..., Q, h] -> L [..., h, Q, Q]. Numerically: difference of
    cumulative sums, masked before exp.
    """
    q = log_a.shape[-2]
    cum = jnp.cumsum(log_a, axis=-2)                      # [..., Q, h]
    cum = jnp.moveaxis(cum, -1, -2)                       # [..., h, Q]
    diff = cum[..., :, None] - cum[..., None, :]          # [..., h, Q, Q]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(
    x: jax.Array,       # [b, s, h, p]   (dt-scaled inputs NOT yet applied)
    dt: jax.Array,      # [b, s, h]      (positive step sizes)
    a_log: jax.Array,   # [h]            (A = -exp(a_log))
    b_mat: jax.Array,   # [b, s, g, n]
    c_mat: jax.Array,   # [b, s, g, n]
    chunk: int,
    init_state: Optional[jax.Array] = None,  # [b, h, p, n]
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y [b, s, h, p], final_state [b, h, p, n])."""
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    hg = h // g
    if s % chunk != 0:
        raise ValueError(f"seq {s} % chunk {chunk} != 0")
    nc = s // chunk

    a = -jnp.exp(a_log.astype(jnp.float32))               # [h], negative
    log_da = dt.astype(jnp.float32) * a                    # [b, s, h]
    xbar = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]

    # chunked views
    xc = xbar.reshape(bsz, nc, chunk, h, p)
    dac = log_da.reshape(bsz, nc, chunk, h)
    bc = b_mat.astype(jnp.float32).reshape(bsz, nc, chunk, g, n)
    cc = c_mat.astype(jnp.float32).reshape(bsz, nc, chunk, g, n)

    # ---- intra-chunk (dual quadratic form) ----
    decay = _segsum_decay(dac)                             # [b, nc, h, Q, Q]
    cb = jnp.einsum("bzign,bzjgn->bzgij", cc, bc)          # [b, nc, g, Q, Q]
    cb = jnp.repeat(cb, hg, axis=2)                        # [b, nc, h, Q, Q]
    y_intra = jnp.einsum("bzhij,bzjhp->bzihp", cb * decay, xc)

    # ---- per-chunk state contribution ----
    cum = jnp.cumsum(dac, axis=2)                          # [b, nc, Q, h]
    total = cum[:, :, -1:, :]                              # [b, nc, 1, h]
    decay_to_end = jnp.exp(total - cum)                    # [b, nc, Q, h]
    # state_k = sum_j exp(sum_{j<t<=Q} log_da_t) * xbar_j ⊗ B_j
    if g == 1:
        s_chunk = jnp.einsum(
            "bzjh,bzjhp,bzjn->bzhpn", decay_to_end, xc, bc[:, :, :, 0, :]
        )
    else:
        bfull = jnp.repeat(bc, hg, axis=3)                 # [b, nc, Q, h, n]
        s_chunk = jnp.einsum("bzjh,bzjhp,bzjhn->bzhpn", decay_to_end, xc, bfull)

    # ---- inter-chunk recurrence over states ----
    chunk_decay = jnp.exp(total[:, :, 0, :])               # [b, nc, h]
    s0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((bsz, h, p, n), jnp.float32)
    )

    def step(state, inp):
        cd, sc = inp                                       # [b,h], [b,h,p,n]
        prev = state
        state = state * cd[..., None, None] + sc
        return state, prev

    states_seq = (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(s_chunk, 1, 0))
    final_state, prev_states = jax.lax.scan(step, s0, states_seq)
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # [b, nc, h, p, n]

    # ---- inter-chunk contribution ----
    decay_from_start = jnp.exp(cum)                        # [b, nc, Q, h]
    if g == 1:
        y_inter = jnp.einsum(
            "bzin,bzih,bzhpn->bzihp",
            cc[:, :, :, 0, :],
            decay_from_start,
            prev_states,
        )
    else:
        cfull = jnp.repeat(cc, hg, axis=3)                 # [b, nc, Q, h, n]
        y_inter = jnp.einsum(
            "bzihn,bzih,bzhpn->bzihp", cfull, decay_from_start, prev_states
        )

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y.astype(x.dtype), final_state


def ssd_reference(
    x: jax.Array, dt: jax.Array, a_log: jax.Array,
    b_mat: jax.Array, c_mat: jax.Array,
    init_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Sequential (primal) scan oracle — one step per token."""
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    hg = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))

    def step(state, inp):
        xt, dtt, bt, ct = inp  # [b,h,p], [b,h], [b,g,n], [b,g,n]
        da = jnp.exp(dtt * a[None])                        # [b, h]
        bt_h = jnp.repeat(bt, hg, axis=1)                  # [b, h, n]
        ct_h = jnp.repeat(ct, hg, axis=1)
        state = state * da[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", xt * dtt[..., None], bt_h
        )
        y = jnp.einsum("bhpn,bhn->bhp", state, ct_h)
        return state, y

    s0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((bsz, h, p, n), jnp.float32)
    )
    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(b_mat.astype(jnp.float32), 1, 0),
        jnp.moveaxis(c_mat.astype(jnp.float32), 1, 0),
    )
    final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


def apply_mamba(
    cfg: ModelConfig,
    params: Params,
    xin: jax.Array,               # [b, s, d]
    ssm_state: Optional[jax.Array] = None,
    conv_state: Optional[Dict[str, jax.Array]] = None,
    impl: str = "chunked",
) -> Tuple[jax.Array, jax.Array, Dict[str, jax.Array]]:
    """Full Mamba-2 mixer. Returns (y [b,s,d], ssm_state, conv_state)."""
    b, s, d = xin.shape
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_groups, cfg.ssm_state

    z = jnp.einsum("bsd,di->bsi", xin, params["wz"].astype(xin.dtype))
    xr = jnp.einsum("bsd,di->bsi", xin, params["wx"].astype(xin.dtype))
    br = jnp.einsum("bsd,dn->bsn", xin, params["wB"].astype(xin.dtype))
    cr = jnp.einsum("bsd,dn->bsn", xin, params["wC"].astype(xin.dtype))
    dt_raw = jnp.einsum("bsd,dh->bsh", xin, params["wdt"].astype(xin.dtype))

    cs_in = conv_state or {}
    xr, cs_x = _causal_conv(xr, params["conv_x"].astype(xin.dtype), cs_in.get("x"))
    br, cs_b = _causal_conv(br, params["conv_B"].astype(xin.dtype), cs_in.get("B"))
    cr, cs_c = _causal_conv(cr, params["conv_C"].astype(xin.dtype), cs_in.get("C"))
    new_conv_state = {"x": cs_x, "B": cs_b, "C": cs_c}

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )
    xh = xr.reshape(b, s, h, p)
    bm = br.reshape(b, s, g, n)
    cm = cr.reshape(b, s, g, n)

    if impl == "chunked" and s > 1:
        chunk = min(cfg.ssm_chunk, s)
        if s % chunk != 0:
            chunk = 1 << int(np.floor(np.log2(s)))
            chunk = max(1, min(chunk, s))
            while s % chunk != 0:
                chunk //= 2
        y, final_state = ssd_chunked(xh, dt, params["A_log"], bm, cm, chunk, ssm_state)
    else:
        y, final_state = ssd_reference(xh, dt, params["A_log"], bm, cm, ssm_state)

    # skip connection D, gate, norm, out-projection
    y = y + xh.astype(y.dtype) * params["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(b, s, cfg.d_inner)
    y = y * jax.nn.silu(z.astype(y.dtype))
    yf = y.astype(jnp.float32)
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(ms + cfg.norm_eps) * params["norm"].astype(jnp.float32)
    y = yf.astype(xin.dtype)
    out = jnp.einsum("bsi,id->bsd", y, params["wo"].astype(xin.dtype))
    return out, final_state, new_conv_state
