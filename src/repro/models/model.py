"""Model assembly: decoder LMs (dense/MoE/hybrid/SSM) and encoder-decoder.

The layer stack is a ``lax.scan`` over *stacked unit parameters* (leading
axis ``n_units``), so the lowered HLO contains one unit body regardless of
depth — essential for 512-device AOT compile times. Remat (activation
checkpointing) wraps the unit body with a configurable policy.

Public entry points (pure functions over param pytrees):

* ``init_lm_params`` / ``lm_forward``        — training / scoring forward
* ``init_lm_state`` / ``lm_prefill`` / ``lm_decode_step`` — serving
* ``init_encdec_params`` / ``encdec_forward`` / ``encdec_prefill`` /
  ``encdec_decode_step``                      — whisper-style enc-dec
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (
    attention_reference,
    decode_attention,
    init_attention,
    init_kv_cache,
    project_out,
    project_qkv,
    update_kv_cache,
)
from .blocks import apply_sublayer, init_unit, init_unit_state
from .config import FFNKind, LayerKind, ModelConfig, SublayerSpec
from .layers import (
    P,
    Params,
    apply_mlp,
    apply_norm,
    embed_tokens,
    init_embedding,
    init_mlp,
    init_norm,
    init_unembed,
    normal_init,
    param_dtype,
    split_params,
    unembed,
)

REMAT_POLICIES = {
    "none": None,
    "full": None,  # jax.checkpoint default: save nothing
    "dots": "dots",
    "dots_no_batch": "dots_no_batch",
}


def _remat_policy(name: str):
    if name in ("none", "full"):
        return None
    if name == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    if name == "dots_no_batch":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    raise ValueError(f"unknown remat policy {name!r}")


class ForwardOptions(NamedTuple):
    attn_impl: str = "auto"         # auto | reference | chunked
    moe_dispatch: str = "gather"    # gather | dense
    mamba_impl: str = "chunked"     # chunked | reference
    remat: str = "none"             # none | full | dots | dots_no_batch
    # GQA contraction order: "grouped" keeps K/V at kv-head granularity
    # (valid sharding when kv_heads % tp == 0); "broadcast" repeats K/V to
    # H query heads (the TP-correct form when KV is replicated — equal
    # FLOPs, more memory traffic: the paper's equal-FLOPs variant regime).
    gqa_mode: str = "grouped"
    # Megatron-SP: the scan carry (residual stream at unit boundaries) is
    # sequence-sharded over 'model' so remat-saved activations divide by tp;
    # the unit interior re-gathers (the AG/RS pair replaces the classic
    # per-sublayer all-reduce). None = let GSPMD propagate.
    boundary_sharding: Optional[Any] = None   # e.g. [b(dp), s(model), d]
    interior_sharding: Optional[Any] = None   # e.g. [b(dp), s, d]
    # Attention-core resharding for archs whose heads don't divide tp:
    # sequence-shard the QUERIES over 'model' (scores [b, H, sq/tp, skv])
    # with K/V replicated — head-count-agnostic attention parallelism.
    attn_q_sharding: Optional[Any] = None     # [b, s, heads, hd] for q + out
    attn_kv_sharding: Optional[Any] = None    # [b, s, kv_heads, hd] for k/v
    # kv-only chunking (q unchunked) for seq-sharded prefill: q_block == -1
    attn_q_block: int = 0                     # 0 = impl default
    # Compute-time expert-weight shardings (ZeRO-3 gather-at-use pin):
    # dict {wi, wg, wo} -> NamedSharding, or None.
    moe_compute_shardings: Optional[Any] = None


def _constrain(x: jax.Array, sharding: Optional[Any]) -> jax.Array:
    if sharding is not None:
        return jax.lax.with_sharding_constraint(x, sharding)
    return x


# ------------------------------------------------------------------ init ---

def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def init_lm_params(cfg: ModelConfig, key: jax.Array) -> Tuple[Params, Any]:
    """(values, axes): embedding + stacked units + final norm (+ lm head).

    Stacked unit leaves get a leading ``layers`` logical axis (the scan dim,
    never mesh-sharded).
    """
    cfg.validate()
    k_embed, k_units, k_head = jax.random.split(key, 3)
    unit_keys = jax.random.split(k_units, cfg.n_units)

    units_p = [init_unit(cfg, uk) for uk in unit_keys]
    split_units = [split_params(u) for u in units_p]
    unit_values = _stack_trees([v for v, _ in split_units])
    axes0 = split_units[0][1]
    unit_axes = jax.tree.map(
        lambda a: ("layers",) + tuple(a),
        axes0,
        is_leaf=lambda x: isinstance(x, tuple),
    )

    embed_v, embed_a = split_params(init_embedding(cfg, k_embed))
    norm_v, norm_a = split_params(init_norm(cfg, cfg.d_model))

    values: Params = {"embed": embed_v, "units": unit_values, "final_norm": norm_v}
    axes: Params = {"embed": embed_a, "units": unit_axes, "final_norm": norm_a}

    head_p = init_unembed(cfg, k_head)
    if head_p is not None:
        head_v, head_a = split_params(head_p)
        values["lm_head"] = head_v
        axes["lm_head"] = head_a
    return values, axes


# -------------------------------------------------------------- forward ---

def lm_forward(
    cfg: ModelConfig,
    params: Params,
    tokens: Optional[jax.Array] = None,      # [b, s] int32
    embeds: Optional[jax.Array] = None,      # [b, s, d] (VLM/audio stubs)
    opts: ForwardOptions = ForwardOptions(),
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits [b, s, vocab] f32, moe_aux)."""
    unit = cfg.pattern_unit()
    if embeds is None:
        assert tokens is not None
        x = embed_tokens(cfg, params["embed"], tokens)
    else:
        x = embeds.astype(jnp.dtype(cfg.dtype))
    positions = jnp.arange(x.shape[1])

    def unit_body(x, unit_params):
        aux = jnp.zeros((), jnp.float32)
        # Pin the checkpoint-saved input's sharding BEFORE the interior
        # gather — otherwise GSPMD propagates the gathered sharding onto the
        # remat-saved carry stack (verified: 16x activation-memory blowup).
        x = _constrain(x, opts.boundary_sharding)
        x = _constrain(x, opts.interior_sharding)
        for i, spec in enumerate(unit):
            x, _, a = apply_sublayer(
                cfg, unit_params[f"sub{i}"], spec, x,
                mode="train",
                positions=positions,
                opts=opts,
            )
            aux = aux + a
        return _constrain(x, opts.boundary_sharding), aux

    if opts.remat != "none":
        unit_body = jax.checkpoint(unit_body, policy=_remat_policy(opts.remat))

    x = _constrain(x, opts.boundary_sharding)
    x, auxes = jax.lax.scan(unit_body, x, params["units"])
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], params.get("lm_head"), x)
    return logits, jnp.sum(auxes)


# --------------------------------------------------------------- serving ---

def init_lm_state(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Stacked decode state: one unit state replicated to n_units."""
    unit_state = init_unit_state(cfg, batch, max_len, jnp.dtype(cfg.dtype))
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_units,) + x.shape),
        unit_state,
    )


def lm_prefill(
    cfg: ModelConfig,
    params: Params,
    state: Dict[str, Any],
    tokens: Optional[jax.Array] = None,
    embeds: Optional[jax.Array] = None,
    opts: ForwardOptions = ForwardOptions(),
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Populate the cache from a prompt (cache_len 0 at entry).

    Returns (last-token logits [b, vocab] f32, new state).
    """
    unit = cfg.pattern_unit()
    if embeds is None:
        x = embed_tokens(cfg, params["embed"], tokens)
    else:
        x = embeds.astype(jnp.dtype(cfg.dtype))
    positions = jnp.arange(x.shape[1])

    def scan_step(x, unit_in):
        unit_params, unit_state = unit_in
        x = _constrain(x, opts.interior_sharding)
        new_state = {}
        for i, spec in enumerate(unit):
            x, sub_state, _ = apply_sublayer(
                cfg, unit_params[f"sub{i}"], spec, x,
                mode="prefill",
                positions=positions,
                state=unit_state[f"sub{i}"],
                opts=opts,
            )
            new_state[f"sub{i}"] = sub_state
        return _constrain(x, opts.boundary_sharding), new_state

    x, new_states = jax.lax.scan(scan_step, x, (params["units"], state))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], params.get("lm_head"), x[:, -1:, :])
    return logits[:, 0, :], new_states


def lm_decode_step(
    cfg: ModelConfig,
    params: Params,
    state: Dict[str, Any],
    tokens: jax.Array,          # [b, 1] int32 — the newest token
    cache_len: jax.Array,       # scalar int32 — tokens already in cache
    opts: ForwardOptions = ForwardOptions(),
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One serving step: returns (logits [b, vocab] f32, new state)."""
    unit = cfg.pattern_unit()
    x = embed_tokens(cfg, params["embed"], tokens)

    def scan_step(x, unit_in):
        unit_params, unit_state = unit_in
        new_state = {}
        for i, spec in enumerate(unit):
            x, sub_state, _ = apply_sublayer(
                cfg, unit_params[f"sub{i}"], spec, x,
                mode="decode",
                state=unit_state[f"sub{i}"],
                cache_len=cache_len,
                opts=opts,
            )
            new_state[f"sub{i}"] = sub_state
        return x, new_state

    x, new_states = jax.lax.scan(scan_step, x, (params["units"], state))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], params.get("lm_head"), x)
    return logits[:, 0, :], new_states


# ------------------------------------------------------- encoder-decoder ---

def init_encdec_params(cfg: ModelConfig, key: jax.Array) -> Tuple[Params, Any]:
    """Whisper-style: encoder stack (bidirectional) + decoder stack with
    cross-attention. The encoder consumes precomputed frame embeddings
    (conv frontend is a stub per the brief)."""
    cfg.validate()
    keys = jax.random.split(key, 8)

    # Encoder: plain attention+MLP sublayers, bidirectional.
    enc_layers = [
        {
            "attn_norm": init_norm(cfg, cfg.d_model),
            "attn": init_attention(cfg, jax.random.fold_in(keys[0], i)),
            "ffn_norm": init_norm(cfg, cfg.d_model),
            "mlp": init_mlp(cfg, jax.random.fold_in(keys[1], i)),
        }
        for i in range(cfg.n_encoder_layers)
    ]
    enc_split = [split_params(l) for l in enc_layers]
    enc_values = _stack_trees([v for v, _ in enc_split])
    enc_axes = jax.tree.map(
        lambda a: ("layers",) + tuple(a), enc_split[0][1],
        is_leaf=lambda x: isinstance(x, tuple),
    )

    # Decoder: self-attn + cross-attn + MLP.
    dec_layers = [
        {
            "self_norm": init_norm(cfg, cfg.d_model),
            "self_attn": init_attention(cfg, jax.random.fold_in(keys[2], i)),
            "cross_norm": init_norm(cfg, cfg.d_model),
            "cross_attn": init_attention(cfg, jax.random.fold_in(keys[3], i)),
            "ffn_norm": init_norm(cfg, cfg.d_model),
            "mlp": init_mlp(cfg, jax.random.fold_in(keys[4], i)),
        }
        for i in range(cfg.n_layers)
    ]
    dec_split = [split_params(l) for l in dec_layers]
    dec_values = _stack_trees([v for v, _ in dec_split])
    dec_axes = jax.tree.map(
        lambda a: ("layers",) + tuple(a), dec_split[0][1],
        is_leaf=lambda x: isinstance(x, tuple),
    )

    embed_v, embed_a = split_params(init_embedding(cfg, keys[5]))
    pos_v, pos_a = split_params(
        {
            "enc": normal_init(keys[6], (cfg.encoder_seq, cfg.d_model), (None, "embed"), param_dtype(cfg)),
        }
    )
    enorm_v, enorm_a = split_params(init_norm(cfg, cfg.d_model))
    dnorm_v, dnorm_a = split_params(init_norm(cfg, cfg.d_model))

    values = {
        "embed": embed_v,
        "pos": pos_v,
        "encoder": enc_values,
        "enc_norm": enorm_v,
        "decoder": dec_values,
        "final_norm": dnorm_v,
    }
    axes = {
        "embed": embed_a,
        "pos": pos_a,
        "encoder": enc_axes,
        "enc_norm": enorm_a,
        "decoder": dec_axes,
        "final_norm": dnorm_a,
    }
    return values, axes


def _encode(
    cfg: ModelConfig, params: Params, enc_embeds: jax.Array,
    opts: "ForwardOptions" = None,
) -> jax.Array:
    """Encoder forward on precomputed frame embeddings [b, s_enc, d]."""
    x = enc_embeds.astype(jnp.dtype(cfg.dtype))
    s = x.shape[1]
    pos = params["pos"]["enc"][:s].astype(x.dtype)
    x = x + pos[None]
    positions = jnp.arange(s)

    def enc_step(x, layer):
        h = apply_norm(cfg, layer["attn_norm"], x)
        q, k, v = project_qkv(cfg, layer["attn"], h, positions)
        o = attention_reference(q, k, v, causal=False)
        x = x + project_out(layer["attn"], o)
        hf = apply_norm(cfg, layer["ffn_norm"], x)
        x = x + apply_mlp(cfg, layer["mlp"], hf)
        return x, None

    if opts is not None and opts.remat != "none":
        enc_step = jax.checkpoint(enc_step, policy=_remat_policy(opts.remat))
    x, _ = jax.lax.scan(enc_step, x, params["encoder"])
    return apply_norm(cfg, params["enc_norm"], x)


def _cross_attend(
    cfg: ModelConfig, layer: Params, x: jax.Array, enc_out: jax.Array,
    positions: jax.Array,
) -> jax.Array:
    h = apply_norm(cfg, layer["cross_norm"], x)
    # queries from decoder; keys/values from encoder output (no RoPE on k).
    q = jnp.einsum("bsd,dhk->bshk", h, layer["cross_attn"]["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dhk->bshk", enc_out, layer["cross_attn"]["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, layer["cross_attn"]["wv"].astype(h.dtype))
    o = attention_reference(q, k, v, causal=False)
    return x + project_out(layer["cross_attn"], o)


def encdec_forward(
    cfg: ModelConfig,
    params: Params,
    enc_embeds: jax.Array,       # [b, s_enc, d] precomputed frame embeddings
    dec_tokens: jax.Array,       # [b, s_dec]
    opts: ForwardOptions = ForwardOptions(),
) -> Tuple[jax.Array, jax.Array]:
    """Training forward. Returns (logits [b, s_dec, vocab] f32, aux=0)."""
    enc_out = _encode(cfg, params, enc_embeds, opts)
    x = embed_tokens(cfg, params["embed"], dec_tokens)
    positions = jnp.arange(x.shape[1])

    def dec_step(x, layer):
        h = apply_norm(cfg, layer["self_norm"], x)
        q, k, v = project_qkv(cfg, layer["self_attn"], h, positions)
        o = attention_reference(q, k, v, causal=True)
        x = x + project_out(layer["self_attn"], o)
        x = _cross_attend(cfg, layer, x, enc_out, positions)
        hf = apply_norm(cfg, layer["ffn_norm"], x)
        x = x + apply_mlp(cfg, layer["mlp"], hf)
        return x, None

    if opts.remat != "none":
        dec_step = jax.checkpoint(dec_step, policy=_remat_policy(opts.remat))
    x, _ = jax.lax.scan(dec_step, x, params["decoder"])
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], None, x)
    return logits, jnp.zeros((), jnp.float32)


def init_encdec_state(
    cfg: ModelConfig, batch: int, max_len: int, s_enc: int
) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    return {
        "self_kv": jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape),
            init_kv_cache(batch, max_len, cfg.n_kv_heads, hd, dt),
        ),
        # cross K/V computed once at prefill: [L, b, s_enc, K, hd]
        "cross_k": jnp.zeros((cfg.n_layers, batch, s_enc, cfg.n_kv_heads, hd), dt),
        "cross_v": jnp.zeros((cfg.n_layers, batch, s_enc, cfg.n_kv_heads, hd), dt),
    }


def encdec_prefill(
    cfg: ModelConfig,
    params: Params,
    state: Dict[str, Any],
    enc_embeds: jax.Array,
    opts: ForwardOptions = ForwardOptions(),
) -> Dict[str, Any]:
    """Run the encoder and precompute per-layer cross K/V."""
    enc_out = _encode(cfg, params, enc_embeds)

    def layer_kv(_, layer):
        k = jnp.einsum(
            "bsd,dhk->bshk", enc_out, layer["cross_attn"]["wk"].astype(enc_out.dtype)
        )
        v = jnp.einsum(
            "bsd,dhk->bshk", enc_out, layer["cross_attn"]["wv"].astype(enc_out.dtype)
        )
        return None, (k, v)

    _, (ck, cv) = jax.lax.scan(layer_kv, None, params["decoder"])
    return {**state, "cross_k": ck, "cross_v": cv}


def encdec_decode_step(
    cfg: ModelConfig,
    params: Params,
    state: Dict[str, Any],
    tokens: jax.Array,          # [b, 1]
    cache_len: jax.Array,
    opts: ForwardOptions = ForwardOptions(),
) -> Tuple[jax.Array, Dict[str, Any]]:
    x = embed_tokens(cfg, params["embed"], tokens)
    s_enc = state["cross_k"].shape[2]

    def dec_step(x, layer_in):
        layer, kv, ck, cv = layer_in
        h = apply_norm(cfg, layer["self_norm"], x)
        positions = jnp.reshape(cache_len, (1,))
        q, k, v = project_qkv(cfg, layer["self_attn"], h, positions)
        kv = update_kv_cache(kv, k, v, cache_len)
        o = decode_attention(q, kv["k"], kv["v"], cache_len + 1)
        x = x + project_out(layer["self_attn"], o)
        # cross attention over the (fixed) encoder output
        hc = apply_norm(cfg, layer["cross_norm"], x)
        qc = jnp.einsum("bsd,dhk->bshk", hc, layer["cross_attn"]["wq"].astype(hc.dtype))
        oc = decode_attention(qc, ck, cv, jnp.int32(s_enc))
        x = x + project_out(layer["cross_attn"], oc)
        hf = apply_norm(cfg, layer["ffn_norm"], x)
        x = x + apply_mlp(cfg, layer["mlp"], hf)
        return x, kv

    x, new_kv = jax.lax.scan(
        dec_step,
        x,
        (params["decoder"], state["self_kv"], state["cross_k"], state["cross_v"]),
    )
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params["embed"], None, x)
    return logits[:, 0, :], {**state, "self_kv": new_kv}
