"""Analytic parameter counts and MODEL_FLOPS (the roofline numerator).

Conventions (PaLM-appendix style):
* matmul-parameter FLOPs: 6·N_active per trained token (2 fwd + 4 bwd),
  2·N_active per decoded token (fwd only). Embedding *lookup* is a gather
  (0 FLOPs); the unembed projection is a matmul and is counted.
* attention-score FLOPs (not in N): per token per attention layer,
  fwd = 4·s_ctx·H·hd (QKᵀ + PV), bwd = 2×fwd. Causal full attention uses
  s_ctx = (s+1)/2; windowed layers use min(window, ·); decode uses the
  actual cache length.
* SSD (Mamba-2) sequence-mix FLOPs per token: 2·Q·(g·n + h·p) intra-chunk
  + 4·h·p·n inter-chunk state ops (fwd; ×3 for training).

``MODEL_FLOPS / HLO_FLOPs`` per cell is reported in EXPERIMENTS.md §Roofline
— it exposes remat recompute, masked-block waste and dispatch overheads.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from .config import FFNKind, LayerKind, ModelConfig


@dataclass(frozen=True)
class ParamCounts:
    total: int            # all parameters
    active: int           # per-token active (MoE: top-k routed + shared)
    embedding: int        # embedding (+untied head) parameters
    matmul_active: int    # active params participating in per-token matmuls
                          # (includes unembed; excludes gather-only embedding)


def _attn_params(cfg: ModelConfig) -> int:
    hd = cfg.resolved_head_dim
    p = cfg.d_model * cfg.n_heads * hd          # q
    p += 2 * cfg.d_model * cfg.n_kv_heads * hd  # k, v
    p += cfg.n_heads * hd * cfg.d_model         # o
    if cfg.qk_norm:
        p += 2 * hd
    return p


def _mlp_params(cfg: ModelConfig, d_ff: int) -> int:
    mult = 3 if cfg.activation in ("swiglu", "geglu") else 2
    return mult * cfg.d_model * d_ff


def _moe_params(cfg: ModelConfig) -> Dict[str, int]:
    f = cfg.resolved_moe_d_ff
    routed_each = _mlp_params(cfg, f)
    shared = 0
    if cfg.n_shared_experts > 0:
        shared = _mlp_params(cfg, cfg.resolved_shared_d_ff) + cfg.d_model
    router = cfg.d_model * cfg.n_experts
    total = router + cfg.n_experts * routed_each + shared
    active = router + cfg.top_k * routed_each + shared
    return {"total": total, "active": active}


def _mamba_params(cfg: ModelConfig) -> int:
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    k = cfg.ssm_conv_kernel
    p = 2 * d * di            # wz, wx
    p += 2 * d * g * n        # wB, wC
    p += d * h                # wdt
    p += k * (di + 2 * g * n)  # convs
    p += 3 * h                # A_log, D, dt_bias
    p += di                   # gated norm
    p += di * d               # out proj
    return p


def param_counts(cfg: ModelConfig) -> ParamCounts:
    embed = cfg.vocab_size * cfg.d_model
    if not cfg.tie_embeddings:
        embed += cfg.d_model * cfg.vocab_size

    total = 0
    active = 0
    for spec in cfg.pattern_unit():
        if spec.kind in (LayerKind.ATTN, LayerKind.ATTN_LOCAL):
            a = _attn_params(cfg)
            total += a
            active += a
        else:
            m = _mamba_params(cfg)
            total += m
            active += m
        if spec.ffn is FFNKind.MOE:
            moe = _moe_params(cfg)
            total += moe["total"]
            active += moe["active"]
        elif cfg.d_ff > 0:
            mp = _mlp_params(cfg, cfg.d_ff)
            total += mp
            active += mp
    total *= cfg.n_units
    active *= cfg.n_units

    if cfg.is_encoder_decoder:
        enc = cfg.n_encoder_layers * (_attn_params(cfg) + _mlp_params(cfg, cfg.d_ff))
        cross = cfg.n_layers * _attn_params(cfg)
        total += enc + cross
        active += enc + cross

    # unembed matmul params (tied weights still do the matmul)
    unembed = cfg.d_model * cfg.vocab_size
    matmul_active = active + unembed

    return ParamCounts(
        total=total + embed,
        active=active + embed,
        embedding=embed,
        matmul_active=matmul_active,
    )


def _attn_layer_count(cfg: ModelConfig) -> Dict[str, int]:
    full = local = mamba = 0
    for spec in cfg.pattern_unit():
        if spec.kind is LayerKind.ATTN:
            full += 1
        elif spec.kind is LayerKind.ATTN_LOCAL:
            local += 1
        else:
            mamba += 1
    return {
        "full": full * cfg.n_units,
        "local": local * cfg.n_units,
        "mamba": mamba * cfg.n_units,
    }


def _seq_mix_flops_per_token(cfg: ModelConfig, s_ctx_full: float, s_ctx_local: float) -> float:
    """Forward sequence-mixing FLOPs per token across all layers."""
    counts = _attn_layer_count(cfg)
    hd = cfg.resolved_head_dim
    per_full = 4.0 * s_ctx_full * cfg.n_heads * hd
    per_local = 4.0 * s_ctx_local * cfg.n_heads * hd
    f = counts["full"] * per_full + counts["local"] * per_local
    if counts["mamba"]:
        q = cfg.ssm_chunk
        g, n = cfg.ssm_groups, cfg.ssm_state
        h, p = cfg.ssm_heads, cfg.ssm_head_dim
        per_mamba = 2.0 * q * (g * n + h * p) + 4.0 * h * p * n
        f += counts["mamba"] * per_mamba
    if cfg.is_encoder_decoder:
        # decoder cross-attention + encoder self-attention (bidirectional)
        f += cfg.n_layers * 4.0 * cfg.encoder_seq * cfg.n_heads * hd
        # encoder tokens aren't the denominating tokens; fold per dec token:
        f += cfg.n_encoder_layers * 4.0 * cfg.encoder_seq * cfg.n_heads * hd
    return f


def training_flops(cfg: ModelConfig, batch: int, seq: int) -> float:
    """MODEL_FLOPS for one training step over batch x seq tokens."""
    pc = param_counts(cfg)
    tokens = batch * seq
    s_full = (seq + 1) / 2.0
    s_local = min(cfg.sliding_window or seq, seq) if cfg.sliding_window else s_full
    s_local = min(s_local, s_full) if cfg.sliding_window else s_full
    mix_fwd = _seq_mix_flops_per_token(cfg, s_full, s_local)
    return tokens * (6.0 * pc.matmul_active + 3.0 * mix_fwd)


def decode_flops(cfg: ModelConfig, batch: int, kv_len: int) -> float:
    """MODEL_FLOPS for one decode step (one new token per sequence)."""
    pc = param_counts(cfg)
    s_local = min(cfg.sliding_window or kv_len, kv_len)
    mix_fwd = _seq_mix_flops_per_token(cfg, float(kv_len), float(s_local))
    return batch * (2.0 * pc.matmul_active + mix_fwd)


def prefill_flops(cfg: ModelConfig, batch: int, seq: int) -> float:
    """MODEL_FLOPS for a prefill pass (forward only)."""
    pc = param_counts(cfg)
    tokens = batch * seq
    s_full = (seq + 1) / 2.0
    s_local = min(cfg.sliding_window or seq, seq) if cfg.sliding_window else s_full
    mix_fwd = _seq_mix_flops_per_token(cfg, s_full, min(s_local, s_full))
    return tokens * (2.0 * pc.matmul_active + mix_fwd)
