"""Transformer / Mamba blocks and the repeating pattern unit.

A *unit* is the smallest repeating group of sublayers (see
``ModelConfig.pattern_unit``); the model scans over stacked unit parameters.
Every block is a pure function; serving modes thread a state pytree
(KV caches / SSM states):

* mode="train"    — full sequence, no state.
* mode="prefill"  — full sequence, writes K/V + final SSM states into state.
* mode="decode"   — single token, reads+updates state.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (
    attention,
    attention_reference,
    decode_attention,
    init_attention,
    init_kv_cache,
    project_out,
    project_qkv,
    update_kv_cache,
)
from .config import FFNKind, LayerKind, ModelConfig, SublayerSpec
from .layers import Params, apply_mlp, apply_norm, init_mlp, init_norm
from .mamba2 import apply_mamba, init_mamba
from .moe import apply_moe, init_moe

BlockState = Optional[Dict[str, Any]]


# ------------------------------------------------------------------ init ---

def init_sublayer(cfg: ModelConfig, key: jax.Array, spec: SublayerSpec) -> Params:
    keys = jax.random.split(key, 6)
    params: Params = {}
    if spec.kind in (LayerKind.ATTN, LayerKind.ATTN_LOCAL):
        params["attn_norm"] = init_norm(cfg, cfg.d_model)
        params["attn"] = init_attention(cfg, keys[0])
        if cfg.post_sublayer_norm:
            params["attn_post_norm"] = init_norm(cfg, cfg.d_model)
    else:  # MAMBA
        params["mamba_norm"] = init_norm(cfg, cfg.d_model)
        params["mamba"] = init_mamba(cfg, keys[1])

    has_ffn = spec.ffn is FFNKind.MOE or cfg.d_ff > 0
    if has_ffn and not cfg.parallel_block:
        params["ffn_norm"] = init_norm(cfg, cfg.d_model)
    if has_ffn:
        if spec.ffn is FFNKind.MOE:
            params["moe"] = init_moe(cfg, keys[2])
        else:
            params["mlp"] = init_mlp(cfg, keys[3])
        if cfg.post_sublayer_norm:
            params["ffn_post_norm"] = init_norm(cfg, cfg.d_model)
    return params


def init_unit(cfg: ModelConfig, key: jax.Array) -> Params:
    unit = cfg.pattern_unit()
    keys = jax.random.split(key, len(unit))
    return {f"sub{i}": init_sublayer(cfg, keys[i], spec) for i, spec in enumerate(unit)}


# ------------------------------------------------------------ attention ----

def _constrain(x, sharding):
    if sharding is not None:
        return jax.lax.with_sharding_constraint(x, sharding)
    return x


def _attn_full(
    cfg: ModelConfig,
    params: Params,
    x: jax.Array,
    positions: jax.Array,
    local: bool,
    causal: bool,
    opts,
    kv_out: Optional[Dict[str, jax.Array]],
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full-sequence attention; optionally writes the cache (prefill)."""
    impl = opts.attn_impl
    q, k, v = project_qkv(cfg, params, x, positions)
    new_cache = None
    if kv_out is not None:
        s = k.shape[1]
        s_len = kv_out["k"].shape[1]
        if s_len >= s:
            new_cache = update_kv_cache(kv_out, k, v, jnp.int32(0))
        else:
            # Ring cache (windowed layer): keep the last s_len positions at
            # their ring slots (position p -> slot p % s_len). The block of
            # trailing positions wraps once; both segment starts are static.
            start = s % s_len
            seg1 = s_len - start
            k_last, v_last = k[:, -s_len:], v[:, -s_len:]
            new_cache = update_kv_cache(
                kv_out, k_last[:, :seg1], v_last[:, :seg1], jnp.int32(start)
            )
            if start > 0:
                new_cache = update_kv_cache(
                    new_cache, k_last[:, seg1:], v_last[:, seg1:], jnp.int32(0)
                )
    if getattr(opts, "gqa_mode", "grouped") == "broadcast" and k.shape[2] != q.shape[2]:
        # TP-correct GQA when KV is replicated but q heads are sharded:
        # repeat K/V to H so no [K, g] reshape crosses the sharded head dim.
        g = q.shape[2] // k.shape[2]
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    q_sh = getattr(opts, "attn_q_sharding", None)
    kv_sh = getattr(opts, "attn_kv_sharding", None)
    q = _constrain(q, q_sh)
    k = _constrain(k, kv_sh)
    v = _constrain(v, kv_sh)
    qb = getattr(opts, "attn_q_block", 0)
    if causal:
        o = attention(
            cfg, q, k, v, local=local, impl=impl,
            q_block=(q.shape[1] if qb == -1 else (qb or 512)),
        )
    else:
        o = attention_reference(
            q, k, v, causal=False,
            window=cfg.sliding_window if local else None,
            logit_cap=cfg.attn_logit_softcap,
        )
    o = _constrain(o, q_sh)
    return project_out(params, o), new_cache


def _attn_decode(
    cfg: ModelConfig,
    params: Params,
    x: jax.Array,
    cache: Dict[str, jax.Array],
    cache_len: jax.Array,
    local: bool,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    positions = jnp.reshape(cache_len, (1,))  # new token at index cache_len
    q, k, v = project_qkv(cfg, params, x, positions)
    s_len = cache["k"].shape[1]
    kv_positions = None
    if local and cfg.sliding_window:
        # Ring buffer: windowed layers allocate only ~window slots. Slot i
        # holds absolute position p = cache_len - ((cache_len - i) mod S)
        # (negative => unwritten). K is RoPE'd at its absolute position
        # before the write, so only the mask needs the ring mapping.
        write_pos = jnp.mod(cache_len, s_len)
        cache = update_kv_cache(cache, k, v, write_pos)
        idx = jnp.arange(s_len)
        kv_positions = cache_len - jnp.mod(cache_len - idx, s_len)
    else:
        cache = update_kv_cache(cache, k, v, cache_len)
    o = decode_attention(
        q,
        cache["k"],
        cache["v"],
        cache_len + 1,
        window=cfg.sliding_window if local else None,
        logit_cap=cfg.attn_logit_softcap,
        kv_positions=kv_positions,
    )
    return project_out(params, o), cache


# ----------------------------------------------------------------- apply ---

def apply_sublayer(
    cfg: ModelConfig,
    params: Params,
    spec: SublayerSpec,
    x: jax.Array,
    *,
    mode: str = "train",                 # train | prefill | decode
    positions: Optional[jax.Array] = None,
    state: BlockState = None,
    cache_len: Optional[jax.Array] = None,
    causal: bool = True,
    opts=None,
) -> Tuple[jax.Array, BlockState, jax.Array]:
    """Returns (x, new_state_or_None, moe_aux_loss)."""
    if opts is None:
        from .model import ForwardOptions

        opts = ForwardOptions()
    aux = jnp.zeros((), jnp.float32)
    local = spec.kind is LayerKind.ATTN_LOCAL
    new_state: Dict[str, Any] = {}

    # ---- mixer ----
    if spec.kind in (LayerKind.ATTN, LayerKind.ATTN_LOCAL):
        h = apply_norm(cfg, params["attn_norm"], x)
        if mode == "decode":
            o, kv = _attn_decode(cfg, params["attn"], h, state["kv"], cache_len, local)
            new_state["kv"] = kv
        else:
            kv_out = state["kv"] if mode == "prefill" else None
            o, kv = _attn_full(
                cfg, params["attn"], h, positions, local, causal, opts, kv_out
            )
            if mode == "prefill":
                new_state["kv"] = kv
        if cfg.post_sublayer_norm:
            o = apply_norm(cfg, params["attn_post_norm"], o)
        mixer_out: Optional[jax.Array] = None
        if cfg.parallel_block:
            mixer_out = o
        else:
            x = x + o
    else:  # MAMBA
        h = apply_norm(cfg, params["mamba_norm"], x)
        o, ssm_state, conv_state = apply_mamba(
            cfg,
            params["mamba"],
            h,
            ssm_state=state.get("ssm") if mode == "decode" else None,
            conv_state=state.get("conv") if mode == "decode" else None,
            impl="step" if mode == "decode" else opts.mamba_impl,
        )
        if mode in ("decode", "prefill"):
            new_state["ssm"] = ssm_state
            new_state["conv"] = conv_state
        x = x + o
        mixer_out = None

    # ---- FFN ----
    has_ffn = spec.ffn is FFNKind.MOE or cfg.d_ff > 0
    if has_ffn:
        if cfg.parallel_block:
            hf = apply_norm(cfg, params["attn_norm"], x)  # shared input norm
        else:
            hf = apply_norm(cfg, params["ffn_norm"], x)
        if spec.ffn is FFNKind.MOE:
            f, aux = apply_moe(
                cfg, params["moe"], hf,
                dispatch=opts.moe_dispatch,
                shardings=getattr(opts, "moe_compute_shardings", None),
            )
        else:
            f = apply_mlp(cfg, params["mlp"], hf)
        if cfg.post_sublayer_norm:
            f = apply_norm(cfg, params["ffn_post_norm"], f)
        if cfg.parallel_block and mixer_out is not None:
            x = x + mixer_out + f
        else:
            x = x + f
    elif cfg.parallel_block and mixer_out is not None:
        x = x + mixer_out

    return x, (new_state if mode in ("decode", "prefill") else None), aux


# ----------------------------------------------------------- decode state --

def init_unit_state(
    cfg: ModelConfig, batch: int, max_len: int, dtype: jnp.dtype
) -> Dict[str, Any]:
    """Decode-state pytree for ONE unit (unstacked)."""
    state: Dict[str, Any] = {}
    for i, spec in enumerate(cfg.pattern_unit()):
        sub: Dict[str, Any] = {}
        if spec.kind in (LayerKind.ATTN, LayerKind.ATTN_LOCAL):
            # Windowed layers only ever read the trailing window: allocate a
            # ring buffer of ~window slots instead of max_len.
            s_len = max_len
            if spec.kind is LayerKind.ATTN_LOCAL and cfg.sliding_window:
                s_len = min(max_len, _round_up(cfg.sliding_window + 1, 128))
            sub["kv"] = init_kv_cache(
                batch, s_len, cfg.n_kv_heads, cfg.resolved_head_dim, dtype
            )
        else:
            k = cfg.ssm_conv_kernel
            sub["ssm"] = jnp.zeros(
                (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
            )
            sub["conv"] = {
                "x": jnp.zeros((batch, k - 1, cfg.d_inner), dtype),
                "B": jnp.zeros((batch, k - 1, cfg.ssm_groups * cfg.ssm_state), dtype),
                "C": jnp.zeros((batch, k - 1, cfg.ssm_groups * cfg.ssm_state), dtype),
            }
        state[f"sub{i}"] = sub
    return state


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
