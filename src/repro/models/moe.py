"""Mixture-of-Experts FFN with two mathematically equivalent dispatches.

* ``gather``  — capacity-based token-choice dispatch: top-k routing, position
  within expert via cumsum, gather [E, C, d] -> expert GEMMs -> weighted
  scatter-add. FLOPs proportional to *active* parameters (the production
  path). Tokens overflowing an expert's capacity are dropped (standard GShard
  semantics); capacity_factor trades drop rate for padding waste.
* ``dense``   — every token runs every expert; routing weights (zero for
  unselected experts) combine the results. No gather/scatter memory ops but
  ~E/top_k x more FLOPs. With no capacity drops the two dispatches are
  bit-identical in exact arithmetic — the equal-*result*, different-FLOPs
  regime of the paper's discriminant test (see repro.autotune).

TPU adaptation: everything is static-shape einsum + cumsum + scatter — no
dynamic shapes, MXU-friendly; expert dim shards over "model" (EP) when
divisible, else per-expert d_ff shards over "model" (TP-in-expert).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import P, Params, normal_init, param_dtype


def init_moe(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = param_dtype(cfg)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.resolved_moe_d_ff
    keys = jax.random.split(key, 8)
    out_std = 0.02 / np.sqrt(2 * cfg.n_layers)
    params: Params = {
        "router": normal_init(keys[0], (d, e), ("embed", None), dt),
        "wi": normal_init(keys[1], (e, d, f), ("experts", "embed", "moe_ffn"), dt),
        "wg": normal_init(keys[2], (e, d, f), ("experts", "embed", "moe_ffn"), dt),
        "wo": normal_init(keys[3], (e, f, d), ("experts", "moe_ffn", "embed"), dt, out_std),
    }
    if cfg.n_shared_experts > 0:
        sf = cfg.resolved_shared_d_ff
        params["shared"] = {
            "wi": normal_init(keys[4], (d, sf), ("embed", "ffn"), dt),
            "wg": normal_init(keys[5], (d, sf), ("embed", "ffn"), dt),
            "wo": normal_init(keys[6], (sf, d), ("ffn", "embed"), dt, out_std),
            "gate": normal_init(keys[7], (d, 1), ("embed", None), dt),
        }
    return params


def _routing(
    cfg: ModelConfig, params: Params, x2d: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Router: probs [T, E], top-k weights [T, k], indices [T, k], aux loss."""
    logits = jnp.einsum(
        "td,de->te", x2d.astype(jnp.float32), params["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, cfg.top_k)
    if cfg.moe_norm_topk:
        top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    # Switch-style load-balance aux loss.
    t = x2d.shape[0]
    e = cfg.n_experts
    dispatch = jax.nn.one_hot(top_i, e, dtype=jnp.float32)        # [T, k, E]
    frac_tokens = jnp.mean(jnp.sum(dispatch, axis=1), axis=0)      # [E]
    frac_probs = jnp.mean(probs, axis=0)                           # [E]
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return probs, top_w, top_i, aux


def _constrain(x, sharding):
    if sharding is not None:
        return jax.lax.with_sharding_constraint(x, sharding)
    return x


def _expert_ffn(
    cfg: ModelConfig, params: Params, xe: jax.Array, shardings=None
) -> jax.Array:
    """Per-expert gated FFN on [E, C, d] -> [E, C, d].

    ``shardings`` (dict wi/wg/wo -> NamedSharding) pins the COMPUTE-time
    weight layout: expert weights are ZeRO-stored with d_model sharded over
    'data', and without the pin GSPMD sometimes resolves the d-contraction
    by all-reducing f32 partial sums (audited: 260 GB/device per AR on
    qwen2-moe) instead of gathering the ~1 GB of weights.
    """
    sh = shardings or {}
    wi = _constrain(params["wi"], sh.get("wi")).astype(xe.dtype)
    wg = _constrain(params["wg"], sh.get("wg")).astype(xe.dtype)
    wo = _constrain(params["wo"], sh.get("wo")).astype(xe.dtype)
    h = jnp.einsum("ecd,edf->ecf", xe, wi)
    g = jnp.einsum("ecd,edf->ecf", xe, wg)
    if cfg.activation == "geglu":
        h = jax.nn.gelu(g, approximate=True) * h
    else:
        h = jax.nn.silu(g) * h
    return jnp.einsum("ecf,efd->ecd", h, wo)


def moe_gather(
    cfg: ModelConfig, params: Params, x2d: jax.Array, shardings=None
) -> Tuple[jax.Array, jax.Array]:
    """Capacity-based gather dispatch. x2d [T, d] -> ([T, d], aux)."""
    t, d = x2d.shape
    e, k = cfg.n_experts, cfg.top_k
    capacity = int(np.ceil(t * k * cfg.moe_capacity_factor / e))
    capacity = max(4, min(t, (capacity + 3) // 4 * 4))

    _, top_w, top_i, aux = _routing(cfg, params, x2d)

    # Position of each assignment within its expert, sort-based: argsort
    # groups assignments by expert; the position is the rank within the
    # expert's run. Integer-only (no [T*k, E] one-hot/cumsum tensors in the
    # fwd or bwd graph — §Perf iteration on granite-moe).
    flat_e = top_i.reshape(-1)                            # [T*k]
    order = jnp.argsort(flat_e, stable=True)              # [T*k]
    counts = jnp.bincount(flat_e, length=e)               # [E]
    starts = jnp.cumsum(counts) - counts                  # exclusive [E]
    sorted_e = flat_e[order]
    pos_sorted = jnp.arange(flat_e.shape[0], dtype=jnp.int32) - starts[sorted_e]
    pos = jnp.zeros_like(flat_e).at[order].set(pos_sorted)

    token_of = jnp.tile(jnp.arange(t)[:, None], (1, k)).reshape(-1)
    # Scatter token ids into the dispatch table. Overflowing assignments have
    # pos >= capacity, i.e. out-of-bounds — mode="drop" discards them without
    # clobbering legitimate slots. Unfilled slots keep the sentinel T.
    disp = jnp.full((e, capacity), t, dtype=jnp.int32)
    disp = disp.at[flat_e, pos].set(token_of, mode="drop")

    x_pad = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], axis=0)
    xe = x_pad[disp]                                      # [E, C, d]
    ye = _expert_ffn(cfg, params, xe, shardings)          # [E, C, d]

    # Combine: weight per (e, c) slot = routing weight of its assignment.
    flat_w = top_w.reshape(-1).astype(x2d.dtype)          # [T*k]
    w_slot = jnp.zeros((e, capacity), x2d.dtype)
    w_slot = w_slot.at[flat_e, pos].set(flat_w, mode="drop")
    out = jnp.zeros((t + 1, d), x2d.dtype)
    out = out.at[disp.reshape(-1)].add(
        (ye * w_slot[..., None]).reshape(-1, d), mode="drop"
    )
    out = out[:t]

    if cfg.n_shared_experts > 0:
        out = out + _shared_expert(cfg, params["shared"], x2d)
    return out, aux


def moe_dense(
    cfg: ModelConfig, params: Params, x2d: jax.Array, shardings=None
) -> Tuple[jax.Array, jax.Array]:
    """Dense dispatch: all tokens x all experts, combine by routing weight."""
    t, d = x2d.shape
    e = cfg.n_experts
    _, top_w, top_i, aux = _routing(cfg, params, x2d)
    combine = jnp.zeros((t, e), jnp.float32)
    combine = combine.at[jnp.arange(t)[:, None], top_i].add(
        top_w.astype(jnp.float32)
    )  # [T, E]

    xe = jnp.broadcast_to(x2d[None], (e, t, d))            # [E, T, d] (view)
    ye = _expert_ffn(cfg, params, xe, shardings)           # [E, T, d]
    out = jnp.einsum("etd,te->td", ye.astype(jnp.float32), combine).astype(x2d.dtype)

    if cfg.n_shared_experts > 0:
        out = out + _shared_expert(cfg, params["shared"], x2d)
    return out, aux


def _shared_expert(cfg: ModelConfig, sp: Params, x2d: jax.Array) -> jax.Array:
    h = jnp.einsum("td,df->tf", x2d, sp["wi"].astype(x2d.dtype))
    g = jnp.einsum("td,df->tf", x2d, sp["wg"].astype(x2d.dtype))
    h = jax.nn.silu(g) * h
    y = jnp.einsum("tf,fd->td", h, sp["wo"].astype(x2d.dtype))
    gate = jax.nn.sigmoid(
        jnp.einsum("td,do->to", x2d.astype(jnp.float32), sp["gate"].astype(jnp.float32))
    ).astype(x2d.dtype)
    return y * gate


def apply_moe(
    cfg: ModelConfig,
    params: Params,
    x: jax.Array,                  # [b, s, d]
    dispatch: str = "gather",
    shardings=None,
) -> Tuple[jax.Array, jax.Array]:
    """Dispatch per GROUP (= batch row), GShard-style.

    Flattening b*s and dispatching over GLOBAL tokens makes the
    position-cumsum a cross-shard dependency, so GSPMD de-shards the whole
    dispatch (audited on qwen2-moe train_4k: 2 TB/device gathered tokens +
    460 GB/device scatter-add all-reduces). Group-local dispatch keeps the
    batch dim sharded end-to-end; per-group capacity is the standard GShard
    load-balancing semantics.
    """
    b, s, d = x.shape
    # Apply the compute-layout pin OUTSIDE the vmap: a constraint inside the
    # vmapped body broadcasts the (unbatched) weights across groups
    # (refuted §Perf iteration: 64x weight materialisation, tc x6).
    if shardings:
        params = dict(params)
        for k in ("wi", "wg", "wo"):
            if k in shardings and shardings[k] is not None:
                params[k] = jax.lax.with_sharding_constraint(params[k], shardings[k])
    if dispatch == "gather":
        y, aux = jax.vmap(
            lambda xr: moe_gather(cfg, params, xr), in_axes=0, out_axes=0
        )(x)
        return y, jnp.mean(aux)
    if dispatch == "dense":
        x2d = x.reshape(b * s, d)
        y, aux = moe_dense(cfg, params, x2d)
        return y.reshape(b, s, d), aux
    raise ValueError(f"unknown MoE dispatch {dispatch!r}")
