"""Primitive layers: params-with-logical-axes, norms, RoPE, MLPs, embeddings.

Parameters are plain nested dicts of arrays. During initialisation each leaf
is a :class:`P` carrying its *logical axis names* (e.g. ``("embed", "ffn")``);
:func:`split_params` separates the value tree from the axis tree. The
distributed layer maps logical axes -> mesh axes (see
``repro/distributed/sharding.py``), so models never mention mesh axes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

Params = Dict[str, Any]
Axes = Tuple[Optional[str], ...]


@dataclass
class P:
    """A parameter leaf paired with logical axis names (len == ndim)."""

    value: jax.Array
    axes: Axes

    def __post_init__(self) -> None:
        if len(self.axes) != self.value.ndim:
            raise ValueError(
                f"axes {self.axes} rank != value rank {self.value.shape}"
            )


def is_p(x: Any) -> bool:
    return isinstance(x, P)


def split_params(tree: Any) -> Tuple[Any, Any]:
    """(values, axes) trees from a tree of :class:`P` leaves."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_p)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_p)
    return values, axes


def param_dtype(cfg: ModelConfig) -> jnp.dtype:
    return jnp.dtype(cfg.param_dtype)


def compute_dtype(cfg: ModelConfig) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ------------------------------------------------------------------ init ---

def normal_init(
    key: jax.Array,
    shape: Sequence[int],
    axes: Axes,
    dtype: jnp.dtype,
    stddev: float = 0.02,
) -> P:
    v = stddev * jax.random.truncated_normal(key, -2.0, 2.0, tuple(shape), jnp.float32)
    return P(v.astype(dtype), tuple(axes))


def zeros_init(shape: Sequence[int], axes: Axes, dtype: jnp.dtype) -> P:
    return P(jnp.zeros(tuple(shape), dtype), tuple(axes))


def ones_init(shape: Sequence[int], axes: Axes, dtype: jnp.dtype) -> P:
    return P(jnp.ones(tuple(shape), dtype), tuple(axes))


# ----------------------------------------------------------------- norms ---

def init_norm(cfg: ModelConfig, dims: int) -> Params:
    dt = param_dtype(cfg)
    if cfg.norm_type == "layernorm":
        return {
            "scale": ones_init((dims,), ("embed",), dt),
            "bias": zeros_init((dims,), ("embed",), dt),
        }
    # rmsnorm: gemma2 stores (w) and applies (1 + w); init accordingly.
    if cfg.rms_one_offset:
        return {"scale": zeros_init((dims,), ("embed",), dt)}
    return {"scale": ones_init((dims,), ("embed",), dt)}


def apply_norm(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    """Norm in f32, cast back to the compute dtype."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
        w = params["scale"].astype(jnp.float32)
        y = y * (1.0 + w) if cfg.rms_one_offset else y * w
    return y.astype(dtype)


def rms_head_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """qwen3 qk-norm: RMS over the head_dim of [..., head_dim]."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)
    return y.astype(dtype)


# ------------------------------------------------------------------ RoPE ---

def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float
) -> jax.Array:
    """Rotate [..., seq, n_heads, head_dim] by position-dependent phases.

    ``positions`` broadcasts against the seq dim: shape [seq] or [batch, seq].
    """
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(head_dim, theta), dtype=jnp.float32)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., s, hd/2]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- embedding ---

def init_embedding(cfg: ModelConfig, key: jax.Array) -> Params:
    return {
        "table": normal_init(
            key, (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), param_dtype(cfg)
        )
    }


def embed_tokens(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    x = params["table"].astype(compute_dtype(cfg))[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dtype=x.dtype)
    return x


def unembed(cfg: ModelConfig, embed_params: Params, head_params: Optional[Params], x: jax.Array) -> jax.Array:
    """Project to vocabulary logits (tied or untied head); f32 logits."""
    if cfg.tie_embeddings:
        table = embed_params["table"]
        logits = jnp.einsum(
            "...d,vd->...v", x.astype(jnp.float32), table.astype(jnp.float32)
        )
    else:
        assert head_params is not None
        w = head_params["w"]
        logits = jnp.einsum(
            "...d,dv->...v", x.astype(jnp.float32), w.astype(jnp.float32)
        )
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def init_unembed(cfg: ModelConfig, key: jax.Array) -> Optional[Params]:
    if cfg.tie_embeddings:
        return None
    return {
        "w": normal_init(
            key, (cfg.d_model, cfg.vocab_size), ("embed", "vocab"), param_dtype(cfg)
        )
    }


# ------------------------------------------------------------------- MLP ---

def init_mlp(cfg: ModelConfig, key: jax.Array, d_ff: Optional[int] = None) -> Params:
    d_ff = d_ff if d_ff is not None else cfg.d_ff
    dt = param_dtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    out_std = 0.02 / np.sqrt(2 * cfg.n_layers)
    if cfg.activation in ("swiglu", "geglu"):
        return {
            "wi": normal_init(k1, (cfg.d_model, d_ff), ("embed", "ffn"), dt),
            "wg": normal_init(k2, (cfg.d_model, d_ff), ("embed", "ffn"), dt),
            "wo": normal_init(k3, (d_ff, cfg.d_model), ("ffn", "embed"), dt, out_std),
        }
    return {
        "wi": normal_init(k1, (cfg.d_model, d_ff), ("embed", "ffn"), dt),
        "wo": normal_init(k3, (d_ff, cfg.d_model), ("ffn", "embed"), dt, out_std),
    }


def apply_mlp(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, params["wi"].astype(x.dtype))
    if cfg.activation == "swiglu":
        g = jnp.einsum("...d,df->...f", x, params["wg"].astype(x.dtype))
        h = jax.nn.silu(g) * h
    elif cfg.activation == "geglu":
        g = jnp.einsum("...d,df->...f", x, params["wg"].astype(x.dtype))
        h = jax.nn.gelu(g, approximate=True) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    return jnp.einsum("...f,fd->...d", h, params["wo"].astype(x.dtype))


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
