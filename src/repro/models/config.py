"""Unified model configuration covering all assigned architecture families.

One ``ModelConfig`` drives dense, MoE, hybrid (attention+Mamba interleave),
SSM-only and encoder-decoder stacks. Layer heterogeneity is expressed as a
repeating *pattern unit*: the stack is ``scan``-ned over identical units so
the lowered HLO contains one unit body regardless of depth (critical for
512-device AOT compile times).
"""

from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple


class LayerKind(str, enum.Enum):
    ATTN = "attn"                # attention + (dense | moe) FFN
    ATTN_LOCAL = "attn_local"    # sliding-window attention + FFN
    MAMBA = "mamba"              # Mamba-2 SSD mixer (+ optional MoE FFN)


class FFNKind(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"


@dataclass(frozen=True)
class SublayerSpec:
    """One sublayer inside the repeating pattern unit."""

    kind: LayerKind
    ffn: FFNKind


@dataclass(frozen=True)
class ModelConfig:
    # -- identity -----------------------------------------------------------
    name: str = "model"
    family: str = "dense"        # dense | moe | hybrid | ssm | vlm | audio

    # -- core dims ----------------------------------------------------------
    n_layers: int = 12
    d_model: int = 1024
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: Optional[int] = None       # default d_model // n_heads
    d_ff: int = 4096
    vocab_size: int = 32000

    # -- attention ----------------------------------------------------------
    qk_norm: bool = False                # qwen3-style RMS norm on q/k heads
    attn_logit_softcap: Optional[float] = None   # gemma2: 50.0
    final_logit_softcap: Optional[float] = None  # gemma2: 30.0
    sliding_window: Optional[int] = None  # window for ATTN_LOCAL sublayers
    local_global_alternating: bool = False  # gemma2: unit = [local, global]
    rope_theta: float = 10000.0
    attn_bias: bool = False
    parallel_block: bool = False         # command-r: attn and FFN in parallel

    # -- FFN / MoE ----------------------------------------------------------
    activation: str = "swiglu"           # swiglu | geglu | gelu
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0            # qwen2-moe: shared experts
    moe_d_ff: Optional[int] = None       # per-expert hidden (defaults d_ff)
    shared_d_ff: Optional[int] = None    # shared-expert hidden
    moe_layer_period: int = 1            # MoE every k-th sublayer
    moe_layer_offset: int = 0
    moe_norm_topk: bool = True           # renormalise top-k weights
    moe_capacity_factor: float = 1.25    # gather-dispatch capacity factor
    router_aux_loss_coef: float = 0.001

    # -- Mamba-2 (SSD) -------------------------------------------------------
    attn_layer_period: int = 0           # jamba: attention every k-th layer
    attn_layer_offset: int = 0
    ssm_state: int = 128
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_kernel: int = 4
    ssm_chunk: int = 256                 # SSD chunk length
    ssm_groups: int = 1                  # B/C groups (like GQA for SSM)

    # -- encoder-decoder -----------------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500              # whisper frame positions (stub)

    # -- norm / embedding ----------------------------------------------------
    norm_type: str = "rmsnorm"           # rmsnorm | layernorm
    norm_eps: float = 1e-6
    post_sublayer_norm: bool = False     # gemma2 sandwich norms
    embed_scale: bool = False            # gemma2: x *= sqrt(d_model)
    tie_embeddings: bool = True
    rms_one_offset: bool = False         # gemma2: weight applied as (1 + w)

    # -- frontend stubs ------------------------------------------------------
    frontend: str = "none"               # none | vision_stub | audio_stub

    # -- numerics ------------------------------------------------------------
    dtype: str = "bfloat16"              # activation/param compute dtype
    param_dtype: str = "bfloat16"

    # -------------------------------------------------------------- derived -
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        assert self.n_heads % max(self.n_kv_heads, 1) == 0
        return self.n_heads // self.n_kv_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        assert self.d_inner % self.ssm_head_dim == 0
        return self.d_inner // self.ssm_head_dim

    @property
    def resolved_moe_d_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    @property
    def resolved_shared_d_ff(self) -> int:
        if self.shared_d_ff is not None:
            return self.shared_d_ff
        return self.resolved_moe_d_ff * max(self.n_shared_experts, 1)

    # ------------------------------------------------------- pattern logic -
    def pattern_unit(self) -> List[SublayerSpec]:
        """The repeating sublayer unit; ``n_layers % len(unit) == 0``."""
        unit_len = self._unit_len()
        specs: List[SublayerSpec] = []
        for pos in range(unit_len):
            specs.append(self._sublayer_at(pos))
        return specs

    def _unit_len(self) -> int:
        candidates = [1]
        if self.local_global_alternating:
            candidates.append(2)
        if self.attn_layer_period > 1:
            candidates.append(self.attn_layer_period)
        if self.is_moe and self.moe_layer_period > 1:
            candidates.append(self.moe_layer_period)
        unit = 1
        for c in candidates:
            unit = _lcm(unit, c)
        if self.n_layers % unit != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"pattern unit {unit}"
            )
        return unit

    def _sublayer_at(self, pos: int) -> SublayerSpec:
        # mixer kind
        if self.attn_layer_period > 1:  # hybrid: attention every k-th layer
            kind = (
                LayerKind.ATTN
                if pos % self.attn_layer_period == self.attn_layer_offset
                else LayerKind.MAMBA
            )
        elif self.family == "ssm":
            kind = LayerKind.MAMBA
        elif self.local_global_alternating:
            kind = LayerKind.ATTN_LOCAL if pos % 2 == 0 else LayerKind.ATTN
        elif self.sliding_window is not None:
            kind = LayerKind.ATTN_LOCAL
        else:
            kind = LayerKind.ATTN
        # ffn kind
        if self.is_moe and pos % max(self.moe_layer_period, 1) == self.moe_layer_offset:
            ffn = FFNKind.MOE
        else:
            ffn = FFNKind.DENSE
        return SublayerSpec(kind=kind, ffn=ffn)

    @property
    def n_units(self) -> int:
        return self.n_layers // self._unit_len()

    def replace(self, **kwargs) -> "ModelConfig":
        return dataclasses.replace(self, **kwargs)

    def validate(self) -> None:
        assert self.n_heads % max(self.n_kv_heads, 1) == 0
        if self.family != "ssm":
            assert self.n_heads > 0 and self.d_model > 0
        self.pattern_unit()  # raises if inconsistent


def _lcm(a: int, b: int) -> int:
    import math

    return a * b // math.gcd(a, b)
