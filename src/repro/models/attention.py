"""Attention: GQA with qk-norm / logit softcap / sliding window, in several
mathematically equivalent implementations (the autotune variant site), plus
KV-cache decode.

Variants (all produce identical outputs up to fp reassociation — exactly the
paper's "equivalent algorithms" regime):

* ``reference``  — materialises [.., sq, skv] scores. Minimal HLO ops; O(s²)
  memory. Used for small sequences and as the correctness oracle.
* ``chunked``    — blockwise online-softmax (flash formulation) as nested
  ``lax.scan``; O(s·block) memory. For causal masks the rectangular scan
  computes masked blocks too (≈2x attention-score FLOPs); the triangle-
  split optimisation and the Pallas kernel remove that waste.
* ``grouped`` vs ``broadcast`` GQA contraction order — equal FLOPs, different
  memory traffic (K/V repeated to H heads or kept grouped).

Decode attends one query against a (possibly sequence-sharded) cache; XLA
inserts the partial-softmax collectives when the cache's seq dim is sharded
(flash-decoding on TPU).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import (
    P,
    Params,
    apply_rope,
    normal_init,
    ones_init,
    param_dtype,
    rms_head_norm,
    softcap,
)

NEG_INF = -2.0e38  # f32-safe mask value


# ---------------------------------------------------------------- params ---

def init_attention(cfg: ModelConfig, key: jax.Array, fused_qkv: bool = False) -> Params:
    dt = param_dtype(cfg)
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    out_std = 0.02 / np.sqrt(2 * cfg.n_layers)
    params: Params = {
        "wq": normal_init(k1, (cfg.d_model, cfg.n_heads, hd), ("embed", "q_heads", "head_dim"), dt),
        "wk": normal_init(k2, (cfg.d_model, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim"), dt),
        "wv": normal_init(k3, (cfg.d_model, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim"), dt),
        "wo": normal_init(k4, (cfg.n_heads, hd, cfg.d_model), ("q_heads", "head_dim", "embed"), dt, out_std),
    }
    if cfg.qk_norm:
        params["q_norm"] = ones_init((hd,), (None,), dt)
        params["k_norm"] = ones_init((hd,), (None,), dt)
    return params


def project_qkv(
    cfg: ModelConfig, params: Params, x: jax.Array, positions: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x [b, s, d] -> q [b, s, H, hd], k/v [b, s, K, hd] with RoPE applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_head_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_head_norm(k, params["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def project_out(params: Params, attn: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", attn, params["wo"].astype(attn.dtype))


# ------------------------------------------------------------ mask logic ---

def _mask_bias(
    q_pos: jax.Array,      # [sq]
    kv_pos: jax.Array,     # [skv]
    causal: bool,
    window: Optional[int],
    kv_len: Optional[jax.Array] = None,  # scalar: valid cache length
) -> jax.Array:
    """Additive bias [sq, skv]: 0 where allowed, NEG_INF where masked."""
    allowed = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), dtype=bool)
    if causal:
        allowed &= kv_pos[None, :] <= q_pos[:, None]
    if window is not None:
        allowed &= kv_pos[None, :] > q_pos[:, None] - window
    if kv_len is not None:
        allowed &= kv_pos[None, :] < kv_len
    return jnp.where(allowed, 0.0, NEG_INF).astype(jnp.float32)


# -------------------------------------------------------------- variants ---

def attention_reference(
    q: jax.Array,          # [b, sq, H, hd]
    k: jax.Array,          # [b, skv, K, hd]
    v: jax.Array,          # [b, skv, K, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    q_offset: int = 0,
    gqa: str = "grouped",  # "grouped" | "broadcast"
) -> jax.Array:
    """Full-scores attention. O(sq*skv) memory; correctness oracle."""
    b, sq, h, hd = q.shape
    kheads = k.shape[2]
    g = h // kheads
    scale = 1.0 / np.sqrt(hd)
    q_pos = jnp.arange(sq) + q_offset
    kv_pos = jnp.arange(k.shape[1])
    bias = _mask_bias(q_pos, kv_pos, causal, window)

    if gqa == "broadcast":
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        scores = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32) * scale
        scores = softcap(scores, logit_cap) + bias[None, None]
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhqs,bshk->bqhk", probs, v)
        return out
    # grouped: keep K/V at kv-head granularity
    qg = q.reshape(b, sq, kheads, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    scores = softcap(scores, logit_cap) + bias[None, None, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h, hd)


def attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    q_block: int = 512,
    kv_block: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """Blockwise online-softmax attention (flash formulation, pure JAX).

    Outer scan over q blocks, inner scan over kv blocks, carrying
    (m, l, acc) running max / normaliser / weighted accumulator. Memory is
    O(q_block * kv_block) per step. Masked (future) blocks are computed and
    discarded — see module docstring for the FLOPs note.
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    kheads = k.shape[2]
    g = h // kheads
    if sq % q_block != 0 or skv % kv_block != 0:
        raise ValueError(f"seq ({sq},{skv}) not divisible by blocks ({q_block},{kv_block})")
    nq, nk = sq // q_block, skv // kv_block
    scale = 1.0 / np.sqrt(hd)

    qb = q.reshape(b, nq, q_block, kheads, g, hd)
    kb = k.reshape(b, nk, kv_block, kheads, hd)
    vb = v.reshape(b, nk, kv_block, kheads, hd)

    def q_step(_, qi_idx):
        qi, i = qi_idx  # qi: [b, q_block, K, g, hd]
        q_pos = jnp.arange(q_block) + i * q_block + q_offset

        def kv_step(carry, kj_vj_j):
            m, l, acc = carry
            kj, vj, j = kj_vj_j
            kv_pos = jnp.arange(kv_block) + j * kv_block
            s = jnp.einsum("bqkgd,bskd->bkgqs", qi, kj).astype(jnp.float32) * scale
            s = softcap(s, logit_cap)
            allowed = jnp.ones((q_block, kv_block), dtype=bool)
            if causal:
                allowed &= kv_pos[None, :] <= q_pos[:, None]
            if window is not None:
                allowed &= kv_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(allowed[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows (m_new == NEG_INF)
            m_safe = jnp.where(m_new <= NEG_INF * 0.5, 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(allowed[None, None, None], p, 0.0)
            alpha = jnp.where(m <= NEG_INF * 0.5, 0.0, jnp.exp(m - m_safe))
            l_new = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(qi.dtype), vj).astype(jnp.float32)
            acc_new = acc * alpha[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kheads, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kheads, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, kheads, g, q_block, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nk)),
        )
        l_safe = jnp.where(l == 0.0, 1.0, l)
        out = (acc / l_safe[..., None]).astype(q.dtype)  # [b, K, g, qb, hd]
        return None, jnp.moveaxis(out, 3, 1)  # [b, qb, K, g, hd]

    _, blocks = jax.lax.scan(q_step, None, (jnp.moveaxis(qb, 1, 0), jnp.arange(nq)))
    # blocks: [nq, b, q_block, K, g, hd]
    out = jnp.moveaxis(blocks, 0, 1).reshape(b, sq, kheads, g, hd)
    return out.reshape(b, sq, h, hd)


def attention_local_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    window: int,
    logit_cap: Optional[float] = None,
    q_block: int = 512,
    q_offset: int = 0,
) -> jax.Array:
    """Sliding-window attention with true FLOPs savings: each q block slices
    only the kv span it can see (length window + q_block), so cost is
    O(s * window) instead of O(s²). Causal by construction."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    kheads = k.shape[2]
    g = h // kheads
    if sq % q_block != 0:
        raise ValueError(f"sq {sq} % q_block {q_block} != 0")
    span = window + q_block  # static slice length
    if span >= skv:
        return attention_chunked(
            q, k, v, causal=True, window=window, logit_cap=logit_cap,
            q_block=q_block, kv_block=min(skv, 1024), q_offset=q_offset,
        )
    nq = sq // q_block
    scale = 1.0 / np.sqrt(hd)
    qb = q.reshape(b, nq, q_block, kheads, g, hd)

    def q_step(_, qi_idx):
        qi, i = qi_idx
        q_start = i * q_block
        # kv span [q_start - window + 1, q_start + q_block); clamp to >= 0.
        start = jnp.maximum(q_start + q_block - span, 0)
        kj = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
        q_pos = jnp.arange(q_block) + q_start + q_offset
        kv_pos = jnp.arange(span) + start + q_offset
        s = jnp.einsum("bqkgd,bskd->bkgqs", qi, kj).astype(jnp.float32) * scale
        s = softcap(s, logit_cap)
        allowed = (kv_pos[None, :] <= q_pos[:, None]) & (
            kv_pos[None, :] > q_pos[:, None] - window
        )
        s = jnp.where(allowed[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(qi.dtype), vj)
        return None, jnp.moveaxis(out, 3, 1)  # [b, qb, K, g, hd]

    _, blocks = jax.lax.scan(q_step, None, (jnp.moveaxis(qb, 1, 0), jnp.arange(nq)))
    out = jnp.moveaxis(blocks, 0, 1).reshape(b, sq, kheads, g, hd)
    return out.reshape(b, sq, h, hd)


def decode_attention(
    q: jax.Array,            # [b, 1, H, hd] — single new query
    k_cache: jax.Array,      # [b, S, K, hd]
    v_cache: jax.Array,      # [b, S, K, hd]
    cache_len: jax.Array,    # scalar or [b]: number of valid positions
    *,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    kv_positions: Optional[jax.Array] = None,  # [S] absolute positions (ring)
) -> jax.Array:
    """One-token attention over the cache; O(S) per step.

    When the cache seq dim is sharded, XLA inserts the max/sum all-reduces of
    the partial softmax (flash-decoding). ``kv_positions`` supports
    ring-buffer caches (windowed layers): slot -> absolute position, negative
    for unwritten slots.
    """
    b, s, kheads, hd = k_cache.shape
    h = q.shape[2]
    g = h // kheads
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(b, 1, kheads, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache).astype(jnp.float32) * scale
    scores = softcap(scores, logit_cap)
    kv_pos = kv_positions if kv_positions is not None else jnp.arange(s)
    q_pos = jnp.asarray(cache_len) - 1  # query sits at position cache_len - 1
    allowed = (kv_pos[None, :] <= jnp.reshape(q_pos, (-1, 1))) & (kv_pos[None, :] >= 0)
    if window is not None:
        allowed &= kv_pos[None, :] > jnp.reshape(q_pos, (-1, 1)) - window
    bias = jnp.where(allowed, 0.0, NEG_INF)  # [b or 1, S]
    scores = scores + bias[:, None, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_cache)
    return out.reshape(b, 1, h, hd)


# --------------------------------------------------------------- KV cache --

def init_kv_cache(
    batch: int, max_len: int, n_kv_heads: int, head_dim: int, dtype: jnp.dtype
) -> Dict[str, jax.Array]:
    return {
        "k": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
    }


def update_kv_cache(
    cache: Dict[str, jax.Array],
    k_new: jax.Array,          # [b, s_new, K, hd]
    v_new: jax.Array,
    position: jax.Array,       # scalar write offset
) -> Dict[str, jax.Array]:
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), position, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), position, axis=1)
    return {"k": k, "v": v}


# ------------------------------------------------------------- dispatcher --

def attention(
    cfg: ModelConfig,
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    local: bool = False,
    impl: str = "auto",
    q_block: int = 512,
    kv_block: int = 1024,
) -> jax.Array:
    """Select implementation by sequence length / layer kind / config."""
    window = cfg.sliding_window if local else None
    cap = cfg.attn_logit_softcap
    sq = q.shape[1]
    if impl == "auto":
        impl = "reference" if sq <= 1024 else "chunked"
    if impl == "reference":
        return attention_reference(q, k, v, causal=True, window=window, logit_cap=cap)
    if impl == "chunked":
        if window is not None and window + q_block < k.shape[1]:
            return attention_local_chunked(
                q, k, v, window=window, logit_cap=cap, q_block=min(q_block, sq)
            )
        return attention_chunked(
            q, k, v, causal=True, window=window, logit_cap=cap,
            q_block=min(q_block, sq), kv_block=min(kv_block, k.shape[1]),
        )
    raise ValueError(f"unknown attention impl {impl!r}")
