"""repro.data — deterministic shard-aware synthetic pipeline."""

from .pipeline import DataConfig, SyntheticLM

__all__ = ["DataConfig", "SyntheticLM"]
