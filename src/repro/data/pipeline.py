"""Deterministic, shard-aware synthetic data pipeline.

Every batch is a pure function of ``(seed, step, global position)`` — no
iterator state. Consequences that matter at cluster scale:

* **exact resume**: a restored step recomputes exactly the batches it would
  have seen (the data cursor is the step number in the checkpoint);
* **elastic re-sharding**: a host owns rows by *global position*, so when
  the data-parallel width changes, the global batch sequence is unchanged —
  only the row->host mapping moves;
* **no input stragglers**: generation is compute-trivial and local.

Token streams mix a Zipf-ish unigram draw with shifted-window structure so
the LM loss actually decreases (examples/train_lm.py) — pure-uniform tokens
have no learnable signal.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: float = 0.7      # fraction of positions copied from context
    copy_offset: int = 16        # structural dependency distance
    zipf_a: float = 1.2


def _fold(*ints: int) -> np.random.Generator:
    seed = 0x9E3779B97F4A7C15
    for i in ints:
        seed = ((seed ^ (i + 1)) * 0xBF58476D1CE4E5B9) % (2**64)
        seed ^= seed >> 31
    return np.random.default_rng(seed % (2**63))


def _zipf_probs(cfg: DataConfig) -> np.ndarray:
    ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
    p = ranks ** (-cfg.zipf_a)
    return p / p.sum()


class SyntheticLM:
    """tokens[b, s] + labels[b, s] per step, sharded by global row."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._probs = _zipf_probs(cfg)

    def _row(self, step: int, row: int) -> np.ndarray:
        cfg = self.cfg
        rng = _fold(cfg.seed, step, row)
        n = cfg.seq_len + 1
        off = cfg.copy_offset
        pad = (-n) % off
        total = n + pad
        fresh = rng.choice(cfg.vocab_size, size=total, p=self._probs)
        # Markov copy chains at distance `off`: position i keeps the value of
        # i - off with prob `structure`, else redraws. Vectorised per chain:
        # value[k] = fresh[last change point <= k].
        chains = total // off
        change = rng.random((chains, off)) >= cfg.structure
        change[0, :] = True
        kidx = np.arange(chains)[:, None] * np.ones((1, off), dtype=np.int64)
        last_change = np.maximum.accumulate(np.where(change, kidx, -1), axis=0)
        fresh2d = fresh.reshape(chains, off)
        toks = fresh2d[last_change, np.arange(off)[None, :]].reshape(total)[:n]
        return toks.astype(np.int32)

    def batch(
        self,
        step: int,
        shard_id: int = 0,
        num_shards: int = 1,
    ) -> Dict[str, np.ndarray]:
        """The shard's slice of the global batch for ``step``."""
        cfg = self.cfg
        if cfg.global_batch % num_shards:
            raise ValueError(
                f"global_batch {cfg.global_batch} !% num_shards {num_shards}"
            )
        rows_per = cfg.global_batch // num_shards
        rows = range(shard_id * rows_per, (shard_id + 1) * rows_per)
        data = np.stack([self._row(step, r) for r in rows])
        return {
            "tokens": data[:, :-1],
            "labels": data[:, 1:].copy(),
        }

    def global_batch(self, step: int) -> Dict[str, np.ndarray]:
        return self.batch(step, 0, 1)

    def iterate(
        self, start_step: int = 0, shard_id: int = 0, num_shards: int = 1
    ) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step, shard_id, num_shards)
            step += 1
