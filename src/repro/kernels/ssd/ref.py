"""Pure-jnp oracle for the SSD chunk kernel: the sequential (primal) scan.

Layout matches the kernel: head-flattened xbar [bh, s, p], per-token decay
logs logda [bh, s], B/C broadcast per head [bh, s, n]. (dt scaling and
A = -exp(A_log) are applied by ops.py before either path.)
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ssd_scan_ref(
    xbar: jax.Array,     # [bh, s, p] (dt-scaled inputs)
    logda: jax.Array,    # [bh, s]    (dt * A, negative)
    b_mat: jax.Array,    # [bh, s, n]
    c_mat: jax.Array,    # [bh, s, n]
    init_state: Optional[jax.Array] = None,  # [bh, p, n]
) -> Tuple[jax.Array, jax.Array]:
    bh, s, p = xbar.shape
    n = b_mat.shape[-1]

    def step(state, inp):
        xt, lt, bt, ct = inp                   # [bh,p], [bh], [bh,n], [bh,n]
        da = jnp.exp(lt)[:, None, None]        # [bh,1,1]
        state = state * da + jnp.einsum("bp,bn->bpn", xt, bt)
        y = jnp.einsum("bpn,bn->bp", state, ct)
        return state, y

    s0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((bh, p, n), jnp.float32)
    )
    xs = (
        jnp.moveaxis(xbar.astype(jnp.float32), 1, 0),
        jnp.moveaxis(logda.astype(jnp.float32), 1, 0),
        jnp.moveaxis(b_mat.astype(jnp.float32), 1, 0),
        jnp.moveaxis(c_mat.astype(jnp.float32), 1, 0),
    )
    final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(xbar.dtype), final
