"""Jit'd wrapper for the SSD chunk kernel: model-layout plumbing.

Takes the Mamba-2 mixer's natural layout (x [b, s, h, p], dt [b, s, h],
A_log [h], B/C [b, s, g, n]), precomputes the kernel inputs
(x̄ = dt*x, logda = dt*A, per-head B/C broadcast), and flattens heads.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .ref import ssd_scan_ref
from .ssd import ssd_scan_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "use_kernel", "interpret"))
def ssd_mix(
    x: jax.Array,        # [b, s, h, p]
    dt: jax.Array,       # [b, s, h] (positive)
    a_log: jax.Array,    # [h]
    b_mat: jax.Array,    # [b, s, g, n]
    c_mat: jax.Array,    # [b, s, g, n]
    *,
    chunk: int = 256,
    use_kernel: bool = True,
    interpret: bool = False,
) -> jax.Array:
    b, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    hg = h // g

    a = -jnp.exp(a_log.astype(jnp.float32))
    logda = dt.astype(jnp.float32) * a                    # [b, s, h]
    xbar = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]

    # head-flatten
    xf = xbar.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    lf = logda.transpose(0, 2, 1).reshape(b * h, s)
    bh_mat = jnp.repeat(b_mat, hg, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, n)
    ch_mat = jnp.repeat(c_mat, hg, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, n)

    if use_kernel:
        yf = ssd_scan_kernel(xf, lf, bh_mat, ch_mat, chunk=chunk, interpret=interpret)
    else:
        yf, _ = ssd_scan_ref(xf, lf, bh_mat, ch_mat)
    return yf.reshape(b, h, s, p).transpose(0, 2, 1, 3).astype(x.dtype)
