"""Mamba-2 SSD chunk scan as a Pallas TPU kernel.

TPU adaptation of the SSD algorithm (arXiv:2405.21060): the chunk axis is
the innermost (sequential) grid dimension, so the inter-chunk recurrent
state [p, n] lives in VMEM scratch that persists across chunk steps — the
Pallas analogue of the ``lax.scan`` carry, with the intra-chunk dual
(quadratic) form evaluated on the MXU:

  per chunk Q:   cum    = cumsum(logda)                       [Q]
                 L      = exp(cum_i - cum_j) (i >= j)         [Q, Q]
                 y      = ((C Bᵀ) ⊙ L) x̄  +  exp(cum) (C · state)
                 state <- exp(cum_Q) * state + (exp(cum_Q - cum) x̄)ᵀ B

Chunk length is a VMEM/MXU tile choice (multiple of 128 recommended); it is
mathematically inert — equal-FLOPs variants ranked by the autotuner.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, l_ref, b_ref, c_ref, y_ref, state_ref, *, chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xb = x_ref[0].astype(jnp.float32)          # [Q, p]
    ld = l_ref[0].astype(jnp.float32)          # [Q]
    bm = b_ref[0].astype(jnp.float32)          # [Q, n]
    cm = c_ref[0].astype(jnp.float32)          # [Q, n]

    cum = jnp.cumsum(ld)                       # [Q]
    # intra-chunk decay matrix L[i, j] = exp(cum_i - cum_j), lower-tri
    diff = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    ltri = ii >= jj
    decay = jnp.where(ltri, jnp.exp(diff), 0.0)

    cb = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                          # [Q, Q]
    y_intra = jax.lax.dot_general(
        cb * decay, xb, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                          # [Q, p]

    state = state_ref[...]                     # [p, n]
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                          # [Q, p]

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    total = cum[chunk - 1]
    decay_to_end = jnp.exp(total - cum)        # [Q]
    s_chunk = jax.lax.dot_general(
        xb * decay_to_end[:, None], bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                          # [p, n]
    state_ref[...] = state * jnp.exp(total) + s_chunk


def ssd_scan_kernel(
    xbar: jax.Array,     # [bh, s, p]
    logda: jax.Array,    # [bh, s]
    b_mat: jax.Array,    # [bh, s, n]
    c_mat: jax.Array,    # [bh, s, n]
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    bh, s, p = xbar.shape
    n = b_mat.shape[-1]
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(f"seq {s} % chunk {chunk} != 0")
    nc = s // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, ic: (i, ic, 0)),
            pl.BlockSpec((1, chunk), lambda i, ic: (i, ic)),
            pl.BlockSpec((1, chunk, n), lambda i, ic: (i, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, ic: (i, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda i, ic: (i, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), xbar.dtype),
        scratch_shapes=[_vmem((p, n), jnp.float32)],
        interpret=interpret,
    )(xbar, logda, b_mat, c_mat)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
