"""Blocked GEMM as a Pallas TPU kernel — the paper's compute substrate.

Every algorithm the ranking methodology compares (matrix-chain
parenthesizations, expression variants) bottoms out in GEMM; this kernel is
the TPU-native building block:

* grid = (M/bm, N/bn, K/bk), K innermost (sequential on TPU) with an f32
  VMEM accumulator persisting across K steps;
* block sizes default to 256x256x512 — MXU-aligned (multiples of 128) and
  sized so 3 tiles (A, B, acc) fit VMEM with headroom:
  256*512*2 + 512*256*2 + 256*256*4 bytes = 0.8 MB;
* mixed precision: bf16/f32 inputs, f32 accumulation, output cast.

ops.py exposes ``matmul`` and ``chain_matmul`` (executes a ChainAlgorithm's
GEMM sequence with this kernel). ref.py is ``jnp.dot``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k_blocks: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ik == n_k_blocks - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_kernel(
    a: jax.Array,                 # [m, k]
    b: jax.Array,                 # [k, n]
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype: Optional[jnp.dtype] = None,
    interpret: bool = False,
) -> jax.Array:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    out_dtype = out_dtype or a.dtype

    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    # pad to block multiples (zero padding is exact for matmul)
    mp, np_, kp = _ceil(m, bm) * bm, _ceil(n, bn) * bn, _ceil(k, bk) * bk
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k))) if (mp != m or kp != k) else a
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n))) if (kp != k or np_ != n) else b

    kernel = functools.partial(_matmul_kernel, n_k_blocks=kp // bk)
    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda im, jn, ik: (im, ik)),
            pl.BlockSpec((bk, bn), lambda im, jn, ik: (ik, jn)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda im, jn, ik: (im, jn)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[_vmem((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]


def _ceil(x: int, m: int) -> int:
    return (x + m - 1) // m


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
