"""Jit'd wrappers: ``matmul`` and ``chain_matmul``.

``chain_matmul`` executes a :class:`repro.expressions.ChainAlgorithm`'s GEMM
sequence with the Pallas kernel — the paper's algorithms running on the
TPU-native building block (the kernel-backed variant set for the
discriminant test at kernel level).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.expressions.chain import ChainAlgorithm

from .matmul import matmul_kernel
from .ref import matmul_ref


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "use_kernel", "interpret"),
)
def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    use_kernel: bool = True,
    interpret: bool = False,
) -> jax.Array:
    if use_kernel:
        return matmul_kernel(
            a, b, block_m=block_m, block_n=block_n, block_k=block_k,
            interpret=interpret,
        )
    return matmul_ref(a, b)


def chain_matmul(
    alg: ChainAlgorithm,
    matrices: Sequence[jax.Array],
    *,
    use_kernel: bool = True,
    interpret: bool = False,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
) -> jax.Array:
    """Execute one chain algorithm's instruction sequence with the kernel."""
    env: Dict[str, jax.Array] = {f"M{i}": m for i, m in enumerate(matrices)}
    last = None
    for dest, lhs, rhs in alg.steps:
        env[dest] = matmul(
            env[lhs], env[rhs],
            use_kernel=use_kernel, interpret=interpret,
            block_m=block_m, block_n=block_n, block_k=block_k,
        )
        last = env[dest]
    assert last is not None
    return last
