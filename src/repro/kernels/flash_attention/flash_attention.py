"""Flash attention as a Pallas TPU kernel.

Design (TPU-native, not a CUDA port — DESIGN.md §2):

* grid = (batch*heads, n_q_blocks, n_kv_blocks) with the kv axis innermost:
  TPU grids execute minor-most sequentially per core, so the online-softmax
  state (m, l, acc) lives in VMEM scratch that persists across kv steps.
* BlockSpecs tile q/o to [block_q, d] and k/v to [block_k, d] in VMEM —
  block sizes default to 128/512, multiples of the 128-lane MXU dimension.
* causal masking skips fully-masked kv blocks via ``pl.when`` — unlike the
  pure-JAX chunked scan, masked blocks cost ZERO flops (the dry-run's
  masked-block waste disappears on the kernel path).
* accumulation is f32; inputs/outputs bf16 or f32.

Validated in interpret mode against ``ref.flash_attention_ref`` over shape /
dtype / blocksize sweeps (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1.0e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *,
    sm_scale: float,
    causal: bool,
    logit_cap: Optional[float],
    window: Optional[int],
    block_q: int,
    block_k: int,
    n_kv_blocks: int,
    q_offset: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Causal / windowed block-level skip: kv block strictly in the future
    # (or entirely outside the window) does no work at all.
    q_lo = iq * block_q + q_offset           # first absolute q position
    q_hi = q_lo + block_q - 1
    k_lo = ik * block_k
    k_hi = k_lo + block_k - 1
    live = jnp.bool_(True)
    if causal:
        live = jnp.logical_and(live, k_lo <= q_hi)
    if window is not None:
        live = jnp.logical_and(live, k_hi > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)      # [bq, d]
        k = k_ref[0].astype(jnp.float32)      # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale                           # [bq, bk]
        if logit_cap:
            s = logit_cap * jnp.tanh(s / logit_cap)
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kv_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kv_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, kv_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                    # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)        # [bq, 1]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                      # [bq, d]
        acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,                 # [bh, sq, d]
    k: jax.Array,                 # [bh, skv, d]
    v: jax.Array,                 # [bh, skv, d]
    *,
    causal: bool = True,
    sm_scale: Optional[float] = None,
    logit_cap: Optional[float] = None,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, d = q.shape
    skv = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    if sq % block_q or skv % block_k:
        raise ValueError(f"seq ({sq},{skv}) must divide blocks ({block_q},{block_k})")
    nq, nk = sq // block_q, skv // block_k
    scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(d)

    kernel = functools.partial(
        _flash_kernel,
        sm_scale=scale,
        causal=causal,
        logit_cap=logit_cap,
        window=window,
        block_q=block_q,
        block_k=block_k,
        n_kv_blocks=nk,
        q_offset=skv - sq if causal else 0,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, iq, ik: (i, iq, 0)),
            pl.BlockSpec((1, block_k, d), lambda i, iq, ik: (i, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda i, iq, ik: (i, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda i, iq, ik: (i, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            _vmem((block_q, d), jnp.float32),   # acc
            _vmem((block_q, 1), jnp.float32),   # m (running max)
            _vmem((block_q, 1), jnp.float32),   # l (normaliser)
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
