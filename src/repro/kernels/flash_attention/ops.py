"""Jit'd public wrapper for the flash-attention kernel.

Handles model-layout plumbing: GQA broadcast (kv heads -> q heads), the
[b, s, h, d] <-> [bh, s, d] flattening, and padding to block multiples.
``use_kernel=False`` routes to the pure-jnp oracle — both paths share this
wrapper so tests sweep them identically.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_kernel
from .ref import flash_attention_ref


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "sm_scale", "logit_cap", "window",
        "block_q", "block_k", "use_kernel", "interpret",
    ),
)
def flash_attention(
    q: jax.Array,                 # [b, sq, h, d]
    k: jax.Array,                 # [b, skv, kv_heads, d]
    v: jax.Array,                 # [b, skv, kv_heads, d]
    *,
    causal: bool = True,
    sm_scale: Optional[float] = None,
    logit_cap: Optional[float] = None,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 512,
    use_kernel: bool = True,
    interpret: bool = False,
) -> jax.Array:
    b, sq, h, d = q.shape
    kh = k.shape[2]
    if h != kh:
        g = h // kh
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, k.shape[1], d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, v.shape[1], d)

    if use_kernel:
        of = flash_attention_kernel(
            qf, kf, vf,
            causal=causal, sm_scale=sm_scale, logit_cap=logit_cap,
            window=window, block_q=block_q, block_k=block_k,
            interpret=interpret,
        )
    else:
        of = flash_attention_ref(
            qf, kf, vf,
            causal=causal, sm_scale=sm_scale, logit_cap=logit_cap,
            window=window,
        )
    return of.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
