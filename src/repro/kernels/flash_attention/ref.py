"""Pure-jnp oracle for the flash-attention kernel.

Layout matches the kernel: q/k/v are head-flattened [bh, s, d]; GQA
broadcast (kv -> q heads) happens in ops.py before either path.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -2.0e38


def flash_attention_ref(
    q: jax.Array,              # [bh, sq, d]
    k: jax.Array,              # [bh, skv, d]
    v: jax.Array,              # [bh, skv, d]
    *,
    causal: bool = True,
    sm_scale: Optional[float] = None,
    logit_cap: Optional[float] = None,
    window: Optional[int] = None,
) -> jax.Array:
    bh, sq, d = q.shape
    skv = k.shape[1]
    scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(d)
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)
    q_pos = jnp.arange(sq)[:, None]
    kv_pos = jnp.arange(skv)[None, :]
    allowed = jnp.ones((sq, skv), bool)
    if causal:
        allowed &= kv_pos <= q_pos + (skv - sq)  # offset when sq != skv
    if window is not None:
        allowed &= kv_pos > q_pos + (skv - sq) - window
    s = jnp.where(allowed[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)
