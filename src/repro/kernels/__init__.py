"""repro.kernels — Pallas TPU kernels for the compute hot-spots.

Each kernel ships three files: <name>.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd model-layout wrapper), ref.py (pure-jnp oracle).
Kernels are validated in interpret mode on CPU; on TPU they replace the
pure-JAX paths (ForwardOptions.attn_impl etc.).
"""

from .flash_attention.ops import flash_attention
from .matmul.ops import chain_matmul, matmul
from .ssd.ops import ssd_mix

__all__ = ["chain_matmul", "flash_attention", "matmul", "ssd_mix"]
