"""repro.kernels — Pallas TPU kernels for the compute hot-spots.

Each kernel ships three files: <name>.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd model-layout wrapper), ref.py (pure-jnp oracle).
Kernels are validated in interpret mode on CPU; on TPU they replace the
pure-JAX paths (ForwardOptions.attn_impl etc.).

The package imports lazily (PEP 562): every ops module imports jax at
module scope, but the kernel_variants census family only needs kernel
*metadata* (names, tile grids, FLOP tables) until a workload is built —
importing ``repro.kernels`` itself stays jax-free until an attribute is
actually resolved.

Caveat (ordinary Python submodule semantics): ``matmul`` and
``flash_attention`` are both exported callables AND subpackages of this
package. Freshly importing a subpackage binds the *module* onto this
package — including as a side effect of ``__getattr__`` itself resolving
a sibling export (``chain_matmul`` lives in ``matmul.ops``, so resolving
it first would leave ``matmul`` shadowed for the rest of a
``from repro.kernels import chain_matmul, matmul``). ``__getattr__``
therefore repairs any export its own import just shadowed. A *user's*
dotted import (``import repro.kernels.matmul.ref``) before any export is
touched can still shadow the callable — code that needs the callables
unconditionally imports them from their defining module
(``from repro.kernels.matmul.ops import matmul``).
"""

from typing import TYPE_CHECKING

#: attribute name -> defining submodule (dotted: each kernel's ops layer)
_EXPORTS = {
    "flash_attention": "flash_attention.ops",
    "chain_matmul": "matmul.ops",
    "matmul": "matmul.ops",
    "ssd_mix": "ssd.ops",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    if name in _EXPORTS:
        import importlib
        import types

        module = importlib.import_module(f".{_EXPORTS[name]}", __name__)
        value = getattr(module, name)
        globals()[name] = value  # cache for subsequent lookups
        # importing the defining submodule binds like-named subpackages
        # (matmul/, flash_attention/) onto this package, shadowing the
        # exported callables; repair any export this import just shadowed
        for n, sub in _EXPORTS.items():
            if isinstance(globals().get(n), types.ModuleType):
                m = importlib.import_module(f".{sub}", __name__)
                globals()[n] = getattr(m, n)
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))


if TYPE_CHECKING:  # pragma: no cover - static analysis only
    from .flash_attention.ops import flash_attention
    from .matmul.ops import chain_matmul, matmul
    from .ssd.ops import ssd_mix
