"""repro.roofline — compute/memory/collective terms from compiled HLO."""

from .hlo import HloCounts, analyze, parse_hlo
from .terms import HBM_BW, ICI_BW, PEAK_FLOPS, RooflineTerms, terms_from_counts

__all__ = [
    "HBM_BW",
    "HloCounts",
    "ICI_BW",
    "PEAK_FLOPS",
    "RooflineTerms",
    "analyze",
    "parse_hlo",
    "terms_from_counts",
]
