"""repro.roofline — compute/memory/collective terms from compiled HLO."""

from .hlo import HloCounts, analyze, parse_hlo
from .terms import (
    DEFAULT_MACHINE,
    HBM_BW,
    ICI_BW,
    MACHINES,
    PEAK_FLOPS,
    MachineSpec,
    RooflineTerms,
    get_machine,
    register_machine,
    synthetic_machine,
    terms_from_counts,
)

__all__ = [
    "DEFAULT_MACHINE",
    "HBM_BW",
    "HloCounts",
    "ICI_BW",
    "MACHINES",
    "MachineSpec",
    "PEAK_FLOPS",
    "RooflineTerms",
    "analyze",
    "get_machine",
    "parse_hlo",
    "register_machine",
    "synthetic_machine",
    "terms_from_counts",
]
