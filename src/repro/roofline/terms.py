"""Roofline terms from the compiled dry-run artifact.

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16 per chip, 819 GB/s HBM
bandwidth, ~50 GB/s/link ICI.

    T_comp = HLO_FLOPs_per_device / peak_FLOPs
    T_mem  = HLO_bytes_per_device / HBM_bw
    T_coll = collective_bytes_per_device / ICI_bw

All inputs come from the per-device (post-SPMD) program via
:mod:`repro.roofline.hlo` (which fixes XLA cost_analysis' missing scan
trip-count multiplication). The dominant term is the bottleneck; the
roofline fraction reported in §Perf is T_ideal_compute / max(terms) where
T_ideal_compute uses analytic MODEL_FLOPS (so wasted HLO compute counts
against the score, not for it).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from .hlo import HloCounts

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    kind: str
    n_devices: int

    hlo_flops_per_dev: float
    hlo_bytes_per_dev: float
    collective_bytes_per_dev: float
    collective_breakdown: Dict[str, float]

    model_flops_total: float          # analytic 6ND-style
    memory_per_dev_bytes: float       # args + temp from memory_analysis

    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0

    def __post_init__(self) -> None:
        self.t_compute = self.hlo_flops_per_dev / PEAK_FLOPS
        self.t_memory = self.hlo_bytes_per_dev / HBM_BW
        self.t_collective = self.collective_bytes_per_dev / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def model_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        total_hlo = self.hlo_flops_per_dev * self.n_devices
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute roofline fraction (the §Perf score): ideal time
        for MODEL_FLOPS on all chips divided by the bounding term."""
        ideal = self.model_flops_total / (self.n_devices * PEAK_FLOPS)
        return ideal / self.t_bound if self.t_bound else 0.0

    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "kind": self.kind,
            "devices": self.n_devices,
            "t_compute_s": round(self.t_compute, 6),
            "t_memory_s": round(self.t_memory, 6),
            "t_collective_s": round(self.t_collective, 6),
            "dominant": self.dominant,
            "model_flops": f"{self.model_flops_total:.4e}",
            "hlo_flops_per_dev": f"{self.hlo_flops_per_dev:.4e}",
            "model_hlo_ratio": round(self.model_flops_ratio, 4),
            "roofline_fraction": round(self.roofline_fraction, 4),
            "mem_per_dev_gb": round(self.memory_per_dev_bytes / 2**30, 3),
            "collectives": {
                k: round(v / 2**30, 3) for k, v in self.collective_breakdown.items() if v
            },
        }


def terms_from_counts(
    arch: str,
    shape: str,
    mesh_desc: str,
    kind: str,
    n_devices: int,
    counts: HloCounts,
    model_flops_total: float,
    memory_per_dev_bytes: float,
) -> RooflineTerms:
    return RooflineTerms(
        arch=arch,
        shape=shape,
        mesh=mesh_desc,
        kind=kind,
        n_devices=n_devices,
        hlo_flops_per_dev=counts.flops,
        hlo_bytes_per_dev=counts.bytes,
        collective_bytes_per_dev=counts.total_collective_bytes,
        collective_breakdown=dict(counts.collective_bytes),
        model_flops_total=model_flops_total,
        memory_per_dev_bytes=memory_per_dev_bytes,
    )
