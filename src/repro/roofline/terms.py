"""Roofline terms from the compiled dry-run artifact.

Hardware is a selectable :class:`MachineSpec` (the :data:`MACHINES`
registry), defaulting to TPU v5e-class — 197 TFLOP/s bf16 per chip,
819 GB/s HBM bandwidth, ~50 GB/s/link ICI:

    T_comp = HLO_FLOPs_per_device / peak_FLOPs
    T_mem  = HLO_bytes_per_device / HBM_bw
    T_coll = collective_bytes_per_device / ICI_bw

All inputs come from the per-device (post-SPMD) program via
:mod:`repro.roofline.hlo` (which fixes XLA cost_analysis' missing scan
trip-count multiplication). The dominant term is the bottleneck; the
roofline fraction reported in §Perf is T_ideal_compute / max(terms) where
T_ideal_compute uses analytic MODEL_FLOPS (so wasted HLO compute counts
against the score, not for it).

Non-TPU hosts get roofline predictions too: the DiscriminantSweep census
runs on arbitrary CPUs and on a *synthetic* machine (the deterministic
cost-model backend), and the AnomalyExplainer needs per-kernel roofline
floors there — :func:`synthetic_machine` derives a spec from the sweep's
``flop_rate``, and ``cpu-1core`` models a pinned BLAS-on-one-core host.
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, Mapping, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    """The hardware constants every roofline term divides by.

    ``dispatch_overhead_s`` is the fixed per-kernel launch cost (Python
    dispatch + runtime) added on top of the compute/memory bound — zero for
    within-one-XLA-program analysis, nonzero when predicting sequences of
    separately dispatched kernels (the AnomalyExplainer's segment model).

    ``eff_curve`` is an optional calibrated GEMM-efficiency curve: sorted
    ``(flops, fraction_of_peak)`` anchor points fitted from
    micro-measurements (:mod:`repro.explain.calibrate`). Real machines
    reach nowhere near peak on tiny kernels — a µs-scale n=32 GEMM runs
    10-70x off the nominal roofline — so :meth:`t_compute` divides by the
    log-interpolated achieved rate instead of raw peak whenever a curve is
    present. Empty curve = nominal peak (the historical behaviour).
    """

    name: str
    peak_flops: float                 # FLOP/s
    hbm_bw: float                     # bytes/s
    ici_bw: float = 0.0               # bytes/s/link (0: no interconnect)
    dispatch_overhead_s: float = 0.0  # seconds per dispatched kernel
    eff_curve: Tuple[Tuple[float, float], ...] = ()  # (flops, frac of peak)

    def __post_init__(self) -> None:
        # JSON round-trips turn the curve into nested lists; normalise so
        # from_dict(to_dict(spec)) == spec holds (frozen: bypass setattr)
        curve = tuple(
            sorted((float(f), float(e)) for f, e in self.eff_curve)
        )
        object.__setattr__(self, "eff_curve", curve)
        if any(e <= 0.0 for _, e in curve):
            raise ValueError(f"eff_curve efficiencies must be > 0: {curve}")

    def efficiency_at(self, flops: float) -> float:
        """Calibrated fraction of peak achieved by a kernel of ``flops``:
        piecewise log-linear in flops between anchor points, clamped at the
        curve's ends. 1.0 when no curve is fitted."""
        curve = self.eff_curve
        if not curve:
            return 1.0
        if flops <= curve[0][0]:
            return curve[0][1]
        if flops >= curve[-1][0]:
            return curve[-1][1]
        for (f0, e0), (f1, e1) in zip(curve, curve[1:]):
            if f0 <= flops <= f1:
                if f1 <= f0:
                    return e1
                w = (math.log(flops) - math.log(f0)) / (
                    math.log(f1) - math.log(f0)
                )
                return e0 + w * (e1 - e0)
        return curve[-1][1]  # pragma: no cover - loop covers the range

    def t_compute(self, flops: float) -> float:
        return flops / (self.peak_flops * self.efficiency_at(flops))

    def t_memory(self, nbytes: float) -> float:
        if self.hbm_bw <= 0:
            return 0.0
        return nbytes / self.hbm_bw

    def t_collective(self, nbytes: float) -> float:
        if nbytes <= 0:
            return 0.0
        if self.ici_bw <= 0:
            raise ValueError(
                f"machine {self.name!r} has no interconnect (ici_bw=0) but "
                f"the program moves {nbytes:.3e} collective bytes"
            )
        return nbytes / self.ici_bw

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, object]) -> "MachineSpec":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)
                      if f.name in d})


#: Selectable hardware registry. ``tpu-v5e`` keeps the historical constants
#: (the module-level aliases below point at it); ``cpu-1core`` models the
#: census host: one pinned core of a ~3 GHz x86 (16 f32 FLOP/cycle FMA
#: throughput, one DDR channel's worth of bandwidth, ~µs JAX dispatch).
MACHINES: Dict[str, MachineSpec] = {
    "tpu-v5e": MachineSpec("tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
                           ici_bw=50e9),
    "cpu-1core": MachineSpec("cpu-1core", peak_flops=5e10, hbm_bw=2e10,
                             dispatch_overhead_s=2e-6),
}

DEFAULT_MACHINE = MACHINES["tpu-v5e"]

#: Back-compat aliases (pre-MachineSpec callers import these).
PEAK_FLOPS = DEFAULT_MACHINE.peak_flops
HBM_BW = DEFAULT_MACHINE.hbm_bw
ICI_BW = DEFAULT_MACHINE.ici_bw


def get_machine(name: str) -> MachineSpec:
    if name not in MACHINES:
        raise KeyError(f"unknown machine {name!r}; one of {sorted(MACHINES)}")
    return MACHINES[name]


def register_machine(spec: MachineSpec) -> MachineSpec:
    """Add (or replace) a registry entry; returns the spec for chaining."""
    MACHINES[spec.name] = spec
    return spec


def synthetic_machine(name: str, flop_rate: float) -> MachineSpec:
    """The DiscriminantSweep cost-model backend as a MachineSpec: a pure
    compute machine running at ``flop_rate`` — its predicted time for any
    kernel is exactly ``flops / flop_rate``, so per-kernel efficiency
    factors recovered against this roofline are the sweep's injected
    per-algorithm efficiency factors. No memory system (the synthetic
    machine has none): the memory term is 0 by ``hbm_bw=0`` convention."""
    return MachineSpec(name=name, peak_flops=float(flop_rate), hbm_bw=0.0)


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    kind: str
    n_devices: int

    hlo_flops_per_dev: float
    hlo_bytes_per_dev: float
    collective_bytes_per_dev: float
    collective_breakdown: Dict[str, float]

    model_flops_total: float          # analytic 6ND-style
    memory_per_dev_bytes: float       # args + temp from memory_analysis

    machine: MachineSpec = DEFAULT_MACHINE
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0

    def __post_init__(self) -> None:
        self.t_compute = self.machine.t_compute(self.hlo_flops_per_dev)
        self.t_memory = self.machine.t_memory(self.hlo_bytes_per_dev)
        self.t_collective = self.machine.t_collective(
            self.collective_bytes_per_dev
        )

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def model_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        total_hlo = self.hlo_flops_per_dev * self.n_devices
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute roofline fraction (the §Perf score): ideal time
        for MODEL_FLOPS on all chips divided by the bounding term."""
        ideal = self.model_flops_total / (self.n_devices * self.machine.peak_flops)
        return ideal / self.t_bound if self.t_bound else 0.0

    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "kind": self.kind,
            "devices": self.n_devices,
            "t_compute_s": round(self.t_compute, 6),
            "t_memory_s": round(self.t_memory, 6),
            "t_collective_s": round(self.t_collective, 6),
            "dominant": self.dominant,
            "model_flops": f"{self.model_flops_total:.4e}",
            "hlo_flops_per_dev": f"{self.hlo_flops_per_dev:.4e}",
            "model_hlo_ratio": round(self.model_flops_ratio, 4),
            "roofline_fraction": round(self.roofline_fraction, 4),
            "mem_per_dev_gb": round(self.memory_per_dev_bytes / 2**30, 3),
            "collectives": {
                k: round(v / 2**30, 3) for k, v in self.collective_breakdown.items() if v
            },
        }


def terms_from_counts(
    arch: str,
    shape: str,
    mesh_desc: str,
    kind: str,
    n_devices: int,
    counts: HloCounts,
    model_flops_total: float,
    memory_per_dev_bytes: float,
    machine: Optional[MachineSpec] = None,
) -> RooflineTerms:
    return RooflineTerms(
        arch=arch,
        shape=shape,
        mesh=mesh_desc,
        kind=kind,
        n_devices=n_devices,
        hlo_flops_per_dev=counts.flops,
        hlo_bytes_per_dev=counts.bytes,
        collective_bytes_per_dev=counts.total_collective_bytes,
        collective_breakdown=dict(counts.collective_bytes),
        model_flops_total=model_flops_total,
        memory_per_dev_bytes=memory_per_dev_bytes,
        machine=machine or DEFAULT_MACHINE,
    )
