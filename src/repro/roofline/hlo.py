"""HLO-text analyzer: FLOPs / bytes / collective bytes with correct
while-loop (scan) trip-count multiplication.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE — a scanned
model body is under-counted by its trip count (verified empirically; see
EXPERIMENTS.md §Dry-run methodology). This analyzer parses
``compiled.as_text()`` instead:

* builds the computation call graph (ENTRY -> while bodies -> fusions),
* extracts while trip counts from ``backend_config known_trip_count``,
* counts per-computation:
  - dot/convolution FLOPs (2 * prod(out) * prod(contracted dims)),
  - HBM traffic model: 2x output bytes of every instruction in
    *control-flow* computations (fused computations keep intermediates in
    registers/VMEM, so only the fusion's own output counts),
  - collective operand bytes per collective kind,
* rolls totals up through the call graph with trip-count multipliers.

All numbers are for the PER-DEVICE (post-SPMD-partitioning) program, which
is exactly what the roofline terms need.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(")
_DIMS_RE = re.compile(r"\{([\d,]*)\}")
# first lowercase word immediately followed by '(' after the type prefix
_OPCODE_RE = re.compile(r"(?:^|\s)([a-z][a-zA-Z0-9\-]*)\(")


def _shape_info(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """All (dtype, dims) arrays in a type string (handles tuples)."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dtype, shape))
    return out


def _nbytes(type_str: str) -> int:
    total = 0
    for dtype, shape in _shape_info(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n * DTYPE_BYTES[dtype]
    return total


def _prod(xs) -> int:
    n = 1
    for x in xs:
        n *= x
    return n


def _split_operands(s: str) -> List[str]:
    """Split an HLO operand list on top-level commas only (shape strings
    like ``f32[32,256]{1,0}`` embed commas inside brackets/braces)."""
    out: List[str] = []
    depth = 0
    cur: List[str] = []
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    tail = "".join(cur).strip()
    if tail:
        out.append(tail)
    return [o for o in out if o]


def _operand_name(op: str) -> str:
    """Bare instruction name of one operand: the trailing ``%name`` token
    (newer HLO prints operands with inline types, older as bare names)."""
    tok = op.split()[-1] if op.split() else op
    return tok.lstrip("%")


def _operand_type(op: str, comp: "Computation") -> str:
    """Type string of one operand — inline when present (jax >= 0.4 CPU
    dialect prints ``dot(f32[...] %x, ...)``), else looked up from the
    defining instruction in the enclosing computation."""
    if _SHAPE_RE.search(op):
        return op
    return comp.shapes.get(_operand_name(op), "")


@dataclass
class Instruction:
    name: str
    rhs: str                 # everything right of '='
    out_type: str            # first type string
    opcode: str
    is_root: bool = False


@dataclass
class Computation:
    name: str
    instructions: List[Instruction] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)   # instr -> type str
    is_entry: bool = False


@dataclass
class HloCounts:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def scaled(self, k: float) -> "HloCounts":
        return HloCounts(
            flops=self.flops * k,
            bytes=self.bytes * k,
            collective_bytes={n: v * k for n, v in self.collective_bytes.items()},
        )

    def add(self, other: "HloCounts") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        for n, v in other.collective_bytes.items():
            self.collective_bytes[n] = self.collective_bytes.get(n, 0.0) + v


COLLECTIVE_OPS = (
    "all-reduce-start", "all-reduce", "all-gather-start", "all-gather",
    "reduce-scatter", "all-to-all", "collective-permute-start",
    "collective-permute",
)

_SKIP_BYTES_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id",
    # control flow: carries alias in place; the ops INSIDE move the data
    "while", "conditional", "call",
}


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for line in text.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr is not None and "=" not in line.split("(")[0]:
            current = Computation(name=hdr.group(2), is_entry=bool(hdr.group(1)))
            comps[current.name] = current
            continue
        if current is None:
            continue
        if line.startswith("}"):
            current = None
            continue
        m = _INSTR_RE.match(line)
        if m is None:
            continue
        name, rhs = m.group(1), m.group(2)
        # rhs = "<type> opcode(operands), attrs". Tuple types start with '('
        # so we locate the opcode as the first lowercase word directly
        # followed by '(' that sits OUTSIDE the type prefix.
        op_m = _OPCODE_RE.search(rhs)
        if op_m is None:
            continue
        out_type = rhs[: op_m.start()].strip()
        opcode = op_m.group(1)
        instr = Instruction(
            name=name, rhs=rhs, out_type=out_type, opcode=opcode,
            is_root="ROOT" in line.split("%", 1)[0],
        )
        current.instructions.append(instr)
        current.shapes[name] = out_type
    return comps


def _dot_flops(instr: Instruction, comp: Computation) -> float:
    """FLOPs of a dot: 2 * prod(output dims) * prod(lhs contracting dims)."""
    arrays = _shape_info(instr.out_type)
    if not arrays:
        return 0.0
    out_elems = _prod(arrays[0][1])
    m = re.search(r"dot\(([^)]*)\)", instr.rhs)
    if m is None:
        return 0.0
    operands = _split_operands(m.group(1))
    lhs_arrays = _shape_info(_operand_type(operands[0], comp)) if operands else []
    cdims_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rhs)
    if not lhs_arrays or cdims_m is None:
        return 2.0 * out_elems  # conservative fallback
    lhs_shape = lhs_arrays[0][1]
    cdims = [int(d) for d in cdims_m.group(1).split(",") if d]
    k = _prod(lhs_shape[d] for d in cdims) if cdims else 1
    return 2.0 * out_elems * k


def _conv_flops(instr: Instruction, comp: Computation) -> float:
    arrays = _shape_info(instr.out_type)
    if not arrays:
        return 0.0
    out_elems = _prod(arrays[0][1])
    m = re.search(r"convolution\(([^)]*)\)", instr.rhs)
    if m is None:
        return 0.0
    operands = _split_operands(m.group(1))
    if len(operands) < 2:
        return 0.0
    rhs_arrays = _shape_info(_operand_type(operands[1], comp))
    if not rhs_arrays:
        return 2.0 * out_elems
    kernel_elems = _prod(rhs_arrays[0][1])
    # per output element: 2 * kernel_elems / out_channels (dim mapping is
    # config-dependent; this coarse form is fine — convs are negligible here)
    return 2.0 * out_elems * max(kernel_elems, 1) ** 0.5


def _dus_update_bytes(
    instr: "Instruction",
    comp: "Computation",
    comps: Dict[str, "Computation"],
) -> Optional[float]:
    """If ``instr`` is a dynamic-update-slice (or a fusion rooted in one),
    return the byte size of the UPDATE operand; else None."""
    if instr.opcode == "dynamic-update-slice":
        m = re.search(r"dynamic-update-slice\(([^)]*)\)", instr.rhs)
        if m:
            ops = _split_operands(m.group(1))
            if len(ops) >= 2:
                return float(_nbytes(_operand_type(ops[1], comp)))
        return None
    if instr.opcode == "fusion":
        m = _CALLS_RE.search(instr.rhs)
        if not m or m.group(1) not in comps:
            return None
        callee = comps[m.group(1)]
        roots = [i for i in callee.instructions if i.is_root]
        root = roots[0] if roots else (callee.instructions[-1] if callee.instructions else None)
        if root is None or root.opcode != "dynamic-update-slice":
            return None
        mm = re.search(r"dynamic-update-slice\(([^)]*)\)", root.rhs)
        if mm:
            ops = _split_operands(mm.group(1))
            if len(ops) >= 2:
                return float(_nbytes(_operand_type(ops[1], callee)))
    return None


def _operand_bytes(instr: "Instruction", comp: "Computation") -> float:
    m = re.search(r"\(([^)]*)\)", instr.rhs)
    if not m:
        return 0.0
    total = 0.0
    for op in _split_operands(m.group(1)):
        total += _nbytes(_operand_type(op, comp))
    return total


#: TPU-calibrated HBM traffic model: elementwise chains fuse into their
#: producers on the TPU target (the CPU HLO we analyze leaves them unfused),
#: so only *major* ops are charged for HBM traffic.
def _op_hbm_bytes(
    instr: "Instruction", comp: "Computation", comps: Dict[str, "Computation"]
) -> float:
    op = instr.opcode
    out_b = _nbytes(instr.out_type)
    if op in ("dot", "convolution"):
        return _operand_bytes(instr, comp) + out_b
    if op in ("copy", "transpose", "reverse", "reshape", "all-to-all",
              "collective-permute", "all-gather", "all-reduce",
              "reduce-scatter"):
        # data movement: read + write (collectives touch HBM both ways on
        # top of the ICI bytes tracked separately)
        return 2.0 * out_b
    if op in ("gather", "dynamic-slice"):
        return 2.0 * out_b
    if op in ("scatter", "dynamic-update-slice"):
        upd = _dus_update_bytes(instr, comp, comps)
        return 2.0 * (upd if upd is not None else out_b)
    if op in ("reduce", "reduce-window", "sort"):
        return _operand_bytes(instr, comp) + out_b
    if op == "fusion":
        upd = _dus_update_bytes(instr, comp, comps)
        if upd is not None:
            return 2.0 * upd
        # fusion writes its output once; its consumers read it once.
        # Parameter reads inside (weights feeding fused elementwise) are
        # charged where major ops consume them.
        return 2.0 * out_b
    return 0.0  # elementwise / control flow / metadata: fused on TPU


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def analyze(text: str) -> HloCounts:
    comps = parse_hlo(text)
    fused: Set[str] = set()
    for comp in comps.values():
        for instr in comp.instructions:
            if instr.opcode == "fusion":
                m = _CALLS_RE.search(instr.rhs)
                if m:
                    fused.add(m.group(1))
            # reduce/sort/scatter apply computations are elementwise-tiny —
            # treat as fused (no HBM traffic of their own).
            m = _APPLY_RE.search(instr.rhs)
            if m:
                fused.add(m.group(1))

    memo: Dict[str, HloCounts] = {}

    def comp_counts(name: str, stack: Tuple[str, ...] = ()) -> HloCounts:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return HloCounts()
        comp = comps[name]
        total = HloCounts()
        in_fused = name in fused
        for instr in comp.instructions:
            if instr.opcode == "dot":
                total.flops += _dot_flops(instr, comp)
            elif instr.opcode == "convolution":
                total.flops += _conv_flops(instr, comp)
            elif instr.opcode.startswith("while"):
                body = _BODY_RE.search(instr.rhs)
                trip_m = _TRIP_RE.search(instr.rhs)
                trip = int(trip_m.group(1)) if trip_m else 1
                if body:
                    total.add(comp_counts(body.group(1), stack + (name,)).scaled(trip))
                cond = _COND_RE.search(instr.rhs)
                if cond:
                    total.add(comp_counts(cond.group(1), stack + (name,)).scaled(trip))
            elif instr.opcode == "fusion":
                m = _CALLS_RE.search(instr.rhs)
                if m:
                    sub = comp_counts(m.group(1), stack + (name,))
                    # FLOPs inside fusions count; bytes don't (fused
                    # intermediates never reach HBM).
                    total.flops += sub.flops
                    for n, v in sub.collective_bytes.items():
                        total.collective_bytes[n] = total.collective_bytes.get(n, 0) + v
            elif instr.opcode in ("call", "custom-call", "async-start"):
                m = _APPLY_RE.search(instr.rhs) or _CALLS_RE.search(instr.rhs)
                if m:
                    total.add(comp_counts(m.group(1), stack + (name,)))
            elif instr.opcode == "conditional":
                m = _BRANCHES_RE.search(instr.rhs)
                if m:
                    branches = [b.strip().lstrip("%") for b in m.group(1).split(",")]
                    branch_counts = [comp_counts(b, stack + (name,)) for b in branches]
                    if branch_counts:  # worst-case branch
                        worst = max(branch_counts, key=lambda c: c.flops + c.bytes)
                        total.add(worst)

            base = instr.opcode.replace("-start", "") + (
                "-start" if instr.opcode.endswith("-start") else ""
            )
            for coll in ("all-reduce", "all-gather", "reduce-scatter",
                         "all-to-all", "collective-permute"):
                if instr.opcode == coll or instr.opcode == coll + "-start":
                    m = re.search(r"\(([^)]*)\)", instr.rhs)
                    if m:
                        bts = 0
                        for op in _split_operands(m.group(1)):
                            bts += _nbytes(_operand_type(op, comp))
                        total.collective_bytes[coll] = (
                            total.collective_bytes.get(coll, 0.0) + bts
                        )
                    break

            if not in_fused:
                total.bytes += _op_hbm_bytes(instr, comp, comps)

        memo[name] = total
        return total

    entry = next((c.name for c in comps.values() if c.is_entry), None)
    if entry is None:
        return HloCounts()
    return comp_counts(entry)


def breakdown_by_opcode(text: str) -> Dict[str, Dict[str, float]]:
    """Per-opcode {flops, bytes} totals with trip-count weighting — the
    §Perf hypothesis generator ("what moves the dominant term")."""
    comps = parse_hlo(text)
    fused: Set[str] = set()
    for comp in comps.values():
        for instr in comp.instructions:
            if instr.opcode == "fusion":
                m = _CALLS_RE.search(instr.rhs)
                if m:
                    fused.add(m.group(1))
            m = _APPLY_RE.search(instr.rhs)
            if m:
                fused.add(m.group(1))

    table: Dict[str, Dict[str, float]] = {}
    memo_mult: Dict[str, float] = {}

    def visit(name: str, mult: float, stack=()) -> None:
        if name not in comps or name in stack:
            return
        comp = comps[name]
        in_fused = name in fused
        for instr in comp.instructions:
            rec = table.setdefault(instr.opcode, {"flops": 0.0, "bytes": 0.0, "count": 0.0})
            if instr.opcode == "dot":
                rec["flops"] += mult * _dot_flops(instr, comp)
            elif instr.opcode == "convolution":
                rec["flops"] += mult * _conv_flops(instr, comp)
            if not in_fused:
                rec["bytes"] += mult * _op_hbm_bytes(instr, comp, comps)
            rec["count"] += mult
            if instr.opcode.startswith("while"):
                body = _BODY_RE.search(instr.rhs)
                trip_m = _TRIP_RE.search(instr.rhs)
                trip = int(trip_m.group(1)) if trip_m else 1
                if body:
                    visit(body.group(1), mult * trip, stack + (name,))
            elif instr.opcode == "fusion":
                m = _CALLS_RE.search(instr.rhs)
                if m:
                    visit(m.group(1), mult, stack + (name,))
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    if entry:
        visit(entry, 1.0)
    return table


def attention_score_traffic(
    text: str, seq_dims: Sequence[int]
) -> float:
    """HBM bytes attributable to materialised attention-score-shaped
    tensors: any non-fused instruction whose output's trailing two dims are
    both in ``seq_dims`` (e.g. {4096, 256} for a seq-sharded 4k cell).

    The Pallas flash-attention kernel keeps these tiles in VMEM; the
    kernel-adjusted memory term subtracts this traffic (EXPERIMENTS.md
    §Perf records both the XLA-attention and kernel-path numbers).
    """
    comps = parse_hlo(text)
    fused: Set[str] = set()
    for comp in comps.values():
        for instr in comp.instructions:
            if instr.opcode == "fusion":
                m = _CALLS_RE.search(instr.rhs)
                if m:
                    fused.add(m.group(1))
            m = _APPLY_RE.search(instr.rhs)
            if m:
                fused.add(m.group(1))
    sset = set(int(s) for s in seq_dims)

    total = 0.0

    def visit(name: str, mult: float, stack=()) -> None:
        nonlocal total
        if name not in comps or name in stack:
            return
        comp = comps[name]
        in_fused = name in fused
        for instr in comp.instructions:
            if not in_fused:
                arrays = _shape_info(instr.out_type)
                if arrays:
                    shape = arrays[0][1]
                    # rank >= 4 [b, h, sq, skv]: avoids counting [b, s, d]
                    # residuals when d_model happens to equal seq_len.
                    if (
                        len(shape) >= 4
                        and shape[-1] in sset
                        and shape[-2] in sset
                    ):
                        total += mult * _op_hbm_bytes(instr, comp, comps)
            if instr.opcode.startswith("while"):
                body = _BODY_RE.search(instr.rhs)
                trip_m = _TRIP_RE.search(instr.rhs)
                trip = int(trip_m.group(1)) if trip_m else 1
                if body:
                    visit(body.group(1), mult * trip, stack + (name,))
            elif instr.opcode == "fusion":
                m = _CALLS_RE.search(instr.rhs)
                if m:
                    visit(m.group(1), mult, stack + (name,))

    entry = next((c.name for c in comps.values() if c.is_entry), None)
    if entry:
        visit(entry, 1.0)
    return total
