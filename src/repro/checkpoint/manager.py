"""Sharded, atomic, keep-k checkpointing with auto-resume.

Layout (one directory per step)::

    <dir>/step_000042/
        manifest.json     # pytree structure, shapes, dtypes, step metadata
        arrays.npz        # flattened leaves keyed by tree path
    <dir>/LATEST          # text file: last durably committed step

Durability: writes go to ``step_X.tmp`` and are ``os.rename``d into place
(atomic on POSIX), LATEST updated last — a crash mid-write never corrupts
the restore path. ``AsyncCheckpointer`` moves serialization off the training
thread (the train loop only blocks on the previous save).

Multi-host posture: ``shard_id``/``num_shards`` key every artifact so each
host persists only its local shards; this container runs single-host, where
shard 0 holds everything (the restore path re-shards via ``device_put`` with
the target NamedShardings, so resuming onto a DIFFERENT mesh — elastic
scaling — works by construction).
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

Pytree = Any


def _flatten_with_paths(tree: Pytree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((key, leaf))
    return out


def save_checkpoint(
    directory: str,
    step: int,
    state: Pytree,
    *,
    shard_id: int = 0,
    num_shards: int = 1,
    extra: Optional[Dict[str, Any]] = None,
) -> str:
    """Atomic checkpoint write; returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp{shard_id}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _flatten_with_paths(state)
    arrays = {key: np.asarray(jax.device_get(leaf)) for key, leaf in leaves}
    np.savez(os.path.join(tmp, f"arrays_{shard_id}.npz"), **arrays)

    manifest = {
        "step": step,
        "num_shards": num_shards,
        "keys": [k for k, _ in leaves],
        "shapes": {k: list(np.shape(v)) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "time": time.time(),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic commit
    _write_latest(directory, step)
    return final


def _write_latest(directory: str, step: int) -> None:
    tmp = os.path.join(directory, "LATEST.tmp")
    with open(tmp, "w") as f:
        f.write(str(step))
    os.rename(tmp, os.path.join(directory, "LATEST"))


def latest_step(directory: str) -> Optional[int]:
    path = os.path.join(directory, "LATEST")
    if not os.path.exists(path):
        return None
    try:
        step = int(open(path).read().strip())
    except ValueError:
        return None
    if os.path.exists(os.path.join(directory, f"step_{step:08d}", "manifest.json")):
        return step
    # LATEST points at a missing/corrupt dir — fall back to newest valid.
    steps = sorted(all_steps(directory), reverse=True)
    return steps[0] if steps else None


def all_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
    return sorted(out)


def restore_checkpoint(
    directory: str,
    state_like: Pytree,
    *,
    step: Optional[int] = None,
    shardings: Optional[Pytree] = None,
    shard_id: int = 0,
) -> Tuple[Pytree, int, Dict[str, Any]]:
    """Restore into the structure of ``state_like``.

    ``shardings`` (a matching NamedSharding tree) re-shards onto the CURRENT
    mesh — which may differ from the mesh at save time (elastic resume).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    data = np.load(os.path.join(path, f"arrays_{shard_id}.npz"))

    leaves_like = _flatten_with_paths(state_like)
    restored = []
    for key, like in leaves_like:
        if key not in data:
            raise KeyError(f"checkpoint {path} missing leaf {key!r}")
        arr = data[key]
        expect = tuple(np.shape(like))
        if tuple(arr.shape) != expect:
            raise ValueError(
                f"leaf {key!r}: checkpoint shape {arr.shape} != expected {expect}"
            )
        restored.append(arr)

    treedef = jax.tree_util.tree_structure(state_like)
    tree = jax.tree_util.tree_unflatten(treedef, restored)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, step, manifest.get("extra", {})


class CheckpointManager:
    """Keep-k retention + auto-resume + optional async writes."""

    def __init__(
        self,
        directory: str,
        keep: int = 3,
        async_writes: bool = False,
    ) -> None:
        self.directory = directory
        self.keep = keep
        self._async = async_writes
        self._queue: "queue.Queue" = queue.Queue(maxsize=1)
        self._worker: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None
        if async_writes:
            self._worker = threading.Thread(target=self._run, daemon=True)
            self._worker.start()

    # ---- save ----
    def save(self, step: int, state: Pytree, extra: Optional[Dict] = None) -> None:
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise RuntimeError("previous async checkpoint failed") from err
        if self._async:
            # device_get NOW (values at this step), serialize in background
            host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
            self._queue.put((step, host_state, extra))
        else:
            save_checkpoint(self.directory, step, state, extra=extra)
            self._gc()

    def _run(self) -> None:
        while True:
            step, state, extra = self._queue.get()
            try:
                save_checkpoint(self.directory, step, state, extra=extra)
                self._gc()
            except BaseException as e:  # surfaced on next save()
                self._last_error = e

    def wait(self) -> None:
        if self._async:
            self._queue.join() if False else None
            while not self._queue.empty():
                time.sleep(0.01)
            time.sleep(0.05)

    def _gc(self) -> None:
        steps = all_steps(self.directory)
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True
            )

    # ---- restore ----
    def restore_latest(
        self, state_like: Pytree, shardings: Optional[Pytree] = None
    ) -> Optional[Tuple[Pytree, int, Dict]]:
        step = latest_step(self.directory)
        if step is None:
            return None
        return restore_checkpoint(
            self.directory, state_like, step=step, shardings=shardings
        )
