"""repro.checkpoint — atomic, sharded, keep-k checkpointing."""

from .manager import (
    CheckpointManager,
    all_steps,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = [
    "CheckpointManager",
    "all_steps",
    "latest_step",
    "restore_checkpoint",
    "save_checkpoint",
]
