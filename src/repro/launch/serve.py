"""Serving launcher: batched generation against any registry arch.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --tokens 24

Smoke configs on the host mesh; on TPU the same step functions jit with the
decode shardings from the distribution plan (KV cache seq-sharded over
'model' — the decode_32k / long_500k dry-run cells).
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCH_NAMES, get_config
from repro.models import init_lm_params
from repro.serve.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-14b", choices=ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.7)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if cfg.is_encoder_decoder:
        raise SystemExit("pick an LM arch for the generation launcher")
    params, _ = init_lm_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(
        cfg, params,
        max_len=args.prompt_len + args.tokens + 8,
        temperature=args.temperature,
    )
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    # first call pays compilation; block before reading the clock so both
    # timings measure execution, not async dispatch
    t0 = time.time()
    out = jax.block_until_ready(engine.generate(prompts, n_new=args.tokens))
    dt_compile = time.time() - t0
    t0 = time.time()
    out = jax.block_until_ready(engine.generate(prompts, n_new=args.tokens))
    dt = time.time() - t0
    print(f"{args.arch} (smoke): {args.batch}x{args.tokens} tokens in "
          f"{dt_compile:.2f}s incl. compile, then {dt:.2f}s steady-state "
          f"({args.batch*args.tokens/dt:.1f} tok/s)")
    print("sample:", list(map(int, out[0, args.prompt_len:])))


if __name__ == "__main__":
    main()
