"""Per-cell lowering plans: (architecture x input-shape x mesh) -> a
jittable step with fully specified in/out shardings and ShapeDtypeStruct
arguments (no device allocation — the shannon/kernels dry-run pattern).

``build_cell`` returns a :class:`CellPlan` whose ``lower()`` produces the
jax ``Lowered`` artifact for ``train_step`` / ``prefill`` / ``serve_step``
as the shape's kind dictates.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.shapes import ShapeSpec
from repro.distributed.sharding import (
    batch_spec,
    dp_axes,
    dp_size,
    make_plan,
    state_specs,
    tree_shardings,
    tp_size,
)
from repro.models import (
    ForwardOptions,
    ModelConfig,
    init_encdec_params,
    init_encdec_state,
    init_lm_params,
    init_lm_state,
)
from repro.serve.engine import make_prefill, make_serve_step
from repro.train.optimizer import AdamW, cosine_schedule
from repro.train.trainer import (
    LossConfig,
    TrainState,
    init_train_state,
    make_train_step,
)

Pytree = Any

#: Activation budget for remat-saved unit inputs per device; drives the
#: microbatch count heuristic.
SAVED_ACT_BUDGET_BYTES = 2 << 30


def _shape_tree(tree: Pytree) -> Pytree:
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def param_shapes(cfg: ModelConfig) -> Tuple[Pytree, Pytree]:
    """(ShapeDtypeStruct tree, logical-axes tree) with ZERO allocation.

    The logical-axes tree contains static string tuples that ``eval_shape``
    cannot return, so it is captured by side effect during the single trace.
    """
    box: Dict[str, Any] = {}
    init = init_encdec_params if cfg.is_encoder_decoder else init_lm_params

    def f(key):
        values, axes = init(cfg, key)
        box["axes"] = axes
        return values

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box["axes"]


def pick_microbatches(
    cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, seq_sharded: bool
) -> int:
    """Smallest divisor of the per-DP-group batch whose remat-saved
    activations fit the per-device budget."""
    dpn = dp_size(mesh)
    b_local = max(shape.global_batch // dpn, 1)
    tp = tp_size(mesh) if seq_sharded else 1
    per_seq = shape.seq_len * cfg.d_model * 2  # bf16 residual stream
    for n_micro in [d for d in range(1, b_local + 1) if b_local % d == 0]:
        saved = cfg.n_units * (b_local // n_micro) * per_seq / tp
        if saved <= SAVED_ACT_BUDGET_BYTES:
            return n_micro
    return b_local


@dataclasses.dataclass
class CellPlan:
    arch: str
    shape: ShapeSpec
    mesh: Mesh
    cfg: ModelConfig
    kind: str                      # train | prefill | decode
    fn: Callable                   # pure step function
    args: Tuple[Pytree, ...]       # ShapeDtypeStruct trees
    in_shardings: Tuple[Pytree, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    num_microbatches: int = 1
    attention_strategy: str = ""
    notes: Tuple[str, ...] = ()

    def lower(self):
        jitted = jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )
        return jitted.lower(*self.args)


def _named(mesh: Mesh, spec: PartitionSpec) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _replicated(mesh: Mesh, tree: Pytree) -> Pytree:
    return jax.tree.map(
        lambda x: _named(mesh, PartitionSpec(*([None] * len(x.shape)))), tree
    )


def build_cell(
    arch: str,
    cfg: ModelConfig,
    shape: ShapeSpec,
    mesh: Mesh,
    opts_override: Optional[Dict[str, Any]] = None,
) -> CellPlan:
    if shape.kind == "train":
        return _build_train_cell(arch, cfg, shape, mesh, opts_override or {})
    if shape.kind == "prefill":
        return _build_prefill_cell(arch, cfg, shape, mesh, opts_override or {})
    return _build_decode_cell(arch, cfg, shape, mesh, opts_override or {})


# ------------------------------------------------------------------ train --

def _sharding_opts(cfg, shape, mesh, plan, overrides, training: bool):
    """Boundary/interior/attention sharding choices (DESIGN.md §5)."""
    notes = []
    tp = tp_size(mesh)
    dpa = dp_axes(mesh)
    dpn = dp_size(mesh)
    b, s = shape.global_batch, shape.seq_len

    b_rule = dpa if (dpa and b % dpn == 0) else None
    # Megatron-SP: carry seq-sharded over model => remat-saved activations
    # divide by tp. Interior re-gathers (AG fwd + AG in remat recompute;
    # the trailing constraint turns the last all-reduce into an RS).
    boundary = interior = None
    if training and overrides.get("sp_boundary", True) and s % tp == 0:
        boundary = _named(mesh, PartitionSpec(b_rule, ("model",), None))
        interior = _named(mesh, PartitionSpec(b_rule, None, None))
        notes.append("SP: carry seq-sharded over model; interior gathered")

    # Attention core for archs whose heads don't divide tp: sequence-shard
    # the QUERIES over 'model' (scores [b, H, sq/tp, skv]) with K/V
    # replicated — head-count-agnostic, no batch reshard, exact FLOPs split.
    attn_q = attn_kv = None
    attn_q_block = 0
    gqa_mode = "broadcast" if plan.attention == "head_q" else "grouped"
    if plan.attention == "sequence" and s % tp == 0:
        attn_q = _named(mesh, PartitionSpec(b_rule, ("model",), None, None))
        attn_kv = _named(mesh, PartitionSpec(b_rule, None, None, None))
        notes.append("attention q seq-sharded over model, K/V replicated")
        if not training:
            # prefill at 32k: kv-only chunking keeps peak scores bounded
            # without q-dim dynamic slicing over the sharded axis.
            attn_q_block = -1
    # nothing here for sequence strategy beyond the above
    elif plan.attention in ("head", "head_q"):
        # Pin the attention-core layout to head-sharded. Without the pin,
        # GSPMD mixes head-sharded forward with seq-sharded backward and
        # inserts all-to-all layout ping-pong + f32 rematerialisations
        # (audited on granite-8b train_4k: ~460 GB/device of avoidable
        # traffic). The constraint applies after the broadcast repeat, so
        # K/V carry H heads in head_q mode too.
        head_spec = _named(mesh, PartitionSpec(b_rule, None, ("model",), None))
        attn_q = head_spec
        attn_kv = head_spec if gqa_mode == "broadcast" else (
            head_spec if cfg.n_kv_heads % tp == 0 else None
        )
        notes.append("attention core pinned head-sharded")
    return boundary, interior, attn_q, attn_kv, attn_q_block, gqa_mode, notes


def _moe_compute_shardings(cfg, mesh, plan):
    """Compute-time expert-weight pin — REFUTED in §Perf iterations it3/it4
    (qwen2-moe train_4k): replicating the ZeRO 'data' shard of d_model at
    use forced GSPMD into fully replicated expert compute (HLO FLOPs x6.4,
    bytes x5.6). Mechanism retained for experimentation; returns None so the
    default path lets GSPMD resolve the contraction (partial-sum ARs, which
    measured CHEAPER than the forced gather)."""
    return None


def _build_train_cell(arch, cfg, shape, mesh, overrides) -> CellPlan:
    plan = make_plan(cfg, mesh, mode="train")
    boundary, interior, attn_q, attn_kv, attn_q_block, gqa_mode, notes = _sharding_opts(
        cfg, shape, mesh, plan, overrides, training=True
    )

    b_local = max(shape.global_batch // dp_size(mesh), 1)
    n_micro = overrides.get(
        "num_microbatches",
        pick_microbatches(cfg, shape, mesh, boundary is not None),
    )
    n_micro = min(n_micro, b_local)  # cannot split below 1 seq/microbatch
    # Training attention default: 'reference' up to 8k — with heads sharded
    # the score matrix is ~1-2 GB ephemeral, whereas the chunked nested-scan
    # BACKWARD materialises every block's scores as saved residuals (audited:
    # ~13 GB/unit on granite-8b). Beyond 8k, chunked (the Pallas kernel path
    # on real TPU has a flash backward and wins everywhere).
    default_attn = "reference" if shape.seq_len <= 8192 else "chunked"
    opts = ForwardOptions(
        attn_impl=overrides.get("attn_impl", default_attn),
        moe_dispatch=overrides.get("moe_dispatch", "gather"),
        mamba_impl="chunked",
        remat=overrides.get("remat", "full"),
        gqa_mode=overrides.get("gqa_mode", gqa_mode),
        boundary_sharding=boundary,
        interior_sharding=interior,
        attn_q_sharding=attn_q,
        attn_kv_sharding=attn_kv,
        attn_q_block=overrides.get("attn_q_block", attn_q_block),
        moe_compute_shardings=_moe_compute_shardings(cfg, mesh, plan),
    )

    # ---- shapes (zero allocation) ----
    params_s, axes = param_shapes(cfg)
    optimizer = AdamW(schedule=cosine_schedule(3e-4, 2000, 100_000))
    state_s = jax.eval_shape(
        lambda p: init_train_state(cfg, optimizer, p), params_s
    )

    param_sh = tree_shardings(plan, axes, params_s)
    # optimizer state shares the param shardings leaf-for-leaf; step scalar
    # replicated.
    opt_sh = type(state_s.opt)(
        step=_named(mesh, PartitionSpec()),
        master=param_sh,
        mu=param_sh,
        nu=param_sh,
    )
    state_sh = TrainState(params=param_sh, opt=opt_sh)

    b, s = shape.global_batch, shape.seq_len
    bspec = batch_spec(mesh, b, extra_dims=1)
    batch_s: Dict[str, Any] = {}
    batch_sh: Dict[str, Any] = {}
    if cfg.is_encoder_decoder:
        batch_s["enc_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype)
        )
        batch_sh["enc_embeds"] = _named(mesh, batch_spec(mesh, b, extra_dims=2))
        batch_s["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        batch_sh["tokens"] = _named(mesh, bspec)
    elif cfg.frontend == "vision_stub":
        batch_s["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.dtype(cfg.dtype))
        batch_sh["embeds"] = _named(mesh, batch_spec(mesh, b, extra_dims=2))
        notes.append("vlm: precomputed patch+token embeddings enter as 'embeds'")
    else:
        batch_s["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        batch_sh["tokens"] = _named(mesh, bspec)
    batch_s["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    batch_sh["labels"] = _named(mesh, bspec)

    step = make_train_step(cfg, optimizer, opts, LossConfig(), num_microbatches=n_micro)

    metrics_sh = None  # let XLA choose for the small metric scalars
    return CellPlan(
        arch=arch,
        shape=shape,
        mesh=mesh,
        cfg=cfg,
        kind="train",
        fn=step,
        args=(state_s, batch_s),
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metrics_sh),
        donate_argnums=(0,),
        num_microbatches=n_micro,
        attention_strategy=plan.attention,
        notes=tuple(notes + plan.fallbacks),
    )


# ---------------------------------------------------------------- prefill --

def _build_prefill_cell(arch, cfg, shape, mesh, overrides) -> CellPlan:
    plan = make_plan(cfg, mesh, mode="prefill")
    b, s = shape.global_batch, shape.seq_len
    _, _, attn_q, attn_kv, attn_q_block, gqa_mode, notes = _sharding_opts(
        cfg, shape, mesh, plan, overrides, training=False
    )

    opts = ForwardOptions(
        attn_impl=overrides.get("attn_impl", "chunked"),
        moe_dispatch=overrides.get("moe_dispatch", "gather"),
        mamba_impl="chunked",
        gqa_mode=overrides.get("gqa_mode", gqa_mode),
        attn_q_sharding=attn_q,
        attn_kv_sharding=attn_kv,
        attn_q_block=overrides.get("attn_q_block", attn_q_block),
        moe_compute_shardings=_moe_compute_shardings(cfg, mesh, plan),
    )

    if cfg.is_encoder_decoder:
        params_s, axes = param_shapes(cfg)
        state_s = jax.eval_shape(
            lambda: init_encdec_state(cfg, b, s, cfg.encoder_seq)
        )
        fn = make_prefill(cfg, opts)
        enc_s = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        param_sh = tree_shardings(plan, axes, params_s)
        st_sh = state_specs(cfg, plan, state_s, b)
        args = (params_s, state_s, enc_s)
        in_sh = (param_sh, st_sh, _named(mesh, batch_spec(mesh, b, extra_dims=2)))
        out_sh = st_sh
        donate = (1,)
    else:
        params_s, axes = param_shapes(cfg)
        state_s = jax.eval_shape(lambda: init_lm_state(cfg, b, s))
        fn = make_prefill(cfg, opts)
        param_sh = tree_shardings(plan, axes, params_s)
        st_sh = state_specs(cfg, plan, state_s, b)
        if cfg.frontend == "vision_stub":
            in_s = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.dtype(cfg.dtype))
            in_batch_sh = _named(mesh, batch_spec(mesh, b, extra_dims=2))
            fn = functools.partial(_prefill_embeds, fn)
            args = (params_s, state_s, in_s)
            notes.append("vlm prefill via precomputed embeds")
        else:
            in_s = jax.ShapeDtypeStruct((b, s), jnp.int32)
            in_batch_sh = _named(mesh, batch_spec(mesh, b, extra_dims=1))
            args = (params_s, state_s, in_s)
        in_sh = (param_sh, st_sh, in_batch_sh)
        out_sh = (None, st_sh)
        donate = (1,)

    return CellPlan(
        arch=arch, shape=shape, mesh=mesh, cfg=cfg, kind="prefill",
        fn=fn, args=args, in_shardings=in_sh, out_shardings=out_sh,
        donate_argnums=donate,
        attention_strategy=plan.attention,
        notes=tuple(notes + plan.fallbacks),
    )


def _prefill_embeds(prefill_fn, params, state, embeds):
    return prefill_fn(params, state, tokens=None, embeds=embeds)


# ----------------------------------------------------------------- decode --

def _build_decode_cell(arch, cfg, shape, mesh, overrides) -> CellPlan:
    plan = make_plan(cfg, mesh, mode="decode")
    notes = []
    b, s = shape.global_batch, shape.seq_len

    opts = ForwardOptions(
        moe_dispatch=overrides.get("moe_dispatch", "gather"),
        moe_compute_shardings=_moe_compute_shardings(
            cfg, mesh, make_plan(cfg, mesh, mode="decode")
        ),
    )
    fn = make_serve_step(cfg, opts)

    if cfg.is_encoder_decoder:
        params_s, axes = param_shapes(cfg)
        state_s = jax.eval_shape(
            lambda: init_encdec_state(cfg, b, s, cfg.encoder_seq)
        )
    else:
        params_s, axes = param_shapes(cfg)
        state_s = jax.eval_shape(lambda: init_lm_state(cfg, b, s))

    param_sh = tree_shardings(plan, axes, params_s)
    st_sh = state_specs(cfg, plan, state_s, b)
    tok_s = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tok_sh = _named(mesh, batch_spec(mesh, b, extra_dims=1))
    len_s = jax.ShapeDtypeStruct((), jnp.int32)
    len_sh = _named(mesh, PartitionSpec())

    return CellPlan(
        arch=arch, shape=shape, mesh=mesh, cfg=cfg, kind="decode",
        fn=fn,
        args=(params_s, state_s, tok_s, len_s),
        in_shardings=(param_sh, st_sh, tok_sh, len_sh),
        out_shardings=(None, st_sh),
        donate_argnums=(1,),
        attention_strategy=plan.attention,
        notes=tuple(notes + plan.fallbacks),
    )
