import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

__doc__ = """§Perf iteration driver: lower one cell with overrides, compute
baseline AND kernel-adjusted roofline terms, append to the iteration log.

    python -m repro.launch.perf --arch qwen2-moe-a2.7b --shape train_4k \
        --label it2_kernel --override '{"num_microbatches": 8}'

Kernel adjustment (the Pallas flash-attention path on real TPU):
  * memory: subtract materialised score-tensor traffic (VMEM-resident in
    the kernel);
  * compute: subtract half the attention-score FLOPs for causal cells
    (block-level skip in the kernel vs the rectangle the XLA path runs).
Both the XLA-path and kernel-path terms are recorded so the §Perf table
shows measured vs modelled-on-TPU numbers separately.

Campaign mode — rank the logged iterations of one (arch, shape) pair with
the paper's methodology over the roofline cost model:

    python -m repro.launch.perf --rank-labels --arch ... --shape ... \
        [--rel-sigma 0.05] [--max-steps N] [--resume]

Each logged label becomes an algorithm; a CostModelTimer draws from its
kernel-adjusted bounding term. The ExperimentEngine campaign persists to
reports/perf_campaign_<arch>_<shape>.json, so a partial run (--max-steps)
resumes bit-identically with --resume (cost-model timers serialize their
RNG state).
"""

import argparse
import json
import time
from typing import Any, Dict, Optional

from repro.configs import SHAPES, get_config
from repro.launch.dryrun import model_flops_for
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell
from repro.models import ModelConfig
from repro.models.config import LayerKind
from repro.roofline import analyze, terms_from_counts
from repro.roofline.hlo import attention_score_traffic
from repro.roofline.terms import DEFAULT_MACHINE

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), "../../.."))
LOG = os.path.join(ROOT, "reports", "perf_iterations.json")


def causal_score_flops(cfg: ModelConfig, b: int, s: int, training: bool) -> float:
    """Per-step FLOPs the flash kernel SKIPS vs the full rectangle: the
    strictly-upper causal half of QKᵀ and PV, fwd (+2x bwd when training)."""
    hd = cfg.resolved_head_dim
    n_attn = sum(
        spec.kind in (LayerKind.ATTN, LayerKind.ATTN_LOCAL)
        for spec in cfg.pattern_unit()
    ) * cfg.n_units
    rect = 4.0 * b * s * s * cfg.n_heads * hd * n_attn  # QK^T + PV fwd
    skipped = rect / 2.0
    return skipped * (3.0 if training else 1.0)


def run_iteration(
    arch: str,
    shape_name: str,
    label: str,
    overrides: Optional[Dict[str, Any]] = None,
    hypothesis: str = "",
) -> Dict[str, Any]:
    cfg = get_config(arch, smoke=False)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=False)

    t0 = time.time()
    cell = build_cell(arch, cfg, shape, mesh, opts_override=dict(overrides or {}))
    compiled = cell.lower().compile()
    txt = compiled.as_text()
    ma = compiled.memory_analysis()
    mem = ma.argument_size_in_bytes + ma.temp_size_in_bytes
    counts = analyze(txt)
    mf = model_flops_for(cfg, shape)

    terms = terms_from_counts(
        arch=arch, shape=shape_name, mesh_desc="16x16", kind=shape.kind,
        n_devices=mesh.devices.size, counts=counts,
        model_flops_total=mf, memory_per_dev_bytes=mem,
    )

    # --- kernel-adjusted (Pallas flash path) ---
    tp = 16
    sdims = {shape.seq_len, shape.seq_len // tp}
    score_bytes = attention_score_traffic(txt, sdims) if shape.kind != "decode" else 0.0
    skip_flops = 0.0
    if shape.kind in ("train", "prefill") and cfg.family != "ssm":
        skip_flops = causal_score_flops(
            cfg, shape.global_batch, shape.seq_len, shape.kind == "train"
        ) / mesh.devices.size
    adj_bytes = max(counts.bytes - score_bytes, 0.0)
    adj_flops = max(counts.flops - skip_flops, 0.0)
    machine = DEFAULT_MACHINE
    t_mem_k = machine.t_memory(adj_bytes)
    t_comp_k = machine.t_compute(adj_flops)
    t_bound_k = max(t_comp_k, t_mem_k, terms.t_collective)
    ideal = mf / (mesh.devices.size * machine.peak_flops)
    frac_k = ideal / t_bound_k if t_bound_k else 0.0

    row = terms.row()
    row.update({
        "label": label,
        "hypothesis": hypothesis,
        "overrides": overrides or {},
        "num_microbatches": cell.num_microbatches,
        "attention_strategy": cell.attention_strategy,
        "kernel_adjusted": {
            "score_bytes_gb": round(score_bytes / 2**30, 2),
            "skipped_flops": f"{skip_flops:.3e}",
            "t_compute_s": round(t_comp_k, 4),
            "t_memory_s": round(t_mem_k, 4),
            "t_collective_s": round(terms.t_collective, 4),
            "dominant": max(
                [("compute", t_comp_k), ("memory", t_mem_k),
                 ("collective", terms.t_collective)], key=lambda kv: kv[1],
            )[0],
            "roofline_fraction": round(frac_k, 4),
        },
        "compile_s": round(time.time() - t0, 1),
    })
    log = json.load(open(LOG)) if os.path.exists(LOG) else []
    log.append(row)
    os.makedirs(os.path.dirname(LOG), exist_ok=True)
    json.dump(log, open(LOG, "w"), indent=1)
    return row


def campaign_path(arch: str, shape: str) -> str:
    safe = f"{arch}_{shape}".replace("/", "_").replace(".", "_")
    return os.path.join(ROOT, "reports", f"perf_campaign_{safe}.json")


def rank_logged_labels(
    arch: str,
    shape: str,
    rel_sigma: float = 0.05,
    max_steps: Optional[int] = None,
    resume: bool = False,
):
    """Rank this (arch, shape)'s logged §Perf iterations as an engine
    campaign over the kernel-adjusted roofline model. Returns the
    TuneReport, or None when fewer than two labels are logged."""
    from repro.autotune import CampaignSite, rank_sites
    from repro.core import CostModelTimer

    rows = json.load(open(LOG)) if os.path.exists(LOG) else []
    rows = [r for r in rows if r.get("arch") == arch and r.get("shape") == shape]
    state = campaign_path(arch, shape)
    site_name = f"{arch}/{shape}"

    if resume and os.path.exists(state):
        reports = rank_sites(resume_from=state, max_steps=max_steps,
                             save_path=state)
        return reports.get(site_name)

    costs, flops = {}, {}
    for r in rows:
        ka = r.get("kernel_adjusted", {})
        label = r.get("label")
        if not label or not ka:
            continue
        costs[label] = max(
            ka.get("t_compute_s", 0.0), ka.get("t_memory_s", 0.0),
            ka.get("t_collective_s", 0.0),
        )
        flops[label] = float(r.get("hlo_flops_per_dev", "0") or 0)
    if len(costs) < 2:
        return None
    site = CampaignSite(
        name=site_name,
        timer=CostModelTimer(costs, rel_sigma=rel_sigma),
        flops=flops,
        backend="cost-model",
    )
    reports = rank_sites([site], max_steps=max_steps, save_path=state)
    return reports[site_name]


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", required=True)
    p.add_argument("--label", default=None)
    p.add_argument("--hypothesis", default="")
    p.add_argument("--override", default=None)
    p.add_argument("--rank-labels", action="store_true",
                   help="rank this (arch, shape)'s logged labels as an "
                        "engine campaign over the roofline cost model")
    p.add_argument("--rel-sigma", type=float, default=0.05)
    p.add_argument("--max-steps", type=int, default=None)
    p.add_argument("--resume", action="store_true",
                   help="resume a persisted --rank-labels campaign")
    args = p.parse_args()

    if args.rank_labels:
        report = rank_logged_labels(
            args.arch, args.shape, rel_sigma=args.rel_sigma,
            max_steps=args.max_steps, resume=args.resume,
        )
        if report is None:
            print(f"need >= 2 logged labels for {args.arch}/{args.shape} in {LOG}")
        else:
            print(report.summary())
            print(f"campaign state: {campaign_path(args.arch, args.shape)}")
        return

    if args.label is None:
        p.error("--label is required unless --rank-labels is given")
    row = run_iteration(
        args.arch, args.shape, args.label,
        overrides=json.loads(args.override) if args.override else None,
        hypothesis=args.hypothesis,
    )
    ka = row["kernel_adjusted"]
    print(f"{args.label}: mem={row['mem_per_dev_gb']}GB "
          f"XLA[tc={row['t_compute_s']} tm={row['t_memory_s']} tx={row['t_collective_s']} "
          f"frac={row['roofline_fraction']}] "
          f"KERNEL[tc={ka['t_compute_s']} tm={ka['t_memory_s']} dom={ka['dominant']} "
          f"frac={ka['roofline_fraction']}]")


if __name__ == "__main__":
    main()
