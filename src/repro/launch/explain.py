"""AnomalyExplainer launcher — plan / run / report for explanation campaigns.

Consume a DiscriminantSweep census, fan its anomalies out across worker
processes (each driving resumable ExperimentEngine campaigns over the
winner/loser kernel segments, :mod:`repro.explain.runner`), then merge the
sharded explanation records and report ranked, evidence-backed cause tables.

    # explain every anomaly of a finished census, 4 workers, resumable
    PYTHONPATH=src python -m repro explain run \\
        --census /tmp/census --out /tmp/census_explain --workers 4

    # inspect / continue / report
    PYTHONPATH=src python -m repro explain status --out DIR
    PYTHONPATH=src python -m repro explain run    --out DIR --workers 4
    PYTHONPATH=src python -m repro explain merge  --out DIR
    PYTHONPATH=src python -m repro explain report --out DIR

Layout under ``--out`` mirrors the sweep: ``espec.json`` (campaign spec; the
work list is a pure function of it plus the census records),
``shard-NNNN.jsonl`` (append-only explanation records),
``shard-NNNN.manifest.json``, ``shard-NNNN.engine.json`` (in-flight chunk,
present only mid-chunk), ``merged.jsonl`` (after ``merge``).

Resume semantics match the sweep: ``run`` is idempotent, and for the
deterministic census backends (``cost_model``, ``simulated``) a SIGKILLed
explain run resumes byte-identical to an uninterrupted one.

Explanation campaigns are also drainable by many machines at once via the
pull-based work queue (``python -m repro queue work --out DIR``) —
see :mod:`repro.launch.queue`.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import List, Optional

from repro.core.sweep import StoreDamaged
from repro.explain.runner import (
    SPEC_FILE,
    ExplainSpec,
    explain_progress,
    explain_summary,
    merge_explained,
    run_explain_shard,
    write_merged_explained,
)
from repro.launch.cliutil import add_fsck_args, deprecated_alias, fsck_command
from repro.launch.sweep import _int_list, _worker_env


def spec_path(out: str) -> str:
    return os.path.join(out, SPEC_FILE)


def add_campaign_args(p: argparse.ArgumentParser) -> None:
    g = p.add_argument_group("campaign (used when OUT has no espec.json yet)")
    g.add_argument("--census", default=None,
                   help="DiscriminantSweep --out directory to explain")
    g.add_argument("--name", default="explain")
    g.add_argument("--shards", type=int, default=4)
    g.add_argument("--m-per-iteration", type=int, default=3)
    g.add_argument("--eps", type=float, default=0.03)
    g.add_argument("--max-measurements", type=int, default=12)
    g.add_argument("--chunk-size", type=int, default=8)
    g.add_argument("--save-every", type=int, default=25)
    g.add_argument("--machine", default="",
                   help="MachineSpec registry name for the roofline floor "
                   "(default: derived from the census backend)")
    g.add_argument("--machine-file", default="",
                   help="calibration JSON from the `calibrate` subcommand; "
                   "overrides --machine with the fitted "
                   "dispatch/efficiency-curve spec")
    g.add_argument("--min-evidence", type=float, default=0.5,
                   help="fraction of the time gap a cause must explain")
    g.add_argument("--flip-probes", type=int, default=16,
                   help="re-ranking probe batches behind not_reproducible")
    g.add_argument("--flip-z", type=float, default=3.0,
                   help="median-gap z below which the probe runs")
    g.add_argument("--flip-min-prob", type=float, default=0.25,
                   help="minimum probed flip probability before an "
                   "insignificant gap counts as not_reproducible")
    g.add_argument("--ladder", default="report",
                   choices=["report", "paper"],
                   help="session quantile ladder: 'report' (default) runs "
                   "one sort per step — all the explainer needs (medians + "
                   "convergence, same samples in the same order); 'paper' "
                   "keeps the census's full 7-range ladder")
    g.add_argument("--seed", type=int, default=0)
    g.add_argument("--fsync", action="store_true")


def load_or_plan_spec(args: argparse.Namespace, *, announce: bool = True) -> ExplainSpec:
    path = spec_path(args.out)
    if os.path.exists(path):
        espec = ExplainSpec.load(path)
        if announce:
            print(f"# using existing plan {path} (census {espec.census})")
        return espec
    if not args.census:
        raise SystemExit(f"{path} missing and no --census given")
    census = os.path.abspath(args.census)
    if not os.path.exists(os.path.join(census, "spec.json")):
        raise SystemExit(f"{census} is not a sweep directory (no spec.json)")
    if os.path.abspath(args.out) == census:
        raise SystemExit(
            "--out must differ from --census (both store shard-NNNN files)"
        )
    os.makedirs(args.out, exist_ok=True)
    espec = ExplainSpec(
        name=args.name,
        census=census,
        n_shards=args.shards,
        m_per_iteration=args.m_per_iteration,
        eps=args.eps,
        max_measurements=args.max_measurements,
        chunk_size=args.chunk_size,
        save_every=args.save_every,
        machine=args.machine,
        machine_file=(
            os.path.abspath(args.machine_file) if args.machine_file else ""
        ),
        min_evidence=args.min_evidence,
        flip_probes=args.flip_probes,
        flip_z=args.flip_z,
        flip_min_prob=args.flip_min_prob,
        ladder=args.ladder,
        base_seed=args.seed,
        fsync=args.fsync,
    )
    espec.save(path)
    if announce:
        prog = explain_progress(espec, args.out)
        print(f"# planned {prog['anomalies']} anomaly explanations over "
              f"{espec.n_shards} shards (census {census})")
    return espec


# ------------------------------------------------------------- subcommands ---


def cmd_plan(args: argparse.Namespace) -> int:
    path = spec_path(args.out)
    if os.path.exists(path) and not args.force:
        raise SystemExit(f"{path} exists; pass --force to re-plan")
    if os.path.exists(path):
        os.remove(path)
        removed = 0
        for fn in sorted(os.listdir(args.out)):
            if (fn.startswith("shard-") and
                    fn.split(".", 1)[-1] in ("jsonl", "manifest.json",
                                             "engine.json", "timings.json",
                                             "lease.json")) \
                    or fn == "merged.jsonl":
                os.remove(os.path.join(args.out, fn))
                removed += 1
        qdir = os.path.join(args.out, "quarantine")
        if os.path.isdir(qdir):
            # quarantined damage belongs to the old plan's records
            import shutil

            shutil.rmtree(qdir)
            removed += 1
        if removed:
            print(f"# --force: removed {removed} stale shard/merge artifacts")
    espec = load_or_plan_spec(args)
    prog = explain_progress(espec, args.out)
    for row in prog["shards"]:
        print(f"#   shard {row['shard']:4d}: {row['total']} anomalies")
    print(f"# spec: {path}")
    return 0


def cmd_run(args: argparse.Namespace) -> int:
    from repro.explain.runner import explain_targets

    espec = load_or_plan_spec(args, announce=False)
    _, targets = explain_targets(espec)  # parse the census once
    prog = explain_progress(espec, args.out, targets=targets)
    print(f"# explaining {prog['anomalies']} anomalies from {espec.census} "
          f"({espec.n_shards} shards)")
    if prog["anomalies"] == 0:
        print("# census has no anomalies — nothing to explain")
        write_merged_explained(espec, args.out)
        return 0
    workers = max(1, min(args.workers, espec.n_shards))
    assignment = {
        w: [s for s in range(espec.n_shards) if s % workers == w]
        for w in range(workers)
    }
    procs: List[subprocess.Popen] = []
    for w, shards in assignment.items():
        cmd = [
            sys.executable, "-m", "repro", "explain", "work",
            "--out", args.out, "--shards", ",".join(map(str, shards)),
        ]
        if args.max_steps_per_shard is not None:
            cmd += ["--max-steps-per-shard", str(args.max_steps_per_shard)]
        procs.append(subprocess.Popen(cmd, env=_worker_env()))
    failed = []
    for w, proc in enumerate(procs):
        rc = proc.wait()
        if rc != 0:
            failed.append((w, rc))
    prog = explain_progress(espec, args.out, targets=targets)
    print(f"# {prog['completed']}/{prog['anomalies']} anomalies explained")
    if failed:
        for w, rc in failed:
            print(f"# worker {w} exited {rc} (shards {assignment[w]})",
                  file=sys.stderr)
        print("# re-run the same command to resume", file=sys.stderr)
        return 1
    if prog["completed"] == prog["anomalies"]:
        try:
            path = write_merged_explained(espec, args.out)
        except StoreDamaged as err:
            print(f"# merge refused: {err}", file=sys.stderr)
            return 1
        print(f"# merged explanations: {path}")
    return 0


def cmd_work(args: argparse.Namespace) -> int:
    """Internal: run an assigned shard list sequentially (one worker)."""
    from repro.explain.runner import explain_targets

    espec = ExplainSpec.load(spec_path(args.out))
    census = explain_targets(espec)  # parse the census once per worker
    for shard in _int_list(args.shards):
        run_explain_shard(
            espec, args.out, shard,
            max_steps=args.max_steps_per_shard,
            progress=lambda msg: print(f"# {msg}", flush=True),
            census=census,
        )
    return 0


def cmd_status(args: argparse.Namespace) -> int:
    espec = ExplainSpec.load(spec_path(args.out))
    prog = explain_progress(espec, args.out)
    print(f"# explain {prog['name']}: {prog['completed']}/{prog['anomalies']} "
          f"anomalies explained")
    for row in prog["shards"]:
        flag = " (chunk in flight)" if row["in_flight_chunk"] else ""
        damage = f" DAMAGED x{row['damaged']}" if row.get("damaged") else ""
        print(f"#   shard {row['shard']:4d}: {row['done']}/{row['total']}"
              f"{flag}{damage}")
    if prog.get("damaged"):
        print(f"# {prog['damaged']} damaged record line(s) — merge will "
              f"refuse; run: python -m repro fsck --out {args.out}")
    return 0


def cmd_merge(args: argparse.Namespace) -> int:
    espec = ExplainSpec.load(spec_path(args.out))
    try:
        path = write_merged_explained(espec, args.out)
    except StoreDamaged as err:
        print(f"# merge refused: {err}", file=sys.stderr)
        return 1
    n = sum(1 for _ in open(path))
    print(f"# merged {n} explanations -> {path}")
    return 0


def cmd_calibrate(args: argparse.Namespace) -> int:
    """Fit a machine's dispatch/GEMM-efficiency curve from
    micro-measurements and save it for ``run --machine-file``."""
    import dataclasses

    from repro.explain.calibrate import (
        DEFAULT_SIZES,
        calibration_table,
        fit_calibration,
        micro_points_synthetic,
        micro_points_wall_clock,
        synthetic_truth,
    )
    from repro.roofline.terms import MachineSpec, get_machine

    if args.peak_flops:
        # a custom-peak spec is NOT the registry machine: only carry the
        # --machine name over when the caller explicitly chose one
        base = MachineSpec(
            name=args.machine if args.machine is not None else "custom",
            peak_flops=args.peak_flops,
            hbm_bw=args.hbm_bw,
        )
    else:
        base = get_machine(args.machine if args.machine is not None
                           else "cpu-1core")
    sizes = _int_list(args.sizes) if args.sizes else list(DEFAULT_SIZES)
    if args.backend == "wall_clock":
        points = micro_points_wall_clock(sizes, reps=args.reps, seed=args.seed)
    else:
        truth = synthetic_truth(
            base,
            dispatch_s=args.truth_dispatch_us * 1e-6,
            eff_knee=args.truth_eff_knee,
            sizes=sizes,
        )
        points = micro_points_synthetic(
            truth, sizes, reps=args.reps, seed=args.seed,
            rel_sigma=args.truth_noise,
        )
    # fit against the dispatch-free nominal spec: dispatch is an OUTPUT
    result = fit_calibration(
        dataclasses.replace(base, dispatch_overhead_s=0.0, eff_curve=()),
        points,
    )
    print(calibration_table(result))
    path = result.save(args.out_file)
    print(f"# calibration -> {path} (pass --machine-file {path} to "
          "plan/run/report)")
    return 0


def cmd_report(args: argparse.Namespace) -> int:
    from repro.launch.report_md import explain_tables

    espec = ExplainSpec.load(spec_path(args.out))
    records = merge_explained(espec, args.out)
    if args.json:
        json.dump(explain_summary(records), sys.stdout, indent=1, sort_keys=True)
        print()
        return 0
    if not records:
        print("(no explained anomalies yet — run the campaign first)")
        return 1
    print(explain_tables(records, name=espec.name))
    return 0


def main(argv: Optional[List[str]] = None, prog: Optional[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog=prog or "repro.launch.explain",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("plan", help="snapshot the campaign spec (espec.json)")
    p.add_argument("--out", required=True)
    p.add_argument("--force", action="store_true")
    add_campaign_args(p)
    p.set_defaults(fn=cmd_plan)

    p = sub.add_parser("run", help="run/resume the campaign with N workers")
    p.add_argument("--out", required=True)
    p.add_argument("--workers", type=int, default=1)
    p.add_argument("--max-steps-per-shard", type=int, default=None,
                   help="pause each shard after N engine steps (resumable)")
    add_campaign_args(p)
    p.set_defaults(fn=cmd_run)

    p = sub.add_parser("work", help="internal: run an assigned shard list")
    p.add_argument("--out", required=True)
    p.add_argument("--shards", required=True, help="comma list of shard ids")
    p.add_argument("--max-steps-per-shard", type=int, default=None)
    p.set_defaults(fn=cmd_work)

    p = sub.add_parser("status", help="explained/total anomalies per shard")
    p.add_argument("--out", required=True)
    p.set_defaults(fn=cmd_status)

    p = sub.add_parser("merge", help="merge shard JSONLs into merged.jsonl")
    p.add_argument("--out", required=True)
    p.set_defaults(fn=cmd_merge)

    p = sub.add_parser("fsck", help="classify/repair/quarantine store damage")
    add_fsck_args(p)
    p.set_defaults(fn=fsck_command)

    p = sub.add_parser(
        "calibrate",
        help="fit a machine's dispatch/GEMM-efficiency curve from "
        "micro-measurements (for run --machine-file)",
    )
    p.add_argument("--out-file", required=True,
                   help="where to save the calibration JSON")
    p.add_argument("--machine", default=None,
                   help="base MachineSpec registry name (default cpu-1core; "
                   "with --peak-flops: the custom spec's name, default "
                   "'custom')")
    p.add_argument("--peak-flops", type=float, default=None,
                   help="build a custom base spec at this peak instead of "
                   "--machine (e.g. a census's synthetic flop_rate)")
    p.add_argument("--hbm-bw", type=float, default=0.0,
                   help="bytes/s of the custom base spec (with --peak-flops)")
    p.add_argument("--backend", default="wall_clock",
                   choices=["wall_clock", "synthetic"],
                   help="synthetic = deterministic draws from a known "
                   "ground-truth machine (tests/CI)")
    p.add_argument("--sizes", default="",
                   help="comma list of GEMM ladder sizes (default 8..256)")
    p.add_argument("--reps", type=int, default=25)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--truth-dispatch-us", type=float, default=2.0,
                   help="synthetic backend: ground-truth dispatch (us)")
    p.add_argument("--truth-eff-knee", type=float, default=64.0,
                   help="synthetic backend: eff(n)=n/(n+knee); 0 = flat")
    p.add_argument("--truth-noise", type=float, default=0.02,
                   help="synthetic backend: lognormal measurement noise")
    p.set_defaults(fn=cmd_calibrate)

    p = sub.add_parser("report", help="cause tables (markdown)")
    p.add_argument("--out", required=True)
    p.add_argument("--json", action="store_true",
                   help="raw explain_summary JSON instead of markdown")
    p.set_defaults(fn=cmd_report)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    deprecated_alias("repro.launch.explain", "explain")
    sys.exit(main())
