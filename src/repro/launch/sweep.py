"""DiscriminantSweep launcher — plan / run / merge / report for the census.

Fan a grid of expression instances out across worker processes, each worker
driving its shards through resumable ExperimentEngine campaigns
(:mod:`repro.core.sweep`), then merge the sharded JSONL results and report
anomaly rates by family and instance size (paper Figs. 5-7).

    # 220-instance default census, 4 workers, resumable under DIR
    PYTHONPATH=src python -m repro census run --out DIR --workers 4

    # inspect / continue
    PYTHONPATH=src python -m repro census status --out DIR
    PYTHONPATH=src python -m repro census run --out DIR --workers 4
    PYTHONPATH=src python -m repro census merge --out DIR
    PYTHONPATH=src python -m repro census report --out DIR

Shard layout under ``--out``: ``spec.json`` (the full grid + campaign
parameters; everything downstream is a pure function of it),
``shard-NNNN.jsonl`` (append-only census records), ``shard-NNNN.manifest.json``
(completed set summary), ``shard-NNNN.engine.json`` (in-flight chunk
campaign, present only mid-chunk), ``merged.jsonl`` (after ``merge``).

Resume semantics: ``run`` is idempotent — re-running after ANY interruption
(including SIGKILL of the whole process group) continues from the last
persisted chunk state and, for the deterministic backends (``cost_model``,
``simulated``), produces a census byte-identical to an uninterrupted run.

To drain one census with MANY machines instead of many local workers,
point any number of ``python -m repro queue work --out DIR`` processes at
the same (shared-filesystem) store — shards are leased dynamically rather
than assigned (:mod:`repro.launch.queue`).

An ACTIVE census (``--predictor MODEL.json``) consults a trained cost
model (:mod:`repro.predict`) before measuring: instances whose predicted
ranking confidence clears ``--predict-threshold`` are committed as
``predicted``-provenance records without measurement; the skip fraction
is surfaced in ``status`` and the report, never silent.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict, List, Optional

import repro
from repro.core.family import family_names, get_family
from repro.launch.cliutil import add_fsck_args, deprecated_alias, fsck_command
from repro.core.sweep import (
    ShardStore,
    StoreDamaged,
    SweepSpec,
    census_summary,
    merge_shards,
    run_shard,
    sweep_progress,
    write_merged,
)

SPEC_FILE = "spec.json"


def spec_path(out: str) -> str:
    return os.path.join(out, SPEC_FILE)


def _int_list(s: str) -> List[int]:
    return [int(x) for x in s.split(",") if x]


def add_grid_args(p: argparse.ArgumentParser) -> None:
    g = p.add_argument_group("grid (used when OUT has no spec.json yet)")
    g.add_argument("--name", default="census")
    g.add_argument("--chains", type=int, default=120,
                   help="random chain instances (0 disables the family)")
    g.add_argument("--chain-sizes", type=_int_list, default=[3, 4],
                   metavar="N,N", help="matrices per chain, cycled")
    g.add_argument("--lo", type=int, default=32, help="min chain dim")
    g.add_argument("--hi", type=int, default=512, help="max chain dim")
    g.add_argument("--families", default="gram,distributive,solve,bilinear",
                   help="beyond-chain families (comma list, empty disables; "
                   "add kernel_variants to census the repo's own kernels)")
    g.add_argument("--sizes", type=_int_list, default=[64, 96, 128, 192, 256],
                   metavar="N,N", help="sizes per beyond-chain family")
    g.add_argument("--per-size", type=int, default=5,
                   help="seeded instances per (family, size)")
    g.add_argument("--kernel-sites", default="matmul,attention,ssd",
                   help="kernel_variants sites (comma list); only read when "
                   "--families includes kernel_variants")
    g.add_argument("--kernel-native", action="store_true",
                   help="run kernel_variants Pallas kernels compiled for the "
                   "local accelerator instead of interpret mode (the manual "
                   "GPU/TPU lane)")
    g.add_argument("--shards", type=int, default=8)
    g.add_argument("--backend", default="cost_model",
                   choices=["cost_model", "simulated", "wall_clock"])
    g.add_argument("--flop-rate", type=float, default=5e10)
    g.add_argument("--eff-sigma", type=float, default=0.05)
    g.add_argument("--noise-sigma", type=float, default=0.02)
    g.add_argument("--bimodal-shift", type=float, default=0.0)
    g.add_argument("--bimodal-prob", type=float, default=0.0)
    g.add_argument("--bimodal-frac", type=float, default=1.0,
                   help="fraction of instances whose simulated timer goes "
                   "bimodal (turbo-regime ground truth; 1.0 = all)")
    g.add_argument("--cache-reuse-frac", type=float, default=0.0,
                   help="per-algorithm probability of an injected "
                   "inter-kernel cache-reuse saving")
    g.add_argument("--cache-reuse-saving", type=float, default=0.0,
                   help="whole-run fraction saved by an injected "
                   "cache-reuse effect")
    g.add_argument("--dispatch-s", type=float, default=0.0,
                   help="synthetic per-kernel dispatch overhead (seconds); "
                   "dominates tiny instances")
    g.add_argument("--m-per-iteration", type=int, default=3)
    g.add_argument("--eps", type=float, default=0.03)
    g.add_argument("--max-measurements", type=int, default=24)
    g.add_argument("--rt-threshold", type=float, default=1.5)
    g.add_argument("--policy", default="least_converged_first",
                   choices=["round_robin", "least_converged_first"])
    g.add_argument("--chunk-size", type=int, default=8)
    g.add_argument("--save-every", type=int, default=25)
    g.add_argument("--seed", type=int, default=0)
    g.add_argument("--fsync", action="store_true",
                   help="fsync record batches (survive power loss, not just "
                   "SIGKILL; serializes workers on many filesystems)")
    g.add_argument("--predictor", default="",
                   help="trained cost model JSON (python -m repro predict "
                   "train); makes the census ACTIVE — instances whose "
                   "predicted ranking confidence clears --predict-threshold "
                   "are emitted as predicted records instead of measured")
    g.add_argument("--predict-threshold", type=float, default=0.95,
                   help="confidence needed to skip measuring an instance")


def spec_from_args(args: argparse.Namespace) -> SweepSpec:
    families: Dict[str, Dict] = {}
    chain_grid = get_family("chain").grid_from_args(args)
    if chain_grid is not None:
        families["chain"] = chain_grid
    known = tuple(n for n in family_names() if n != "chain")
    for fam in [f for f in args.families.split(",") if f]:
        if fam not in known:
            raise SystemExit(f"unknown family {fam!r}; one of {known}")
        grid = get_family(fam).grid_from_args(args)
        if grid is not None:
            families[fam] = grid
    return SweepSpec(
        name=args.name,
        families=families,
        n_shards=args.shards,
        backend=args.backend,
        flop_rate=args.flop_rate,
        eff_sigma=args.eff_sigma,
        noise_sigma=args.noise_sigma,
        bimodal_shift=args.bimodal_shift,
        bimodal_prob=args.bimodal_prob,
        bimodal_frac=args.bimodal_frac,
        cache_reuse_frac=args.cache_reuse_frac,
        cache_reuse_saving=args.cache_reuse_saving,
        dispatch_s=args.dispatch_s,
        m_per_iteration=args.m_per_iteration,
        eps=args.eps,
        max_measurements=args.max_measurements,
        rt_threshold=args.rt_threshold,
        policy=args.policy,
        chunk_size=args.chunk_size,
        save_every=args.save_every,
        base_seed=args.seed,
        fsync=args.fsync,
        predictor_model=args.predictor,
        predict_threshold=args.predict_threshold,
    )


def load_or_plan_spec(args: argparse.Namespace, *, announce: bool = True) -> SweepSpec:
    path = spec_path(args.out)
    if os.path.exists(path):
        spec = SweepSpec.load(path)
        if announce:
            print(f"# using existing plan {path} "
                  f"({len(spec.expand())} instances, {spec.n_shards} shards)")
        return spec
    os.makedirs(args.out, exist_ok=True)
    spec = spec_from_args(args)
    spec.save(path)
    if announce:
        n = len(spec.expand())
        fams = {f: sum(1 for i in spec.expand() if i.family == f)
                for f in sorted(spec.families)}
        print(f"# planned {n} instances over {spec.n_shards} shards "
              f"[{spec.backend}]: "
              + ", ".join(f"{f}={c}" for f, c in fams.items()))
    return spec


# ------------------------------------------------------------- subcommands ---


def cmd_plan(args: argparse.Namespace) -> int:
    path = spec_path(args.out)
    if os.path.exists(path) and not args.force:
        raise SystemExit(f"{path} exists; pass --force to re-plan "
                         "(existing shard results would be reinterpreted)")
    if os.path.exists(path):
        # a new plan invalidates every artifact derived from the old one:
        # record uids encode (family, n, index) but not the grid bounds or
        # campaign knobs, so stale shard files would silently satisfy the
        # new grid with results measured under the old parameters
        os.remove(path)
        removed = 0
        for fn in sorted(os.listdir(args.out)):
            if (fn.startswith("shard-") and
                    fn.split(".", 1)[-1] in ("jsonl", "manifest.json",
                                             "engine.json", "timings.json",
                                             "lease.json")) \
                    or fn == "merged.jsonl":
                os.remove(os.path.join(args.out, fn))
                removed += 1
        qdir = os.path.join(args.out, "quarantine")
        if os.path.isdir(qdir):
            # quarantined damage belongs to the old plan's records
            import shutil

            shutil.rmtree(qdir)
            removed += 1
        if removed:
            print(f"# --force: removed {removed} stale shard/merge artifacts")
    spec = load_or_plan_spec(args)
    for shard in range(spec.n_shards):
        n = len(spec.shard_instances(shard))
        print(f"#   shard {shard:4d}: {n} instances")
    print(f"# spec: {path}")
    return 0


def _worker_env() -> Dict[str, str]:
    """Child interpreters must import ``repro`` the same way we did — and
    must not each spin up an nproc-wide BLAS pool: N workers x N spinning
    BLAS threads on N cores turns the census into a futex benchmark. The
    analysis layer is single-threaded numpy; parallelism comes from the
    worker processes."""
    env = dict(os.environ)
    # namespace package: locate the src dir via __path__, not __file__
    src_dir = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    parts = [src_dir] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
    env["PYTHONPATH"] = os.pathsep.join(dict.fromkeys(parts))
    for var in ("OPENBLAS_NUM_THREADS", "OMP_NUM_THREADS", "MKL_NUM_THREADS"):
        env.setdefault(var, "1")
    return env


def cmd_run(args: argparse.Namespace) -> int:
    spec = load_or_plan_spec(args)
    workers = max(1, min(args.workers, spec.n_shards))
    assignment = {
        w: [s for s in range(spec.n_shards) if s % workers == w]
        for w in range(workers)
    }
    procs: List[subprocess.Popen] = []
    for w, shards in assignment.items():
        cmd = [
            sys.executable, "-m", "repro", "census", "work",
            "--out", args.out, "--shards", ",".join(map(str, shards)),
        ]
        if args.max_steps_per_shard is not None:
            cmd += ["--max-steps-per-shard", str(args.max_steps_per_shard)]
        procs.append(subprocess.Popen(cmd, env=_worker_env()))
    failed = []
    for w, proc in enumerate(procs):
        rc = proc.wait()
        if rc != 0:
            failed.append((w, rc))
    prog = sweep_progress(spec, args.out)
    print(f"# {prog['completed']}/{prog['instances']} instances complete")
    if failed:
        for w, rc in failed:
            print(f"# worker {w} exited {rc} (shards {assignment[w]})",
                  file=sys.stderr)
        print("# re-run the same command to resume", file=sys.stderr)
        return 1
    if prog["completed"] == prog["instances"]:
        try:
            path = write_merged(spec, args.out)
        except StoreDamaged as err:
            print(f"# merge refused: {err}", file=sys.stderr)
            return 1
        print(f"# merged census: {path}")
    return 0


def cmd_work(args: argparse.Namespace) -> int:
    """Internal: run an assigned shard list sequentially (one worker)."""
    spec = SweepSpec.load(spec_path(args.out))
    for shard in _int_list(args.shards):
        run_shard(
            spec, args.out, shard,
            max_steps=args.max_steps_per_shard,
            progress=lambda msg: print(f"# {msg}", flush=True),
        )
    return 0


def cmd_status(args: argparse.Namespace) -> int:
    spec = SweepSpec.load(spec_path(args.out))
    prog = sweep_progress(spec, args.out)
    print(f"# sweep {prog['name']}: {prog['completed']}/{prog['instances']} "
          f"instances complete")
    if prog["completed"]:
        fams = ", ".join(
            f"{fam}={a['anomalies']}/{a['done']}"
            for fam, a in sorted(prog["by_family"].items())
        )
        print(f"# anomalies so far: {prog['anomalies']}/{prog['completed']} "
              f"({fams})")
    if prog.get("predicted"):
        frac = prog["predicted"] / max(prog["completed"], 1)
        print(f"# predicted without measurement: {prog['predicted']}"
              f"/{prog['completed']} (skip fraction {100.0 * frac:.1f}%)")
    for row in prog["shards"]:
        flag = " (chunk in flight)" if row["in_flight_chunk"] else ""
        anom = f", {row['anomalies']} anomalies" if row["done"] else ""
        damage = f" DAMAGED x{row['damaged']}" if row.get("damaged") else ""
        print(f"#   shard {row['shard']:4d}: {row['done']}/{row['total']}"
              f"{anom}{flag}{damage}")
    if prog.get("damaged"):
        print(f"# {prog['damaged']} damaged record line(s) — merge will "
              f"refuse; run: python -m repro fsck --out {args.out}")
    return 0


def cmd_merge(args: argparse.Namespace) -> int:
    spec = SweepSpec.load(spec_path(args.out))
    try:
        path = write_merged(spec, args.out)
    except StoreDamaged as err:
        print(f"# merge refused: {err}", file=sys.stderr)
        return 1
    n = sum(1 for _ in open(path))
    print(f"# merged {n} records -> {path}")
    return 0


def cmd_report(args: argparse.Namespace) -> int:
    from repro.launch.report_md import census_tables

    spec = SweepSpec.load(spec_path(args.out))
    records = merge_shards(spec, args.out)
    if not records:
        print("(no completed instances yet — run the sweep first)")
        return 1
    if args.json:
        json.dump(census_summary(records), sys.stdout, indent=1, sort_keys=True)
        print()
    else:
        print(census_tables(records, name=spec.name))
    return 0


def main(argv: Optional[List[str]] = None, prog: Optional[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog=prog or "repro.launch.sweep",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("plan", help="expand the grid and write spec.json")
    p.add_argument("--out", required=True)
    p.add_argument("--force", action="store_true")
    add_grid_args(p)
    p.set_defaults(fn=cmd_plan)

    p = sub.add_parser("run", help="run/resume the census with N workers")
    p.add_argument("--out", required=True)
    p.add_argument("--workers", type=int, default=1)
    p.add_argument("--max-steps-per-shard", type=int, default=None,
                   help="pause each shard after N engine steps (resumable)")
    add_grid_args(p)
    p.set_defaults(fn=cmd_run)

    p = sub.add_parser("work", help="internal: run an assigned shard list")
    p.add_argument("--out", required=True)
    p.add_argument("--shards", required=True, help="comma list of shard ids")
    p.add_argument("--max-steps-per-shard", type=int, default=None)
    p.set_defaults(fn=cmd_work)

    p = sub.add_parser("status", help="completed/total per shard")
    p.add_argument("--out", required=True)
    p.set_defaults(fn=cmd_status)

    p = sub.add_parser("merge", help="merge shard JSONLs into merged.jsonl")
    p.add_argument("--out", required=True)
    p.set_defaults(fn=cmd_merge)

    p = sub.add_parser("fsck", help="classify/repair/quarantine store damage")
    add_fsck_args(p)
    p.set_defaults(fn=fsck_command)

    p = sub.add_parser("report", help="anomaly-rate tables (markdown)")
    p.add_argument("--out", required=True)
    p.add_argument("--json", action="store_true",
                   help="raw census_summary JSON instead of markdown")
    p.set_defaults(fn=cmd_report)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    deprecated_alias("repro.launch.sweep", "census")
    sys.exit(main())
