"""Shared CLI plumbing for the launch surfaces — one definition per flag.

The launch modules each used to re-declare their own ``--out``/``--dry-run``
pairs, and the five ``fsck`` routes (``census fsck``, ``explain fsck``,
``queue fsck``, ``oracle fsck``, and the standalone ``fsck``) had drifted
into subtly different help texts and option sets. Both the umbrella CLI
(``python -m repro``, :mod:`repro.launch.cli`) and the legacy
``python -m repro.launch.X`` aliases now route through these helpers, so
the flag sets cannot drift again — ``tests/test_cli_unified.py`` diffs the
five fsck help texts to hold that.
"""

from __future__ import annotations

import argparse
import sys


def add_fsck_args(p: argparse.ArgumentParser) -> None:
    """THE fsck flag set. Every fsck route — the four sub-surface
    ``fsck`` verbs and the standalone ``repro fsck`` — registers exactly
    these options and dispatches to :func:`fsck_command`."""
    p.add_argument("--out", required=True, help="store root to check")
    p.add_argument("--dry-run", action="store_true",
                   help="classify and report only; change nothing")


def fsck_command(args: argparse.Namespace) -> int:
    """The one fsck entry all routes share (lazy import keeps ``--help``
    cheap)."""
    from repro.launch.fsck import run_fsck

    return run_fsck(args.out, dry_run=args.dry_run)


def deprecated_alias(old: str, new: str) -> None:
    """One-line pointer printed (stderr) by the legacy
    ``python -m repro.launch.X`` entrypoints. They keep working — scripts
    do not break — but the umbrella ``python -m repro`` owns the docs."""
    print(f"# note: `python -m {old}` is a legacy alias; "
          f"prefer `python -m repro {new}`", file=sys.stderr)
