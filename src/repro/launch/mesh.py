"""Mesh construction for single-pod and multi-pod deployments.

``make_production_mesh`` builds the 16x16 (256-chip pod, axes data x model)
or 2x16x16 (two pods, axes pod x data x model) target mesh. Functions only —
importing this module never touches jax device state.

The builder generalises: ``make_mesh_shape(n_pods, dp, tp)`` supports
arbitrary pod counts for 1000+-node deployments (the 'pod' axis carries pure
data parallelism, so scaling pods never changes per-pod sharding — see
DESIGN.md §5).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

from repro.launch.compat import make_mesh as _compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_mesh(n_pods: int = 1, dp: int = 16, tp: int = 16):
    """General mesh: (pod, data, model) or (data, model) when n_pods == 1."""
    if n_pods > 1:
        return _compat_make_mesh((n_pods, dp, tp), ("pod", "data", "model"))
    return _compat_make_mesh((dp, tp), ("data", "model"))


def make_host_mesh(tp: Optional[int] = None):
    """Mesh over whatever devices exist (CPU smoke / tests).

    Picks (dp, tp) = (n // tp, tp) with tp the largest power of two <= n
    unless given. Falls back to (1, 1) on a single device.
    """
    n = len(jax.devices())
    if tp is None:
        tp = 1
        while tp * 2 <= n and tp * 2 <= 8:
            tp *= 2
    dp = max(n // tp, 1)
    return _compat_make_mesh((dp, tp), ("data", "model"))


def describe(mesh) -> str:
    return (
        f"mesh axes={mesh.axis_names} shape={tuple(mesh.shape[a] for a in mesh.axis_names)} "
        f"devices={mesh.devices.size}"
    )
