"""The umbrella CLI: ``python -m repro <surface> <verb> ...``.

One entrypoint over the six launch surfaces — each sub-CLI keeps its own
parser (registered here, never duplicated) and stays invocable as
``python -m repro.launch.X`` for old scripts (a thin alias that prints a
one-line deprecation pointer):

    python -m repro census  run --out DIR --workers 4   # DiscriminantSweep
    python -m repro explain run --census DIR --out E    # AnomalyExplainer
    python -m repro queue   work --out DIR              # pull-based drain
    python -m repro fsck    --out DIR [--dry-run]       # repair any store
    python -m repro oracle  warm --out C --census DIR   # ranking service
    python -m repro predict train --census DIR --out M  # learned cost model

Dispatch is manual (argv[0] lookup, remainder forwarded verbatim) rather
than argparse-subparser composition: every surface's ``main(argv, prog=)``
owns its full argparse tree, and the umbrella just rebrands ``prog`` so
``--help`` prints the command the user actually typed.
"""

from __future__ import annotations

import sys
from typing import Callable, List, Optional, Tuple


def _census_main(argv: List[str], prog: str) -> int:
    from repro.launch.sweep import main

    return main(argv, prog=prog)


def _explain_main(argv: List[str], prog: str) -> int:
    from repro.launch.explain import main

    return main(argv, prog=prog)


def _queue_main(argv: List[str], prog: str) -> int:
    from repro.launch.queue import main

    return main(argv, prog=prog)


def _fsck_main(argv: List[str], prog: str) -> int:
    from repro.launch.fsck import main

    return main(argv, prog=prog)


def _oracle_main(argv: List[str], prog: str) -> int:
    from repro.launch.oracle import main

    return main(argv, prog=prog)


def _predict_main(argv: List[str], prog: str) -> int:
    from repro.launch.predict import main

    return main(argv, prog=prog)


#: surface name -> (dispatcher, one-line help). Lazy imports keep
#: ``python -m repro --help`` free of every surface's dependency tree.
SURFACES: "dict[str, Tuple[Callable[[List[str], str], int], str]]" = {
    "census": (_census_main,
               "plan/run/merge/report the FLOPs-discriminant census"),
    "explain": (_explain_main,
                "explain the census's anomalies (root-cause campaigns)"),
    "queue": (_queue_main,
              "drain any campaign store with pull-based multi-host workers"),
    "fsck": (_fsck_main,
             "classify/repair/quarantine damage in any campaign store"),
    "oracle": (_oracle_main,
               "warm/query/serve the ranking-as-a-service cache"),
    "predict": (_predict_main,
                "train/apply the learned cost model (active censuses)"),
}


def _usage() -> str:
    lines = [
        "usage: python -m repro <surface> <verb> [options]",
        "",
        "surfaces:",
    ]
    for name, (_, help_line) in SURFACES.items():
        lines.append(f"  {name:<8} {help_line}")
    lines += [
        "",
        "run `python -m repro <surface> --help` for that surface's verbs.",
    ]
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        print(_usage())
        return 0 if argv else 2
    surface, rest = argv[0], argv[1:]
    entry = SURFACES.get(surface)
    if entry is None:
        print(f"unknown surface {surface!r}\n\n{_usage()}", file=sys.stderr)
        return 2
    return entry[0](rest, f"repro {surface}")


if __name__ == "__main__":
    sys.exit(main())
