"""Production-style training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
        --steps 50 --batch 8 --seq 256 --ckpt-dir /tmp/repro_ckpt

Wires the full stack: arch registry -> sharding plan over the host mesh ->
elastic trainer (checkpoint/auto-resume, membership events) -> deterministic
data pipeline. ``--simulate-failure STEP:NEW_HOSTS`` exercises the elastic
re-mesh path mid-run (single-host container: hosts = simulated DP groups).

On a real cluster the same module runs under ``jax.distributed`` with the
production mesh from ``repro.launch.mesh``.
"""

from __future__ import annotations

import argparse

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import ARCH_NAMES, get_config
from repro.data import DataConfig, SyntheticLM
from repro.launch.compat import make_mesh as compat_make_mesh
from repro.launch.mesh import make_host_mesh
from repro.models import ForwardOptions, init_encdec_params, init_lm_params
from repro.train.elastic import ElasticConfig, ElasticTrainer
from repro.train.optimizer import AdamW, cosine_schedule


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="granite-8b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument(
        "--simulate-failure", default=None,
        help="STEP:NEW_HOSTS — elastic re-mesh before STEP",
    )
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if cfg.is_encoder_decoder:
        raise SystemExit("training launcher drives LM archs; whisper uses "
                         "the encdec loss path in tests/benchmarks")

    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
    ))
    optimizer = AdamW(schedule=cosine_schedule(args.lr, 10, args.steps))
    ckpt = CheckpointManager(args.ckpt_dir, keep=3)

    def make_mesh_fn(n_hosts: int):
        # host-count -> dp width at smoke scale
        n_dev = len(jax.devices())
        dp = max(min(n_hosts, n_dev), 1)
        return compat_make_mesh((dp, max(n_dev // dp, 1)), ("data", "model"))

    trainer = ElasticTrainer(
        cfg=cfg,
        optimizer=optimizer,
        data=data,
        ckpt=ckpt,
        make_mesh_fn=make_mesh_fn,
        opts=ForwardOptions(attn_impl="reference"),
        elastic_cfg=ElasticConfig(checkpoint_every=args.ckpt_every),
    )
    trainer.start(
        n_hosts=1,
        init_params_fn=lambda: init_lm_params(cfg, jax.random.PRNGKey(0))[0],
    )

    events = {}
    if args.simulate_failure:
        step_s, hosts_s = args.simulate_failure.split(":")
        events[int(step_s)] = int(hosts_s)

    history = trainer.run(args.steps, membership_events=events)
    for h in history[:: max(len(history) // 10, 1)]:
        print(f"step {h['step']:4d} loss={h['loss']:.4f} nll={h['nll']:.4f}")
    print(f"final loss={history[-1]['loss']:.4f}; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
