"""Ranking-as-a-service launcher: warm, query, serve, inspect the oracle.

The cache root is an ordinary campaign store (kind ``oracle``, marker
``ocache.json``) — ``queue``/``fsck`` already understand it — and this
CLI adds the serving-side verbs:

    # build the cache from a finished census (+ optional explain store)
    PYTHONPATH=src python -m repro oracle warm \\
        --out CACHE --census CENSUS [--explain EXPLAIN]

    # one query, or a JSONL batch
    PYTHONPATH=src python -m repro oracle query --out CACHE \\
        --family gram --params '{"size": 96, "seed": 0}'
    PYTHONPATH=src python -m repro oracle query --out CACHE \\
        --batch queries.jsonl --json verdicts.jsonl

    # JSONL queries in, JSON verdicts out, background cache refresh
    PYTHONPATH=src python -m repro oracle serve --out CACHE --refresh

    # shards / pending misses / leases
    PYTHONPATH=src python -m repro oracle status --out CACHE

    # background measurement of enqueued misses = the ordinary pull queue
    PYTHONPATH=src python -m repro queue work --out CACHE

Every query line is ``{"family": ..., "params": {...}}`` (optional
``machine``); every verdict line carries ``confidence`` (``measured`` /
``bucketed`` / ``learned_model`` / ``model_only``), the ranked
algorithms, and the anomaly
verdict with the explainer's cause when available. Misses answer
immediately from the analytic cost model and are enqueued for background
measurement — the hot path never blocks.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import threading
from typing import Any, Dict, List, Optional

from repro.launch.cliutil import add_fsck_args, deprecated_alias, fsck_command
from repro.serve.cache import (
    CONFIDENCE_MODEL_ONLY,
    SPEC_FILE,
    OracleCache,
    OracleCacheSpec,
)
from repro.serve.oracle import (
    OracleQueue,
    RankingOracle,
    default_machine_name,
    hit_rate,
)


# ------------------------------------------------------------------- warm ---


def cmd_warm(args: argparse.Namespace) -> int:
    from repro.core.sweep import SweepSpec, merge_shards

    spec_path = os.path.join(args.out, SPEC_FILE)
    if os.path.exists(spec_path):
        spec = OracleCacheSpec.load(spec_path)
        if args.census and os.path.abspath(args.census) != os.path.abspath(spec.census):
            print(f"# {args.out} is already a cache for census {spec.census}",
                  file=sys.stderr)
            return 1
    else:
        if not args.census:
            print("# --census is required the first time a cache is warmed",
                  file=sys.stderr)
            return 1
        spec = OracleCacheSpec(
            census=os.path.abspath(args.census),
            explain=os.path.abspath(args.explain) if args.explain else "",
            machine=args.machine,
            model=os.path.abspath(args.model) if args.model else "",
            n_shards=args.shards,
            lru_capacity=args.lru_capacity,
            per_octave=args.per_octave,
        )
    sweep = SweepSpec.load(os.path.join(spec.census, "spec.json"))
    census_records = merge_shards(sweep, spec.census)
    explain_records: List[Dict[str, Any]] = []
    if spec.explain:
        from repro.explain.runner import ExplainSpec, merge_explained

        espec = ExplainSpec.load(os.path.join(spec.explain, "espec.json"))
        explain_records = merge_explained(espec, spec.explain)
    cache = OracleCache.create(args.out, spec)
    machine = default_machine_name(spec, sweep)
    written = cache.warm(census_records, explain_records, machine=machine)
    print(f"# warmed {args.out}: {written} entr{'y' if written == 1 else 'ies'} "
          f"written, {len(cache)} total, machine {machine}, "
          f"{len(census_records)} census + {len(explain_records)} explain "
          f"records")
    return 0


# ------------------------------------------------------------------ query ---


def _emit(verdicts: List[Dict[str, Any]], json_path: str) -> None:
    lines = "".join(
        json.dumps(v, sort_keys=True, separators=(",", ":")) + "\n"
        for v in verdicts
    )
    if json_path:
        tmp = json_path + ".tmp"
        with open(tmp, "w") as fh:
            fh.write(lines)
        os.replace(tmp, json_path)
    else:
        sys.stdout.write(lines)


def _load_batch(path: str) -> List[Dict[str, Any]]:
    with open(path) as fh:
        return [json.loads(line) for line in fh if line.strip()]


def cmd_query(args: argparse.Namespace) -> int:
    oracle = RankingOracle.open(args.out)
    if args.batch:
        queries = _load_batch(args.batch)
    elif args.family:
        queries = [{"family": args.family, "params": json.loads(args.params)}]
    else:
        print("# need --family/--params or --batch", file=sys.stderr)
        return 1
    verdicts = oracle.query_batch(
        queries, machine=args.machine or None, enqueue=not args.no_enqueue,
    )
    _emit(verdicts, args.json)
    anomalies = sum(1 for v in verdicts if v["is_anomaly"])
    enqueued = sum(1 for v in verdicts if v["enqueued"])
    print(f"# {len(verdicts)} quer{'y' if len(verdicts) == 1 else 'ies'}: "
          f"hit rate {hit_rate(verdicts):.2f}, {anomalies} anomalies, "
          f"{enqueued} enqueued for measurement", file=sys.stderr)
    return 0


# ------------------------------------------------------------------ serve ---


def _refresh_loop(root: str, stop: threading.Event, poll: float) -> None:
    """Background refresher: repeatedly drain the cache's pending misses
    through the ordinary lease-guarded pull queue until told to stop.
    Runs as a daemon thread next to the serve loop — the serve loop never
    waits on it."""
    from repro.core.lease import default_owner
    from repro.launch.queue import drain

    owner = f"oracle-serve:{default_owner()}"
    while not stop.is_set():
        try:
            drain(OracleQueue(root), owner, say=None)
        except Exception as err:  # keep serving even if a refresh pass dies
            print(f"# refresh pass failed: {err}", file=sys.stderr)
        stop.wait(poll)


def cmd_serve(args: argparse.Namespace) -> int:
    oracle = RankingOracle.open(args.out)
    stop = threading.Event()
    refresher: Optional[threading.Thread] = None
    if args.refresh:
        refresher = threading.Thread(
            target=_refresh_loop, args=(args.out, stop, args.poll), daemon=True,
        )
        refresher.start()
    stream = open(args.queries) if args.queries else sys.stdin
    served = 0
    verdicts: List[Dict[str, Any]] = []
    try:
        for line in stream:
            line = line.strip()
            if not line:
                continue
            q = json.loads(line)
            v = oracle.query(
                str(q["family"]), q["params"],
                machine=q.get("machine") or (args.machine or None),
            )
            verdicts.append(v)
            sys.stdout.write(
                json.dumps(v, sort_keys=True, separators=(",", ":")) + "\n"
            )
            sys.stdout.flush()
            served += 1
            if args.reload_every and served % args.reload_every == 0:
                oracle.reload()
    finally:
        if stream is not sys.stdin:
            stream.close()
        stop.set()
        if refresher is not None:
            refresher.join(timeout=max(60.0, args.poll * 4))
    print(f"# served {served} verdicts: hit rate {hit_rate(verdicts):.2f}",
          file=sys.stderr)
    return 0


# ----------------------------------------------------------------- status ---


def cmd_status(args: argparse.Namespace) -> int:
    import time

    from repro.core.lease import LEASE_CORRUPT, read_lease_ex
    from repro.core.sweep import ShardStore

    cache = OracleCache.open(args.out)
    totals, pendings = cache.miss_totals()
    print(f"# oracle cache {args.out}: {len(cache)} entries, "
          f"{sum(totals)} misses enqueued, {sum(pendings)} pending")
    now = time.time()
    for shard in range(cache.spec.n_shards):
        store = ShardStore(args.out, shard)
        manifest = store.read_manifest() or {}
        lease, lease_state = read_lease_ex(store.lease_path)
        state = "done" if manifest.get("done") else "open"
        holder = ""
        if lease_state == LEASE_CORRUPT:
            holder = " lease CORRUPT (fsck will clear it)"
        elif lease is not None:
            age = lease.age(now)
            holder = (f" leased by {lease.owner} (heartbeat {age:.0f}s ago"
                      f"{', EXPIRED' if lease.expired(now) else ''})")
        n_entries = sum(
            1 for pos in cache._index.values() if pos[0] == shard
        )
        print(f"#   shard {shard:4d}: {n_entries} entries, "
              f"{pendings[shard]}/{totals[shard]} misses pending "
              f"[{state}]{holder}")
    if cache.damaged:
        print(f"# {len(cache.damaged)} damaged line(s) — run: "
              f"python -m repro fsck --out {args.out}")
    return 0


# ------------------------------------------------------------------- main ---


def main(argv: Optional[List[str]] = None, prog: Optional[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog=prog or "repro.launch.oracle",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("warm", help="build/refresh the cache from merged "
                       "census (+ explain) stores")
    p.add_argument("--out", required=True, help="cache root")
    p.add_argument("--census", default="", help="census store root")
    p.add_argument("--explain", default="",
                   help="explain store root (attaches anomaly causes)")
    p.add_argument("--machine", default="",
                   help="MachineSpec registry name for the cache keys "
                   "(default: derived from the census backend)")
    p.add_argument("--model", default="",
                   help="trained cost model JSON (python -m repro predict "
                   "train): cache misses consult it before the analytic "
                   "roofline and answer with confidence learned_model")
    p.add_argument("--shards", type=int, default=4)
    p.add_argument("--lru-capacity", type=int, default=4096)
    p.add_argument("--per-octave", type=int, default=1,
                   help="shape-bucket granularity (sub-buckets per "
                   "power-of-two octave)")
    p.set_defaults(fn=cmd_warm)

    p = sub.add_parser("query", help="one query or a JSONL batch")
    p.add_argument("--out", required=True)
    p.add_argument("--family", default="")
    p.add_argument("--params", default="{}",
                   help='instance params as JSON, e.g. \'{"size": 96, "seed": 0}\'')
    p.add_argument("--batch", default="",
                   help="JSONL file of {family, params[, machine]} queries")
    p.add_argument("--machine", default="")
    p.add_argument("--json", default="",
                   help="write verdicts to this file instead of stdout")
    p.add_argument("--no-enqueue", action="store_true",
                   help="answer misses from the model without enqueueing "
                   "them for measurement")
    p.set_defaults(fn=cmd_query)

    p = sub.add_parser("serve", help="JSONL queries in (stdin or --queries), "
                       "JSON verdicts out")
    p.add_argument("--out", required=True)
    p.add_argument("--queries", default="",
                   help="read queries from this file instead of stdin")
    p.add_argument("--machine", default="")
    p.add_argument("--refresh", action="store_true",
                   help="drain enqueued misses in a background thread "
                   "while serving")
    p.add_argument("--poll", type=float, default=1.0,
                   help="seconds between background refresh passes")
    p.add_argument("--reload-every", type=int, default=100,
                   help="re-open the cache every N queries to pick up "
                   "background refreshes (0: never)")
    p.set_defaults(fn=cmd_serve)

    p = sub.add_parser("status", help="entries, pending misses, leases")
    p.add_argument("--out", required=True)
    p.set_defaults(fn=cmd_status)

    p = sub.add_parser("fsck", help="classify/repair/quarantine cache damage")
    add_fsck_args(p)
    p.set_defaults(fn=fsck_command)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    deprecated_alias("repro.launch.oracle", "oracle")
    sys.exit(main())
