"""Inline the generated roofline/perf tables into EXPERIMENTS.md."""

import os
import re

from repro.launch.report_md import perf_table, roofline_table

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), "../../.."))
PATH = os.path.join(ROOT, "EXPERIMENTS.md")


def main() -> None:
    text = open(PATH).read()
    text = text.replace(
        "<!-- ROOFLINE_TABLE_16x16 -->", roofline_table("16x16").rstrip()
    )
    text = text.replace(
        "<!-- ROOFLINE_TABLE_2x16x16 -->", roofline_table("2x16x16").rstrip()
    )
    text = text.replace("<!-- PERF_TABLE -->", perf_table().rstrip())
    open(PATH, "w").write(text)
    print("EXPERIMENTS.md tables inlined")


if __name__ == "__main__":
    main()
