"""JAX version-compat shims for the launch layer.

The repo targets a range of JAX releases (see README "Supported JAX
versions"). The launch layer is the only place that touches version-moving
jax APIs, and this module is the single choke point for those differences:

* ``jax.sharding.AxisType`` (and ``jax.make_mesh(..., axis_types=)``) only
  exist from jax 0.6; on older releases every mesh axis is implicitly
  "auto", which is exactly what we request on newer releases — so the shim
  simply omits the argument when the enum is missing.
* ``jax.shard_map`` (with ``check_vma=``) graduated from
  ``jax.experimental.shard_map.shard_map`` (with ``check_rep=``);
  :func:`shard_map` speaks the new spelling on any supported release.

Use :func:`make_mesh` instead of calling ``jax.make_mesh`` directly
anywhere a mesh is built (``repro.launch.mesh``, ``repro.launch.train``,
tests).
"""

from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax

#: True when this jax exposes explicit axis types (jax >= 0.6).
HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:  # jax < 0.6: experimental home, old keyword
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def auto_axis_types(n: int) -> Optional[tuple]:
    """``(AxisType.Auto,) * n`` on jax >= 0.6, else None (implicit auto)."""
    if HAS_AXIS_TYPE:
        return (jax.sharding.AxisType.Auto,) * n
    return None


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices: Optional[Sequence] = None,
):
    """``jax.make_mesh`` with auto axis types on every axis, portable across
    the AxisType API break (jax 0.6)."""
    kwargs = {}
    if HAS_AXIS_TYPE:
        kwargs["axis_types"] = auto_axis_types(len(axis_names))
    if devices is not None:
        kwargs["devices"] = devices
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shard_map(
    f: Callable,
    *,
    mesh,
    in_specs,
    out_specs,
    check_vma: bool = True,
):
    """``jax.shard_map`` portable across its graduation from
    ``jax.experimental`` (the replication-check kwarg was renamed
    ``check_rep`` -> ``check_vma`` in the move)."""
    kwargs = {_CHECK_KW: check_vma}
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )
