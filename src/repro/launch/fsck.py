"""fsck for census/explain stores — classify, repair, quarantine.

A store that survived chaos (host kills, torn appends, bitrot, foreign
writes) holds a mix of perfectly good records and damage. ``merge``
refuses to run over damage (:class:`repro.core.sweep.StoreDamaged`)
because silently skipping unreadable lines publishes a census missing
rows it claims to have. This tool is the repair path:

    PYTHONPATH=src python -m repro fsck --out DIR [--dry-run]

(also reachable as ``repro census fsck`` / ``repro explain fsck`` /
``repro queue fsck`` / ``repro oracle fsck`` — all five routes share one
flag set, :func:`repro.launch.cliutil.add_fsck_args`).

For every shard it classifies damage and acts:

``torn_tail``
    the final line is unterminated or unreadable — a SIGKILL mid-append.
    The batch never committed; the bytes are quarantined and the file
    truncated back to the last whole record. Nothing is lost.
``mid_file_corruption`` / ``checksum_mismatch``
    an interior line that does not decode / decodes but fails its own
    ``_crc``. The damaged line is **excised** (quarantined byte-for-byte
    into ``quarantine/``) and the shard's ``done`` flag cleared, so the
    next drain re-runs exactly the missing instances — records are pure
    functions of (spec, seed, index), so the re-measured rows are
    byte-identical to the lost ones and the post-repair merge matches a
    never-damaged run.
``manifest_drift``
    the slim manifest disagrees with the JSONL (stale counts, wrong
    rolling CRC, legacy format). Rebuilt from the records — the JSONL is
    the source of truth.
``corrupt_lease`` / ``stale_lease``
    half-written lease JSON (carries no heartbeat, would block the shard
    forever) or an expired one — quarantined / removed. A **live** lease
    skips that shard's repairs entirely: fsck never races an active
    worker.
``corrupt_engine_state``
    unreadable in-flight chunk state — quarantined; the chunk re-runs
    deterministically from its records.
``leftover_tmp``
    orphaned ``*.tmp`` / lease graves from interrupted atomic renames —
    quarantined.
``damaged_merged``
    a torn/corrupt ``merged.jsonl`` — quarantined; ``merge`` regenerates
    it from the shards.

Every action lands in ``quarantine/damage-report.json`` (machine-readable:
one finding per damage site with its classification, action, and the
quarantined byte count). Exit status: 0 when the store is clean or fully
repaired, 1 when damage remains (``--dry-run``, or shards skipped under a
live lease).
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time
import zlib
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional

from repro.core.lease import LEASE_CORRUPT, LEASE_OK, read_lease_ex
from repro.core.sweep import (
    LINE_CRC_MISMATCH,
    LINE_LEGACY,
    LINE_OK,
    LINE_UNDECODABLE,
    ShardStore,
    parse_record_line,
)

QUARANTINE_DIR = "quarantine"
REPORT_FILE = "damage-report.json"

#: artifacts whose *absence* of a pattern match means "foreign file, leave it"
_SHARD_RE = re.compile(r"^shard-(\d{4})\.jsonl$")
_TMP_RE = re.compile(r"(\.tmp(\.[0-9a-f]+)?|\.stale\.[0-9a-f]+)$")


@dataclass
class Finding:
    """One damage site: what it is, where, and what fsck did about it."""

    kind: str                 #: classification (torn_tail, manifest_drift, ...)
    path: str                 #: damaged file (relative to the store root)
    action: str               #: repaired | quarantined | skipped | would_repair...
    shard: Optional[int] = None
    line: Optional[int] = None        #: 1-based, for record-line damage
    bytes_quarantined: int = 0
    detail: str = ""

    def to_dict(self) -> Dict[str, Any]:
        d = asdict(self)
        if d["bytes_quarantined"] == 0:
            del d["bytes_quarantined"]
        return {k: v for k, v in d.items() if v is not None and v != ""}


@dataclass
class FsckReport:
    out: str
    kind: str                 #: sweep | explain | unknown
    n_shards: int
    dry_run: bool
    findings: List[Finding] = field(default_factory=list)

    @property
    def clean(self) -> bool:
        return not self.findings

    @property
    def remaining(self) -> int:
        """Damage NOT resolved: dry-run findings and live-lease skips."""
        return sum(1 for f in self.findings
                   if not f.action.startswith(("repaired", "quarantined")))

    def to_dict(self) -> Dict[str, Any]:
        counts: Dict[str, int] = {}
        for f in self.findings:
            counts[f.kind] = counts.get(f.kind, 0) + 1
        return {
            "out": self.out,
            "store_kind": self.kind,
            "n_shards": self.n_shards,
            "dry_run": self.dry_run,
            "clean": self.clean,
            "remaining": self.remaining,
            "by_kind": counts,
            "findings": [f.to_dict() for f in self.findings],
        }


def _store_kind(out: str) -> str:
    """The root's campaign kind, via the store-kind registry. fsck never
    refuses to run: an ambiguous root (two kinds' spec files) reports
    ``"ambiguous"`` and falls back to shard-file scanning."""
    from repro.core.stores import AmbiguousStore, detect_store_kind

    try:
        kind = detect_store_kind(out)
    except AmbiguousStore:
        return "ambiguous"
    return kind.name if kind is not None else "unknown"


def _detect_n_shards(out: str) -> int:
    """Shard count from the spec when possible, else from the files on
    disk — fsck must work even when the spec itself is the casualty."""
    from repro.core.stores import AmbiguousStore, detect_store_kind

    try:
        kind = detect_store_kind(out)
        if kind is not None:
            return kind.load_n_shards(out)
    except (AmbiguousStore, OSError, ValueError, KeyError, TypeError):
        pass
    highest = -1
    for fn in os.listdir(out):
        m = _SHARD_RE.match(fn)
        if m:
            highest = max(highest, int(m.group(1)))
    return highest + 1


def _quarantine(out: str, name: str, data: bytes, *, dry_run: bool) -> str:
    """Write damaged bytes into ``quarantine/`` (unique name), return the
    relative path."""
    rel = os.path.join(QUARANTINE_DIR, name)
    if not dry_run:
        qdir = os.path.join(out, QUARANTINE_DIR)
        os.makedirs(qdir, exist_ok=True)
        path = os.path.join(out, rel)
        n = 1
        while os.path.exists(path):
            rel = os.path.join(QUARANTINE_DIR, f"{name}.{n}")
            path = os.path.join(out, rel)
            n += 1
        with open(path, "wb") as fh:
            fh.write(data)
    return rel


def _act(action: str, dry_run: bool) -> str:
    return f"would_{action}" if dry_run else action


def _fsck_records(out: str, shard: int, report: FsckReport) -> bool:
    """Scan + repair one shard's JSONL and manifest. Returns True when
    records were LOST (excised/truncated) — the caller clears ``done``."""
    dry = report.dry_run
    store = ShardStore(out, shard)
    rel_records = os.path.basename(store.records_path)
    if not os.path.exists(store.records_path):
        return False
    with open(store.records_path, "rb") as fh:
        data = fh.read()
    lines = data.splitlines(keepends=True)
    # the old manifest's byte watermark is the commit record: a damaged
    # FINAL line past it is an uncommitted torn tail (truncating loses
    # nothing), but one at-or-under it was a committed record (last-line
    # bitrot) — that is data loss, and `done` must be cleared or the
    # queue would never re-run the excised instance
    old = store.read_manifest()
    watermark = int(old.get("records_bytes", 0)) if old else 0
    good: List[bytes] = []
    lost = False
    offset = 0
    for i, line in enumerate(lines):
        offset += len(line)
        last = i == len(lines) - 1
        terminated = line.endswith(b"\n")
        rec, status = parse_record_line(line) if terminated else (None, "torn")
        if terminated and status in (LINE_OK, LINE_LEGACY):
            good.append(line)
            continue
        if last and offset > watermark:
            # unterminated or unreadable final line the manifest never
            # committed: the batch never landed — truncating loses nothing
            q = _quarantine(out, f"shard-{shard:04d}.tail.torn", line,
                            dry_run=dry)
            report.findings.append(Finding(
                kind="torn_tail", path=rel_records, shard=shard,
                line=i + 1, action=_act("repaired", dry),
                bytes_quarantined=len(line),
                detail=f"unterminated/{status} tail truncated -> {q}",
            ))
        else:
            kind = ("checksum_mismatch" if status == LINE_CRC_MISMATCH
                    else "mid_file_corruption")
            q = _quarantine(
                out, f"shard-{shard:04d}.line-{i + 1:05d}.{status}", line,
                dry_run=dry)
            report.findings.append(Finding(
                kind=kind, path=rel_records, shard=shard, line=i + 1,
                action=_act("quarantined", dry), bytes_quarantined=len(line),
                detail=f"record excised -> {q}; instance will be re-run",
            ))
            lost = True
    repaired_data = b"".join(good)
    file_changed = repaired_data != data
    if file_changed and not dry:
        tmp = store.records_path + ".fsck.tmp"
        with open(tmp, "wb") as fh:
            fh.write(repaired_data)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, store.records_path)

    # ------------------------------------------------------ the manifest ---
    # recompute the slim manifest from the (repaired) records — the JSONL
    # is the source of truth; drop `done` whenever records were lost so
    # the queue re-drains exactly the missing instances
    n_completed = 0
    by_family: Dict[str, Dict[str, int]] = {}
    crc = 0
    for line in good:
        rec, _ = parse_record_line(line)
        n_completed += 1
        fam = by_family.setdefault(
            str(rec.get("family", "?")), {"done": 0, "anomalies": 0})
        fam["done"] += 1
        if rec.get("is_anomaly"):
            fam["anomalies"] += 1
        if rec.get("provenance") == "predicted":
            fam["predicted"] = fam.get("predicted", 0) + 1
        crc = zlib.crc32(line, crc)
    truth = {
        "shard": shard,
        "n_completed": n_completed,
        "records_bytes": len(repaired_data),
        "records_crc32": format(crc & 0xFFFFFFFF, "08x"),
        "by_family": by_family,
    }
    old = store.read_manifest()
    keep_done = bool(old and old.get("done")) and not lost
    if keep_done:
        truth["done"] = True
    stale = old is None or any(old.get(k) != v for k, v in truth.items()) \
        or (bool(old.get("done")) and not keep_done)
    if stale and (old is not None or good):
        rel_manifest = os.path.basename(store.manifest_path)
        if old is None:
            why = "manifest missing"
        else:
            diff = [k for k, v in truth.items() if old.get(k) != v]
            if bool(old.get("done")) and not keep_done:
                diff.append("done")
            why = f"stale fields: {', '.join(diff)}"
        report.findings.append(Finding(
            kind="manifest_drift", path=rel_manifest, shard=shard,
            action=_act("repaired", dry), detail=f"rebuilt from records ({why})",
        ))
        if not dry:
            tmp = store.manifest_path + ".fsck.tmp"
            with open(tmp, "w") as fh:
                json.dump(truth, fh, indent=1, sort_keys=True)
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(tmp, store.manifest_path)
    return lost


def _fsck_sidecars(out: str, shard: int, report: FsckReport) -> None:
    """Lease + engine-state health for one shard (records already done)."""
    dry = report.dry_run
    store = ShardStore(out, shard)
    if os.path.exists(store.engine_path):
        try:
            with open(store.engine_path) as fh:
                json.load(fh)
        except (OSError, ValueError):
            with open(store.engine_path, "rb") as fh:
                blob = fh.read()
            q = _quarantine(out, f"shard-{shard:04d}.engine.corrupt.json",
                            blob, dry_run=dry)
            report.findings.append(Finding(
                kind="corrupt_engine_state",
                path=os.path.basename(store.engine_path), shard=shard,
                action=_act("quarantined", dry), bytes_quarantined=len(blob),
                detail=f"-> {q}; chunk re-runs deterministically",
            ))
            if not dry:
                os.remove(store.engine_path)


def _fsck_lease(out: str, shard: int, report: FsckReport) -> bool:
    """Classify the shard's lease. Returns True when a LIVE owner holds it
    — the shard must be skipped (fsck never races an active worker)."""
    dry = report.dry_run
    store = ShardStore(out, shard)
    rel = os.path.basename(store.lease_path)
    info, state = read_lease_ex(store.lease_path)
    if state == LEASE_CORRUPT:
        try:
            with open(store.lease_path, "rb") as fh:
                blob = fh.read()
        except OSError:
            blob = b""
        q = _quarantine(out, f"shard-{shard:04d}.lease.corrupt.json", blob,
                        dry_run=dry)
        report.findings.append(Finding(
            kind="corrupt_lease", path=rel, shard=shard,
            action=_act("quarantined", dry), bytes_quarantined=len(blob),
            detail=f"half-written lease -> {q}; shard is stealable again",
        ))
        if not dry:
            try:
                os.remove(store.lease_path)
            except OSError:
                pass
        return False
    if state == LEASE_OK:
        if info.expired():
            report.findings.append(Finding(
                kind="stale_lease", path=rel, shard=shard,
                action=_act("repaired", dry),
                detail=f"owner {info.owner} silent {info.age():.0f}s "
                       f"(ttl {info.ttl:.0f}s); removed",
            ))
            if not dry:
                try:
                    os.remove(store.lease_path)
                except OSError:
                    pass
            return False
        report.findings.append(Finding(
            kind="live_lease", path=rel, shard=shard, action="skipped",
            detail=f"held by {info.owner} (heartbeat {info.age():.0f}s ago) "
                   "— shard left untouched",
        ))
        return True
    return False


def _fsck_merged(out: str, report: FsckReport) -> None:
    """A merged.jsonl with any unreadable line is quarantined whole — it is
    derived data; ``merge`` regenerates it from the shards."""
    dry = report.dry_run
    path = os.path.join(out, "merged.jsonl")
    if not os.path.exists(path):
        return
    with open(path, "rb") as fh:
        data = fh.read()
    ok = True
    bad_line = 0
    for i, line in enumerate(data.splitlines(keepends=True)):
        if not line.endswith(b"\n"):
            ok, bad_line = False, i + 1
            break
        _, status = parse_record_line(line)
        if status in (LINE_UNDECODABLE, LINE_CRC_MISMATCH):
            ok, bad_line = False, i + 1
            break
    if ok:
        return
    q = _quarantine(out, "merged.damaged.jsonl", data, dry_run=dry)
    report.findings.append(Finding(
        kind="damaged_merged", path="merged.jsonl", line=bad_line,
        action=_act("quarantined", dry), bytes_quarantined=len(data),
        detail=f"-> {q}; re-run merge to regenerate",
    ))
    if not dry:
        os.remove(path)


def _fsck_leftovers(out: str, report: FsckReport) -> None:
    dry = report.dry_run
    for fn in sorted(os.listdir(out)):
        if not _TMP_RE.search(fn):
            continue
        path = os.path.join(out, fn)
        if not os.path.isfile(path):
            continue
        try:
            with open(path, "rb") as fh:
                blob = fh.read()
        except OSError:
            continue
        q = _quarantine(out, fn, blob, dry_run=dry)
        report.findings.append(Finding(
            kind="leftover_tmp", path=fn, action=_act("quarantined", dry),
            bytes_quarantined=len(blob),
            detail=f"orphaned atomic-rename temp -> {q}",
        ))
        if not dry:
            os.remove(path)


def fsck_store(out: str, *, dry_run: bool = False) -> FsckReport:
    """Scan ``out``, repair/quarantine what can be, report everything.

    Safe to run on a live store: shards under an unexpired lease are
    reported but left untouched. Idempotent — a second run on a repaired
    store finds nothing."""
    if not os.path.isdir(out):
        raise SystemExit(f"{out} is not a directory")
    report = FsckReport(out=out, kind=_store_kind(out),
                        n_shards=_detect_n_shards(out), dry_run=dry_run)
    for shard in range(report.n_shards):
        if _fsck_lease(out, shard, report):
            continue  # live owner: their shard, their problem
        _fsck_records(out, shard, report)
        _fsck_sidecars(out, shard, report)
    _fsck_merged(out, report)
    _fsck_leftovers(out, report)
    if not dry_run:
        qdir = os.path.join(out, QUARANTINE_DIR)
        os.makedirs(qdir, exist_ok=True)
        tmp = os.path.join(qdir, REPORT_FILE + ".tmp")
        doc = dict(report.to_dict(), generated_at=time.time())
        with open(tmp, "w") as fh:
            json.dump(doc, fh, indent=1, sort_keys=True)
        os.replace(tmp, os.path.join(qdir, REPORT_FILE))
    return report


def print_report(report: FsckReport, say=print) -> None:
    mode = " (dry run)" if report.dry_run else ""
    if report.clean:
        say(f"# fsck {report.out}: clean ({report.kind}, "
            f"{report.n_shards} shards){mode}")
        return
    say(f"# fsck {report.out}: {len(report.findings)} finding(s) "
        f"({report.kind}, {report.n_shards} shards){mode}")
    for f in report.findings:
        where = f.path + (f":{f.line}" if f.line else "")
        say(f"#   [{f.kind}] {where} — {f.action}"
            + (f" ({f.detail})" if f.detail else ""))
    if not report.dry_run:
        say(f"# report: {os.path.join(report.out, QUARANTINE_DIR, REPORT_FILE)}")
    if report.remaining:
        say(f"# {report.remaining} finding(s) unresolved")


def run_fsck(out: str, *, dry_run: bool = False, say=print) -> int:
    """The shared entry point behind ``fsck`` and the launcher
    subcommands. Returns a process exit code."""
    report = fsck_store(out, dry_run=dry_run)
    print_report(report, say)
    return 1 if report.remaining else 0


def main(argv: Optional[List[str]] = None, prog: Optional[str] = None) -> int:
    from repro.launch.cliutil import add_fsck_args

    ap = argparse.ArgumentParser(
        prog=prog or "repro.launch.fsck",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    add_fsck_args(ap)
    args = ap.parse_args(argv)
    return run_fsck(args.out, dry_run=args.dry_run)


if __name__ == "__main__":
    from repro.launch.cliutil import deprecated_alias

    deprecated_alias("repro.launch.fsck", "fsck")
    sys.exit(main())
