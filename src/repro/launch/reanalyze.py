"""Re-derive roofline rows from cached dry-run HLO (no recompile).

    python -m repro.launch.reanalyze [--mesh single|multi]

Reads reports/hlo/<arch>_<shape>_<mesh>.txt.gz written by dryrun.py and
rewrites the matching report rows with the CURRENT analyzer — analyzer
iterations (the §Perf loop) never pay the compile cost twice.

Campaign reanalysis — the same never-remeasure principle for the ranking
methodology:

    python -m repro.launch.reanalyze --campaign reports/perf_campaign_X.json

Loads a persisted ExperimentEngine state (sessions restore with a detached
timer — no measurement backend needed), re-runs Procedure 3 (mean ranks
over the quantile ladder) on every session's STORED measurements with the
current code, and prints stored-vs-recomputed rankings per session.
"""

import argparse
import gzip
import json
import os

from repro.configs import SHAPES, get_config
from repro.launch.dryrun import model_flops_for
from repro.roofline import analyze, terms_from_counts

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), "../../.."))


def reanalyze_campaign(path: str) -> None:
    """Re-rank a persisted campaign's measurement stores (no re-measuring).

    Re-analysis is pure analysis, so it flows through the batched
    QuantileTable: one ``np.percentile`` pass per session instead of the
    pairwise per-comparison evaluation — large stored campaigns re-rank in
    seconds."""
    from repro.core import ExperimentEngine, QuantileTable, mean_ranks

    engine = ExperimentEngine.load(path)
    print(f"campaign {path}: {len(engine)} sessions, "
          f"{engine.steps_taken} iterations taken, policy={engine.policy}")
    for session in engine:
        if session.measurements_per_alg == 0:
            print(f"  {session.name}: no measurements yet; skipped")
            continue
        table = QuantileTable.from_ranges(
            session.store, (*session.quantile_ranges, session.report_range)
        )
        mr = mean_ranks(
            session.order,
            None,
            quantile_ranges=session.quantile_ranges,
            report_range=session.report_range,
            tie_break=session.tie_break,
            table=table,
        )
        stored = session.history[-1] if session.history else None
        stored_seq = (
            "|".join(f"{n}:r{r}" for n, r in zip(stored.order, stored.ranks))
            if stored else "<none>"
        )
        fresh_seq = "|".join(f"{n}:r{r}" for n, r in zip(mr.order, mr.ranks))
        flag = "" if stored_seq == fresh_seq else "  <-- CHANGED"
        print(f"  {session.name}: N={session.measurements_per_alg} "
              f"converged={session.converged}")
        print(f"    stored:     {stored_seq}")
        print(f"    reanalyzed: {fresh_seq}{flag}")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--mesh", choices=["single", "multi"], default="single")
    p.add_argument("--campaign", default=None,
                   help="re-rank a persisted ExperimentEngine state file "
                        "instead of the roofline reports")
    args = p.parse_args()
    if args.campaign:
        if not os.path.exists(args.campaign):
            p.error(f"no campaign state at {args.campaign}")
        reanalyze_campaign(args.campaign)
        return
    label = "2x16x16" if args.mesh == "multi" else "16x16"
    n_dev = 512 if args.mesh == "multi" else 256
    report = os.path.join(ROOT, f"reports/dryrun_{label}.json")
    rows = json.load(open(report))
    for row in rows:
        if not row.get("status", "").startswith("ok"):
            continue
        path = os.path.join(ROOT, "reports/hlo",
                            f"{row['arch']}_{row['shape']}_{label}.txt.gz")
        if not os.path.exists(path):
            print(f"missing HLO for {row['arch']}/{row['shape']}; skipped")
            continue
        counts = analyze(gzip.open(path, "rt").read())
        cfg = get_config(row["arch"], smoke=False)
        shape = SHAPES[row["shape"]]
        terms = terms_from_counts(
            arch=row["arch"], shape=row["shape"], mesh_desc=label,
            kind=shape.kind, n_devices=n_dev, counts=counts,
            model_flops_total=model_flops_for(cfg, shape),
            memory_per_dev_bytes=row["mem_per_dev_gb"] * 2**30,
        )
        keep = {k: row[k] for k in (
            "status", "attention_strategy", "num_microbatches", "notes",
            "fit_attempts", "compile_s", "params_total", "params_active",
        ) if k in row}
        row.clear()
        row.update(terms.row())
        row.update(keep)
        print(f"reanalyzed {row['arch']:26s} {row['shape']:12s} "
              f"dom={row['dominant']} frac={row['roofline_fraction']}")
    json.dump(rows, open(report, "w"), indent=1)


if __name__ == "__main__":
    main()
