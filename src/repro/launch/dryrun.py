import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

__doc__ = """Multi-pod dry-run: AOT lower + compile every (architecture x
input-shape) cell on the production meshes, prove memory fits, and extract
roofline terms.

The two lines above run before ANY other import (jax locks the device count
on first init, and the dry-run needs 512 placeholder host devices).

Usage (each run writes/updates a JSON report):

    python -m repro.launch.dryrun --mesh single            # 16x16 = 256
    python -m repro.launch.dryrun --mesh multi             # 2x16x16 = 512
    python -m repro.launch.dryrun --arch granite-8b --shape train_4k
    python -m repro.launch.dryrun --list

The single-pod pass feeds the §Roofline table; the multi-pod pass proves the
'pod' axis shards (data-parallel gradient all-reduce spans pods).

Memory-fit loop: if a train cell's per-device footprint exceeds the HBM
budget, the microbatch count is doubled and the cell re-lowered — the loop
records every attempt (this is the 'fix sharding until it fits' evidence).
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, List, Optional

import jax

from repro.configs import ARCH_NAMES, SHAPES, SKIPS, get_config
from repro.configs.shapes import ShapeSpec
from repro.launch.mesh import describe, make_production_mesh
from repro.launch.specs import build_cell
from repro.models import (
    decode_flops,
    param_counts,
    prefill_flops,
    training_flops,
)
from repro.roofline import analyze, terms_from_counts

HBM_BUDGET_BYTES = 15 * 2**30     # v5e 16GB, ~1GB headroom
MAX_FIT_ATTEMPTS = 5

DEFAULT_REPORT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                              "reports", "dryrun")


def model_flops_for(cfg, shape: ShapeSpec) -> float:
    if shape.kind == "train":
        return training_flops(cfg, shape.global_batch, shape.seq_len)
    if shape.kind == "prefill":
        return prefill_flops(cfg, shape.global_batch, shape.seq_len)
    return decode_flops(cfg, shape.global_batch, shape.seq_len)


def run_cell(
    arch: str,
    shape_name: str,
    mesh,
    mesh_label: str,
    overrides: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """Lower + compile one cell; returns the report row (or error row)."""
    shape = SHAPES[shape_name]
    skip = SKIPS.get((arch, shape_name))
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_label,
                "status": "skipped", "reason": skip}

    cfg = get_config(arch, smoke=False)
    overrides = dict(overrides or {})
    attempts: List[Dict[str, Any]] = []
    t_start = time.time()

    for attempt in range(MAX_FIT_ATTEMPTS):
        try:
            cell = build_cell(arch, cfg, shape, mesh, opts_override=overrides)
            lowered = cell.lower()
            compiled = lowered.compile()
        except Exception as e:  # sharding/compile bug — the thing dry-runs catch
            return {
                "arch": arch, "shape": shape_name, "mesh": mesh_label,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:],
                "attempts": attempts,
            }

        ma = compiled.memory_analysis()
        # donated inputs alias outputs; live = args + temps
        mem = ma.argument_size_in_bytes + ma.temp_size_in_bytes
        attempts.append({
            "num_microbatches": cell.num_microbatches,
            "mem_per_dev_gb": round(mem / 2**30, 2),
            "temp_gb": round(ma.temp_size_in_bytes / 2**30, 2),
        })
        if mem <= HBM_BUDGET_BYTES or shape.kind != "train":
            break
        # fit loop: double microbatches (halving live activations), capped
        # at 1 sequence per microbatch
        from repro.distributed.sharding import dp_size as _dpsz

        b_local = max(shape.global_batch // _dpsz(mesh), 1)
        cur = overrides.get("num_microbatches", cell.num_microbatches)
        nxt = min(max(cur * 2, 2), b_local)
        if nxt == cur:
            break  # already at the floor; report as-is
        overrides["num_microbatches"] = nxt
    else:
        compiled = None

    if compiled is None:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_label,
                "status": "oom", "attempts": attempts}

    hlo_text = compiled.as_text()
    hlo_dir = os.path.abspath(os.path.join(
        os.path.dirname(__file__), "../../..", "reports", "hlo"))
    os.makedirs(hlo_dir, exist_ok=True)
    import gzip

    hlo_path = os.path.join(hlo_dir, f"{arch}_{shape_name}_{mesh_label}.txt.gz")
    with gzip.open(hlo_path, "wt") as f:
        f.write(hlo_text)
    counts = analyze(hlo_text)
    terms = terms_from_counts(
        arch=arch,
        shape=shape_name,
        mesh_desc=mesh_label,
        kind=shape.kind,
        n_devices=mesh.devices.size,
        counts=counts,
        model_flops_total=model_flops_for(cfg, shape),
        memory_per_dev_bytes=mem,
    )
    row = terms.row()
    row.update({
        "status": "ok" if mem <= HBM_BUDGET_BYTES else "ok_overbudget",
        "attention_strategy": cell.attention_strategy,
        "num_microbatches": cell.num_microbatches,
        "notes": list(cell.notes),
        "fit_attempts": attempts,
        "compile_s": round(time.time() - t_start, 1),
        "params_total": param_counts(cfg).total,
        "params_active": param_counts(cfg).active,
    })
    return row


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--mesh", choices=["single", "multi"], default="single")
    p.add_argument("--arch", default=None, help="one arch (default: all)")
    p.add_argument("--shape", default=None, help="one shape (default: all)")
    p.add_argument("--out", default=None, help="report JSON path")
    p.add_argument("--list", action="store_true")
    p.add_argument("--override", default=None,
                   help="JSON dict of opts overrides (perf experiments)")
    args = p.parse_args()

    if args.list:
        for a in ARCH_NAMES:
            for s in SHAPES:
                skip = SKIPS.get((a, s))
                print(f"{a:26s} {s:12s} {'SKIP: ' + skip if skip else 'run'}")
        return

    multi = args.mesh == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    label = "2x16x16" if multi else "16x16"
    print(f"# dry-run mesh {label}: {describe(mesh)}", flush=True)

    archs = [args.arch] if args.arch else ARCH_NAMES
    shapes = [args.shape] if args.shape else list(SHAPES)
    overrides = json.loads(args.override) if args.override else None

    out_path = args.out or os.path.abspath(
        os.path.join(os.path.dirname(__file__), "../../..",
                     f"reports/dryrun_{label}.json")
    )
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    rows: List[Dict[str, Any]] = []
    if os.path.exists(out_path) and not (args.arch or args.shape):
        pass  # full rerun replaces the report
    elif os.path.exists(out_path):
        rows = [r for r in json.load(open(out_path))
                if not ((args.arch is None or r["arch"] in archs)
                        and (args.shape is None or r["shape"] in shapes))]

    for arch in archs:
        for shape_name in shapes:
            t0 = time.time()
            row = run_cell(arch, shape_name, mesh, label, overrides)
            rows.append(row)
            status = row["status"]
            extra = ""
            if status.startswith("ok"):
                extra = (f"dom={row['dominant']} frac={row['roofline_fraction']}"
                         f" mem={row['mem_per_dev_gb']}GB micro={row['num_microbatches']}")
            elif status == "error":
                extra = row["error"][:120]
            elif status == "skipped":
                extra = row["reason"][:80]
            print(f"[{time.time()-t0:6.1f}s] {arch:26s} {shape_name:12s} "
                  f"{status:8s} {extra}", flush=True)
            json.dump(rows, open(out_path, "w"), indent=1)

    n_ok = sum(r["status"].startswith("ok") for r in rows)
    n_skip = sum(r["status"] == "skipped" for r in rows)
    n_err = len(rows) - n_ok - n_skip
    print(f"# done: {n_ok} ok, {n_skip} skipped, {n_err} errors -> {out_path}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
