"""Render EXPERIMENTS.md roofline tables from the dry-run reports."""

import json
import os
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), "../../.."))


def roofline_table(label: str) -> str:
    path = os.path.join(ROOT, f"reports/dryrun_{label}.json")
    if not os.path.exists(path):
        return f"(report {path} missing)\n"
    rows = json.load(open(path))
    out = [
        "| arch | shape | T_comp (s) | T_mem (s) | T_coll (s) | dominant | "
        "MODEL/HLO | frac | mem/dev | micro | attn |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in rows:
        if r["status"] == "skipped":
            out.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — | — | — | "
                f"{r['reason'][:60]} |"
            )
            continue
        if not r["status"].startswith("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | {r['status']} "
                       f"| — | — | — | — | {r.get('error','')[:60]} |")
            continue
        flag = "" if r["status"] == "ok" else " ⚠"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"{r['dominant']} | {r['model_hlo_ratio']:.3f} | "
            f"{r['roofline_fraction']:.4f} | {r['mem_per_dev_gb']:.1f}G{flag} | "
            f"{r.get('num_microbatches', 1)} | {r.get('attention_strategy','')} |"
        )
    return "\n".join(out) + "\n"


def perf_table() -> str:
    path = os.path.join(ROOT, "reports/perf_iterations.json")
    if not os.path.exists(path):
        return "(no perf iterations logged)\n"
    rows = json.load(open(path))
    out = [
        "| cell | iteration | T_comp | T_mem | T_coll | mem/dev | frac (XLA) | "
        "frac (kernel) | hypothesis |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        ka = r.get("kernel_adjusted", {})
        out.append(
            f"| {r['arch']}/{r['shape']} | {r['label']} | {r['t_compute_s']:.2f} | "
            f"{r['t_memory_s']:.2f} | {r['t_collective_s']:.2f} | "
            f"{r['mem_per_dev_gb']:.1f}G | {r['roofline_fraction']:.4f} | "
            f"{ka.get('roofline_fraction', '—')} | {r.get('hypothesis','')[:80]} |"
        )
    return "\n".join(out) + "\n"


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "single"
    if which == "perf":
        print(perf_table())
    else:
        label = "2x16x16" if which == "multi" else "16x16"
        print(roofline_table(label))
