"""Render EXPERIMENTS.md roofline tables from the dry-run reports, and the
census (DiscriminantSweep) anomaly-rate tables in the style of the paper's
Figs. 5-7."""

import json
import os
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), "../../.."))


def _census_agg_row(label: str, a: dict) -> str:
    reasons = a.get("reasons", {})
    return (
        f"| {label} | {a['n']} | {a['anomalies']} | {100.0 * a['rate']:.1f}% | "
        f"{reasons.get('min_flops_split', 0)} | "
        f"{reasons.get('faster_outside_min_flops', 0)} | "
        f"{a['converged']}/{a['n']} |"
    )


_CENSUS_HEADER = (
    "| {col} | n | anomalies | rate | S_F split | faster outside S_F | "
    "converged |\n|---|---|---|---|---|---|---|"
)


def _family_notes(by_family) -> list:
    """One italic footnote per censused family, from the AlgorithmFamily
    registry's descriptions (families a reader of the report cannot be
    assumed to know, e.g. kernel_variants). Unregistered family names in
    old stores are skipped silently."""
    from repro.core.family import get_family

    notes = []
    for fam in by_family:
        try:
            desc = get_family(fam).description
        except KeyError:
            continue
        if desc:
            notes.append(f"*{fam}*: {desc}.")
    return notes


def census_tables(records, name: str = "census") -> str:
    """Markdown anomaly-rate tables (overall / by family / by instance size
    / family x size) from merged DiscriminantSweep records — the paper's
    Figs. 5-7 presentation of "an abundance of anomalies"."""
    from repro.core.sweep import census_summary

    s = census_summary(records)
    total = s["total"]
    out = [
        f"## Census `{name}` — FLOPs-discriminant anomaly rate",
        "",
        f"{total['n']} instances, {total['anomalies']} anomalies "
        f"({100.0 * total['rate']:.1f}%), "
        f"{total['converged']}/{total['n']} campaigns converged.",
        "",
    ]
    n_pred = total.get("predicted", 0)
    if n_pred:
        out += [
            f"{n_pred}/{total['n']} instances predicted without measurement "
            f"by the learned cost model (skip fraction "
            f"{100.0 * n_pred / max(total['n'], 1):.1f}%); the rest were "
            "measured normally.",
            "",
        ]
    out += [
        "### By expression family",
        "",
        _CENSUS_HEADER.format(col="family"),
    ]
    for fam, a in s["by_family"].items():
        out.append(_census_agg_row(fam, a))
    notes = _family_notes(s["by_family"])
    if notes:
        out += [""] + notes
    out += ["", "### By instance size (geometric-mean dimension)", "",
            _CENSUS_HEADER.format(col="size")]
    for bucket, a in s["by_size"].items():
        out.append(_census_agg_row(f"`{bucket}`", a))
    out += ["", "### Family x size", "",
            _CENSUS_HEADER.format(col="family / size")]
    for fam, buckets in s["by_family_size"].items():
        for bucket, a in buckets.items():
            out.append(_census_agg_row(f"{fam} `{bucket}`", a))
    return "\n".join(out) + "\n"


def predict_tables(rows, name: str = "predict") -> str:
    """Markdown prediction-error tables from
    :func:`repro.predict.active.prediction_errors` rows: per
    (family, machine) the mean absolute log10-time error against the
    deterministic ground truth, winner/anomaly agreement with the census
    verdicts, and the fraction the confidence gate would skip."""
    groups = {}
    for r in rows:
        groups.setdefault((r["family"], r["machine"]), []).append(r)
    n_skip = sum(1 for r in rows if r["skipped"])
    out = [
        f"## Predictor `{name}` — learned cost model vs the census",
        "",
        f"{len(rows)} instances scored; the confidence gate would skip "
        f"{n_skip} ({100.0 * n_skip / max(len(rows), 1):.1f}%) without "
        "measurement.",
        "",
        "| family | machine | n | mean |Δlog10 t| | winner match | "
        "anomaly match | would skip |",
        "|---|---|---|---|---|---|---|",
    ]
    for (fam, machine), g in sorted(groups.items()):
        errs = [r["abs_dlog10_t"] for r in g if r["abs_dlog10_t"] is not None]
        err = f"{sum(errs) / len(errs):.4f}" if errs else "—"
        wins = sum(1 for r in g if r["winner_match"])
        anoms = sum(1 for r in g if r["anomaly_match"])
        skips = sum(1 for r in g if r["skipped"])
        out.append(
            f"| {fam} | {machine} | {len(g)} | {err} | "
            f"{wins}/{len(g)} | {anoms}/{len(g)} | "
            f"{100.0 * skips / len(g):.1f}% |"
        )
    return "\n".join(out) + "\n"


def explain_tables(records, name: str = "explain") -> str:
    """Markdown cause tables from merged AnomalyExplainer records: cause
    rates with evidence, family x cause, offending-kernel tally, and the
    highest-evidence examples — the census anomalies, explained."""
    from repro.explain.runner import explain_summary

    s = explain_summary(records)
    out = [
        f"## Explanations `{name}` — anomaly root causes",
        "",
        f"{s['total']} anomalies explained, mean evidence "
        f"{s['mean_evidence']:.2f} (fraction of the winner/loser time gap "
        "the assigned cause accounts for).",
        "",
        "### By cause",
        "",
        "| cause | n | share | mean evidence |",
        "|---|---|---|---|",
    ]
    for cause, a in s["by_cause"].items():
        out.append(f"| {cause} | {a['n']} | {100.0 * a['share']:.1f}% | "
                   f"{a['mean_evidence']:.2f} |")
    out += ["", "### Family x cause", "",
            "| family | cause | n | mean evidence |", "|---|---|---|---|"]
    for fam, causes in s["by_family_cause"].items():
        for cause, a in causes.items():
            out.append(f"| {fam} | {cause} | {a['n']} | "
                       f"{a['mean_evidence']:.2f} |")
    if s["offending_ops"]:
        out += ["", "### Offending kernels", "",
                "| kernel op | anomalies it explains |", "|---|---|"]
        for op, n in sorted(s["offending_ops"].items(),
                            key=lambda kv: (-kv[1], kv[0])):
            out.append(f"| {op} | {n} |")
    top = sorted(records, key=lambda r: (-float(r["evidence"]), r["index"]))[:5]
    if top:
        out += ["", "### Highest-evidence examples", "",
                "| uid | reason | cause | evidence | offending kernel/pair | "
                "gap | gap z | flip p | modes |",
                "|---|---|---|---|---|---|---|---|---|"]
        for r in top:
            z = r.get("gap_zscore")
            flip = r.get("flip_probability")
            modes = (r.get("bimodality") or {}).get("share")
            out.append(
                f"| {r['uid']} | {r['reason']} | {r['cause']} | "
                f"{float(r['evidence']):.2f} | "
                f"{r.get('offending_kernel') or '—'} | "
                f"{100.0 * float(r['gap_rel']):.1f}% | "
                f"{f'{float(z):.1f}' if z is not None else '—'} | "
                f"{f'{float(flip):.2f}' if flip is not None else '—'} | "
                f"{f'{100.0 * float(modes):.0f}%' if modes is not None else '—'} |"
            )
    return "\n".join(out) + "\n"


def roofline_table(label: str) -> str:
    path = os.path.join(ROOT, f"reports/dryrun_{label}.json")
    if not os.path.exists(path):
        return f"(report {path} missing)\n"
    rows = json.load(open(path))
    out = [
        "| arch | shape | T_comp (s) | T_mem (s) | T_coll (s) | dominant | "
        "MODEL/HLO | frac | mem/dev | micro | attn |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in rows:
        if r["status"] == "skipped":
            out.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — | — | — | "
                f"{r['reason'][:60]} |"
            )
            continue
        if not r["status"].startswith("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | {r['status']} "
                       f"| — | — | — | — | {r.get('error','')[:60]} |")
            continue
        flag = "" if r["status"] == "ok" else " ⚠"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"{r['dominant']} | {r['model_hlo_ratio']:.3f} | "
            f"{r['roofline_fraction']:.4f} | {r['mem_per_dev_gb']:.1f}G{flag} | "
            f"{r.get('num_microbatches', 1)} | {r.get('attention_strategy','')} |"
        )
    return "\n".join(out) + "\n"


def perf_table() -> str:
    path = os.path.join(ROOT, "reports/perf_iterations.json")
    if not os.path.exists(path):
        return "(no perf iterations logged)\n"
    rows = json.load(open(path))
    out = [
        "| cell | iteration | T_comp | T_mem | T_coll | mem/dev | frac (XLA) | "
        "frac (kernel) | hypothesis |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        ka = r.get("kernel_adjusted", {})
        out.append(
            f"| {r['arch']}/{r['shape']} | {r['label']} | {r['t_compute_s']:.2f} | "
            f"{r['t_memory_s']:.2f} | {r['t_collective_s']:.2f} | "
            f"{r['mem_per_dev_gb']:.1f}G | {r['roofline_fraction']:.4f} | "
            f"{ka.get('roofline_fraction', '—')} | {r.get('hypothesis','')[:80]} |"
        )
    return "\n".join(out) + "\n"


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "single"
    if which == "perf":
        print(perf_table())
    else:
        label = "2x16x16" if which == "multi" else "16x16"
        print(roofline_table(label))
