"""Pull-based work queue — drain one campaign with any number of hosts.

``launch/sweep.py run`` forks workers on ONE box and assigns shards
statically. This launcher inverts that: the shared store directory IS the
queue, and every participating host runs ``queue work`` against it,
repeatedly leasing whichever shard is unfinished and unclaimed
(:mod:`repro.core.lease`), driving it with the ordinary resumable shard
runner, and releasing it. Nothing is assigned; hosts that join late, leave
early, or die mid-chunk just shift which host resumes each shard — and for
the deterministic backends the merged result is byte-identical to a 1-host
run, because a lease takeover is literally the kill/resume path.

    # host A (and B, C, ... — any count, any time, same shared dir)
    PYTHONPATH=src python -m repro queue work --out /shared/census

    # simulate N hosts locally (the CI byte-identity smoke)
    PYTHONPATH=src python -m repro queue run --out DIR --hosts 2

    # who holds what
    PYTHONPATH=src python -m repro queue status --out DIR

The queue serves both campaign kinds, auto-detected from the store root:
``spec.json`` = a DiscriminantSweep census, ``espec.json`` = an
AnomalyExplainer campaign. On-disk layout per shard (all under ``--out``):

    shard-NNNN.jsonl           append-only records (source of truth)
    shard-NNNN.manifest.json   slim counts + done flag
    shard-NNNN.engine.json     in-flight chunk state (present mid-chunk)
    shard-NNNN.lease.json      held by at most one live host
    shard-NNNN.timings.json    advisory per-stage wall-clock totals

Requirements on the shared filesystem: atomic ``O_EXCL`` create, atomic
rename, and clocks agreeing to well within the lease TTL — POSIX-y NFS
and every local filesystem qualify.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time
import zlib
from typing import Any, Callable, Dict, List, Optional

from repro.core.lease import (
    DEFAULT_HEARTBEAT_INTERVAL,
    DEFAULT_TTL,
    LEASE_CORRUPT,
    LeaseLost,
    acquire_lease_with_backoff,
    read_lease_ex,
)
from repro.core.sweep import ShardStore, StoreDamaged, SweepSpec, shard_counts
from repro.launch.cliutil import add_fsck_args, deprecated_alias, fsck_command

SWEEP_SPEC = "spec.json"
EXPLAIN_SPEC = "espec.json"


# ----------------------------------------------------------- the adapters ---


class SweepQueue:
    """A census store as a drainable queue."""

    kind = "sweep"

    def __init__(self, out: str) -> None:
        self.out = out
        self.spec = SweepSpec.load(os.path.join(out, SWEEP_SPEC))
        self.n_shards = self.spec.n_shards

    def shard_totals(self) -> List[int]:
        totals = [0] * self.n_shards
        for inst in self.spec.expand():
            totals[self.spec.shard_of(inst)] += 1
        return totals

    def run_shard(self, shard: int, *, heartbeat, max_steps, progress) -> None:
        from repro.core.sweep import run_shard

        run_shard(
            self.spec, self.out, shard,
            max_steps=max_steps, progress=progress, heartbeat=heartbeat,
        )

    def merge(self) -> str:
        from repro.core.sweep import write_merged

        return write_merged(self.spec, self.out)

    def progress(self) -> Dict[str, int]:
        from repro.core.sweep import sweep_progress

        prog = sweep_progress(self.spec, self.out)
        return {"completed": prog["completed"], "total": prog["instances"]}


class ExplainQueue:
    """An explanation-campaign store as a drainable queue."""

    kind = "explain"

    def __init__(self, out: str) -> None:
        from repro.explain.runner import ExplainSpec, explain_targets

        self.out = out
        self.espec = ExplainSpec.load(os.path.join(out, EXPLAIN_SPEC))
        self.n_shards = self.espec.n_shards
        #: (sweep spec, anomaly work list) — parsed once per host process
        self.census = explain_targets(self.espec)

    def shard_totals(self) -> List[int]:
        from repro.explain.runner import shard_targets

        _, targets = self.census
        return [
            len(shard_targets(self.espec, targets, s))
            for s in range(self.n_shards)
        ]

    def run_shard(self, shard: int, *, heartbeat, max_steps, progress) -> None:
        from repro.explain.runner import run_explain_shard

        run_explain_shard(
            self.espec, self.out, shard,
            max_steps=max_steps, progress=progress,
            census=self.census, heartbeat=heartbeat,
        )

    def merge(self) -> str:
        from repro.explain.runner import write_merged_explained

        return write_merged_explained(self.espec, self.out)

    def progress(self) -> Dict[str, int]:
        from repro.explain.runner import explain_progress

        _, targets = self.census
        prog = explain_progress(self.espec, self.out, targets=targets)
        return {"completed": prog["completed"], "total": prog["anomalies"]}


def open_queue(out: str):
    """The store's adapter, auto-detected through the store-kind registry
    (:mod:`repro.core.stores`): which registered spec file the root holds
    decides the drain path, and a root holding more than one refuses
    rather than guessing."""
    from repro.core.stores import AmbiguousStore, detect_store_kind, store_kinds

    try:
        kind = detect_store_kind(out)
    except AmbiguousStore as err:
        raise SystemExit(str(err)) from None
    if kind is None:
        known = ", ".join(f"{k.name} ({k.spec_file})" for k in store_kinds())
        raise SystemExit(
            f"{out} holds no campaign spec — known store kinds: {known}; "
            "plan a campaign there first"
        )
    return kind.make_queue(out)


# ------------------------------------------------------------- the worker ---


def _shard_done(out: str, shard: int) -> bool:
    manifest = ShardStore(out, shard).read_manifest()
    return bool(manifest and manifest.get("done"))


def drain(
    queue: Any,
    owner: str,
    *,
    ttl: float = DEFAULT_TTL,
    interval: float = DEFAULT_HEARTBEAT_INTERVAL,
    poll: float = 1.0,
    max_steps: Optional[int] = None,
    say: Optional[Callable[[str], None]] = None,
) -> bool:
    """One host's pull loop: lease-an-unfinished-shard, run it, release,
    repeat, until every shard's manifest says done. Dead hosts' shards are
    adopted once their lease TTL expires (the acquire path breaks expired
    leases); losing our own lease mid-shard (:class:`LeaseLost`) abandons
    that shard without committing and moves on.

    Returns True when the whole campaign is drained. With ``max_steps``
    set, each shard is driven at most once and the loop exits after one
    sweep over the shards (possibly leaving paused, resumable shards) —
    the deadline/test entry point.

    Degradation: a shard whose store turns out to be damaged
    (:class:`StoreDamaged` — mid-file corruption that only fsck may
    repair) is released and remembered, never retried by this host; when
    every unfinished shard is damaged the drain returns False instead of
    spinning, and the operator runs fsck. Lease acquisition uses bounded
    jittered backoff, so transient IO errors and thundering-herd
    contention degrade to a later pass rather than a crash.
    """
    tell = say or (lambda msg: None)
    n = queue.n_shards
    # spread hosts across the ring so they don't all fight for shard 0
    start = zlib.adler32(owner.encode("utf-8")) % max(1, n)
    order = list(range(start, n)) + list(range(start))
    single_pass = max_steps is not None
    damaged: set = set()
    while True:
        worked = False
        all_done = True
        for shard in order:
            if _shard_done(queue.out, shard):
                continue
            all_done = False
            if shard in damaged:
                continue
            lease = acquire_lease_with_backoff(
                ShardStore(queue.out, shard).lease_path, owner,
                ttl=ttl, interval=interval,
            )
            if lease is None:
                continue  # a live host has it (or IO kept failing)
            tell(f"{owner}: leased shard {shard}")
            try:
                queue.run_shard(
                    shard,
                    heartbeat=lease.heartbeat,
                    max_steps=max_steps,
                    progress=tell,
                )
            except LeaseLost:
                tell(f"{owner}: lost shard {shard} lease (taken over); "
                     "moving on")
                continue
            except StoreDamaged as err:
                damaged.add(shard)
                lease.release()
                tell(f"{owner}: shard {shard} store is damaged ({err}); "
                     "re-enqueued for after fsck, moving on")
                continue
            lease.release()
            worked = True
        if all_done:
            return True
        pending = [s for s in order
                   if s not in damaged and not _shard_done(queue.out, s)]
        if damaged and not pending:
            tell(f"{owner}: every unfinished shard is damaged "
                 f"({sorted(damaged)}) — run fsck, then drain again")
            return False
        if single_pass:
            return False
        if not worked:
            # everything unfinished is leased elsewhere: wait for either a
            # release (done) or a TTL expiry (dead host) to free a shard
            time.sleep(poll)


# ------------------------------------------------------------- subcommands ---


def _owner(args: argparse.Namespace) -> str:
    from repro.core.lease import default_owner

    if args.host:
        import uuid

        return f"{args.host}:{os.getpid()}:{uuid.uuid4().hex[:8]}"
    return default_owner()


def cmd_work(args: argparse.Namespace) -> int:
    queue = open_queue(args.out)
    owner = _owner(args)
    done = drain(
        queue, owner,
        ttl=args.ttl, interval=args.heartbeat, poll=args.poll,
        max_steps=args.max_steps_per_shard,
        say=lambda msg: print(f"# {msg}", flush=True),
    )
    prog = queue.progress()
    print(f"# {owner}: {prog['completed']}/{prog['total']} complete "
          f"({'drained' if done else 'paused'})")
    return 0


def cmd_run(args: argparse.Namespace) -> int:
    """Simulate N hosts locally: N ``work`` subprocesses over one store."""
    from repro.launch.sweep import _worker_env

    queue = open_queue(args.out)
    hosts = max(1, args.hosts)
    procs: List[subprocess.Popen] = []
    for h in range(hosts):
        cmd = [
            sys.executable, "-m", "repro", "queue", "work",
            "--out", args.out, "--host", f"simhost-{h}",
            "--ttl", str(args.ttl), "--heartbeat", str(args.heartbeat),
            "--poll", str(args.poll),
        ]
        if args.max_steps_per_shard is not None:
            cmd += ["--max-steps-per-shard", str(args.max_steps_per_shard)]
        procs.append(subprocess.Popen(cmd, env=_worker_env()))
    rcs = [p.wait() for p in procs]
    failed = [(h, rc) for h, rc in enumerate(rcs) if rc != 0]
    prog = queue.progress()
    print(f"# {prog['completed']}/{prog['total']} complete "
          f"({queue.kind}, {hosts} hosts)")
    if failed:
        for h, rc in failed:
            print(f"# host {h} exited {rc}", file=sys.stderr)
        print("# re-run the same command to resume", file=sys.stderr)
        return 1
    if prog["completed"] == prog["total"]:
        try:
            print(f"# merged: {queue.merge()}")
        except StoreDamaged as err:
            print(f"# merge refused: {err}", file=sys.stderr)
            return 1
    return 0


def cmd_status(args: argparse.Namespace) -> int:
    queue = open_queue(args.out)
    totals = queue.shard_totals()
    prog = queue.progress()
    print(f"# {queue.kind} queue {args.out}: "
          f"{prog['completed']}/{prog['total']} complete")
    now = time.time()
    total_damaged = 0
    for shard in range(queue.n_shards):
        store = ShardStore(queue.out, shard)
        counts = shard_counts(store)
        lease, lease_state = read_lease_ex(store.lease_path)
        state = "done" if counts["done_flag"] else "open"
        holder = ""
        if lease_state == LEASE_CORRUPT:
            holder = " lease CORRUPT (fsck will clear it)"
        elif lease is not None:
            age = lease.age(now)
            holder = (f" leased by {lease.owner} "
                      f"(heartbeat {age:.0f}s ago"
                      f"{', EXPIRED' if lease.expired(now) else ''})")
        damage = ""
        if counts.get("damaged"):
            total_damaged += counts["damaged"]
            damage = f" DAMAGED x{counts['damaged']}"
        print(f"#   shard {shard:4d}: {counts['done']}/{totals[shard]} "
              f"[{state}]{holder}{damage}")
    if total_damaged:
        print(f"# {total_damaged} damaged record line(s) — merge will "
              f"refuse; run: python -m repro fsck --out {args.out}")
    return 0


def main(argv: Optional[List[str]] = None, prog: Optional[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog=prog or "repro.launch.queue",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    def add_worker_args(p: argparse.ArgumentParser) -> None:
        p.add_argument("--out", required=True,
                       help="shared store root (sweep or explain)")
        p.add_argument("--ttl", type=float, default=DEFAULT_TTL,
                       help="seconds without a heartbeat before a lease "
                       "counts as dead and may be adopted")
        p.add_argument("--heartbeat", type=float,
                       default=DEFAULT_HEARTBEAT_INTERVAL,
                       help="seconds between lease heartbeats (<< ttl)")
        p.add_argument("--poll", type=float, default=1.0,
                       help="seconds between queue polls when all "
                       "unfinished shards are leased elsewhere")
        p.add_argument("--max-steps-per-shard", type=int, default=None,
                       help="pause each shard after N engine steps and make "
                       "one pass only (resumable)")

    p = sub.add_parser("work", help="pull worker: lease+run shards until "
                       "the campaign is drained")
    add_worker_args(p)
    p.add_argument("--host", default="",
                   help="host label for the lease owner token "
                   "(default: the real hostname)")
    p.set_defaults(fn=cmd_work)

    p = sub.add_parser("run", help="simulate N hosts locally (N work "
                       "subprocesses over one store)")
    add_worker_args(p)
    p.add_argument("--hosts", type=int, default=2)
    p.set_defaults(fn=cmd_run)

    p = sub.add_parser("status", help="per-shard progress + lease holders")
    p.add_argument("--out", required=True)
    p.set_defaults(fn=cmd_status)

    p = sub.add_parser("fsck", help="classify/repair/quarantine store damage")
    add_fsck_args(p)
    p.set_defaults(fn=fsck_command)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    deprecated_alias("repro.launch.queue", "queue")
    sys.exit(main())
