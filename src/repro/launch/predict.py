"""Learned cost model launcher — train / predict / eval for active censuses.

Train a ridge model (:mod:`repro.predict`) from a finished deterministic
census, inspect its per-instance rank predictions, and score it against a
measured census (the pred-error tables):

    # fit the model from a merged census store
    PYTHONPATH=src python -m repro predict train \\
        --census /tmp/census --out /tmp/model.json

    # per-instance predicted ranking + confidence (what the gate would do)
    PYTHONPATH=src python -m repro predict predict \\
        --census /tmp/census --model /tmp/model.json

    # pred-error tables per family/machine against the measured records
    PYTHONPATH=src python -m repro predict eval \\
        --census /tmp/census --model /tmp/model.json

The trained JSON is what ``repro census run --predictor MODEL.json``
consults to skip confidently-predicted instances, and what
``repro oracle warm --model MODEL.json`` serves cache misses from.
Training targets exist only for the deterministic backends
(``cost_model`` / ``simulated``): those records' measured outcomes are
reconstructible bit-exactly from their rebuild pointers. Wall-clock
records are skipped at train time and the count is reported.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from repro.launch.cliutil import deprecated_alias


def _load_census(census: str):
    from repro.core.sweep import SweepSpec, merge_shards

    spec = SweepSpec.load(os.path.join(census, "spec.json"))
    return spec, merge_shards(spec, census)


def cmd_train(args: argparse.Namespace) -> int:
    from repro.predict.model import train_model

    spec, records = _load_census(args.census)
    if not records:
        print("# census has no completed records — run it first",
              file=sys.stderr)
        return 1
    try:
        model = train_model(spec, records, machine=args.machine,
                            alpha=args.alpha)
    except ValueError as err:
        print(f"# {err}", file=sys.stderr)
        return 1
    model.save(args.out)
    skipped = (f", {model.n_skipped} wall-clock records skipped"
               if model.n_skipped else "")
    print(f"# trained {args.out}: {model.n_train} (instance, algorithm) "
          f"rows{skipped}, machine {model.machine}, residual sigma "
          f"{model.residual_sigma:.4f} (log10 s), "
          f"digest {model.train_digest[:12]}")
    return 0


def cmd_predict(args: argparse.Namespace) -> int:
    from repro.predict.active import ActivePredictor

    spec, _ = _load_census(args.census)
    threshold = args.threshold if args.threshold is not None \
        else spec.predict_threshold
    predictor = ActivePredictor.open(
        args.model, spec, threshold=threshold, machine=args.machine,
    )
    instances = spec.expand()
    skipped = 0
    rows = []
    for inst in instances:
        pred = predictor.predict(inst)
        skip = pred.confidence >= predictor.threshold
        skipped += skip
        if args.json:
            rows.append(json.dumps(
                predictor.record(inst, pred), sort_keys=True,
                separators=(",", ":"),
            ))
        else:
            order = sorted(pred.ranks, key=lambda a: (pred.ranks[a], a))
            anom = f" ANOMALY({pred.reason})" if pred.is_anomaly else ""
            rows.append(
                f"# {inst.uid}: {' < '.join(order)} "
                f"conf={pred.confidence:.3f}"
                f" [{'skip' if skip else 'measure'}]{anom}"
            )
    print("\n".join(rows))
    frac = skipped / max(len(instances), 1)
    print(f"# gate at threshold {predictor.threshold}: {skipped}"
          f"/{len(instances)} instances would skip measurement "
          f"({100.0 * frac:.1f}%)", file=sys.stderr)
    return 0


def cmd_eval(args: argparse.Namespace) -> int:
    from repro.launch.report_md import predict_tables
    from repro.predict.active import prediction_errors
    from repro.predict.model import RidgeModel

    spec, records = _load_census(args.census)
    if not records:
        print("# census has no completed records — run it first",
              file=sys.stderr)
        return 1
    model = RidgeModel.load(args.model)
    rows = prediction_errors(spec, records, model, machine=args.machine)
    if args.json:
        json.dump(rows, sys.stdout, indent=1, sort_keys=True)
        print()
    else:
        print(predict_tables(rows, name=spec.name))
    return 0


def main(argv: Optional[List[str]] = None, prog: Optional[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog=prog or "repro.launch.predict",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("train", help="fit the ridge model from a finished "
                       "deterministic census")
    p.add_argument("--census", required=True, help="census store root")
    p.add_argument("--out", required=True, help="model JSON to write")
    p.add_argument("--machine", default="",
                   help="MachineSpec registry name to cost features against "
                   "(default: derived from the census backend)")
    p.add_argument("--alpha", type=float, default=1e-3,
                   help="ridge regularization strength")
    p.set_defaults(fn=cmd_train)

    p = sub.add_parser("predict", help="per-instance predicted ranking, "
                       "confidence, and the gate's skip/measure decision")
    p.add_argument("--census", required=True, help="census store root")
    p.add_argument("--model", required=True, help="trained model JSON")
    p.add_argument("--threshold", type=float, default=None,
                   help="confidence gate (default: the spec's "
                   "predict_threshold)")
    p.add_argument("--machine", default="")
    p.add_argument("--json", action="store_true",
                   help="emit predicted-provenance census records (JSONL) "
                   "instead of the table")
    p.set_defaults(fn=cmd_predict)

    p = sub.add_parser("eval", help="pred-error tables per family/machine "
                       "against the measured census records")
    p.add_argument("--census", required=True, help="census store root")
    p.add_argument("--model", required=True, help="trained model JSON")
    p.add_argument("--machine", default="")
    p.add_argument("--json", action="store_true",
                   help="raw evaluation rows as JSON instead of markdown")
    p.set_defaults(fn=cmd_eval)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    deprecated_alias("repro.launch.predict", "predict")
    sys.exit(main())
