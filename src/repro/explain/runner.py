"""ExplainSpec + sharded, resumable explanation campaigns.

An explanation campaign consumes a finished (or finishing) DiscriminantSweep
census and produces one explanation record per anomaly. It reuses the whole
measurement stack: each anomaly becomes a
:class:`~repro.core.session.MeasurementSession` whose measured names are the
winner and loser algorithms *plus every kernel segment of both*, driven in
chunks through :class:`~repro.core.engine.ExperimentEngine` campaigns with
the same persistence contract as the sweep — engine state saved every
``save_every`` steps, records appended to per-shard JSONL
(:class:`~repro.core.sweep.ShardStore`), and for the deterministic census
backends a SIGKILLed explain run resumes **byte-identical** to an
uninterrupted one.

Backends follow the census: a ``cost_model``/``simulated`` census is
explained on the same synthetic machine (segment costs reconstructed from
the record's ``kernels``/``flops``/``base_seed`` pointers — zero census
re-runs, zero jax imports); a ``wall_clock`` census re-measures each kernel
in isolation with fresh jitted workloads.
"""

from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.faults import FaultPlan, active_plan
from repro.core.measure import CostModelTimer, NoiseProfile, SimulatedTimer, Timer, WallClockTimer
from repro.core.session import MeasurementSession
from repro.core.sweep import (
    LINE_CRC_MISMATCH,
    LINE_UNDECODABLE,
    InstanceSpec,
    ShardStore,
    StoreDamaged,
    SweepSpec,
    instance_entry,
    merge_shards,
    parse_record_line,
    run_chunked_campaign,
    shard_counts,
    synthetic_instance_model,
)
from repro.core.types import DEFAULT_QUANTILE_RANGES, REPORT_QUANTILE_RANGE
from repro.roofline.terms import MachineSpec, get_machine, synthetic_machine

from .attribution import AlgorithmAttribution, attribute_algorithm
from .calibrate import load_calibrated_machine
from .classify import (
    DEFAULT_FLIP_MIN_PROB,
    DEFAULT_FLIP_Z,
    classify_anomaly,
    pick_winner_loser,
)
from .distributions import median_gap_zscore, session_bimodality
from .decompose import (
    KernelSpec,
    build_kernel_workload,
    kernel_name,
    kernels_from_compact,
    kernels_from_record,
    kernels_to_compact,
)

SPEC_FILE = "espec.json"


@dataclass
class ExplainSpec:
    """One explanation campaign, declaratively. ``census`` points at the
    sweep's ``--out`` directory; everything else is campaign knobs. The
    work list (which anomalies, in which shard) is a pure function of this
    spec plus the census records, so any worker anywhere agrees on it."""

    name: str = "explain"
    census: str = ""
    n_shards: int = 4
    #: segment measurement campaign (Procedure 4 over kernels)
    m_per_iteration: int = 3
    eps: float = 0.03
    max_measurements: int = 12
    chunk_size: int = 8
    save_every: int = 25
    #: MachineSpec registry name; empty = derive from the census backend
    #: (synthetic machine for cost_model/simulated, cpu-1core for wall_clock)
    machine: str = ""
    #: path to a ``calibrate`` output file; overrides ``machine`` with the
    #: fitted dispatch/efficiency-curve spec
    machine_file: str = ""
    min_evidence: float = 0.5
    #: re-ranking confidence probe: when the winner/loser median gap is
    #: non-positive or below ``flip_z`` standard errors, re-measure both
    #: under the census protocol ``flip_probes`` times and report the flip
    #: probability (the ``not_reproducible`` evidence).
    flip_probes: int = 16
    flip_z: float = DEFAULT_FLIP_Z
    flip_min_prob: float = DEFAULT_FLIP_MIN_PROB
    #: quantile ladder for the segment sessions. ``"report"`` (default)
    #: runs one Procedure-2 sort per step — the report range only, which is
    #: all the explainer consumes (segment *medians* + convergence); this
    #: draws the exact same samples in the exact same order as the full
    #: ladder (the hypothesis reorder comes from the report-range sort
    #: either way), it just stops paying for the six extra ladder sorts
    #: that only feed the census's rank-stability diagnostics. ``"paper"``
    #: keeps the full 7-range ladder of the census.
    ladder: str = "report"
    base_seed: int = 0
    fsync: bool = False

    def __post_init__(self) -> None:
        if self.n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if not 0.0 <= self.min_evidence <= 1.0:
            raise ValueError("min_evidence must be in [0, 1]")
        if self.flip_probes < 1:
            raise ValueError("flip_probes must be >= 1")
        if self.ladder not in ("report", "paper"):
            raise ValueError('ladder must be "report" or "paper"')

    def quantile_ranges(self) -> Tuple[Tuple[float, float], ...]:
        """The session quantile ladder this campaign measures with."""
        if self.ladder == "paper":
            return tuple(DEFAULT_QUANTILE_RANGES)
        return (REPORT_QUANTILE_RANGE,)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["version"] = 1
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ExplainSpec":
        kwargs = {
            f.name: d[f.name] for f in dataclasses.fields(cls) if f.name in d
        }
        return cls(**kwargs)

    def save(self, path: str) -> str:
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(self.to_dict(), fh, indent=1, sort_keys=True)
        os.replace(tmp, path)
        return path

    @classmethod
    def load(cls, path: str) -> "ExplainSpec":
        with open(path) as fh:
            return cls.from_dict(json.load(fh))


# ------------------------------------------------------------ the work list ---


def load_census(espec: ExplainSpec) -> Tuple[SweepSpec, List[Dict[str, Any]]]:
    """(sweep spec, merged census records) for the campaign's census."""
    spec_file = os.path.join(espec.census, "spec.json")
    sweep_spec = SweepSpec.load(spec_file)
    return sweep_spec, merge_shards(sweep_spec, espec.census)


#: census lines are canonical compact JSON (``sort_keys``, no spaces), so
#: every anomaly line contains the first marker verbatim; the second
#: tolerates hand-edited / pretty-printed stores.
_ANOMALY_MARKERS = (b'"is_anomaly":true', b'"is_anomaly": true')


def anomaly_records(sweep_spec: SweepSpec, root: str) -> List[Dict[str, Any]]:
    """Anomalous census records, deduped by uid, in global grid order —
    the result of ``[r for r in merge_shards(...) if r["is_anomaly"]]``
    without json-parsing the overwhelmingly non-anomalous majority: lines
    missing the ``is_anomaly: true`` substring are skipped unparsed, so
    the scan cost tracks the anomaly count, not the census size."""
    seen: Dict[str, Dict[str, Any]] = {}
    for shard in range(sweep_spec.n_shards):
        path = ShardStore(root, shard).records_path
        try:
            fh = open(path, "rb")
        except OSError:
            continue
        with fh:
            lines = fh.read().splitlines(keepends=True)
        for i, line in enumerate(lines):
            if not line.endswith(b"\n"):
                break  # torn tail: an append in flight or a kill
            if not any(m in line for m in _ANOMALY_MARKERS):
                continue
            rec, status = parse_record_line(line)
            if status in (LINE_UNDECODABLE, LINE_CRC_MISMATCH):
                if i == len(lines) - 1:
                    break  # a torn tail that happens to end in \n
                raise StoreDamaged(
                    f"{path}: line {i + 1} is {status} mid-file — the "
                    "census this campaign feeds on is damaged; run "
                    f"`python -m repro.launch.fsck --out {root}` first"
                )
            if rec.get("is_anomaly"):
                seen.setdefault(str(rec["uid"]), rec)
    return sorted(seen.values(), key=lambda r: r["index"])


def explain_targets(espec: ExplainSpec) -> Tuple[SweepSpec, List[Dict[str, Any]]]:
    """(sweep spec, anomaly records in global grid order) — the campaign's
    deterministic work list. Non-anomalous records need no explanation."""
    spec_file = os.path.join(espec.census, "spec.json")
    sweep_spec = SweepSpec.load(spec_file)
    return sweep_spec, anomaly_records(sweep_spec, espec.census)


def shard_targets(espec: ExplainSpec, targets: Sequence[Mapping[str, Any]],
                  shard: int) -> List[Mapping[str, Any]]:
    """Round-robin by work-list position (like the sweep: adjacent,
    similar-cost anomalies land on different shards)."""
    if not 0 <= shard < espec.n_shards:
        raise ValueError(f"shard {shard} out of range [0, {espec.n_shards})")
    return [r for i, r in enumerate(targets) if i % espec.n_shards == shard]


def resolve_machine(espec: ExplainSpec, sweep_spec: SweepSpec) -> MachineSpec:
    """The roofline floor's hardware: a calibrated machine file first, then
    an explicit registry pick, else derived from the census backend (the
    synthetic machine IS the cost-model census's hardware — predictions of
    flops/flop_rate make the recovered per-kernel efficiencies equal the
    injected factors)."""
    if espec.machine_file:
        return load_calibrated_machine(espec.machine_file)
    if espec.machine:
        return get_machine(espec.machine)
    if sweep_spec.backend in ("cost_model", "simulated"):
        return synthetic_machine(f"sweep:{sweep_spec.name}", sweep_spec.flop_rate)
    return get_machine("cpu-1core")


def record_to_instance(sweep_spec: SweepSpec, record: Mapping[str, Any]) -> InstanceSpec:
    """Rebuild the census row from its pointers (``params`` in PR 4+
    records); pre-pointer censuses fall back to a grid re-expansion."""
    if record.get("params"):
        return InstanceSpec(
            index=int(record["index"]), uid=str(record["uid"]),
            family=str(record["family"]), params=dict(record["params"]),
        )
    by_uid = {i.uid: i for i in sweep_spec.expand()}
    return by_uid[str(record["uid"])]


def _record_flops(sweep_spec: SweepSpec, record: Mapping[str, Any]) -> Dict[str, float]:
    """Analytic FLOPs per algorithm: the record's pointer when present
    (bit-exact with what the census measured), else rebuilt analytically."""
    if record.get("flops"):
        return {k: float(v) for k, v in record["flops"].items()}
    flops, _, _ = instance_entry(record_to_instance(sweep_spec, record))
    return {k: float(v) for k, v in flops.items()}


# -------------------------------------------------------- session building ---


def _entropy(espec: ExplainSpec, record: Mapping[str, Any], stream: int) -> List[int]:
    """Explain-side RNG entropy, disjoint from the sweep's streams (the
    sweep uses streams 1-3; explain starts at 11)."""
    return [int(espec.base_seed), int(record["index"]), int(stream)]


def _measurement_names(
    winner: str, loser: str,
    kernels: Mapping[str, Sequence[KernelSpec]],
) -> List[str]:
    """Session measurement order: whole algorithms first, then each
    algorithm's kernel segments in execution order."""
    names = [winner, loser]
    for alg in (winner, loser):
        names += [kernel_name(alg, i, k) for i, k in enumerate(kernels[alg])]
    return names


def _record_instance_model(
    sweep_spec: SweepSpec,
    record: Mapping[str, Any],
    all_kernels: Optional[Mapping[str, Sequence[KernelSpec]]] = None,
):
    """The synthetic machine's per-instance ground truth, rebuilt from the
    record's ``base_seed``/``index``/``flops``/``kernels`` pointers (same
    RNG streams the census consumed — see
    :func:`repro.core.sweep.synthetic_instance_model`). ``all_kernels`` is
    the record's full per-algorithm decomposition when the caller already
    parsed it."""
    flops = _record_flops(sweep_spec, record)
    if all_kernels is None:
        all_kernels = kernels_from_record(record)
    kernel_counts = {alg: len(ks) for alg, ks in all_kernels.items()}
    return synthetic_instance_model(
        sweep_spec,
        int(record["index"]),
        flops,
        kernel_counts,
        base_seed=int(record.get("base_seed", sweep_spec.base_seed)),
    )


def _synthetic_segment_costs(
    sweep_spec: SweepSpec,
    record: Mapping[str, Any],
    involved: Sequence[str],
    kernels: Mapping[str, Sequence[KernelSpec]],
    all_kernels: Optional[Mapping[str, Sequence[KernelSpec]]] = None,
) -> Tuple[Dict[str, float], bool]:
    """(true costs per measured name, bimodal flag) on the synthetic
    machine. Whole-algorithm costs come straight from the reconstructed
    instance model (injected efficiency x cache-reuse saving + per-kernel
    dispatch — exactly what the census measured); each isolated segment
    costs its kernel's FLOP share at the algorithm's efficiency plus ONE
    dispatch. Cache reuse is deliberately *absent* from the segments (an
    isolated kernel has nobody to share cache with), which is how the
    injected reuse surfaces as a negative attribution residual."""
    model = _record_instance_model(sweep_spec, record, all_kernels)
    costs: Dict[str, float] = {}
    for alg in involved:
        costs[alg] = model.costs[alg]
        for i, k in enumerate(kernels[alg]):
            c = k.flops / sweep_spec.flop_rate * model.efficiencies[alg]
            if sweep_spec.dispatch_s > 0.0:
                c += sweep_spec.dispatch_s
            costs[kernel_name(alg, i, k)] = c
    return costs, model.bimodal


def _build_timer(
    espec: ExplainSpec,
    sweep_spec: SweepSpec,
    record: Mapping[str, Any],
    involved: Sequence[str],
    kernels: Mapping[str, Sequence[KernelSpec]],
    all_kernels: Optional[Mapping[str, Sequence[KernelSpec]]] = None,
) -> Timer:
    if sweep_spec.backend == "wall_clock":
        return WallClockTimer(
            _wall_clock_workloads(sweep_spec, record, involved, kernels)
        )
    costs, bimodal = _synthetic_segment_costs(
        sweep_spec, record, involved, kernels, all_kernels
    )
    noise_seed = int(
        np.random.default_rng(_entropy(espec, record, 11)).integers(0, 2**63 - 1)
    )
    if sweep_spec.backend == "cost_model":
        return CostModelTimer(
            costs, rel_sigma=sweep_spec.noise_sigma, seed=noise_seed
        )
    profiles = {
        name: NoiseProfile(
            base=cost,
            rel_sigma=sweep_spec.noise_sigma,
            bimodal_shift=sweep_spec.bimodal_shift if bimodal else 0.0,
            bimodal_prob=sweep_spec.bimodal_prob if bimodal else 0.0,
        )
        for name, cost in costs.items()
    }
    return SimulatedTimer(profiles, seed=noise_seed)


def _whole_algorithm_workloads(
    inst: InstanceSpec, involved: Sequence[str]
) -> Dict[str, Callable[[], Any]]:
    """Jitted+warmed workloads for ONLY the involved algorithms, resolved
    through the family registry (families with large enumerations — chains
    — override ``explain_workloads`` to build the involved pair
    selectively instead of compiling everything)."""
    from repro.core.family import get_family

    return get_family(inst.family).explain_workloads(inst, involved)


def _wall_clock_workloads(
    sweep_spec: SweepSpec,
    record: Mapping[str, Any],
    involved: Sequence[str],
    kernels: Mapping[str, Sequence[KernelSpec]],
) -> Dict[str, Callable[[], Any]]:
    """Whole-algorithm workloads come from the instance builders (same
    inputs as the census measured); kernel segments get fresh isolated
    jitted workloads."""
    inst = record_to_instance(sweep_spec, record)
    out = _whole_algorithm_workloads(inst, involved)
    seed = int(record["index"])
    for alg in involved:
        for i, k in enumerate(kernels[alg]):
            out[kernel_name(alg, i, k)] = build_kernel_workload(k, seed=seed)
    return out


def build_explain_session(
    espec: ExplainSpec,
    sweep_spec: SweepSpec,
    record: Mapping[str, Any],
) -> MeasurementSession:
    """One anomaly's explanation as a resumable measurement session: the
    winner/loser pair and all their kernel segments, measured together
    under Procedure 4 so segment medians stabilize before attribution."""
    winner, loser = pick_winner_loser(record)
    all_kernels = kernels_from_record(record)
    kernels = {winner: all_kernels[winner], loser: all_kernels[loser]}
    names = _measurement_names(winner, loser, kernels)
    timer = _build_timer(espec, sweep_spec, record, (winner, loser), kernels,
                         all_kernels)
    machine = resolve_machine(espec, sweep_spec)
    shuffle_seed = int(
        np.random.default_rng(_entropy(espec, record, 13)).integers(0, 2**31 - 1)
    )
    return MeasurementSession(
        str(record["uid"]),
        names,
        timer,
        m_per_iteration=espec.m_per_iteration,
        eps=espec.eps,
        max_measurements=espec.max_measurements,
        quantile_ranges=espec.quantile_ranges(),
        shuffle_seed=shuffle_seed,
        meta={
            "uid": str(record["uid"]),
            "index": int(record["index"]),
            "family": str(record["family"]),
            "size": record.get("size"),
            "reason": str(record.get("reason", "")),
            "winner": winner,
            "loser": loser,
            "kernels": kernels_to_compact(kernels),
            "machine": machine.to_dict(),
            "backend": sweep_spec.backend,
            #: the census's batch size — the re-ranking probe replays the
            #: census protocol, not the explain campaign's
            "census_m": sweep_spec.m_per_iteration,
        },
    )


# ------------------------------------------------------------- the records ---


def _median_times(session: MeasurementSession) -> Dict[str, float]:
    return {
        name: float(np.median(session.store.row(name)))
        for name in session.store.names()
    }


def reranking_probe(
    session: MeasurementSession,
    winner: str,
    loser: str,
    m: int,
    n_probes: int,
) -> float:
    """Flip probability of the census winner/loser order under the census
    protocol: ``n_probes`` fresh batches of ``m`` measurements per
    algorithm, each batch re-ranked by median. Returns the fraction of
    probes where the loser measures no slower than the winner — the
    confidence that the census ranking was a noise artifact.

    The probe continues the session's own timer stream (deterministic for
    the cost_model/simulated backends), and only runs after the session
    has finished measuring, so kill/resume byte-identity is preserved: a
    resumed chunk replays to the same final timer state and draws the same
    probe samples."""
    timer = session.timer
    m = max(1, int(m))
    flips = 0
    for _ in range(max(1, int(n_probes))):
        w = float(np.median(timer.measure_many(winner, m)))
        l = float(np.median(timer.measure_many(loser, m)))
        if l <= w:
            flips += 1
    return flips / max(1, int(n_probes))


def record_from_explain_session(
    session: MeasurementSession, espec: ExplainSpec
) -> Dict[str, Any]:
    """One explanation JSONL record. Deterministic-fields-only, like the
    census records: medians of deterministic draws, analytic rooflines,
    distribution statistics of deterministic samples — a resumed explain
    run merges byte-identical."""
    meta = session.meta
    machine = MachineSpec.from_dict(meta["machine"])
    kernels = kernels_from_compact(meta["kernels"])
    medians = _median_times(session)
    winner, loser = meta["winner"], meta["loser"]
    attrs: Dict[str, AlgorithmAttribution] = {
        alg: attribute_algorithm(
            alg, medians[alg], kernels[alg], medians, machine
        )
        for alg in (winner, loser)
    }
    bimodality = session_bimodality(
        {name: session.store.row(name) for name in session.store.names()}
    )
    gap, _, z = median_gap_zscore(
        session.store.row(winner), session.store.row(loser)
    )
    flip_p: Optional[float] = None
    if not bimodality.is_bimodal and (gap <= 0 or z < espec.flip_z):
        flip_p = reranking_probe(
            session, winner, loser,
            # the census's batch size (falling back to the explain
            # campaign's for pre-census_m sessions): the probe measures
            # whether the CENSUS protocol reproduces its own ranking
            m=int(meta.get("census_m", espec.m_per_iteration)),
            n_probes=espec.flip_probes,
        )
    expl = classify_anomaly(
        meta, attrs[winner], attrs[loser],
        min_evidence=espec.min_evidence,
        bimodality=bimodality,
        flip_probability=flip_p,
        gap_zscore=z,
        flip_z=espec.flip_z,
        flip_min_prob=espec.flip_min_prob,
    )
    out = {
        "uid": meta["uid"],
        "index": int(meta["index"]),
        "family": meta["family"],
        "size": meta["size"],
        "machine": machine.name,
        "backend": meta.get("backend", ""),
        "measurements_per_alg": session.measurements_per_alg,
        "iterations": session.iterations,
        "converged": session.converged,
        "gap_zscore": z if np.isfinite(z) else None,
        "flip_probability": flip_p,
        "bimodality": bimodality.to_dict(),
        "attribution": {
            "winner": attrs[winner].row(),
            "loser": attrs[loser].row(),
        },
    }
    out.update(expl.to_dict())
    return out


# --------------------------------------------------------------- the runner ---


def _wall_clock_explain_timers(
    espec: ExplainSpec,
    sweep_spec: SweepSpec,
    records_by_uid: Mapping[str, Mapping[str, Any]],
    uids: Sequence[str],
) -> Dict[str, Timer]:
    """Rebuild wall-clock segment backends for a resumed chunk (callables
    do not serialize; everything derives from the census records)."""
    timers: Dict[str, Timer] = {}
    for uid in uids:
        record = records_by_uid[uid]
        winner, loser = pick_winner_loser(record)
        all_kernels = kernels_from_record(record)
        kernels = {winner: all_kernels[winner], loser: all_kernels[loser]}
        timers[uid] = WallClockTimer(
            _wall_clock_workloads(sweep_spec, record, (winner, loser), kernels)
        )
    return timers


def run_explain_shard(
    espec: ExplainSpec,
    root: str,
    shard: int,
    *,
    max_steps: Optional[int] = None,
    progress: Optional[Callable[[str], None]] = None,
    census: Optional[Tuple[SweepSpec, List[Dict[str, Any]]]] = None,
    heartbeat: Optional[Callable[..., None]] = None,
    faults: Optional[FaultPlan] = None,
) -> ShardStore:
    """Run (or resume) one shard of the explanation campaign to completion.

    Identical persistence contract to :func:`repro.core.sweep.run_shard`:
    anomalies are processed in chunks of ``espec.chunk_size``, each chunk
    one interleaved engine campaign persisted every ``espec.save_every``
    steps; completed chunks append explanation records to the shard JSONL
    and drop the engine state. Any kill point resumes losing at most
    ``save_every`` steps of work and zero determinism (cost_model /
    simulated censuses resume bit-identical).

    ``census`` is an optional preloaded :func:`explain_targets` result —
    workers driving several shards pass it so the census JSONLs are parsed
    once per process, not once per shard. ``heartbeat`` is the work-queue
    lease hook (see :func:`repro.core.sweep.run_chunked_campaign`).

    Wall-clock stage totals land in the shard's sidecar timings file under
    explain-stage names: ``decompose_s`` (session build — kernel
    decomposition + workload setup), ``measure_s`` (engine steps),
    ``classify_s`` (attribution / classification in record_fn) and
    ``append_s`` (store I/O) — the attribution substrate for explain
    throughput regressions.
    """
    if faults is None:
        faults = active_plan()
    sweep_spec, targets = census if census is not None else explain_targets(espec)
    mine = shard_targets(espec, targets, shard)
    records_by_uid = {str(r["uid"]): r for r in mine}
    store = ShardStore(root, shard, fsync=espec.fsync, faults=faults).open()
    rebuild = None
    if sweep_spec.backend == "wall_clock":
        rebuild = lambda names: _wall_clock_explain_timers(
            espec, sweep_spec, records_by_uid, names
        )
    timings: Dict[str, float] = {}
    run_chunked_campaign(
        store,
        list(records_by_uid),
        lambda uid: build_explain_session(espec, sweep_spec, records_by_uid[uid]),
        lambda session: record_from_explain_session(session, espec),
        chunk_size=espec.chunk_size,
        save_every=espec.save_every,
        rebuild_timers=rebuild,
        max_steps=max_steps,
        progress=progress,
        label=f"explain shard {shard}",
        heartbeat=heartbeat,
        timings=timings,
        faults=faults,
    )
    if timings:
        store.add_timings({
            "decompose_s": timings.get("build_s", 0.0),
            "measure_s": timings.get("step_s", 0.0),
            "classify_s": timings.get("record_s", 0.0),
            "append_s": timings.get("append_s", 0.0),
            "steps": timings.get("steps", 0.0),
            "records": timings.get("records", 0.0),
        })
    return store


# ------------------------------------------------------------ merge/triage ---


def merge_explained(espec: ExplainSpec, root: str,
                    *, strict: bool = True) -> List[Dict[str, Any]]:
    """All shard explanation records, deduped by uid, in census grid order.

    ``strict`` (the default) refuses to merge past mid-file damage, like
    :func:`repro.core.sweep.merge_shards` — run fsck, then merge."""
    seen: Dict[str, Dict[str, Any]] = {}
    damaged: Dict[int, int] = {}
    for shard in range(espec.n_shards):
        store = ShardStore(root, shard).open(readonly=True)
        if store.damaged:
            damaged[shard] = len(store.damaged)
        for r in store.records:
            seen.setdefault(r["uid"], r)
    if damaged and strict:
        detail = ", ".join(f"shard {s}: {n} line(s)"
                           for s, n in sorted(damaged.items()))
        raise StoreDamaged(
            f"{root} holds {sum(damaged.values())} damaged record line(s) "
            f"({detail}) — refusing to merge past silent data loss; run "
            f"`python -m repro.launch.fsck --out {root}` first"
        )
    return sorted(seen.values(), key=lambda r: r["index"])


def write_merged_explained(
    espec: ExplainSpec, root: str, path: Optional[str] = None
) -> str:
    path = path or os.path.join(root, "merged.jsonl")
    records = merge_explained(espec, root)
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        for r in records:
            fh.write(json.dumps(r, sort_keys=True, separators=(",", ":")) + "\n")
    os.replace(tmp, path)
    return path


def explain_summary(records: Sequence[Mapping[str, Any]]) -> Dict[str, Any]:
    """Cause-rate aggregates: overall, by cause, by family x cause, and the
    offending-kernel-op tally — the numbers behind the cause tables."""
    n = len(records)

    def cause_agg(rows: Sequence[Mapping[str, Any]]) -> Dict[str, Any]:
        by: Dict[str, Dict[str, Any]] = {}
        for r in rows:
            c = by.setdefault(r["cause"], {"n": 0, "evidence_sum": 0.0})
            c["n"] += 1
            c["evidence_sum"] += float(r["evidence"])
        return {
            cause: {
                "n": c["n"],
                "share": c["n"] / len(rows) if rows else 0.0,
                "mean_evidence": c["evidence_sum"] / c["n"],
            }
            for cause, c in sorted(by.items())
        }

    by_family: Dict[str, Any] = {}
    for fam in sorted({r["family"] for r in records}):
        by_family[fam] = cause_agg([r for r in records if r["family"] == fam])
    offending: Dict[str, int] = {}
    for r in records:
        k = r.get("offending_kernel")
        if k:
            op = k.split("[", 1)[0]
            offending[op] = offending.get(op, 0) + 1
    return {
        "total": n,
        "mean_evidence": (
            sum(float(r["evidence"]) for r in records) / n if n else 0.0
        ),
        "by_cause": cause_agg(list(records)),
        "by_family_cause": by_family,
        "offending_ops": offending,
    }


def explain_progress(
    espec: ExplainSpec,
    root: str,
    targets: Optional[Sequence[Mapping[str, Any]]] = None,
) -> Dict[str, Any]:
    """Explained / total anomalies per shard (the status line). ``targets``
    is an optional preloaded anomaly list — drivers that already parsed
    the census skip a second parse. Done counts are served from the slim
    shard manifests (:func:`repro.core.sweep.shard_counts`) — a status
    poll no longer re-parses every explanation JSONL."""
    if targets is None:
        _, targets = explain_targets(espec)
    per_shard = []
    total_done = 0
    total_damaged = 0
    for shard in range(espec.n_shards):
        n_total = len(shard_targets(espec, targets, shard))
        store = ShardStore(root, shard)
        counts = shard_counts(store)
        per_shard.append({
            "shard": shard, "done": counts["done"], "total": n_total,
            "in_flight_chunk": os.path.exists(store.engine_path),
            "damaged": counts.get("damaged", 0),
        })
        total_done += counts["done"]
        total_damaged += counts.get("damaged", 0)
    return {
        "name": espec.name,
        "anomalies": len(targets),
        "completed": total_done,
        "damaged": total_damaged,
        "shards": per_shard,
    }
