"""Distribution-level tests over segment measurement samples.

Median reconciliation (PR 4's attribution pipeline) explains anomalies the
medians can see; ELAPS-style analysis says the *distributions* carry the
rest of the story. Two tools live here:

* :func:`mode_mixture` — a deterministic 2-means mixture test on one
  sample set (the 1-D analogue of Hartigan's dip: find the split that
  minimises within-cluster variance, then score how far apart the two
  cluster means sit relative to the within-cluster spread). A processor
  alternating between frequency levels (paper Fig. 6, "turbo boost")
  produces exactly this signature in every measured name at once.
* :func:`median_gap_zscore` — the significance of a winner/loser median
  gap against the sampling noise of the two medians. A census ranking the
  explainer's medians cannot reproduce at any reasonable z is a candidate
  ``not_reproducible`` anomaly; the re-ranking probe
  (:func:`repro.explain.runner.reranking_probe`) then measures the actual
  flip probability.

Thresholds were calibrated empirically: for 12-sample lognormal (unimodal)
draws the optimal-split separation sits at ~3.2 median, < 8 at the 1e-4
tail, while a ``bimodal_shift=0.5``-style second mode at realistic
measurement noise separates by 30+ — so ``min_separation=8`` cleanly
splits the two regimes, and majority-voting across a session's measured
names (:func:`session_bimodality`) suppresses both residual error
directions.

Pure numpy, deterministic, no jax.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Mapping, Sequence, Tuple

import numpy as np

#: Optimal-split separation below which a sample set is considered
#: unimodal (see module docstring for the calibration).
MIN_SEPARATION = 8.0
#: Minimum samples in the smaller cluster before a split counts as a mode
#: (a lone straggler is an outlier, not a frequency regime).
MIN_MINORITY = 2


@dataclass(frozen=True)
class ModeMixture:
    """One sample set, split into its best two-mean mixture."""

    n: int
    mu_lo: float           # mean of the faster cluster
    mu_hi: float           # mean of the slower cluster
    within_std: float      # pooled within-cluster standard deviation
    separation: float      # (mu_hi - mu_lo) / within_std
    minority: int          # size of the smaller cluster
    is_bimodal: bool

    def to_dict(self) -> Dict[str, Any]:
        return {
            "n": self.n,
            "mu_lo": self.mu_lo,
            "mu_hi": self.mu_hi,
            "separation": self.separation,
            "minority": self.minority,
            "is_bimodal": self.is_bimodal,
        }


def mode_mixture(
    samples: Sequence[float],
    *,
    min_separation: float = MIN_SEPARATION,
    min_minority: int = MIN_MINORITY,
) -> ModeMixture:
    """Best 2-means split of one measurement sample set.

    Sorts the samples and scans every split point for the minimum total
    within-cluster sum of squares (the exact 1-D 2-means optimum), then
    calls the set bimodal when the cluster means separate by at least
    ``min_separation`` pooled within-cluster standard deviations and the
    smaller cluster holds at least ``min_minority`` samples. Two exactly
    repeated values (zero within-variance, e.g. a noiseless cost model
    with a genuine slow mode) separate infinitely and count as bimodal.
    """
    x = np.sort(np.asarray(list(samples), dtype=np.float64))
    n = int(x.size)
    if n < 2 * max(1, min_minority):
        return ModeMixture(n, float(x.mean()) if n else 0.0,
                           float(x.mean()) if n else 0.0, 0.0, 0.0, 0, False)
    # prefix sums make every candidate split O(1):
    #   ss(lo) + ss(hi) = sum(x^2) - len_lo*mean_lo^2 - len_hi*mean_hi^2
    csum = np.cumsum(x)
    csq = np.cumsum(x * x)
    total_sum, total_sq = csum[-1], csq[-1]
    ks = np.arange(1, n)
    mean_lo = csum[:-1] / ks
    mean_hi = (total_sum - csum[:-1]) / (n - ks)
    within_ss = total_sq - ks * mean_lo**2 - (n - ks) * mean_hi**2
    k = int(np.argmin(within_ss))
    mu_lo, mu_hi = float(mean_lo[k]), float(mean_hi[k])
    within = float(np.sqrt(max(within_ss[k], 0.0) / max(n - 2, 1)))
    # floor the spread at a sliver of the scale so exact repeats (zero
    # within-variance) separate hugely instead of dividing by zero
    scale = max(abs(mu_hi), abs(mu_lo), 1e-300)
    within = max(within, 1e-9 * scale)
    separation = (mu_hi - mu_lo) / within
    minority = int(min(k + 1, n - (k + 1)))
    return ModeMixture(
        n=n,
        mu_lo=mu_lo,
        mu_hi=mu_hi,
        within_std=within,
        separation=float(separation),
        minority=minority,
        is_bimodal=(separation >= min_separation and minority >= min_minority),
    )


@dataclass(frozen=True)
class SessionBimodality:
    """Mode-mixture verdicts over every measured name of one explain
    session, majority-voted: a frequency regime is a property of the
    *machine*, so it shows up in (nearly) all distributions at once —
    which is exactly what separates it from a single slow kernel."""

    n_names: int
    n_bimodal: int
    mean_separation: float   # over the bimodal names (0.0 when none)

    @property
    def share(self) -> float:
        return self.n_bimodal / self.n_names if self.n_names else 0.0

    @property
    def is_bimodal(self) -> bool:
        return self.n_names > 0 and 2 * self.n_bimodal >= self.n_names

    def to_dict(self) -> Dict[str, Any]:
        return {
            "n_names": self.n_names,
            "n_bimodal": self.n_bimodal,
            "share": self.share,
            "mean_separation": self.mean_separation,
            "is_bimodal": self.is_bimodal,
        }


def session_bimodality(
    rows: Mapping[str, Sequence[float]],
    *,
    min_separation: float = MIN_SEPARATION,
    min_minority: int = MIN_MINORITY,
) -> SessionBimodality:
    """Majority vote of :func:`mode_mixture` across a session's measured
    names (whole algorithms and kernel segments alike)."""
    verdicts = [
        mode_mixture(samples, min_separation=min_separation,
                     min_minority=min_minority)
        for samples in rows.values()
    ]
    bimodal = [v for v in verdicts if v.is_bimodal]
    mean_sep = (
        float(np.mean([v.separation for v in bimodal])) if bimodal else 0.0
    )
    return SessionBimodality(
        n_names=len(verdicts), n_bimodal=len(bimodal),
        mean_separation=mean_sep,
    )


def median_gap_zscore(
    winner_samples: Sequence[float], loser_samples: Sequence[float]
) -> Tuple[float, float, float]:
    """``(gap, se, z)`` of the loser-minus-winner median difference.

    ``se`` is the large-sample standard error of the difference of two
    sample medians (1.2533 ~ sqrt(pi/2) per median under approximate
    normality); ``z = gap / se``. A z below ~3 means the explain
    re-measurement cannot statistically reproduce the census ranking —
    the trigger for the re-ranking confidence probe."""
    w = np.asarray(list(winner_samples), dtype=np.float64)
    l = np.asarray(list(loser_samples), dtype=np.float64)
    gap = float(np.median(l) - np.median(w))
    def med_var(x: np.ndarray) -> float:
        if x.size < 2:
            return 0.0
        return (1.2533 * float(np.std(x, ddof=1)) / np.sqrt(x.size)) ** 2
    se = float(np.sqrt(med_var(w) + med_var(l)))
    if se <= 0.0:
        # noiseless backend: any nonzero gap is infinitely significant,
        # an exact tie is infinitely insignificant
        z = float("inf") if gap != 0.0 else 0.0
    else:
        z = gap / se
    return gap, se, z
