"""repro.explain — AnomalyExplainer: root-cause attribution for census
anomalies.

The paper stops at *detecting* anomalies ("an anomaly ... can then be used
in the investigation of the root cause of performance differences"); this
package performs that investigation, ELAPS-style, by decomposing each
algorithm into its kernel sequence and reconciling measured segment times
against a per-kernel roofline floor:

* :mod:`repro.explain.decompose` — algorithm -> kernel sequence
  (GEMM/GEMV/SYRK/solve calls with shapes, exact analytic FLOPs/bytes),
  plus isolated-kernel JAX workloads for wall-clock re-measurement.
* :mod:`repro.explain.attribution` — per-kernel efficiency factors: median
  measured segment time over the :class:`~repro.roofline.MachineSpec`
  roofline prediction, rolled up into whole-algorithm attributions with a
  dispatch/overhead residual.
* :mod:`repro.explain.classify` — the cause taxonomy
  (``shape_kernel_efficiency`` / ``memory_bound_segment`` /
  ``dispatch_overhead`` / ``unexplained``) with a numeric evidence score:
  the fraction of the winner/loser time gap the chosen cause explains.
* :mod:`repro.explain.runner` — :class:`ExplainSpec` + sharded, resumable
  explanation campaigns on the :class:`~repro.core.engine.ExperimentEngine`
  (kill/resume byte-identical for the deterministic census backends),
  mirroring the DiscriminantSweep layout. CLI:
  ``python -m repro.launch.explain``.

Everything imports without jax (kernel workloads build lazily), so
cost-model explanation workers stay as light as census workers.
"""

from .attribution import AlgorithmAttribution, KernelAttribution, attribute_algorithm
from .classify import CAUSES, Explanation, classify_anomaly
from .decompose import KernelSpec, decompose_instance, kernels_from_record
from .runner import (
    ExplainSpec,
    build_explain_session,
    explain_progress,
    explain_summary,
    explain_targets,
    merge_explained,
    run_explain_shard,
)

__all__ = [
    "AlgorithmAttribution",
    "CAUSES",
    "Explanation",
    "ExplainSpec",
    "KernelAttribution",
    "KernelSpec",
    "attribute_algorithm",
    "build_explain_session",
    "classify_anomaly",
    "decompose_instance",
    "explain_progress",
    "explain_summary",
    "explain_targets",
    "kernels_from_record",
    "merge_explained",
    "run_explain_shard",
]
