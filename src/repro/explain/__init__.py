"""repro.explain — AnomalyExplainer: root-cause attribution for census
anomalies.

The paper stops at *detecting* anomalies ("an anomaly ... can then be used
in the investigation of the root cause of performance differences"); this
package performs that investigation, ELAPS-style, by decomposing each
algorithm into its kernel sequence and reconciling measured segment times
against a per-kernel roofline floor:

* :mod:`repro.explain.decompose` — algorithm -> kernel sequence
  (GEMM/GEMV/SYRK/solve calls with shapes, exact analytic FLOPs/bytes),
  plus isolated-kernel JAX workloads for wall-clock re-measurement.
* :mod:`repro.explain.attribution` — per-kernel efficiency factors: median
  measured segment time over the :class:`~repro.roofline.MachineSpec`
  roofline prediction, rolled up into whole-algorithm attributions with a
  dispatch/overhead residual.
* :mod:`repro.explain.distributions` — distribution-level statistics over
  the segment samples: the 2-means mode-mixture test (turbo/frequency
  regimes) and the median-gap significance behind the re-ranking probe.
* :mod:`repro.explain.classify` — the cause taxonomy
  (``shape_kernel_efficiency`` / ``memory_bound_segment`` /
  ``dispatch_overhead`` / ``frequency_bimodality`` / ``cache_reuse_pair``
  / ``not_reproducible`` / ``unexplained``) with a numeric evidence score
  per cause (gap fraction explained, distribution share, or probe flip
  probability — see the module docstring).
* :mod:`repro.explain.calibrate` — per-machine dispatch/GEMM-efficiency
  calibration from micro-measurements, so tiny-instance memory-vs-dispatch
  splits reconcile against the floor the machine actually has. CLI:
  ``python -m repro.launch.explain calibrate``.
* :mod:`repro.explain.runner` — :class:`ExplainSpec` + sharded, resumable
  explanation campaigns on the :class:`~repro.core.engine.ExperimentEngine`
  (kill/resume byte-identical for the deterministic census backends),
  mirroring the DiscriminantSweep layout. CLI:
  ``python -m repro.launch.explain``.

Everything imports without jax (kernel workloads build lazily), so
cost-model explanation workers stay as light as census workers.
"""

from .attribution import AlgorithmAttribution, KernelAttribution, attribute_algorithm
from .calibrate import (
    CalibrationResult,
    fit_calibration,
    load_calibrated_machine,
    micro_points_synthetic,
    micro_points_wall_clock,
    synthetic_truth,
)
from .classify import CAUSES, Explanation, classify_anomaly
from .decompose import KernelSpec, decompose_instance, kernels_from_record
from .distributions import (
    ModeMixture,
    SessionBimodality,
    median_gap_zscore,
    mode_mixture,
    session_bimodality,
)
from .runner import (
    ExplainSpec,
    build_explain_session,
    explain_progress,
    explain_summary,
    explain_targets,
    merge_explained,
    reranking_probe,
    run_explain_shard,
)

__all__ = [
    "AlgorithmAttribution",
    "CAUSES",
    "CalibrationResult",
    "Explanation",
    "ExplainSpec",
    "KernelAttribution",
    "KernelSpec",
    "ModeMixture",
    "SessionBimodality",
    "attribute_algorithm",
    "build_explain_session",
    "classify_anomaly",
    "decompose_instance",
    "explain_progress",
    "explain_summary",
    "explain_targets",
    "fit_calibration",
    "kernels_from_record",
    "load_calibrated_machine",
    "median_gap_zscore",
    "merge_explained",
    "micro_points_synthetic",
    "micro_points_wall_clock",
    "mode_mixture",
    "reranking_probe",
    "run_explain_shard",
    "session_bimodality",
    "synthetic_truth",
]
